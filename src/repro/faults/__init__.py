"""repro.faults — deterministic fault injection + erasure recovery.

The chaos layer for the split link: :class:`FaultPlan` is a seeded,
schedule-driven description of what the network does (drop / corrupt /
delay / duplicate / truncate / disconnect, per-direction rates or
explicit step lists, every draw replayable).  It installs into
``repro.transport.Channel`` (payload-level erasures inside train and
pipeline loops, resolved against a :class:`RecoveryPolicy` by
:func:`negotiate_payload`) and into the frontdoor's
``FrameStream`` (wire-level frame faults on the asyncio path, recovered
via CRC32 + sequence numbers + NACK/retransmit).

:class:`ChannelErasure` is the typed "the channel ate it" error both
layers surface instead of decoding garbage.
"""
from repro.faults.plan import (FAULT_KINDS, ChannelErasure, FaultEvent,
                               FaultPlan)
from repro.faults.recovery import (RecoveryPolicy, erasure_mask_like,
                                   negotiate_payload)

__all__ = [
    "FAULT_KINDS", "FaultEvent", "FaultPlan", "ChannelErasure",
    "RecoveryPolicy", "negotiate_payload", "erasure_mask_like",
]
