"""Deterministic fault injection for the split link.

A :class:`FaultPlan` is a SEEDED description of what the network does to
the cut-layer exchange: per-direction rates (drop / corrupt / delay /
duplicate / truncate / disconnect) plus an optional explicit schedule of
step -> events.  Every draw is keyed by ``(seed, direction, step,
attempt, salt)`` through a crc32 hash, so the same plan replays the same
failures bit-for-bit — a chaos run is an experiment, not a flake.

The plan installs at two layers:

* **payload level** (``repro.transport.Channel``): each training step's
  payload is split into ``packets`` contiguous spans of the feature axis;
  each packet is independently dropped or corrupted.  A per-packet CRC on
  a real wire detects corruption, so both faults surface identically as
  ERASURES — a keep-mask over the payload that the mask-aware HRR decode
  (``decode_masked``) renormalizes over, never as garbage activations.

* **wire level** (``repro.frontdoor.stream.FrameStream``): faults apply
  to individual frames as they are written — dropped from the wire,
  byte-flipped (caught by the frame CRC32), truncated (length prefix
  fixed up so the stream stays in sync but the CRC fails), duplicated,
  delayed, or a forced ``disconnect`` (transport abort, exercising the
  reconnect-with-resume path).  ``attempt`` is the connection epoch:
  explicit scheduled events fire on epoch 0 only, so a scheduled
  disconnect does not re-trigger after the resume it was meant to test.

An all-zero plan (``FaultPlan()`` or rates all 0 with no schedule) is
structurally inert: every install site checks :meth:`is_zero` and takes
the exact pre-fault code path, so zero-plan runs are bit-identical to no
plan at all (pinned in tests/test_faults.py).
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np

#: fault kinds a plan can draw.  ``disconnect`` is wire-only (a payload
#: has no connection to sever); the rest apply at both layers.
FAULT_KINDS = ("drop", "corrupt", "delay", "duplicate", "truncate",
               "disconnect")
_PAYLOAD_KINDS = ("drop", "corrupt")


class ChannelErasure(Exception):
    """A payload (or frame) was lost or corrupted beyond what the
    configured recovery policy can repair.  Typed so callers branch on
    "the channel ate it" instead of decoding garbage activations."""

    def __init__(self, msg: str, *, direction: str | None = None,
                 step: int | None = None, erased_frac: float | None = None,
                 attempts: int | None = None):
        super().__init__(msg)
        self.direction = direction
        self.step = step
        self.erased_frac = erased_frac
        self.attempts = attempts


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault: a kind plus a uniform-[0,1) argument the
    injector interprets (corrupt: which byte to flip; truncate: fraction
    of the body to keep; delay: scaled sleep)."""
    kind: str
    arg: float = 0.0


def _normalize_rates(rates) -> dict:
    """Accept flat ``{kind: rate}`` (all directions) or nested
    ``{direction: {kind: rate}}``; return the nested form with the flat
    part under the wildcard direction ``"*"``."""
    if not rates:
        return {}
    flat = {k: float(v) for k, v in rates.items()
            if not isinstance(v, dict)}
    nested = {d: {k: float(v) for k, v in r.items()}
              for d, r in rates.items() if isinstance(r, dict)}
    for scope in (flat, *nested.values()):
        for kind, rate in scope.items():
            if kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {kind!r} "
                                 f"(expected one of {FAULT_KINDS})")
            if not 0.0 <= rate <= 1.0:
                raise ValueError(f"fault rate {kind}={rate} outside [0, 1]")
    if flat:
        nested["*"] = flat
    return nested


def _normalize_schedule(schedule) -> dict:
    """``{direction: {step: kind | (kind, ...) | FaultEvent(s)}}`` (or the
    flat ``{step: ...}`` form for all directions) -> nested dict of
    FaultEvent tuples."""
    if not schedule:
        return {}
    if all(isinstance(k, int) for k in schedule):
        schedule = {"*": schedule}
    out = {}
    for direction, steps in schedule.items():
        out[direction] = {}
        for step, events in steps.items():
            if isinstance(events, (str, FaultEvent)):
                events = (events,)
            norm = []
            for ev in events:
                if isinstance(ev, str):
                    ev = FaultEvent(ev)
                if ev.kind not in FAULT_KINDS:
                    raise ValueError(f"unknown fault kind {ev.kind!r} in "
                                     f"schedule (expected {FAULT_KINDS})")
                norm.append(ev)
            out[direction][int(step)] = tuple(norm)
    return out


class FaultPlan:
    """Seeded, replayable fault schedule for one link.

    ``rates``: flat ``{kind: rate}`` applied to every direction, or
    ``{direction: {kind: rate}}`` (directions are free-form tags —
    ``"fwd"``/``"bwd"`` at the payload layer, ``"c2s"``/``"s2c"`` on the
    wire; the wildcard ``"*"`` applies everywhere).

    ``schedule``: explicit ``{direction: {step: events}}`` fired exactly
    once, at connection epoch 0 (``attempt=0``) — the deterministic
    "fault X at step N" hook chaos tests are built from.

    ``packets``: payload packetization granularity — the feature axis is
    split into this many contiguous spans, each an independent erasure
    unit (a real wire frames payloads in MTU-sized packets; losing one
    loses a span of features, not IID elements).
    """

    def __init__(self, seed: int = 0, rates=None, schedule=None,
                 packets: int = 16):
        if packets < 1:
            raise ValueError(f"packets must be >= 1, got {packets}")
        self.seed = int(seed)
        self.packets = int(packets)
        self.rates = _normalize_rates(rates)
        self.schedule = _normalize_schedule(schedule)

    # ---- determinism core ------------------------------------------------

    def _rng(self, direction: str, step: int, attempt: int,
             salt: int) -> np.random.RandomState:
        key = f"{self.seed}|{direction}|{step}|{attempt}|{salt}"
        return np.random.RandomState(
            zlib.crc32(key.encode("utf-8")) & 0x7FFFFFFF)

    def rates_for(self, direction: str) -> dict:
        merged = dict(self.rates.get("*", {}))
        merged.update(self.rates.get(direction, {}))
        return merged

    def scheduled(self, direction: str, step: int) -> tuple:
        events = ()
        for scope in ("*", direction):
            events += self.schedule.get(scope, {}).get(int(step), ())
        return events

    def is_zero(self) -> bool:
        """True when this plan can never inject anything — install sites
        use this to take the structurally identical no-fault code path."""
        if any(self.schedule.get(d) for d in self.schedule):
            return False
        return all(r == 0.0 for scope in self.rates.values()
                   for r in scope.values())

    # ---- wire layer ------------------------------------------------------

    def frame_events(self, direction: str, seq: int,
                     epoch: int = 0) -> tuple[FaultEvent, ...]:
        """The faults hitting frame ``seq`` of ``direction`` on connection
        ``epoch``.  Scheduled events fire on epoch 0 only; rate-drawn
        events key the rng on the epoch, so a retried connection sees a
        fresh (but still deterministic) fault pattern."""
        events = list(self.scheduled(direction, seq)) if epoch == 0 else []
        rates = self.rates_for(direction)
        if rates:
            rng = self._rng(direction, seq, epoch, salt=1)
            # one draw per kind in canonical order, fire-if-below: draws
            # stay aligned when a single rate changes between configs
            for kind in FAULT_KINDS:
                u = rng.random_sample()
                if rates.get(kind, 0.0) > 0.0 and u < rates[kind]:
                    events.append(FaultEvent(kind, rng.random_sample()))
        return tuple(events)

    # ---- payload layer ---------------------------------------------------

    def packet_edges(self, D: int) -> np.ndarray:
        """Packet boundary sizes along a D-wide feature axis."""
        p = min(self.packets, D)
        base = D // p
        sizes = np.full(p, base, dtype=np.int64)
        sizes[:D - base * p] += 1
        return sizes

    def packet_faults(self, direction: str, step: int,
                      shape: tuple[int, ...],
                      attempt: int = 0) -> np.ndarray:
        """Bool (rows, packets) array, True where a packet of this step's
        payload is LOST (dropped, or corrupted and caught by its CRC —
        both are erasures by the time they reach the decoder).

        ``attempt`` indexes retransmissions: attempt k redraws only from
        the rng keyed on k, so a NACK/retransmit loop converges
        deterministically (the recovery layer intersects the loss masks).
        """
        rates = self.rates_for(direction)
        drop = rates.get("drop", 0.0)
        corrupt = rates.get("corrupt", 0.0)
        if attempt == 0:
            for ev in self.scheduled(direction, step):
                if ev.kind == "drop":
                    drop = max(drop, ev.arg or 1.0)
                elif ev.kind == "corrupt":
                    corrupt = max(corrupt, ev.arg or 1.0)
        rows = int(np.prod(shape[:-1], dtype=np.int64)) if len(shape) > 1 else 1
        p = min(self.packets, int(shape[-1]))
        if drop == 0.0 and corrupt == 0.0:
            return np.zeros((rows, p), dtype=bool)
        rng = self._rng(direction, step, attempt, salt=2)
        u_drop = rng.random_sample((rows, p))
        u_corr = rng.random_sample((rows, p))
        return (u_drop < drop) | (u_corr < corrupt)

    def expand_packets(self, shape: tuple[int, ...],
                       keep_packets: np.ndarray) -> np.ndarray:
        """Packet keep-mask (rows, packets) -> element keep-mask of
        ``shape`` (float32, 1.0 kept / 0.0 erased)."""
        D = int(shape[-1])
        sizes = self.packet_edges(D)
        keep = np.repeat(keep_packets.astype(np.float32), sizes, axis=-1)
        return keep.reshape(shape)

    def payload_keep(self, direction: str, step: int,
                     shape: tuple[int, ...],
                     attempt: int = 0) -> np.ndarray:
        """Convenience: the element-level keep mask for one payload with
        no recovery (first transmission only)."""
        lost = self.packet_faults(direction, step, shape, attempt)
        return self.expand_packets(shape, ~lost)

    def __repr__(self) -> str:
        return (f"FaultPlan(seed={self.seed}, rates={self.rates}, "
                f"schedule_steps="
                f"{ {d: sorted(s) for d, s in self.schedule.items()} }, "
                f"packets={self.packets})")
