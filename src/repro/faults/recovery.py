"""Erasure-vs-retransmit recovery policy for payload-level faults.

The superposition structure C3-SL compresses with is also a
graceful-degradation primitive: quasi-orthogonal bindings mean losing a
span of a superposed payload degrades retrieval SNR smoothly, and the
mask-aware decode (``decode_masked``) renormalizes over the surviving
elements so the reconstruction stays unbiased.  That gives two ways to
handle a lossy step, chosen per :class:`RecoveryPolicy`:

* ``mode="erasure"`` — accept the loss up to ``max_erasure_frac`` and
  decode through the mask; the erasure-degraded SNR flows into the
  adaptive deadband controller, so sustained loss shows up as an R
  step-down, not a crash.  Beyond the threshold, NACK/retransmit the
  missing packets (each retransmission redrawn under the plan's
  attempt-keyed rng) until within budget.

* ``mode="retransmit"`` — a lossless link: every missing packet is
  retransmitted until the payload is complete (classic NACK loop), and
  the extra wire traffic is accounted in ``wire_mult``.

Either way, a bounded ``retry_budget``: when retransmission cannot get
the loss under the acceptable threshold, :class:`ChannelErasure` is
raised — the typed "this step's payload is gone" signal callers handle
(skip the step, drop the connection) instead of training on garbage.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.faults.plan import ChannelErasure, FaultPlan


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """How a channel responds to payload loss.

    ``max_erasure_frac``: largest fraction of packets the erasure-tolerant
    decode accepts without retransmitting (mode="erasure" only; the
    retransmit mode accepts zero).  ``retry_budget``: max NACK rounds per
    payload before the step surfaces as :class:`ChannelErasure`.
    """
    mode: str = "erasure"            # "erasure" | "retransmit"
    max_erasure_frac: float = 0.5
    retry_budget: int = 4

    def __post_init__(self):
        if self.mode not in ("erasure", "retransmit"):
            raise ValueError(f"unknown recovery mode {self.mode!r} "
                             "(expected erasure | retransmit)")
        if not 0.0 <= self.max_erasure_frac <= 1.0:
            raise ValueError(f"max_erasure_frac={self.max_erasure_frac} "
                             "outside [0, 1]")
        if self.retry_budget < 0:
            raise ValueError(f"retry_budget must be >= 0, "
                             f"got {self.retry_budget}")


def negotiate_payload(plan: FaultPlan, direction: str, step: int,
                      shape: tuple[int, ...],
                      policy: RecoveryPolicy | None = None):
    """Resolve one payload's faults under a recovery policy.

    Simulates the NACK loop a real receiver runs: the first transmission
    loses packets per ``plan``; while the loss exceeds what the policy
    accepts, the missing packets are retransmitted (attempt-keyed redraw,
    so a retransmitted packet can be lost again) and the loss masks
    intersect.  Returns ``(keep, info)``:

    * ``keep`` — float32 element keep-mask of ``shape`` (all-ones when
      nothing was ultimately lost),
    * ``info`` — ``{"attempts", "erased_frac", "erased_packets",
      "wire_mult"}``; ``wire_mult`` is total-transmitted / payload-size
      (1.0 = no retransmissions), the chaos bench's goodput denominator.

    Raises :class:`ChannelErasure` when the retry budget is exhausted and
    the residual loss still exceeds the policy's acceptance threshold.
    """
    policy = policy or RecoveryPolicy()
    allowed = 0.0 if policy.mode == "retransmit" else policy.max_erasure_frac
    lost = plan.packet_faults(direction, step, shape, attempt=0)
    attempts = 1
    resent_frac = 0.0
    while lost.any() and float(lost.mean()) > allowed \
            and attempts <= policy.retry_budget:
        # NACK round: only the missing packets are resent; the
        # retransmission sees fresh attempt-keyed faults on those packets
        resent_frac += float(lost.mean())
        fresh = plan.packet_faults(direction, step, shape, attempt=attempts)
        lost = lost & fresh
        attempts += 1
    erased = float(lost.mean())
    if lost.any() and erased > allowed:
        raise ChannelErasure(
            f"{direction} payload at step {step}: {erased:.0%} of packets "
            f"still missing after {attempts - 1} retransmission rounds "
            f"(policy {policy.mode}, accepts {allowed:.0%})",
            direction=direction, step=step, erased_frac=erased,
            attempts=attempts)
    keep = plan.expand_packets(shape, ~lost)
    info = {"attempts": attempts,
            "erased_frac": erased,
            "erased_packets": int(lost.sum()),
            "wire_mult": 1.0 + resent_frac}
    return keep, info


def erasure_mask_like(shape: tuple[int, ...]) -> np.ndarray:
    """An all-ones keep mask (the no-loss mask) for ``shape`` — what a
    fault-free step feeds a masked program so every step shares one
    compiled branch."""
    return np.ones(shape, dtype=np.float32)
