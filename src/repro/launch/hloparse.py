"""Post-SPMD HLO text analysis with while-loop trip-count awareness.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE, which makes
it useless for scan-over-layers programs (a 88-layer model reports the cost
of one layer).  This module parses `compiled.as_text()` instead:

  * splits the module into computations,
  * per computation, sums dot/conv FLOPs and collective operand bytes,
  * finds `while` ops, infers each loop's trip count from the constant in
    its condition computation (lax.scan lowers to a canonical `i < N` loop),
  * walks the call graph from ENTRY multiplying nested bodies' costs by
    their trip counts.

All numbers are per-device (the text is the per-device SPMD program).
"""
from __future__ import annotations

import collections
import dataclasses
import re

_DT_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
             "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
             "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
# header params may contain tuple-typed (nested-paren) args — match prefix only
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_CALL_TARGET_RE = re.compile(r"(?:body|to_apply|branch_computations|called_computations)=\{?%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"\bwhile\(")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(shape_text: str):
    total_elems, total_bytes = 0, 0
    for m in _SHAPE_RE.finditer(shape_text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total_elems += n
        total_bytes += n * _DT_BYTES[dt]
    return total_elems, total_bytes


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    coll_bytes: float = 0.0
    hbm_bytes: float = 0.0  # sum of top-level op output bytes (write side)
    topk_wire_bytes: float = 0.0  # measured mask-encoded top-k payload bytes
    coll_by_op: dict = dataclasses.field(default_factory=lambda: collections.Counter())
    # (child computation, trip count, structural?) edges
    children: list = dataclasses.field(default_factory=list)


# TopK lowerings this parser recognizes: the XLA custom-call (CPU/GPU) and
# the first-class `topk(...)` HLO op (newer XLA).  Both produce a
# (values[rows, k], indices[rows, k]) tuple from an operand [rows, D].
_TOPK_RE = re.compile(r'custom_call_target="TopK"|\btopk\(')


def _is_magnitude_topk(opname: str, defs: dict, comps: dict | None) -> bool:
    """True when the top-k's operand is |x| — the wire-stage signature.

    ``repro.codecs.wire.TopKSparsify`` always ranks MAGNITUDES (top_k of
    ``abs``); other top-ks in the program (the MoE router ranks raw logits)
    are not sparsified payloads and must not count as wire bytes.  The abs
    may be a standalone op or swallowed into a fusion, so resolve one level
    of ``calls=`` indirection."""
    d = defs.get(opname, "")
    if " abs(" in d or "= abs(" in d:
        return True
    if "fusion(" in d and comps is not None:
        cm = re.search(r"calls=%?([\w.\-]+)", d)
        if cm:
            return any("abs(" in body_ln for body_ln in comps.get(cm.group(1), []))
    return False


def _topk_wire_bytes_for_line(ln: str, defs: dict | None = None,
                              comps: dict | None = None) -> float:
    """MEASURED wire bytes of one top-k op's mask-encoded payload.

    ``repro.codecs.wire.TopKSparsify`` ships a D-bit mask + k f32 survivors
    per row; the analytic formula trusts the codec's payload_shape.  Here
    the SAME quantity is derived from the compiled program instead: the
    top-k op's VALUES output [rows..., k] gives the true row count and k,
    its operand [rows..., D] gives the mask width — so sparsified payload
    bytes can be audited post-SPMD (loop trips are applied by the caller's
    walk, like every other per-computation stat).  Only MAGNITUDE top-ks
    (operand resolving to ``abs``, see :func:`_is_magnitude_topk`) count
    when ``defs`` is given — a router's top-k over raw logits is program
    control flow, not payload.  Operands print with inline types or as
    bare names depending on the HLO printer version (same dialect split
    ``_dot_flops`` handles); ``defs`` doubles as the shape fallback.
    """
    if not _TOPK_RE.search(ln):
        return 0.0
    call = "custom-call(" if "custom-call(" in ln else "topk("
    left, _, right = ln.partition(call)
    outs = _SHAPE_RE.findall(left)
    opnd = _SHAPE_RE.search(right)
    nm = re.match(r"\s*(?:\w+\[[\d,]*\]\S*\s+)?%?([\w.\-]+)", right)
    if defs is not None:
        if nm is None or not _is_magnitude_topk(nm.group(1), defs, comps):
            return 0.0
        if opnd is None:
            opnd = _SHAPE_RE.search(defs.get(nm.group(1), ""))
    if not outs or not opnd:
        return 0.0
    val_dims = [int(d) for d in outs[0][1].split(",") if d.strip()]
    op_dims = [int(d) for d in opnd.group(2).split(",") if d.strip()]
    if len(val_dims) < 2 or len(op_dims) < 2:
        return 0.0
    k = val_dims[-1]
    rows = 1
    for d in val_dims[:-1]:
        rows *= d
    D = op_dims[-1]
    return rows * ((D + 7) // 8 + 4 * k)


_HBM_SKIP_OPS = ("parameter(", "get-tuple-element(", "tuple(", "constant(",
                 "bitcast(", "after-all(", "partition-id(", "replica-id(")


def _hbm_bytes_for_line(ln: str, out_shape_head: str, shapes: dict) -> float:
    """HBM write bytes for one op.  dynamic-update-slice writes only the
    update operand (in-place), not the whole buffer — scan stacking would
    otherwise be overcounted by the stack length."""
    if "dynamic-update-slice(" in ln:
        m = re.search(r"dynamic-update-slice\(\s*%?[\w.\-]+\s*,\s*%?([\w.\-]+)", ln)
        if m and m.group(1) in shapes:
            _, b = _shape_elems_bytes(shapes[m.group(1)].split(" ")[0])
            return b
    _, b = _shape_elems_bytes(out_shape_head)
    return b


def split_computations(hlo: str, headers: dict | None = None) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(stripped)
            if m and stripped.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                if headers is not None:
                    headers[cur] = stripped
            continue
        if stripped == "}":
            cur = None
            continue
        comps[cur].append(stripped)
    return comps


def _header_param_order(header: str) -> list[str]:
    """Param names in declaration order from a computation header."""
    m = re.search(r"\((.*)\)\s*->", header)
    if not m:
        return []
    names = []
    # params look like "name: type[...]"; tuple types add nested commas, but
    # names always precede ':' at depth 1
    depth = 0
    token = ""
    for ch in m.group(1) + ",":
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        if ch == "," and depth == 0:
            if ":" in token:
                names.append(token.split(":")[0].strip().lstrip("%"))
            token = ""
        else:
            token += ch
    return names


def _trace_trip_constant(while_line: str, comps, headers, defs) -> int | None:
    """lax.scan while: cond does compare(counter, limit); the limit is a
    carried tuple element initialized with constant(N).  Trace it."""
    cm = _COND_RE.search(while_line)
    om = re.search(r"while\(\s*%?([\w.\-]+)\s*\)", while_line)
    if not cm or not om:
        return None
    cond = cm.group(1)
    params = _header_param_order(headers.get(cond, ""))
    cmp_line = next((l for l in comps.get(cond, []) if "compare(" in l), None)
    if cmp_line is None:
        return None
    ops = re.search(r"compare\(\s*%?([\w.\-]+)\s*,\s*%?([\w.\-]+)\s*\)", cmp_line)
    if not ops:
        return None
    init_def = defs.get(om.group(1), "")
    tm = re.search(r"tuple\((.*)\)", init_def)
    init_elems = []
    if tm:
        init_elems = [t.strip().lstrip("%") for t in tm.group(1).split(",")]
    for opname in (ops.group(2), ops.group(1)):
        # direct constant in cond?
        d = defs.get(opname, "")
        km = re.search(r"constant\((\d+)\)", d)
        if km:
            return int(km.group(1))
        # tuple-element param -> init operand
        if opname in params:
            idx = params.index(opname)
            if idx < len(init_elems):
                km = re.search(r"constant\((\d+)\)", defs.get(init_elems[idx], ""))
                if km:
                    return int(km.group(1))
    return None


def _build_shape_map(comps) -> dict[str, str]:
    shapes = {}
    for lines in comps.values():
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                shapes[m.group(1)] = m.group(2)
    return shapes


def _dot_flops(line: str, out_shape_text: str, shapes: dict[str, str]) -> float:
    # operand lists print either bare names ("dot(%a, %b)") or with inline
    # types ("dot(f32[64,128]{1,0} %a, ...)") depending on the HLO printer
    # version — take the inline shape when present, else look the name up
    m = re.search(r"dot\(\s*(?:(\w+\[[\d,]*\]\S*)\s+)?%?([\w.\-]+)", line)
    if not m:
        return 0.0
    lhs = m.group(1) or shapes.get(m.group(2), "")
    lhs_m = _SHAPE_RE.search(lhs)
    out_m = _SHAPE_RE.search(out_shape_text)
    if not lhs_m or not out_m:
        return 0.0
    lhs_dims = [int(d) for d in lhs_m.group(2).split(",") if d.strip()]
    out_dims = [int(d) for d in out_m.group(2).split(",") if d.strip()]
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    contract = 1
    if cm:
        for idx in cm.group(1).split(","):
            if idx.strip():
                contract *= lhs_dims[int(idx)]
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    return 2.0 * out_elems * contract


def _trip_count(cond_lines: list[str]) -> int:
    """lax.scan condition: compare(counter, constant(N)), direction=LT."""
    best = 1
    for ln in cond_lines:
        for m in re.finditer(r"constant\((\d+)\)", ln):
            best = max(best, int(m.group(1)))
    return best


def _trip_from_carry(while_line: str) -> int:
    """jax lowers scan by carrying stacked (N, ...) xs/ys in the while tuple
    and dynamic-slicing per step, so the loop length is the modal leading dim
    of the carried arrays (stacked params/ys dominate the tuple)."""
    counts = collections.Counter()
    m = re.search(r"=\s*\((.*?)\)\s*while\(", while_line)
    if not m:
        return 1
    for sm in _SHAPE_RE.finditer(m.group(1)):
        dims = [int(d) for d in sm.group(2).split(",") if d.strip()]
        if len(dims) >= 2 and dims[0] > 1:
            counts[dims[0]] += 1
    if not counts:
        return 1
    return counts.most_common(1)[0][0]


def _bf16_upcast_factor(ln: str, defs: dict, comps: dict) -> float:
    """XLA:CPU lowers bf16 dots as convert-to-f32 + f32 dot, and the SPMD
    partitioner then moves FSDP/TP all-gathers AFTER the convert — so f32
    collectives that originate from bf16 tensors are a CPU artifact; the
    TPU target gathers bf16.  Returns 0.5 for such collectives."""
    if "f32[" not in ln:
        return 1.0
    om = re.search(r"(?:all-gather|all-reduce|reduce-scatter|all-to-all|"
                   r"collective-permute)(?:-start)?\(\s*%?([\w.\-]+)", ln)
    if not om:
        return 1.0
    src_def = defs.get(om.group(1), "")
    if "convert" in src_def and "f32[" in src_def:
        cm = re.search(r"calls=%?([\w.\-]+)", src_def)
        body = "\n".join(comps.get(cm.group(1), [])) if cm else src_def
        if "bf16[" in body or "convert" in src_def:
            return 0.5
    return 1.0


def analyze(hlo: str):
    headers: dict[str, str] = {}
    comps = split_computations(hlo, headers)
    shapes = _build_shape_map(comps)
    # full def line per op name (for constant/tuple tracing)
    defs: dict[str, str] = {}
    for lines in comps.values():
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                defs[m.group(1)] = ln
    stats: dict[str, CompStats] = {}

    for name, lines in comps.items():
        cs = CompStats()
        for ln in lines:
            dm = _DEF_RE.match(ln)
            out_shape = dm.group(2) if dm else ln
            if " dot(" in ln or re.search(r"=\s*\S+\s+dot\(", ln):
                cs.dot_flops += _dot_flops(ln, out_shape, shapes)
            cs.topk_wire_bytes += _topk_wire_bytes_for_line(ln, defs, comps)
            if not any(skip in ln for skip in _HBM_SKIP_OPS):
                head = out_shape.split(" ")[0]
                cs.hbm_bytes += _hbm_bytes_for_line(ln, head, shapes)
            for op in _COLLECTIVES:
                if re.search(rf"\b{op}(?:-start)?\(", ln):
                    # operand bytes = output shape bytes (same size)
                    _, b = _shape_elems_bytes(out_shape.split(" ")[0])
                    b *= _bf16_upcast_factor(ln, defs, comps)
                    cs.coll_bytes += b
                    cs.coll_by_op[op] += b
                    break
            if _WHILE_RE.search(ln):
                bm = re.search(r"body=%?([\w.\-]+)", ln)
                cm = _COND_RE.search(ln)
                trip = _trip_count(comps.get(cm.group(1), [])) if cm else 1
                if trip <= 1:
                    trip = _trace_trip_constant(ln, comps, headers, defs) or \
                        _trip_from_carry(ln)
                if bm:
                    cs.children.append((bm.group(1), trip, True))
            else:
                for m in re.finditer(r"(?:to_apply|calls)=\{?%?([\w.\-]+)", ln):
                    cs.children.append((m.group(1), 1, False))
                m = re.search(r"branch_computations=\{([^}]*)\}", ln)
                if m:
                    for b in m.group(1).split(","):
                        cs.children.append((b.strip().lstrip("%"), 1, True))
        stats[name] = cs

    entry = None
    for line in hlo.splitlines():
        m = re.match(r"ENTRY\s+%?([\w.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None:
        entry = next(iter(comps))

    totals = {"dot_flops": 0.0, "coll_bytes": 0.0, "hbm_bytes": 0.0,
              "topk_wire_bytes": 0.0, "coll_by_op": collections.Counter()}
    seen_stack = []

    def walk(name: str, mult: float, structural: bool):
        if name not in stats or name in seen_stack:
            return
        seen_stack.append(name)
        cs = stats[name]
        totals["dot_flops"] += mult * cs.dot_flops
        totals["coll_bytes"] += mult * cs.coll_bytes
        totals["topk_wire_bytes"] += mult * cs.topk_wire_bytes
        if structural:
            # fusion internals never touch HBM; only structural computations
            # (entry / while bodies / branches) write buffers.  x2 = read+write.
            totals["hbm_bytes"] += 2.0 * mult * cs.hbm_bytes
        for op, b in cs.coll_by_op.items():
            totals["coll_by_op"][op] += mult * b
        for child, trip, child_structural in cs.children:
            walk(child, mult * trip, child_structural)
        seen_stack.pop()

    walk(entry, 1.0, True)
    totals["coll_by_op"] = dict(totals["coll_by_op"])
    return totals


def top_hbm_ops(hlo: str, k: int = 20):
    """The k largest HBM writers (op output bytes x loop trips) — the
    profile view the §Perf hillclimbs read."""
    headers: dict[str, str] = {}
    comps = split_computations(hlo, headers)
    defs: dict[str, str] = {}
    shapes: dict[str, str] = {}
    for lines in comps.values():
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                defs[m.group(1)] = ln
                shapes[m.group(1)] = m.group(2)

    # computation -> multiplier (structural only), via the same walk
    mult: dict[str, float] = {}
    children: dict[str, list] = {}
    for name, lines in comps.items():
        ch = []
        for ln in lines:
            if _WHILE_RE.search(ln):
                bm = re.search(r"body=%?([\w.\-]+)", ln)
                cm = _COND_RE.search(ln)
                trip = _trip_count(comps.get(cm.group(1), [])) if cm else 1
                if trip <= 1:
                    trip = _trace_trip_constant(ln, comps, headers, defs) or \
                        _trip_from_carry(ln)
                if bm:
                    ch.append((bm.group(1), trip))
        children[name] = ch
    entry = None
    for line in hlo.splitlines():
        m = re.match(r"ENTRY\s+%?([\w.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break

    stack = [(entry, 1.0)]
    seen = set()
    while stack:
        name, m0 = stack.pop()
        if name in seen or name not in comps:
            continue
        seen.add(name)
        mult[name] = m0
        for child, trip in children.get(name, []):
            stack.append((child, m0 * trip))

    rows = []
    for name, m0 in mult.items():
        for ln in comps[name]:
            if any(skip in ln for skip in _HBM_SKIP_OPS):
                continue
            dm = _DEF_RE.match(ln)
            if not dm:
                continue
            head = dm.group(2).split(" ")[0]
            b = _hbm_bytes_for_line(ln, head, shapes)
            if b:
                meta = re.search(r'op_name="([^"]*)"', ln)
                rows.append((b * m0, head, meta.group(1)[:90] if meta else "",
                             name))
    rows.sort(reverse=True)
    return rows[:k]
