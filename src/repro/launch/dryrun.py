import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init), hence no `from __future__` in this module.
"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
against 512 placeholder host devices; record memory/cost/collective stats.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-large-123b \
        --shape train_4k --mesh single [--codec c3sl --R 4] [--pipeline]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # full 40x2 sweep

Results land in benchmarks/results/dryrun/*.json (one file per combo) and
feed EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import codecs, transport
from repro.configs.base import ModelConfig, get_config
from repro.data.pipeline import SHAPES, input_specs
from repro.launch import mesh as mesh_lib
from repro.models import lm as lm_lib
from repro.optim import adamw
from repro.sharding import rules as sh

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../benchmarks/results/dryrun")


def shape_adjusted_config(arch: str, shape_name: str) -> ModelConfig | None:
    """Per-shape config variants; None = combination skipped (DESIGN.md)."""
    cfg = get_config(arch)
    if shape_name == "long_500k":
        if cfg.is_encdec:
            return None  # full-attention cross-attn decoder — documented skip
        if not cfg.attention_free:
            # sliding-window variant makes dense/hybrid archs sub-quadratic
            cfg = dataclasses.replace(cfg, sliding_window=4096)
    return cfg


def make_codec(cfg: ModelConfig, shape_name: str, codec_spec: str, R: int,
               quant_bits=None, unitary=False):
    """Build the cut-layer codec (or per-direction ``SplitLink`` from a
    ``... >> bwd:...`` spec) from a registry spec string ("none" = off)."""
    if codec_spec in (None, "", "none"):
        return None, None
    shape = SHAPES[shape_name]
    B = shape["global_batch"]
    if shape["kind"] == "decode":
        D = cfg.d_model
    else:
        # cut-layer feature per sample = (S_total, d_model) flattened
        D = shape["seq_len"] * cfg.d_model
    c = transport.build_link_or_codec(codec_spec, quant_bits=quant_bits,
                                      R=R, D=D, backend="fft",
                                      unitary=unitary)
    c = codecs.clamp_R(c, B if B >= 2 else 1)
    return c, jax.eval_shape(lambda: c.init(jax.random.PRNGKey(0)))


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of collectives in post-SPMD HLO (per device)."""
    sizes = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
             "all-to-all": 0, "collective-permute": 0}
    dt_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f64": 8,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "c64": 8}
    op_pat = re.compile(
        r"=\s*(\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start)?\(")
    shape_pat = re.compile(r"(\w+)\[([\d,]*)\]")
    for m in op_pat.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        for sm in shape_pat.finditer(shapes):
            dtype, dims = sm.group(1), sm.group(2)
            nelem = 1
            for d in dims.split(","):
                if d.strip():
                    nelem *= int(d)
            sizes[op] += nelem * dt_bytes.get(dtype, 4)
    sizes["total"] = sum(sizes.values())
    return sizes


def np_prod_batch_shards(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def roofline_terms(flops, hbm_bytes, coll_bytes, n_chips):
    """Three roofline terms in seconds (cost/collective stats are per-device
    under SPMD, so no extra division by chips)."""
    return {
        "compute_s": flops / mesh_lib.PEAK_FLOPS_BF16,
        "memory_s": hbm_bytes / mesh_lib.HBM_BW,
        "collective_s": coll_bytes / mesh_lib.ICI_BW_PER_LINK,
    }


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """6*N_active*D tokens processed (training); decode: 2*N_active per token."""
    spec = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if spec["kind"] == "train":
        tokens = spec["global_batch"] * spec["seq_len"]
        return 6.0 * n_active * tokens
    if spec["kind"] == "prefill":
        tokens = spec["global_batch"] * spec["seq_len"]
        return 2.0 * n_active * tokens
    return 2.0 * n_active * spec["global_batch"]  # one token per sequence


def build_train_step(cfg: ModelConfig, codec=None, codec_params=None,
                     num_microbatches: int = 1):
    """Full training step: loss + grads (+ grad-accumulation scan) + AdamW.

    Microbatching bounds peak activation memory: the global batch is split
    into `num_microbatches` chunks processed sequentially with f32 grad
    accumulation (the standard fit-a-big-model configuration)."""
    opt = adamw(1e-4)
    from repro.optim import apply_updates

    def loss_fn(p, mb):
        return lm_lib.lm_loss(p, mb, cfg, codec=codec, codec_params=codec_params)

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            M = num_microbatches

            def split_mb(x):
                return x.reshape(M, x.shape[0] // M, *x.shape[1:])

            mbs = jax.tree.map(split_mb, batch)

            def body(carry, mb):
                loss_acc, grad_acc = carry
                # barrier: stops XLA hoisting the FSDP param all-gathers out
                # of the microbatch loop (which would materialize the fully
                # gathered stacks at entry and undo the memory saving)
                params_b = jax.lax.optimization_barrier(params)
                l, g = jax.value_and_grad(loss_fn)(params_b, mb)
                grad_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), grad_acc, g)
                return (loss_acc + l, grad_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.array(0.0, jnp.float32), zeros), mbs)
            loss = loss / M
            grads = jax.tree.map(lambda g: g / M, grads)
        updates, opt_state2 = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state2, loss

    return opt, train_step


def _lower_and_compile(cfg, shape_name, mesh, codec, codec_params,
                       param_dtype=jnp.bfloat16, num_microbatches=1):
    spec = SHAPES[shape_name]
    params = lm_lib.abstract_params(cfg, param_dtype)
    param_sh = sh.param_shardings(
        params, mesh, mode="decode" if spec["kind"] == "decode" else "train")
    batch = input_specs(cfg, shape_name)
    batch_sh = sh.batch_shardings(batch, mesh)
    repl = NamedSharding(mesh, P())

    with mesh_lib.set_mesh(mesh):
        if spec["kind"] == "train":
            opt, train_step = build_train_step(cfg, codec, codec_params,
                                               num_microbatches)
            opt_state = jax.eval_shape(opt.init, params)
            opt_sh = sh.opt_state_shardings(opt_state, mesh)
            fn = jax.jit(train_step,
                         in_shardings=(param_sh, opt_sh, batch_sh),
                         out_shardings=(param_sh, opt_sh, repl),
                         donate_argnums=(0, 1))
            lowered = fn.lower(params, opt_state, batch)
        elif spec["kind"] == "prefill":
            def prefill(params, batch):
                # serving prefill returns the LAST-token logits (the full
                # (B, S, V) tensor is never materialized for big vocabs)
                logits, _ = lm_lib.lm_forward(params, batch, cfg, remat=False,
                                              last_only=True)
                return logits[:, -1, :]
            bspec = sh.batch_spec(mesh)  # P("data") or P(("pod","data"))
            out_sh = NamedSharding(mesh, sh._guard(
                P(bspec[0], "model"),
                (spec["global_batch"], cfg.vocab_size), mesh))
            fn = jax.jit(prefill, in_shardings=(param_sh, batch_sh),
                         out_shardings=out_sh)
            lowered = fn.lower(params, batch)
        else:  # decode
            cache = lm_lib.abstract_decode_cache(cfg, spec["global_batch"],
                                                 spec["seq_len"], param_dtype)
            cache_sh = sh.cache_shardings(cache, mesh)

            def serve_step(params, cache, tokens, pos):
                return lm_lib.decode_step(params, cache, tokens, pos, cfg,
                                          codec=codec, codec_params=codec_params)

            fn = jax.jit(serve_step,
                         in_shardings=(param_sh, cache_sh, batch_sh["tokens"], repl),
                         out_shardings=(batch_sh["tokens"], cache_sh),
                         donate_argnums=(1,))
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = fn.lower(params, cache, batch["tokens"], pos)
        compiled = lowered.compile()
    return lowered, compiled


def dryrun_one(arch: str, shape_name: str, mesh_kind: str, *, codec_kind="none",
               R=4, pipeline=False, quant_bits=None, unitary=False,
               save=True, tag="baseline", param_dtype=jnp.bfloat16,
               cfg_override=None, force_microbatches=None):
    from repro.launch import hloparse
    cfg = cfg_override or shape_adjusted_config(arch, shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
              "codec": codec_kind, "R": R}
    if cfg is None:
        result["status"] = "skipped"
        result["reason"] = "long_500k unsupported (enc-dec full attention); see DESIGN.md"
        return _save(result) if save else result

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    spec = SHAPES[shape_name]
    t0 = time.time()

    codec, codec_params = make_codec(cfg, shape_name, codec_kind, R,
                                     quant_bits, unitary)

    # auto-tune microbatching until the step fits HBM (train only), stopping
    # at diminishing returns (fixed param/optimizer buffers set a floor)
    HBM_BUDGET = 15 * 2 ** 30  # v5e: 16 GiB minus runtime reserve
    num_microbatches = force_microbatches or 1
    prev_peak = None
    while True:
        lowered, compiled = _lower_and_compile(
            cfg, shape_name, mesh, codec, codec_params, param_dtype,
            num_microbatches)
        m = compiled.memory_analysis()
        peak = ((getattr(m, "argument_size_in_bytes", 0) or 0)
                + (getattr(m, "temp_size_in_bytes", 0) or 0))
        if (force_microbatches or spec["kind"] != "train"
                or peak <= HBM_BUDGET or num_microbatches >= 32):
            break
        if prev_peak is not None and peak > 0.92 * prev_peak:
            break  # plateau: activations no longer dominate
        if spec["global_batch"] // (2 * num_microbatches
                                    * int(np_prod_batch_shards(mesh))) < 1:
            break  # per-device microbatch must stay >= 1
        prev_peak = peak
        num_microbatches *= 2
    result["num_microbatches"] = num_microbatches
    t_lower = time.time() - t0

    mem = compiled.memory_analysis()
    # trip-count-aware HLO analysis (see hloparse; cost_analysis counts
    # while bodies once and is useless for scan-over-layers programs)
    stats = hloparse.analyze(compiled.as_text())
    coll = dict(stats["coll_by_op"])
    coll["total"] = stats["coll_bytes"]
    # mask-aware wire accounting: sparsified (topk) payload bytes MEASURED
    # from the compiled HLO — rows/k/D read off the lowered top-k ops
    # (trip-count aware) instead of trusting the analytic formula; the
    # cross-check against payload_wire_bytes is pinned in
    # tests/test_hloparse.py
    topk_wire = stats["topk_wire_bytes"]
    flops = stats["dot_flops"]
    hbm_bytes = stats["hbm_bytes"]
    mf = model_flops(cfg, shape_name)
    terms = roofline_terms(flops, hbm_bytes, coll["total"], n_chips)
    dominant = max(terms, key=terms.get)
    t_compile = time.time() - t0 - t_lower

    result.update({
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "per_device": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
                          + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": hbm_bytes,
        "collective_bytes_per_device": coll,
        "topk_wire_bytes_hlo": topk_wire,
        "model_flops_global": mf,
        "model_flops_per_device": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / flops if flops else None,
        "roofline": terms,
        "dominant": dominant,
        "params_global": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    })
    return _save(result) if save else result


def _pod_permute_bytes(hlo: str) -> float:
    """Bytes of collective-permutes whose source->target pairs cross the pod
    boundary (distance 256 on the (2,16,16) mesh) — the SL wire itself, as
    opposed to model-axis resharding permutes.  Microbatch-loop trips are
    already reflected (the permute sits in the scan body, counted per line
    here x its shape; the loop multiplies payload identically across
    variants, so ratios are exact and absolute numbers are per-iteration)."""
    import re as _re
    from repro.launch import hloparse as hp
    total = 0.0
    for ln in hlo.splitlines():
        if "collective-permute" not in ln:
            continue
        pm = _re.search(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}", ln)
        if not pm:
            continue
        pairs = _re.findall(r"\{(\d+),(\d+)\}", pm.group(1))
        if not pairs or abs(int(pairs[0][0]) - int(pairs[0][1])) != 256:
            continue
        m = hp._DEF_RE.match(ln.strip())
        if m:
            _, b = hp._shape_elems_bytes(m.group(2).split(" ")[0])
            total += b
    return total


def pipeline_dryrun(arch: str, *, R: int = 4, quant_bits=None, unitary=False,
                    num_microbatches: int = 4, shape_name: str = "train_4k",
                    tag: str = "pipeline", save: bool = True,
                    codec_kind: str = "c3sl", async_depth: int = 1):
    """Dry-run the 2-stage pod pipeline (paper topology at scale): lower the
    pipelined train loss on the multi-pod mesh and report the inter-pod
    collective-permute bytes — the wire the C3-SL codec compresses.
    ``codec_kind`` may be a ``... >> bwd:...`` link spec (per-direction
    gradient compression); ``async_depth=2`` lowers the double-buffered
    channel schedule."""
    from repro.core import split as split_lib
    from repro.launch import hloparse

    cfg = get_config(arch)
    mesh = mesh_lib.make_production_mesh(multi_pod=True)
    spec = SHAPES[shape_name]
    B, S = spec["global_batch"], spec["seq_len"]
    mb = B // num_microbatches
    D_flat = S * cfg.d_model

    if codec_kind == "none":
        codec = codecs.build("identity", D=D_flat)
        codec_params = {}
    else:
        codec = codecs.clamp_R(
            transport.build_link_or_codec(codec_kind, quant_bits=quant_bits,
                                          R=R, D=D_flat, backend="fft",
                                          unitary=unitary), mb)
        codec_params = jax.eval_shape(lambda: codec.init(jax.random.PRNGKey(0)))

    # f32 params: XLA:CPU's AllReducePromotion pass crashes on the bf16
    # grad all-reduces this program produces (compiler bug); f32 sidesteps
    # it and the codec-compression RATIOS are dtype-independent.
    full = lm_lib.abstract_params(cfg, jnp.float32)
    params = {
        "embed": {"embed": full["embed"]},
        "blocks": jax.eval_shape(lm_lib.split_stack_for_pipeline, full["stack"]),
        "head": {"final_norm": full["final_norm"], "head": full["head"]},
        "codec": codec_params,
    }
    embed_fn, stage_fn, head_loss_fn = lm_lib.make_pipeline_fns(cfg)
    loss_fn = split_lib.make_pod_pipeline_loss_fn(
        embed_fn, stage_fn, head_loss_fn, codec, mesh,
        num_microbatches=num_microbatches, async_depth=async_depth)

    from jax.sharding import NamedSharding
    param_sh = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), params)
    # stage placement: blocks sharded over pod on the leading stage axis
    param_sh["blocks"] = jax.tree.map(
        lambda l: NamedSharding(mesh, sh._guard(
            P("pod", None, None, "model"), l.shape, mesh)),
        params["blocks"])
    batch = {"x": jax.ShapeDtypeStruct((B, S), jnp.int32),
             "y": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    batch_sh = jax.tree.map(  # replicated over pod (both stages read it),
        lambda l: NamedSharding(mesh, sh._guard(  # sharded over data
            P("data", None), l.shape, mesh)), batch)

    def grad_step(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    with mesh_lib.set_mesh(mesh):
        lowered = jax.jit(grad_step, in_shardings=(param_sh, batch_sh)).lower(
            params, batch)
        compiled = lowered.compile()

    hlo = compiled.as_text()
    stats = hloparse.analyze(hlo)
    mem = compiled.memory_analysis()
    result = {
        "arch": arch, "shape": shape_name, "mesh": "multi-pipeline",
        "tag": tag, "codec": codec_kind if codec_kind != "none" else "identity",
        # links report the FORWARD channel's R (SplitLink carries no bare R)
        "R": getattr(codec.fwd.current if isinstance(codec, transport.SplitLink)
                     else codec, "R", 1),
        "quant": quant_bits,
        "num_microbatches": num_microbatches, "async_depth": async_depth,
        "status": "ok",
        "collective_bytes_per_device": dict(stats["coll_by_op"],
                                            total=stats["coll_bytes"]),
        "interpod_permute_bytes": _pod_permute_bytes(hlo),
        "topk_wire_bytes_hlo": stats["topk_wire_bytes"],
        "hlo_flops_per_device": stats["dot_flops"],
        "per_device": {"peak_bytes":
                       (getattr(mem, "argument_size_in_bytes", 0) or 0)
                       + (getattr(mem, "temp_size_in_bytes", 0) or 0)},
    }
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        name = f"{arch}_{shape_name}_pipeline_{tag}.json"
        with open(os.path.join(RESULTS_DIR, name), "w") as f:
            json.dump(result, f, indent=1)
    return result


def _save(result):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = f"{result['arch']}_{result['shape']}_{result['mesh']}_{result['tag']}.json"
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--codec", default="none",
                    help="registry spec, e.g. 'c3sl:R=4|int8' (see repro.codecs)")
    ap.add_argument("--R", type=int, default=4)
    ap.add_argument("--quant", type=int, default=None)
    ap.add_argument("--unitary", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--pipeline", action="store_true")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    if args.all:
        from repro.configs.archs import ALL_ARCHS
        combos = [(a, s, m) for a in ALL_ARCHS for s in SHAPES
                  for m in ("single", "multi")]
    else:
        combos = [(args.arch, args.shape, args.mesh)]

    failures = 0
    for arch, shape_name, mesh_kind in combos:
        try:
            r = dryrun_one(arch, shape_name, mesh_kind, codec_kind=args.codec,
                           R=args.R, tag=args.tag, quant_bits=args.quant,
                           unitary=args.unitary)
            status = r["status"]
            extra = ""
            if status == "ok":
                pk = r["per_device"]["peak_bytes"]
                extra = (f"peak={pk/2**30:.2f}GiB dom={r['dominant']} "
                         f"compile={r['compile_s']}s")
            print(f"[dryrun] {arch} {shape_name} {mesh_kind}: {status} {extra}",
                  flush=True)
        except Exception:
            failures += 1
            print(f"[dryrun] {arch} {shape_name} {mesh_kind}: FAILED", flush=True)
            traceback.print_exc()
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
