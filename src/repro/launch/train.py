"""Training driver (runs for real on whatever devices exist; CPU-friendly).

Examples:
    # reduced-config LM training with the C3-SL boundary codec (registry
    # spec string; see repro.codecs for the grammar)
    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
        --steps 50 --batch 16 --seq 128 --codec "c3sl:R=4"

    # int8 wire format composed behind the HRR transform
    PYTHONPATH=src python -m repro.launch.train --reduced --steps 2 \
        --codec "c3sl:R=4|int8"

    # Adaptive-R: SNR-driven schedule over a {2,4,8,16} bucket ladder; the
    # loop logs per-step R + wire bytes and compiles one branch per bucket
    PYTHONPATH=src python -m repro.launch.train --reduced --steps 50 \
        --codec "adaptive:c3sl:R=16,min_R=2,target_snr=-6|int8"

    # 2-stage pod pipeline on a host mesh (needs >= 2 devices: set
    # XLA_FLAGS=--xla_force_host_platform_device_count=2)
    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
        --pipeline --microbatches 4 --steps 20
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro import codecs, transport
from repro.checkpoint import save_checkpoint
from repro.configs.base import get_config, reduced
from repro.data.pipeline import SyntheticTokenDataset, make_batch_iterator
from repro.launch import mesh as mesh_lib
from repro.models import lm as lm_lib
from repro.optim import adamw, apply_updates, clip_by_global_norm
from repro.transport import pipeline as pipeline_lib


def make_codec(spec: str, D: int, *, R: int = 4, quant=None, unitary=False,
               max_R: int | None = None):
    """Build (codec-or-link, params) from a registry spec string.

    ``spec == "none"`` means no codec at all.  A ``... >> bwd:...`` spec
    builds a per-direction ``repro.transport.SplitLink`` (the backward
    gradient payload gets its own codec/R).  The legacy --R/--quant/
    --unitary flags act as defaults for spec-omitted fields (explicit spec
    args win; --quant 8 appends the int8 wire stage to plain specs).
    """
    if spec in (None, "", "none"):
        return None, None
    codec = transport.build_link_or_codec(spec, quant_bits=quant, D=D, R=R,
                                          unitary=unitary)
    if max_R is not None:
        codec = codecs.clamp_R(codec, max_R)
    return codec, codec.init(jax.random.PRNGKey(7))


def _arm_train_sanitizers(args):
    """The --sanitize tier for the train loops: global NaN trap, checkify
    float checks compiled into every step branch, and per-step host-side
    finite checks.  Returns None when sanitize mode is off."""
    if not getattr(args, "sanitize", False):
        return None
    from repro.analysis import sanitize as sanitize_lib
    sanitize_lib.enable_debug_nans()
    print("[sanitize] debug_nans + checkify float checks + per-step "
          "finite checks armed", flush=True)
    return sanitize_lib


def run_standard(args, cfg):
    sanitize_lib = _arm_train_sanitizers(args)
    rng = jax.random.PRNGKey(args.seed)
    params = lm_lib.init_lm_params(rng, cfg)
    opt = adamw(args.lr)
    opt_state = opt.init(params)
    # R clamps to the batch BEFORE init (matching serve.py): batch-wise
    # grouping needs R | batch, and an adaptive ladder must not be able to
    # ramp to a bucket that would fail the divisibility check mid-training
    codec, codec_params = make_codec(args.codec, args.seq * cfg.d_model,
                                     R=args.R, quant=args.quant,
                                     unitary=args.unitary, max_R=args.batch)
    # make_codec returns a SplitLink only for ' >> bwd:' specs, which are
    # always asymmetric — mirrored behavior is just the bare-codec path
    link = codec if isinstance(codec, transport.SplitLink) else None
    adaptive = isinstance(codec, codecs.AdaptiveC3SL)
    adaptive_bwd = link is not None and link.bwd.adaptive

    # Seeded fault injection on the cut link (the CI chaos-smoke job): a
    # FaultPlan draws per-step packet loss on the boundary payload, the
    # RecoveryPolicy decides erasure-tolerant decode vs NACK/retransmit.
    # Clean runs (no fault flags) never touch this path — the compiled
    # programs are bit-identical to pre-fault builds.
    fault_link = None
    if args.fault_drop > 0.0 or args.fault_corrupt > 0.0:
        if codec is None:
            raise SystemExit("--fault-drop/--fault-corrupt need a boundary "
                             "codec (--codec): a raw split has no payload "
                             "to lose")
        plan = transport.FaultPlan(
            seed=args.fault_seed,
            rates={"drop": args.fault_drop, "corrupt": args.fault_corrupt})
        fault_link = link if link is not None else transport.as_link(codec)
        fault_link.install_faults(
            plan, transport.RecoveryPolicy(mode=args.fault_mode))
        print(f"[faults] installed on the cut link: drop={args.fault_drop} "
              f"corrupt={args.fault_corrupt} seed={args.fault_seed} "
              f"recovery={args.fault_mode}", flush=True)

    def make_step(step_codec, step_codec_params):
        """One jitted train step closing over ONE static codec/link + its
        params.  Under Adaptive-R this is called once per (R_fwd, R_bwd)
        bucket pair — each pair is its own compiled branch, so host-side
        schedule switches never retrace.  The probe argument taps the
        gradient-retrieval SNR (asymmetric links; zero otherwise).  With
        faults installed the step takes the erasure keep-masks as a runtime
        argument (bucket-static shapes — masked steps share the branch)."""
        def _body(params, opt_state, batch, probe, erasure):
            def loss_fn(p, pr):
                return lm_lib.lm_loss(p, batch, cfg, codec=step_codec,
                                      codec_params=step_codec_params,
                                      with_metrics=True, bwd_probe=pr,
                                      erasure=erasure)
            (loss, metrics), (grads, bwd_snr) = jax.value_and_grad(
                loss_fn, argnums=(0, 1), has_aux=True)(params, probe)
            grads, gn = clip_by_global_norm(grads, 1.0)
            updates, opt_state2 = opt.update(grads, opt_state, params)
            return (apply_updates(params, updates), opt_state2, loss, gn,
                    metrics.get("cut_snr"), bwd_snr)
        fn = _body if fault_link is not None \
            else functools.partial(_body, erasure=None)
        if sanitize_lib is not None:
            # each bucket branch compiles WITH checkify's float checks;
            # the wrapper throws host-side on the first NaN/Inf/div0
            return sanitize_lib.checkify_jit(fn)
        return jax.jit(fn)

    step_fns = transport.build_link_program_table(codec, codec_params,
                                                  make_step)
    train_san = sanitize_lib.TrainSanitizer() if sanitize_lib else None

    data = SyntheticTokenDataset(cfg.vocab_size, args.seq, seed=args.seed)
    it = make_batch_iterator(data, args.batch)
    t0 = time.time()
    losses = []
    wire_fwd_total = wire_bwd_total = 0
    fault_skipped = 0
    probe0 = jnp.float32(0.0)
    tokens_per_step = args.batch * args.seq
    # MFU denominator: this host's measured-equivalent peak (CPU has no
    # published peak; report model-FLOPs throughput instead)
    step_flops = 6.0 * cfg.active_param_count() * tokens_per_step
    for step in range(args.steps):
        batch = next(it)
        if cfg.frontend:
            batch["frontend"] = jnp.zeros(
                (args.batch, cfg.frontend_seq, cfg.frontend_dim))
        erasure = fault_info = None
        if fault_link is not None:
            try:
                erasure, fault_info = fault_link.next_erasure(args.batch)
            except transport.ChannelErasure as e:
                # this step's payload is unrecoverable under the policy's
                # retry budget — skip it rather than train on garbage
                fault_skipped += 1
                print(f"step {step:5d} SKIPPED (unrecoverable): {e}",
                      flush=True)
                continue
        key = transport.link_program_key(codec)
        if fault_link is None:
            params, opt_state, loss, gn, snr, bwd_snr = step_fns[key](
                params, opt_state, batch, probe0)
        else:
            params, opt_state, loss, gn, snr, bwd_snr = step_fns[key](
                params, opt_state, batch, probe0, erasure)
        losses.append(loss)       # device value; one sync after the loop
        if train_san is not None:
            train_san.check_step(step, loss=loss, gnorm=gn)
        # actual bytes this step put on the boundary, per direction: the
        # backward payload has the forward's compressed shape (mirrored /
        # bare codecs) or its own channel's wire format (asymmetric links)
        if codec is None:
            wf = wb = 0
        elif link is not None:
            wf = link.wire_bytes_fwd(args.batch)
            wb = link.wire_bytes_bwd(args.batch)
        else:
            step_codec = codec.buckets[key] if adaptive else codec
            wf = wb = step_codec.wire_bytes(args.batch)
        if fault_info is not None:
            # retransmissions inflate the actual wire traffic
            if fault_info.get("fwd"):
                wf = int(round(wf * fault_info["fwd"]["wire_mult"]))  # lint-ok: R3 host ints from the fault schedule, no device value
            if fault_info.get("bwd"):
                wb = int(round(wb * fault_info["bwd"]["wire_mult"]))  # lint-ok: R3 host ints from the fault schedule, no device value
        wire_fwd_total += wf
        wire_bwd_total += wb
        if link is not None:
            link.observe(fwd_snr=float(snr) if snr is not None else None,  # lint-ok: R3 adaptive controller is host-side by design: it must see this step's SNR before the next dispatch
                         bwd_snr=(float(bwd_snr) if adaptive_bwd else None))  # lint-ok: R3 adaptive controller is host-side by design
        elif adaptive:
            codec.observe(float(snr))      # EMA + ladder walk for NEXT step  # lint-ok: R3 adaptive controller is host-side by design
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tps = tokens_per_step * (step + 1) / dt
            sched = ""
            if codec is not None:
                sched = f" wire fwd {wf:,d}B + bwd {wb:,d}B /step"
                if link is not None:
                    # static channels keep a constant R; adaptive ones show
                    # the bucket that SERVED this step (the dispatch key)
                    rf = key[0] if key[0] is not None \
                        else getattr(link.fwd.codec, "R", 1)
                    rb = key[1] if key[1] is not None \
                        else getattr(link.bwd.codec, "R", 1)
                    sched = (f" R={rf}>>bwd:{rb}"
                             f" snr {float(snr):.1f}dB"  # lint-ok: R3 log-gated (log_every cadence)
                             f" grad-snr {float(bwd_snr):.1f}dB" + sched)  # lint-ok: R3 log-gated (log_every cadence)
                elif adaptive:
                    sched = (f" R={key} snr {float(snr):.1f}dB "  # lint-ok: R3 log-gated (log_every cadence)
                             f"(ema {codec.ema_snr:.1f})" + sched)
                elif snr is not None:
                    sched = f" snr {float(snr):.1f}dB" + sched  # lint-ok: R3 log-gated (log_every cadence)
                if fault_info is not None and fault_info.get("fwd"):
                    fi = fault_info["fwd"]
                    sched += (f" [erased {fi['erased_frac']:.0%} "
                              f"x{fi['wire_mult']:.2f} wire]")
            print(f"step {step:5d} loss {float(loss):.4f} gnorm {float(gn):.3f}"  # lint-ok: R3 log-gated (log_every cadence)
                  f"{sched} | {tps:,.0f} tok/s, "
                  f"{step_flops*(step+1)/dt/1e9:.1f} "
                  f"GFLOP/s model-flops ({dt:.1f}s)", flush=True)
    # single deferred device->host sync for the whole run: the per-step
    # float(loss) serialized every dispatch with the previous step's compute
    losses = [float(l) for l in losses]
    if codec is not None:
        print(f"boundary traffic: {wire_fwd_total:,d} B fwd + "
              f"{wire_bwd_total:,d} B bwd = "
              f"{wire_fwd_total + wire_bwd_total:,d} B total over "
              f"{args.steps} steps", flush=True)
    if fault_link is not None:
        print(f"[faults] {fault_skipped} of {args.steps} steps skipped as "
              f"unrecoverable", flush=True)
        if not losses:
            raise SystemExit("[faults] every step was unrecoverable — "
                             "raise the retry budget or lower the rates")
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, {"params": params},
                        {"arch": cfg.name, "loss": losses[-1]})
    return losses


def run_pipeline(args, cfg):
    """2-stage pod pipeline with the compressed channel (repro.core.split)."""
    sanitize_lib = _arm_train_sanitizers(args)
    n_dev = len(jax.devices())
    assert n_dev >= 2 and n_dev % 2 == 0, \
        "pipeline mode needs an even device count (set --xla_force_host_platform_device_count)"
    mesh = mesh_lib.make_host_mesh(data=n_dev // 2, model=1, pod=2)

    rng = jax.random.PRNGKey(args.seed)
    full = lm_lib.init_lm_params(rng, cfg)
    # R is clamped to the microbatch size BEFORE init so the key shapes match
    mb = args.batch // args.microbatches
    codec, codec_params = make_codec(
        args.codec, args.seq * cfg.d_model, R=args.R, quant=args.quant,
        unitary=args.unitary, max_R=mb)
    if codec is None:
        codec = codecs.build("identity", D=args.seq * cfg.d_model)
        codec_params = {}
    if isinstance(codec, transport.SplitLink):
        if codec.fwd.adaptive or codec.bwd.adaptive:
            # the pipeline's scan/shard_map closes over ONE codec pair —
            # pin both channels at their current buckets rather than
            # silently baking whatever was current at trace time
            print(f"[pipeline] adaptive link pinned at "
                  f"R={codec.fwd.current_R}>>bwd:{codec.bwd.current_R} "
                  f"(per-step adaptation needs the single-program path)",
                  flush=True)
            codec_params = transport.slice_link_params(codec, codec_params)
            codec = transport.pin_link(codec)
    elif isinstance(codec, codecs.AdaptiveC3SL):
        # same contract for a bare adaptive codec (PR-4 behavior)
        print(f"[pipeline] adaptive codec pinned to its current bucket "
              f"R={codec.current_R} (per-step adaptation needs the "
              f"single-program path)", flush=True)
        codec_params = codec.params_for(codec_params)
        codec = codec.current

    params = {
        "embed": {"embed": full["embed"]},
        "blocks": lm_lib.split_stack_for_pipeline(full["stack"]),
        "head": {"final_norm": full["final_norm"], "head": full["head"]},
        "codec": codec_params,
    }
    embed_fn, stage_fn, head_loss_fn = lm_lib.make_pipeline_fns(cfg)
    loss_fn = pipeline_lib.make_pod_pipeline_loss_fn(
        lambda p, x: embed_fn(p, x), stage_fn,
        lambda p, h, y: head_loss_fn(p, h, y), codec, mesh,
        num_microbatches=args.microbatches, async_depth=args.async_depth)

    opt = adamw(args.lr)
    opt_state = opt.init(params)

    def _step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, gn = clip_by_global_norm(grads, 1.0)
        updates, opt_state2 = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state2, loss, gn

    step_fn = (sanitize_lib.checkify_jit(_step) if sanitize_lib
               else jax.jit(_step))
    train_san = sanitize_lib.TrainSanitizer() if sanitize_lib else None

    data = SyntheticTokenDataset(cfg.vocab_size, args.seq, seed=args.seed)
    it = make_batch_iterator(data, args.batch)
    losses = []
    t0 = time.time()
    with mesh_lib.set_mesh(mesh):
        for step in range(args.steps):
            b = next(it)
            batch = {"x": b["tokens"], "y": b["labels"]}
            params, opt_state, loss, gn = step_fn(params, opt_state, batch)
            losses.append(loss)   # device value; one sync after the loop
            if train_san is not None:
                train_san.check_step(step, loss=loss, gnorm=gn)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[pipeline] step {step:5d} loss {float(loss):.4f} "  # lint-ok: R3 log-gated (log_every cadence)
                      f"({time.time()-t0:.1f}s)", flush=True)
    losses = [float(l) for l in losses]   # one deferred sync for the run
    wf = transport.split_comm_bytes(codec, mb, directions=1)
    wb = transport.split_comm_bytes(codec, mb) - wf
    print(f"[pipeline] channel: async_depth={args.async_depth}, per-microbatch "
          f"wire fwd {wf:,d} B + bwd {wb:,d} B", flush=True)
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--codec", default="none",
                    help="registry spec, e.g. 'c3sl:R=4|int8', "
                         "'adaptive:c3sl:R=16,min_R=2,target_snr=-6|int8', "
                         "or a per-direction link "
                         "'c3sl:R=8|int8 >> bwd:c3sl:R=4|int8' "
                         "(see repro.codecs / repro.transport)")
    ap.add_argument("--R", type=int, default=4,
                    help="default R for specs that omit it")
    ap.add_argument("--quant", type=int, default=None,
                    help="8 appends the int8 wire stage to the spec")
    ap.add_argument("--unitary", action="store_true")
    ap.add_argument("--pipeline", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--async-depth", type=int, default=1,
                    help="in-flight payload buffers on the pod channel: 1 = "
                         "synchronous (send serializes with the next "
                         "microbatch), 2 = the ppermute overlaps the next "
                         "front pass (one extra bubble step)")
    ap.add_argument("--fault-drop", type=float, default=0.0,
                    help="seeded per-packet drop rate on the cut payload "
                         "(repro.faults.FaultPlan; 0 = clean, and the "
                         "compiled programs are bit-identical to a "
                         "fault-free build)")
    ap.add_argument("--fault-corrupt", type=float, default=0.0,
                    help="seeded per-packet corruption rate on the cut "
                         "payload (corrupt packets are discarded = erased)")
    ap.add_argument("--fault-seed", type=int, default=7,
                    help="FaultPlan seed (the whole chaos run is replayable)")
    ap.add_argument("--fault-mode", choices=["erasure", "retransmit"],
                    default="erasure",
                    help="lossy-step recovery: 'erasure' decodes through "
                         "the renormalized mask (loss degrades SNR, feeds "
                         "the adaptive controller), 'retransmit' NACKs "
                         "until complete and pays the wire bytes")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--sanitize", action="store_true",
                    help="runtime sanitizer tier (repro.analysis.sanitize): "
                         "jax_debug_nans, checkify float checks compiled "
                         "into every step branch, per-step finite checks "
                         "on loss/grad-norm; trades throughput for checks")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    if args.pipeline and (args.fault_drop > 0.0 or args.fault_corrupt > 0.0):
        raise SystemExit("fault injection drives the standard loop; the "
                         "pipeline path takes erasure masks through "
                         "make_pod_pipeline_loss_fn(with_erasure=True) "
                         "(see tests/test_faults.py)")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"(active {cfg.active_param_count()/1e6:.1f}M)")
    if args.pipeline:
        losses = run_pipeline(args, cfg)
    else:
        losses = run_standard(args, cfg)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
