"""Training driver (runs for real on whatever devices exist; CPU-friendly).

Examples:
    # reduced-config LM training with the C3-SL boundary codec (registry
    # spec string; see repro.codecs for the grammar)
    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
        --steps 50 --batch 16 --seq 128 --codec "c3sl:R=4"

    # int8 wire format composed behind the HRR transform
    PYTHONPATH=src python -m repro.launch.train --reduced --steps 2 \
        --codec "c3sl:R=4|int8"

    # Adaptive-R: SNR-driven schedule over a {2,4,8,16} bucket ladder; the
    # loop logs per-step R + wire bytes and compiles one branch per bucket
    PYTHONPATH=src python -m repro.launch.train --reduced --steps 50 \
        --codec "adaptive:c3sl:R=16,min_R=2,target_snr=-6|int8"

    # 2-stage pod pipeline on a host mesh (needs >= 2 devices: set
    # XLA_FLAGS=--xla_force_host_platform_device_count=2)
    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
        --pipeline --microbatches 4 --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import codecs
from repro.checkpoint import save_checkpoint
from repro.configs.base import get_config, reduced
from repro.core import split as split_lib
from repro.data.pipeline import SyntheticTokenDataset, make_batch_iterator
from repro.launch import mesh as mesh_lib
from repro.models import lm as lm_lib
from repro.optim import adamw, apply_updates, clip_by_global_norm


def make_codec(spec: str, D: int, *, R: int = 4, quant=None, unitary=False,
               max_R: int | None = None):
    """Build (codec, params) from a registry spec string.

    ``spec == "none"`` means no codec at all.  The legacy --R/--quant/
    --unitary flags act as defaults for spec-omitted fields (explicit spec
    args win; --quant 8 appends the int8 wire stage).
    """
    if spec in (None, "", "none"):
        return None, None
    spec = codecs.apply_quant_bits(spec, quant)
    codec = codecs.build(spec, D=D, R=R, unitary=unitary)
    if max_R is not None:
        codec = codecs.clamp_R(codec, max_R)
    return codec, codec.init(jax.random.PRNGKey(7))


def run_standard(args, cfg):
    rng = jax.random.PRNGKey(args.seed)
    params = lm_lib.init_lm_params(rng, cfg)
    opt = adamw(args.lr)
    opt_state = opt.init(params)
    # R clamps to the batch BEFORE init (matching serve.py): batch-wise
    # grouping needs R | batch, and an adaptive ladder must not be able to
    # ramp to a bucket that would fail the divisibility check mid-training
    codec, codec_params = make_codec(args.codec, args.seq * cfg.d_model,
                                     R=args.R, quant=args.quant,
                                     unitary=args.unitary, max_R=args.batch)
    adaptive = isinstance(codec, codecs.AdaptiveC3SL)

    def make_step(step_codec, step_codec_params):
        """One jitted train step closing over ONE static codec + params.
        Under Adaptive-R this is called once per R bucket — each bucket is
        its own compiled branch, so the host-side R switch never retraces."""
        @jax.jit
        def step_fn(params, opt_state, batch):
            def loss_fn(p):
                return lm_lib.lm_loss(p, batch, cfg, codec=step_codec,
                                      codec_params=step_codec_params,
                                      with_metrics=True)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
            grads, gn = clip_by_global_norm(grads, 1.0)
            updates, opt_state2 = opt.update(grads, opt_state, params)
            return (apply_updates(params, updates), opt_state2, loss, gn,
                    metrics.get("cut_snr"))
        return step_fn

    step_fns = codecs.build_program_table(codec, codec_params, make_step)

    data = SyntheticTokenDataset(cfg.vocab_size, args.seq, seed=args.seed)
    it = make_batch_iterator(data, args.batch)
    t0 = time.time()
    losses = []
    wire_total = 0
    tokens_per_step = args.batch * args.seq
    # MFU denominator: this host's measured-equivalent peak (CPU has no
    # published peak; report model-FLOPs throughput instead)
    step_flops = 6.0 * cfg.active_param_count() * tokens_per_step
    for step in range(args.steps):
        batch = next(it)
        if cfg.frontend:
            batch["frontend"] = jnp.zeros(
                (args.batch, cfg.frontend_seq, cfg.frontend_dim))
        R = codecs.program_key(codec)
        params, opt_state, loss, gn, snr = step_fns[R](params, opt_state,
                                                       batch)
        losses.append(float(loss))
        # actual bytes this step put on the boundary, both directions (the
        # backward payload has the forward's compressed shape — see
        # tests/test_codecs.py::test_codec_gradient_is_compressed_shape)
        step_codec = codec.buckets[R] if adaptive else codec
        step_wire = (2 * step_codec.wire_bytes(args.batch)
                     if step_codec is not None else 0)
        wire_total += step_wire
        if adaptive:
            codec.observe(float(snr))      # EMA + ladder walk for NEXT step
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tps = tokens_per_step * (step + 1) / dt
            sched = ""
            if codec is not None:
                sched = f" wire {step_wire:,d}B/step"
                if adaptive:
                    sched = (f" R={R} snr {float(snr):.1f}dB"
                             f" (ema {codec.ema_snr:.1f})" + sched)
                elif snr is not None:
                    sched = f" snr {float(snr):.1f}dB" + sched
            print(f"step {step:5d} loss {float(loss):.4f} gnorm {float(gn):.3f}"
                  f"{sched} | {tps:,.0f} tok/s, "
                  f"{step_flops*(step+1)/dt/1e9:.1f} "
                  f"GFLOP/s model-flops ({dt:.1f}s)", flush=True)
    if codec is not None:
        print(f"boundary traffic: {wire_total:,d} B total over {args.steps} "
              f"steps (fwd+bwd)", flush=True)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, {"params": params},
                        {"arch": cfg.name, "loss": losses[-1]})
    return losses


def run_pipeline(args, cfg):
    """2-stage pod pipeline with the compressed channel (repro.core.split)."""
    n_dev = len(jax.devices())
    assert n_dev >= 2 and n_dev % 2 == 0, \
        "pipeline mode needs an even device count (set --xla_force_host_platform_device_count)"
    mesh = mesh_lib.make_host_mesh(data=n_dev // 2, model=1, pod=2)

    rng = jax.random.PRNGKey(args.seed)
    full = lm_lib.init_lm_params(rng, cfg)
    # R is clamped to the microbatch size BEFORE init so the key shapes match
    mb = args.batch // args.microbatches
    codec, codec_params = make_codec(
        args.codec, args.seq * cfg.d_model, R=args.R, quant=args.quant,
        unitary=args.unitary, max_R=mb)
    if codec is None:
        codec = codecs.build("identity", D=args.seq * cfg.d_model)
        codec_params = {}
    if isinstance(codec, codecs.AdaptiveC3SL):
        # the pipeline's scan/shard_map closes over ONE codec — run the
        # adaptive wrapper's current bucket statically rather than silently
        # baking whatever R was current at trace time
        print(f"[pipeline] adaptive codec pinned to its current bucket "
              f"R={codec.current_R} (per-step adaptation needs the "
              f"single-program path)", flush=True)
        codec_params = codec.params_for(codec_params)
        codec = codec.current

    params = {
        "embed": {"embed": full["embed"]},
        "blocks": lm_lib.split_stack_for_pipeline(full["stack"]),
        "head": {"final_norm": full["final_norm"], "head": full["head"]},
        "codec": codec_params,
    }
    embed_fn, stage_fn, head_loss_fn = lm_lib.make_pipeline_fns(cfg)
    loss_fn = split_lib.make_pod_pipeline_loss_fn(
        lambda p, x: embed_fn(p, x), stage_fn,
        lambda p, h, y: head_loss_fn(p, h, y), codec, mesh,
        num_microbatches=args.microbatches)

    opt = adamw(args.lr)
    opt_state = opt.init(params)

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads, gn = clip_by_global_norm(grads, 1.0)
        updates, opt_state2 = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state2, loss, gn

    data = SyntheticTokenDataset(cfg.vocab_size, args.seq, seed=args.seed)
    it = make_batch_iterator(data, args.batch)
    losses = []
    t0 = time.time()
    with jax.set_mesh(mesh):
        for step in range(args.steps):
            b = next(it)
            batch = {"x": b["tokens"], "y": b["labels"]}
            params, opt_state, loss, gn = step_fn(params, opt_state, batch)
            losses.append(float(loss))
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[pipeline] step {step:5d} loss {float(loss):.4f} "
                      f"({time.time()-t0:.1f}s)", flush=True)
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--codec", default="none",
                    help="registry spec, e.g. 'c3sl:R=4|int8' or "
                         "'adaptive:c3sl:R=16,min_R=2,target_snr=-6|int8' "
                         "(see repro.codecs)")
    ap.add_argument("--R", type=int, default=4,
                    help="default R for specs that omit it")
    ap.add_argument("--quant", type=int, default=None,
                    help="8 appends the int8 wire stage to the spec")
    ap.add_argument("--unitary", action="store_true")
    ap.add_argument("--pipeline", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"(active {cfg.active_param_count()/1e6:.1f}M)")
    if args.pipeline:
        losses = run_pipeline(args, cfg)
    else:
        losses = run_standard(args, cfg)
    print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
