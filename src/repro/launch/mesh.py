"""Production mesh factory.

Never touches jax device state at import time — everything is a function.
Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5; older CPU-only installs can still import mesh-free paths
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover
    AxisType = None


def _mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh over however many (host) devices exist — tests/examples."""
    if pod:
        return _mesh((pod, data, model), ("pod", "data", "model"))
    return _mesh((data, model), ("data", "model"))


def set_mesh(mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` on current jax,
    the ``Mesh`` object's own context on older releases (which predate
    ``jax.set_mesh`` but activate the mesh the same way for jit/shard_map)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


# TPU v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW_PER_LINK = 50e9        # B/s (per direction per link)
