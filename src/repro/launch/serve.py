"""Batched serving driver: lockstep decode loop, or the full
continuous-batching engine with chunked prefill (--engine).

Runs for real on CPU with reduced configs; demonstrates the C3-SL serving
integration (cut-layer features compressed batch-wise across the decode
batch).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        --batch 8 --steps 32 --codec "c3sl:R=4"

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \
        --engine --requests 16 --prompt-len 64 --max-new 16 \
        --chunk-size 16 --codec "c3sl:R=4|int8"

    # multi-tenant networked front door (see src/repro/frontdoor/README.md)
    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \
        --frontdoor --port 8787 --kv-layout paged --preemption \
        --codec "adaptive:c3sl:R=4,min_R=2|int8"
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import codecs, transport
from repro.configs.base import get_config, reduced
from repro.models import lm as lm_lib


def _serving_codec(spec: str, D: int, R: int, batch: int):
    """Build the serving-side codec from a spec.  Per-direction link specs
    (``... >> bwd:...``) keep the LINK: the engine serves the forward
    channel — no gradient crosses the cut at inference, so the backward
    codec is accounted as wire_bytes_bwd == 0 — and a ``draft:`` segment
    becomes the speculative feedback channel (auto-enables spec decode)."""
    if transport.is_link_spec(spec):
        link = transport.build_link(spec, D=D, R=R).with_max_R(batch)
        print(f"[serve] link spec {link.spec()!r}: forward channel serves "
              f"(no gradient crosses the cut at inference)"
              + ("; draft channel feeds speculative decode"
                 if link.draft is not None else ""), flush=True)
        return link
    return codecs.clamp_R(codecs.build(spec, D=D, R=R), batch)


def _run_engine(cfg, params, args):
    """Continuous batching: chunked prefill + device-resident stepping."""
    from repro.serving.engine import Request
    eng = _build_engine(cfg, params, args)
    rng = jax.random.PRNGKey(args.seed + 1)
    prompts = jax.random.randint(rng, (args.requests, args.prompt_len), 0,
                                 cfg.vocab_size)
    for u, p in enumerate(prompts.tolist()):
        eng.submit(Request(uid=u, prompt=p, max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run()
    dt = time.time() - t0
    gen = sum(len(r.out) for r in done)
    total = gen + args.requests * args.prompt_len
    print(f"arch={cfg.name} engine mode={args.prefill_mode} "
          f"slots={args.batch} chunk={eng.chunk_size} sync={eng.sync_every} "
          f"kv={args.kv_layout} interleave={eng.interleave} "
          f"codec={eng.codec.spec() if eng.codec is not None else 'none'}")
    if eng.codec is not None:
        line = (f"cut-layer wire: fwd {eng.stats['wire_bytes_fwd']:,d} B + "
                f"bwd {eng.stats['wire_bytes_bwd']:,d} B "
                f"over {eng.stats['decode_steps']} decode steps + "
                f"{eng.stats['prefill_chunks']} prefill chunks")
        if eng.r_served:
            hist = dict(sorted(eng.r_served.items()))
            line += f"; served R schedule {hist} (decode steps + chunks)"
        print(line)
    if eng.spec_cfg is not None:
        s = eng.stats
        tried = s["spec_accepted"] + s["spec_rejected"]
        wpt = eng.wire_per_token()
        print(f"speculative: k={eng._k_ctl.current_k} "
              f"head={eng.spec_cfg.draft_head} "
              f"draft={eng.draft_codec.spec() if eng.draft_codec else 'raw'} "
              f"rounds={s['spec_rounds']} accepted={s['spec_accepted']} "
              f"rejected={s['spec_rejected']} rollbacks={s['spec_rollbacks']} "
              f"(acceptance {s['spec_accepted'] / max(tried, 1):.2f}); "
              f"wire {wpt['wire_bytes_per_token']:.1f} B/token "
              f"(fwd {wpt['wire_bytes_fwd']:,d} + "
              f"draft {wpt['wire_bytes_draft']:,d} B)")
    if eng.paged is not None:
        print(f"paged pool: {eng.paged.num_pages} pages x "
              f"{eng.paged.page_size} positions "
              f"(vs {args.batch * args.cache_len} contiguous positions); "
              f"cache bytes {eng.cache_bytes}")
    ttfts = [r.t_first - r.t_submit for r in done if r.t_first is not None]
    print(f"{len(done)} requests ({args.requests * args.prompt_len} prompt + "
          f"{gen} generated tokens) in {dt:.2f}s ({total / dt:.1f} tok/s); "
          f"mean TTFT {sum(ttfts) / max(len(ttfts), 1) * 1e3:.1f}ms; "
          f"dispatches {eng.stats['dispatches']}")
    print("sample output:", done[0].out[:16])


def _spec_config(args):
    """SpecConfig from the --draft-* flags; None when none were given (a
    --codec link spec with a draft: segment still auto-enables in the
    engine with defaults)."""
    from repro.serving.spec import SpecConfig
    if (args.draft_k is None and args.draft_spec is None
            and args.draft_head is None and not args.draft_adaptive):
        return None
    kw = {}
    if args.draft_k is not None:
        kw["k"] = args.draft_k
    if args.draft_spec is not None and args.draft_spec != "none":
        kw["draft"] = args.draft_spec
    if args.draft_head is not None:
        kw["draft_head"] = args.draft_head
    if args.draft_adaptive:
        kw["adaptive"] = True
    return SpecConfig(**kw)


def _build_engine(cfg, params, args):
    from repro.serving.engine import BatchedEngine
    codec = None
    if args.codec != "none":
        codec = _serving_codec(args.codec, cfg.d_model, args.R, args.batch)
    spec_decode = _spec_config(args)
    if spec_decode is not None and not args.greedy:
        raise SystemExit("--draft-* speculative decoding needs --greedy "
                         "(greedy verification is the bit-identity "
                         "guarantee)")
    eng = BatchedEngine(params, cfg, num_slots=args.batch,
                        max_len=args.cache_len, codec=codec,
                        codec_params=(codec.init(jax.random.PRNGKey(7))
                                      if codec is not None else None),
                        greedy=args.greedy, seed=args.seed,
                        prefill_mode=args.prefill_mode,
                        chunk_size=args.chunk_size, sync_every=args.sync_every,
                        kv_layout=args.kv_layout, page_size=args.page_size,
                        num_pages=args.num_pages, interleave=args.interleave,
                        preemption=args.preemption, spec_decode=spec_decode)
    if args.pin_R is not None:
        if not isinstance(eng.codec, codecs.AdaptiveC3SL):
            raise SystemExit("--pin-R needs an 'adaptive:...' --codec spec")
        eng.codec.pin(args.pin_R)
    if getattr(args, "sanitize", False):
        from repro.analysis.sanitize import EngineSanitizer, enable_debug_nans
        enable_debug_nans()
        eng.attach_sanitizer(EngineSanitizer(eng))
        print("[sanitize] debug_nans + per-tick engine invariant checks "
              "armed (pool accounting, slot hygiene, live-slot cut "
              "zeroing)", flush=True)
    return eng


def _run_frontdoor(cfg, params, args):
    """Serve the engine over the multi-tenant front door (TCP loopback by
    default) until interrupted.  Clients connect with
    ``repro.frontdoor.FrontDoorClient`` or anything speaking the frame
    protocol in ``src/repro/frontdoor/README.md``."""
    import asyncio

    from repro.frontdoor import (AdmissionController, FrontDoorServer,
                                 TenantPolicy)
    eng = _build_engine(cfg, params, args)
    server = FrontDoorServer(
        eng, host=args.host, port=args.port,
        admission=AdmissionController(
            max_queue_depth=args.max_queue_depth,
            default_policy=TenantPolicy(max_inflight=args.max_inflight)))

    async def serve():
        detector = None
        if getattr(args, "sanitize", False):
            from repro.analysis.sanitize import SlowCallbackDetector
            detector = SlowCallbackDetector().install()
        host, port = await server.start()
        spec = eng.codec.spec() if eng.codec is not None else "none"
        print(f"[serve] front door on {host}:{port} arch={cfg.name} "
              f"slots={args.batch} kv={args.kv_layout} codec={spec} "
              f"preemption={args.preemption} (ctrl-c to stop)", flush=True)
        try:
            await asyncio.Event().wait()
        finally:
            if detector is not None:
                await detector.stop()
                print(f"[sanitize] {detector.report()}", flush=True)
            await server.stop(drain=False)

    try:
        asyncio.run(serve())
    except KeyboardInterrupt:
        pass
    print(f"[serve] front door stopped; engine stats: "
          f"dispatches={eng.stats['dispatches']} "
          f"evictions={eng.stats['evictions']} "
          f"wire fwd {eng.stats['wire_bytes_fwd']:,d} B")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--codec", default="none",
                    help="registry spec, e.g. 'c3sl:R=4|int8', "
                         "'adaptive:c3sl:R=8,min_R=2|int8', or a link spec "
                         "'c3sl:R=4|int8 >> bwd:c3sl:R=2' (serving uses the "
                         "forward channel; see repro.transport)")
    ap.add_argument("--R", type=int, default=4,
                    help="default R for specs that omit it")
    ap.add_argument("--pin-R", type=int, default=None,
                    help="pin an adaptive codec's schedule to one bucket "
                         "(serving has no in-graph SNR probe; R is driven "
                         "externally via engine.observe_snr or pinned)")
    ap.add_argument("--quant-kv", action="store_true",
                    help="int8 KV cache (2x less cache HBM)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true")
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine (chunked prefill + "
                         "device-resident slot state) instead of the "
                         "lockstep decode loop")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--chunk-size", type=int, default=16)
    ap.add_argument("--sync-every", type=int, default=8)
    ap.add_argument("--prefill-mode", choices=["chunked", "decode"],
                    default="chunked",
                    help="'decode' = legacy prefill-as-decode baseline")
    ap.add_argument("--kv-layout", choices=["contiguous", "paged"],
                    default="contiguous",
                    help="'paged' = shared page pool + per-slot page tables "
                         "(short requests stop reserving max_len positions)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="cache positions per page (paged layout)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="physical pages in the pool (default: fully "
                         "provisioned = slots * ceil(max_len/page_size); "
                         "smaller pools oversubscribe and queue admissions)")
    ap.add_argument("--interleave", type=int, default=0,
                    help="decode steps interleaved after each prefill chunk "
                         "(0 = prefill admitted prompts to completion; the "
                         "TTFT vs inter-token-latency knob)")
    ap.add_argument("--draft-k", type=int, default=None,
                    help="speculative decoding: draft tokens per verify "
                         "round (k positions advance per round trip; "
                         "engine/frontdoor modes, needs --greedy)")
    ap.add_argument("--draft-spec", default=None,
                    help="draft feedback channel codec spec, e.g. "
                         "'c3sl:R=8|int8' ('none' = raw f32 feedback); "
                         "overrides a --codec link spec's 'draft:' segment")
    ap.add_argument("--draft-head", choices=["tied", "copy"], default=None,
                    help="client-side draft proposer: 'tied' (tied-embedding "
                         "head over the fed-back cut feature) or 'copy' "
                         "(repeat last token, zero feedback bytes)")
    ap.add_argument("--draft-adaptive", action="store_true",
                    help="adapt k from the measured acceptance rate "
                         "(EMA deadband over the {1,2,4,8} ladder)")
    ap.add_argument("--preemption", action="store_true",
                    help="evict lower-priority slots (pages freed, request "
                         "re-queued for re-prefill) instead of FIFO-blocking "
                         "when the queue head cannot be admitted "
                         "(chunked prefill only)")
    ap.add_argument("--frontdoor", action="store_true",
                    help="serve the engine over the multi-tenant TCP front "
                         "door (repro.frontdoor) instead of running a local "
                         "request batch")
    ap.add_argument("--host", default="127.0.0.1",
                    help="front door bind address")
    ap.add_argument("--port", type=int, default=8787,
                    help="front door port (0 = ephemeral)")
    ap.add_argument("--max-inflight", type=int, default=8,
                    help="per-tenant in-flight request cap (front door)")
    ap.add_argument("--max-queue-depth", type=int, default=64,
                    help="server-wide backlog cap before BUSY shedding "
                         "(front door)")
    ap.add_argument("--sanitize", action="store_true",
                    help="runtime sanitizer tier (repro.analysis.sanitize): "
                         "jax_debug_nans + per-tick engine invariant checks "
                         "(--engine/--frontdoor paths; an invariant trip "
                         "raises out of the serving loop) and event-loop "
                         "stall diagnostics on the front door")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.quant_kv:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    rng = jax.random.PRNGKey(args.seed)
    params = lm_lib.init_lm_params(rng, cfg)

    if args.frontdoor:
        _run_frontdoor(cfg, params, args)
        return
    if args.engine:
        _run_engine(cfg, params, args)
        return

    codec = codec_params = None
    if args.codec != "none":
        codec = _serving_codec(args.codec, cfg.d_model, args.R, args.batch)
        codec_params = codec.init(jax.random.PRNGKey(7))
        if isinstance(codec, transport.SplitLink):
            # lockstep loop serves the forward channel (same fwd params —
            # link.init feeds every channel the same rng)
            codec_params = codec.fwd_params(codec_params)
            codec = codec.fwd.codec
    adaptive = isinstance(codec, codecs.AdaptiveC3SL)
    if args.pin_R is not None:
        if not adaptive:
            raise SystemExit("--pin-R needs an 'adaptive:...' --codec spec")
        codec.pin(args.pin_R)

    fe = None
    if cfg.frontend:
        fe = jax.random.normal(rng, (args.batch, cfg.frontend_seq, cfg.frontend_dim))
    cache = lm_lib.init_decode_cache(params, cfg, args.batch, args.cache_len,
                                     frontend_emb=fe)

    def make_step(step_codec, step_codec_params):
        # one compiled branch per (bucket) codec; the Adaptive-R wrapper
        # itself must never be closed over by jit (host-side switching)
        @jax.jit
        def step(params, cache, tokens, pos, key):
            logits, cache = lm_lib.decode_step(params, cache, tokens, pos, cfg,
                                               codec=step_codec,
                                               codec_params=step_codec_params)
            if args.greedy:
                nxt = jnp.argmax(logits[:, -1], axis=-1)
            else:
                nxt = jax.random.categorical(key, logits[:, -1], axis=-1)
            return nxt[:, None].astype(jnp.int32), cache

        return step

    step_fns = codecs.build_program_table(codec, codec_params, make_step)

    tokens = jax.random.randint(rng, (args.batch, 1), 0, cfg.vocab_size)
    t0 = time.time()
    outs = [tokens]
    wire_total = 0
    for t in range(args.steps):
        rng, key = jax.random.split(rng)
        R = codecs.program_key(codec)
        tokens, cache = step_fns[R](params, cache, tokens, jnp.int32(t), key)
        if codec is not None:
            step_codec = codec.buckets[R] if adaptive else codec
            wire_total += codecs.payload_wire_bytes(
                step_codec, step_codec.payload_shape(args.batch))
        outs.append(tokens)
    dt = time.time() - t0
    seq = jnp.concatenate(outs, axis=1)
    print(f"arch={cfg.name} batch={args.batch} steps={args.steps} "
          f"codec={codec.spec() if codec is not None else 'none'} "
          f"R={getattr(codec, 'R', 1)}")
    print(f"decoded {args.steps} tokens/seq in {dt:.2f}s "
          f"({args.batch*args.steps/dt:.1f} tok/s total)")
    print("sample token ids:", seq[0, :16].tolist())
    if codec is not None:
        base = args.steps * args.batch * cfg.d_model * 4
        print(f"cut-layer wire bytes: {wire_total} over {args.steps} steps "
              f"vs vanilla {base} ({base/max(wire_total, 1):.1f}x compression)")


if __name__ == "__main__":
    main()
