"""Batched serving driver: prefill-free cache init + token-by-token decode.

Runs for real on CPU with reduced configs; demonstrates the C3-SL serving
integration (cut-layer features compressed batch-wise across the decode
batch).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        --batch 8 --steps 32 --codec "c3sl:R=4"
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import codecs
from repro.configs.base import get_config, reduced
from repro.models import lm as lm_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--codec", default="none",
                    help="registry spec, e.g. 'c3sl:R=4|int8' (see repro.codecs)")
    ap.add_argument("--R", type=int, default=4,
                    help="default R for specs that omit it")
    ap.add_argument("--quant-kv", action="store_true",
                    help="int8 KV cache (2x less cache HBM)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--greedy", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    if args.quant_kv:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    rng = jax.random.PRNGKey(args.seed)
    params = lm_lib.init_lm_params(rng, cfg)

    codec = codec_params = None
    if args.codec != "none":
        codec = codecs.clamp_R(
            codecs.build(args.codec, D=cfg.d_model, R=args.R), args.batch)
        codec_params = codec.init(jax.random.PRNGKey(7))

    fe = None
    if cfg.frontend:
        fe = jax.random.normal(rng, (args.batch, cfg.frontend_seq, cfg.frontend_dim))
    cache = lm_lib.init_decode_cache(params, cfg, args.batch, args.cache_len,
                                     frontend_emb=fe)

    @jax.jit
    def step(params, cache, tokens, pos, key):
        logits, cache = lm_lib.decode_step(params, cache, tokens, pos, cfg,
                                           codec=codec, codec_params=codec_params)
        if args.greedy:
            nxt = jnp.argmax(logits[:, -1], axis=-1)
        else:
            nxt = jax.random.categorical(key, logits[:, -1], axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    tokens = jax.random.randint(rng, (args.batch, 1), 0, cfg.vocab_size)
    t0 = time.time()
    outs = [tokens]
    for t in range(args.steps):
        rng, key = jax.random.split(rng)
        tokens, cache = step(params, cache, tokens, jnp.int32(t), key)
        outs.append(tokens)
    dt = time.time() - t0
    seq = jnp.concatenate(outs, axis=1)
    print(f"arch={cfg.name} batch={args.batch} steps={args.steps} "
          f"codec={codec.spec() if codec is not None else 'none'} "
          f"R={getattr(codec, 'R', 1)}")
    print(f"decoded {args.steps} tokens/seq in {dt:.2f}s "
          f"({args.batch*args.steps/dt:.1f} tok/s total)")
    print("sample token ids:", seq[0, :16].tolist())
    if codec is not None:
        wire = codec.wire_bytes(args.batch)
        base = args.batch * cfg.d_model * 4
        print(f"cut-layer wire bytes/step: {wire} vs vanilla {base} "
              f"({base/wire:.1f}x compression)")


if __name__ == "__main__":
    main()
