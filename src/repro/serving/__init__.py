from repro.serving.engine import BatchedEngine, Request
