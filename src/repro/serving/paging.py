"""Host-side page allocator for the paged KV cache.

The device-side layout (pools + page tables, see ``repro.models.paging``)
is pure data; WHICH physical pages a slot holds is serving policy and is
decided here, on the host, at admit/retire boundaries only — the jitted
step never allocates.

The engine reserves a request's full worst-case footprint at admit
(``ceil(min(prompt_len + max_new_tokens, max_len) / page_size)`` pages),
so a mid-flight decode can never run out of pages and there is no
preemption path; the memory win over the contiguous layout is that a
short request ties up its own footprint instead of ``max_len`` positions.
Admission is FIFO-blocking: when the head of the queue does not fit, the
engine waits for pages to free rather than admitting later (smaller)
requests past it, so a long request cannot be starved.
"""
from __future__ import annotations


class PageAllocator:
    """Free-list allocator over ``num_pages`` physical pages.

    Frees are pushed back in retire order, so a recycled slot typically
    gets DIFFERENT physical pages than its previous occupant — the
    equivalence tests lean on this to exercise free + realloc shuffling.
    """

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free = list(range(num_pages))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """Pop ``n`` pages, or None (allocation is all-or-nothing)."""
        if n > len(self._free):
            return None
        got, self._free = self._free[:n], self._free[n:]
        return got

    def free(self, pages: list[int]) -> None:
        for p in pages:
            if not 0 <= p < self.num_pages or p in self._free:
                raise ValueError(f"double/invalid free of page {p}")
        self._free.extend(pages)
