"""Continuous-batching serving engine (vLLM-lite, pure JAX).

Fixed pool of `num_slots` decode slots sharing one stacked KV cache; every
slot advances at its OWN position.  When a sequence finishes (EOS or
max_new_tokens), its slot is recycled for the next queued request
mid-flight — no draining the batch.

Two prefill modes:

* ``"chunked"`` (default) — the fast path.  Prompts are ingested C tokens
  per dispatch through ``lm.prefill_chunk`` (ragged tails padded under a
  length mask), so a length-L prompt costs ceil(L/C) dispatches instead of
  L.  Slot state (positions, last token, done flags, output buffer) lives
  ON DEVICE and is advanced inside the jitted step with `jnp.where`
  masking; the Python loop syncs with the device only every ``sync_every``
  decode steps (EOS flags fetched in batches) and on admit/retire
  boundaries.  Cache and state buffers are donated to the jitted programs,
  so XLA updates them in place instead of copying the KV cache every step.

* ``"decode"`` — the original prefill-as-decode path (one token, one
  dispatch, one host sync per engine step), kept as the measurable
  baseline for benchmarks/bench_serving.py and for equivalence tests.

The C3-SL codec applies to each step's cut-layer features across the
active slots; on the chunked path the features are grouped PER POSITION
(`sequence_group_encode` layout), the same group shape as the decode
path's batch-wise groups.  Outputs match the decode path token-for-token
when slot occupancy matches too (full batch, equal-length prompts,
lockstep admission); empty slots or ragged prompts contribute different
padding features to the superposition on the two paths, so there outputs
agree only up to codec cross-talk — the price batch-wise compression
always puts on occupancy changes.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs as codecs_lib
from repro.configs.base import ModelConfig
from repro.models import lm as lm_lib


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list            # token ids
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Request | None = None
    pos: int = 0             # next cache position to write (legacy mode)
    in_prompt: int = 0       # tokens of the prompt already ingested (legacy)


class BatchedEngine:
    def __init__(self, params, cfg: ModelConfig, *, num_slots: int = 8,
                 max_len: int = 256, eos_id: int | None = None,
                 codec=None, codec_params=None, greedy: bool = True,
                 seed: int = 0, prefill_mode: str = "chunked",
                 chunk_size: int = 16, sync_every: int = 8):
        # `codec` may be a ready codec object or a registry spec string
        # (e.g. "c3sl:R=4|int8"); specs are built against the decode cut
        # layer (D = d_model) and clamped to the slot count.  "none" means
        # codec off, matching the launch CLIs.
        if isinstance(codec, str):
            if codec == "none":
                codec = codec_params = None
            else:
                codec = codecs_lib.clamp_R(
                    codecs_lib.build(codec, D=cfg.d_model), num_slots)
                if codec_params is None:
                    codec_params = codec.init(jax.random.PRNGKey(seed))
        if prefill_mode not in ("chunked", "decode"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r} "
                             "(expected 'chunked' | 'decode')")
        self.codec = codec
        self.codec_params = codec_params
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.prefill_mode = prefill_mode
        # each ring slot must be written at most once per chunk (SWA caches
        # are rings of length sliding_window)
        if cfg.sliding_window:
            chunk_size = min(chunk_size, cfg.sliding_window)
        self.chunk_size = max(1, min(chunk_size, max_len))
        self.sync_every = max(1, sync_every)
        self.rng = jax.random.PRNGKey(seed)
        self.cache = lm_lib.init_decode_cache(params, cfg, num_slots, max_len)
        self.slots = [_Slot() for _ in range(num_slots)]
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._tokens_decoded = 0
        self.state = self._init_state()
        self._build_programs()

    # ------------------------------------------------------------------
    # compiled programs
    # ------------------------------------------------------------------

    def _init_state(self):
        """Device-resident slot state: advanced inside the jitted step, read
        back only at admit/retire boundaries."""
        B = self.num_slots
        z = lambda dt: jnp.zeros((B,), dt)  # noqa: E731
        return {
            "pos": z(jnp.int32),         # next cache position to write
            "last_tok": z(jnp.int32),    # decode input for the next step
            "active": z(bool),           # prompt fully ingested, generating
            "done": z(bool),             # finished, awaiting retire
            "out_len": z(jnp.int32),     # generated tokens so far
            "max_new": jnp.ones((B,), jnp.int32),
            "out_buf": jnp.zeros((B, self.max_len + 1), jnp.int32),
        }

    def _build_programs(self):
        cfg, codec, codec_params = self.cfg, self.codec, self.codec_params
        greedy, eos_id, max_len = self.greedy, self.eos_id, self.max_len

        def pick(logits, key):
            if greedy:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)

        def finish_check(state, nxt, out_len, pos):
            fin = (out_len >= state["max_new"]) | (pos >= max_len)
            if eos_id is not None:
                fin |= nxt == eos_id
            return fin

        def step_fn(params, cache, state, key):
            """One fused decode step: model forward + ALL slot bookkeeping."""
            live = state["active"] & ~state["done"]
            logits, cache = lm_lib.decode_step(
                params, cache, state["last_tok"][:, None], state["pos"], cfg,
                codec=codec, codec_params=codec_params)
            nxt = jnp.where(live, pick(logits[:, -1], key), state["last_tok"])
            B, cap = state["out_buf"].shape
            col = jnp.where(live, jnp.minimum(state["out_len"], cap - 1), cap)
            out_buf = state["out_buf"].at[jnp.arange(B), col].set(nxt, mode="drop")
            out_len = state["out_len"] + live.astype(jnp.int32)
            pos = state["pos"] + live.astype(jnp.int32)
            done = state["done"] | (live & finish_check(state, nxt, out_len, pos))
            return cache, {**state, "pos": pos, "last_tok": nxt, "done": done,
                           "out_len": out_len, "out_buf": out_buf}

        def prefill_fn(params, cache, state, tokens, valid, completes, key):
            """Ingest one prompt chunk for the rows `valid` marks; rows whose
            prompt ends in this chunk (`completes`) commit their first
            generated token from the last prompt position's logits."""
            logits, cache = lm_lib.prefill_chunk(
                params, cache, tokens, state["pos"], cfg,
                codec=codec, codec_params=codec_params, valid=valid)
            nxt = jnp.where(completes, pick(logits, key), state["last_tok"])
            B, cap = state["out_buf"].shape
            col = jnp.where(completes, jnp.minimum(state["out_len"], cap - 1), cap)
            out_buf = state["out_buf"].at[jnp.arange(B), col].set(nxt, mode="drop")
            out_len = state["out_len"] + completes.astype(jnp.int32)
            pos = state["pos"] + valid.sum(-1).astype(jnp.int32)
            done = state["done"] | (completes
                                    & finish_check(state, nxt, out_len, pos))
            return cache, {**state, "pos": pos, "last_tok": nxt, "done": done,
                           "active": state["active"] | completes,
                           "out_len": out_len, "out_buf": out_buf}

        def reset_fn(cache, mask):
            """Layout-aware zeroing of the rows `mask` marks.  The cache
            layout is known by KEY: "stack" leaves carry (num_superblocks,
            B, ...), "first" leaves (B, ...), "memory" (encoder output) is
            never per-slot state — no shape guessing against dims that
            happen to equal num_slots (heads, cache length, ...)."""
            def zero(subtree, axis):
                def z(leaf):
                    m = mask.reshape((1,) * axis + (-1,)
                                     + (1,) * (leaf.ndim - axis - 1))
                    return jnp.where(m, 0, leaf)
                return jax.tree.map(z, subtree)
            new = dict(cache)
            new["stack"] = zero(cache["stack"], 1)
            if "first" in cache:
                new["first"] = zero(cache["first"], 0)
            return new

        def legacy_step_fn(params, cache, tokens, pos, key):
            logits, cache = lm_lib.decode_step(params, cache, tokens, pos, cfg,
                                               codec=codec,
                                               codec_params=codec_params)
            return pick(logits[:, -1], key), cache

        self._step = jax.jit(step_fn, donate_argnums=(1, 2))
        self._prefill = jax.jit(prefill_fn, donate_argnums=(1, 2))
        self._reset = jax.jit(reset_fn, donate_argnums=(0,))
        self._step_legacy = jax.jit(legacy_step_fn)

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError(f"request {req.uid}: empty prompt")
        if len(req.prompt) > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt length {len(req.prompt)} exceeds "
                f"the engine's max_len={self.max_len} cache positions; "
                f"truncate the prompt or build the engine with a larger "
                f"max_len")
        self.queue.append(req)

    @property
    def active(self) -> int:
        return sum(s.req is not None for s in self.slots)

    def run(self, max_steps: int = 10_000) -> list[Request]:
        if self.prefill_mode == "decode":
            return self._run_legacy(max_steps)
        steps = 0
        while steps < max_steps:
            self._boundary()
            if not (self.queue or self.active):
                break
            for _ in range(self.sync_every):
                self.rng, key = jax.random.split(self.rng)
                self.cache, self.state = self._step(
                    self.params, self.cache, self.state, key)
                steps += 1
                if steps >= max_steps:
                    break
        self._boundary()
        return self.finished

    # ------------------------------------------------------------------
    # fast path internals
    # ------------------------------------------------------------------

    def _boundary(self):
        """Admit/retire boundary: the ONLY place the fast path syncs with
        the device outside the batched `sync_every` cadence."""
        st = {k: np.array(v) for k, v in jax.device_get(self.state).items()}
        touched = False
        for i, slot in enumerate(self.slots):
            if slot.req is not None and st["done"][i]:
                n = int(st["out_len"][i])
                slot.req.out = [int(t) for t in st["out_buf"][i, :n]]
                slot.req.done = True
                self.finished.append(slot.req)
                self._tokens_decoded += n
                slot.req = None
                st["active"][i] = st["done"][i] = False
                st["pos"][i] = st["last_tok"][i] = st["out_len"][i] = 0
                st["out_buf"][i, :] = 0
                touched = True
        admitted: list[int] = []
        for i, slot in enumerate(self.slots):
            if slot.req is None and self.queue:
                slot.req = self.queue.popleft()
                st["active"][i] = st["done"][i] = False
                st["pos"][i] = st["last_tok"][i] = st["out_len"][i] = 0
                st["max_new"][i] = slot.req.max_new_tokens
                st["out_buf"][i, :] = 0
                admitted.append(i)
                touched = True
        if touched:
            self.state = jax.device_put(st)
        if admitted:
            mask = np.zeros((self.num_slots,), bool)
            mask[admitted] = True
            self.cache = self._reset(self.cache, jnp.asarray(mask))
            self._prefill_admitted(admitted)

    def _prefill_admitted(self, admitted: list[int]):
        """Chunk the admitted slots' prompts: ceil(max_len/C) dispatches,
        ragged tails padded under the length mask, zero host syncs (the
        schedule depends only on host-known prompt lengths)."""
        B, C = self.num_slots, self.chunk_size
        prompts = {i: self.slots[i].req.prompt for i in admitted}
        n_chunks = -(-max(len(p) for p in prompts.values()) // C)
        for k in range(n_chunks):
            tokens = np.zeros((B, C), np.int32)
            valid = np.zeros((B, C), bool)
            completes = np.zeros((B,), bool)
            for i, prompt in prompts.items():
                seg = prompt[k * C:(k + 1) * C]
                if seg:
                    tokens[i, :len(seg)] = seg
                    valid[i, :len(seg)] = True
                completes[i] = k * C < len(prompt) <= (k + 1) * C
            self.rng, key = jax.random.split(self.rng)
            self.cache, self.state = self._prefill(
                self.params, self.cache, self.state, jnp.asarray(tokens),
                jnp.asarray(valid), jnp.asarray(completes), key)

    # ------------------------------------------------------------------
    # legacy path (prefill-as-decode, one host sync per token) — kept as
    # the benchmark baseline and for equivalence tests
    # ------------------------------------------------------------------

    def _reset_slot_cache(self, idx: int):
        """Zero one slot's cache rows so a recycled slot starts clean."""
        mask = np.zeros((self.num_slots,), bool)
        mask[idx] = True
        self.cache = self._reset(self.cache, jnp.asarray(mask))

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.req is None and self.queue:
                slot.req = self.queue.popleft()
                slot.pos = 0
                slot.in_prompt = 0
                self._reset_slot_cache(i)

    def step(self):
        """One legacy engine step: every active slot ingests/decodes one
        token ("prefill as decode"), then a host sync."""
        self._admit()
        if self.active == 0:
            return False
        tokens = np.zeros((self.num_slots, 1), np.int32)
        pos = np.zeros((self.num_slots,), np.int32)
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            if s.in_prompt < len(s.req.prompt):
                tokens[i, 0] = s.req.prompt[s.in_prompt]
            else:
                tokens[i, 0] = s.req.out[-1]
            pos[i] = s.pos
        self.rng, key = jax.random.split(self.rng)
        nxt, self.cache = self._step_legacy(self.params, self.cache,
                                            jnp.asarray(tokens),
                                            jnp.asarray(pos), key)
        nxt = np.asarray(nxt)
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            s.pos += 1
            fed_prompt = s.in_prompt < len(s.req.prompt)
            if fed_prompt:
                s.in_prompt += 1
            # the prediction counts once the WHOLE prompt is in: the last
            # prompt token's logits give the first generated token
            if not fed_prompt or s.in_prompt == len(s.req.prompt):
                tok = int(nxt[i])
                s.req.out.append(tok)
                self._tokens_decoded += 1
                if (self.eos_id is not None and tok == self.eos_id) \
                        or len(s.req.out) >= s.req.max_new_tokens \
                        or s.pos >= self.max_len:
                    s.req.done = True
            if s.req.done:
                self.finished.append(s.req)
                s.req = None
        return True

    def _run_legacy(self, max_steps: int) -> list[Request]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
