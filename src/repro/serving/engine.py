"""Continuous-batching serving engine (vLLM-lite, pure JAX).

Fixed pool of `num_slots` decode slots sharing one stacked KV cache; every
slot advances at its OWN position.  When a sequence finishes (EOS or
max_new_tokens), its slot is recycled for the next queued request
mid-flight — no draining the batch.

Two prefill modes:

* ``"chunked"`` (default) — the fast path.  Prompts are ingested C tokens
  per dispatch through ``lm.prefill_chunk`` (ragged tails padded under a
  length mask), so a length-L prompt costs ceil(L/C) dispatches instead of
  L.  Slot state (positions, last token, done flags, output buffer) lives
  ON DEVICE and is advanced inside the jitted step with `jnp.where`
  masking; decode runs in jitted WINDOWS — a `lax.while_loop` of up to
  ``sync_every`` fused steps per dispatch that exits device-side the
  moment no slot is live, so a drained batch never pays for the rest of
  its window.  The Python loop syncs with the device only per window and
  on admit/retire boundaries.  Cache and state buffers are donated to the
  jitted programs, so XLA updates them in place instead of copying the KV
  cache every step.

* ``"decode"`` — the original prefill-as-decode path (one token, one
  dispatch, one host sync per engine step), kept as the measurable
  baseline for benchmarks/bench_serving.py and for equivalence tests.

Two KV-cache layouts (``kv_layout``):

* ``"contiguous"`` (default) — every slot owns a (max_len, ...) strip, so
  one short request reserves as much HBM as a long one.
* ``"paged"`` — per-position cache leaves are shared pools of
  ``page_size``-position pages addressed through per-slot page tables
  (repro.models.paging); a request reserves only
  ``ceil(min(prompt + max_new, max_len) / page_size)`` pages at admit and
  frees them at retire.  ``num_pages`` sizes the pool — below
  ``num_slots * ceil(max_len / page_size)`` it is an oversubscribed pool
  and admission waits (FIFO) for pages.  Paged reads gather the pool into
  the exact contiguous layout inside the jitted step, so outputs are
  bit-identical to the contiguous baseline (same masks, same reductions).

Two paged read paths (``kv_read``, paged layout only):

* ``"gather"`` (default) — materialize the contiguous view via
  ``gather_pages`` and run the stock attention reduction over it.
* ``"kernel"`` — the Pallas paged-attention kernel walks the page table
  IN-KERNEL for the stacked superblocks' GQA decode reads (no contiguous
  gather), bit-identical to the gather path (pinned in
  tests/test_paged_kernel.py).  Not every read is covered: MLA latents,
  the unstacked first-dense superblock, and every prefill read stay on
  gather — the engine warns LOUDLY about each fallback at construction
  (never silently), and ``stats["kv_read_execution_mode"]`` reports
  whether the kernel is compiled or CPU-interpreted.

Prefill/decode interleaving (``interleave``): 0 prefills every admitted
prompt to completion before decoding resumes (lowest time-to-first-token
for the admitted request, but running slots stall for the whole prompt);
k > 0 alternates one prefill chunk with up to k decode steps, bounding
how long running requests stall per admitted prompt at the cost of a
slower prefill.  The knob trades new-request TTFT against in-flight
inter-token latency; GREEDY outputs are unaffected without a codec
(rows are independent — with sampling the dispatch schedule changes the
RNG-key stream, so tokens differ), and the equivalence suite runs at
interleave=0.

Adaptive-R codecs (``codec="adaptive:c3sl:R=8,min_R=2|int8"``): the engine
pre-compiles one program set per R bucket and picks the bucket HOST-SIDE
at every dispatch, so the served R can change between windows/chunks with
zero recompiles.  ``stats["payload_wire_bytes"]`` accumulates the ACTUAL
cut-layer bytes shipped (scale/mask bytes included, sequence-grouped 3-D
prefill payloads accounted at their true row count) and ``r_served``
counts the served schedule per bucket; feed the controller between dispatches via
``observe_snr`` or pin it (``engine.codec.pin(R)``).

Per-direction link specs (``codec="c3sl:R=8|int8 >> bwd:c3sl:R=4"``, see
``repro.transport``) resolve to the FORWARD channel — no gradient crosses
the cut at inference — with the per-direction stats keys
(``wire_bytes_fwd`` == ``payload_wire_bytes``, ``wire_bytes_bwd`` == 0)
kept aligned with the train-side protocol.

Paged-pool utilization: when the page pool is starving the head of the
queue, decode windows exit device-side the moment ANY slot finishes
(``stats["eos_early_exits"]``) and the finished slot is retired FROM THAT
HOST SYNC — outputs captured at their actual emitted length, the whole
worst-case ``prompt + max_new`` page reservation freed — instead of the
reservation being held until the next boundary's retire sweep;
``pool_accounting()`` exposes the free/in-use split the tests pin.

Slot preemption (``preemption=True``, chunked mode): when the head of the
queue is blocked on pages (or on a free slot) and outranks running work
(``Request.priority``), the boundary EVICTS strictly-lower-priority slots
— least progress first — frees their reservations, and re-queues the
evicted requests right behind the preempting head.  A re-admitted request
re-prefills its prompt plus the tokens it had already emitted, so greedy
output is bit-identical to an uninterrupted run (pinned in
tests/test_preemption.py); the price is the re-prefill compute.  This
replaces the pure FIFO-blocking reservation policy under oversubscription:
free pages no longer sit idle behind a blocked high-priority head.

``tick()`` is the incremental form of ``run()`` — one boundary + one
prefill/decode iteration + one boundary — for callers that interleave
engine work with other activity (the ``repro.frontdoor`` server's asyncio
loop, open-loop arrival benchmarks).

The C3-SL codec applies to each step's cut-layer features across the
active slots; on the chunked path the features are grouped PER POSITION
(`sequence_group_encode` layout), the same group shape as the decode
path's batch-wise groups.  Outputs match the decode path token-for-token
when slot occupancy matches too (full batch, equal-length prompts,
lockstep admission); empty slots or ragged prompts contribute different
padding features to the superposition on the two paths, so there outputs
agree only up to codec cross-talk — the price batch-wise compression
always puts on occupancy changes.  The same caveat applies to paged vs
contiguous under a codec: non-live rows read (masked-out but
codec-visible) stale pages instead of zeroed strips.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import Counter, deque

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs as codecs_lib
from repro.configs.base import ModelConfig
from repro.models import lm as lm_lib
from repro.models.paging import PagedLayout
from repro.serving import spec as spec_lib
from repro.serving.paging import PageAllocator
from repro.serving.spec import AdaptiveK, SpecConfig


def _codec_execution_mode(codec) -> str:
    """How the codec's transform ACTUALLY executes on this host ("none"
    without a codec).  Unwraps the Adaptive-R scheduler (``.current``) and
    wire-stage chains (``.transform``) down to the transform codec, whose
    ``execution_mode()`` distinguishes pallas-compiled / pallas-interpret /
    fft-fallback from the canonical ``spec()`` backend tag."""
    if codec is None:
        return "none"
    codec = getattr(codec, "current", codec)   # Adaptive-R wrapper
    codec = getattr(codec, "transform", codec)  # Chain of wire stages
    if hasattr(codec, "execution_mode"):
        return codec.execution_mode()
    return "unknown"


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list            # token ids
    max_new_tokens: int = 16
    priority: int = 0       # higher preempts lower (engine preemption=True)
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0   # set by submit()
    t_first: float | None = None  # first token observed (TTFT = t_first - t_submit)
    evictions: int = 0      # times this request was preempted mid-flight
    # speculative-decoding per-request stats (0 unless the engine ran with
    # spec_decode): tokens emitted through verify rounds, draft positions
    # the verify rejected, and rounds that truncated (accepted < k)
    accepted: int = 0
    rejected: int = 0
    rollbacks: int = 0


@dataclasses.dataclass
class _Slot:
    req: Request | None = None
    pos: int = 0             # next cache position to write (legacy mode)
    in_prompt: int = 0       # tokens of the prompt already ingested (legacy)
    ingested: int = 0        # tokens of the feed already ingested (chunked)
    # what this residency must ingest before decoding: the prompt, plus —
    # after an eviction — the tokens already emitted, so a re-admitted
    # request re-prefills its full generated-so-far context and greedy
    # decode continues exactly where it left off
    feed: list = dataclasses.field(default_factory=list)
    pages: list = dataclasses.field(default_factory=list)  # owned linear pages


class BatchedEngine:
    def __init__(self, params, cfg: ModelConfig, *, num_slots: int = 8,
                 max_len: int = 256, eos_id: int | None = None,
                 codec=None, codec_params=None, greedy: bool = True,
                 seed: int = 0, prefill_mode: str = "chunked",
                 chunk_size: int = 16, sync_every: int = 8,
                 kv_layout: str = "contiguous", page_size: int = 16,
                 num_pages: int | None = None, interleave: int = 0,
                 preemption: bool = False, kv_read: str = "gather",
                 spec_decode: SpecConfig | bool | None = None):
        # `codec` may be a ready codec object, a registry spec string
        # (e.g. "c3sl:R=4|int8"), or a per-direction link spec/SplitLink
        # ("c3sl:R=8|int8 >> bwd:c3sl:R=4").  Serving is forward-only —
        # no gradient crosses the cut — so the engine compresses with the
        # link's FORWARD channel and accounts the backward direction as 0
        # (stats["wire_bytes_bwd"]).  Specs are built against the decode cut
        # layer (D = d_model) and clamped to the slot count.  "none" means
        # codec off, matching the launch CLIs.
        from repro import transport
        self.link_spec = None
        # a link spec's "draft:" segment is the speculative feedback
        # channel's codec — captured here, consumed by the spec_decode
        # resolution below (its presence auto-enables speculation)
        draft_codec = draft_params = None
        if isinstance(codec, str):
            if codec == "none":
                codec = codec_params = None
            else:
                if transport.is_link_spec(codec):
                    link = transport.build_link(codec, D=cfg.d_model)
                    self.link_spec = link.spec()
                    if codec_params is not None:
                        # caller-supplied params follow the LINK's tree;
                        # the engine serves the forward channel only
                        codec_params = link.fwd_params(codec_params)
                    if link.draft is not None:
                        draft_codec = codecs_lib.clamp_R(link.draft.codec,
                                                         num_slots)
                    codec = link.fwd.codec
                codec = codecs_lib.clamp_R(
                    codecs_lib.build(codec, D=cfg.d_model)
                    if isinstance(codec, str) else codec, num_slots)
                if codec_params is None:
                    codec_params = codec.init(jax.random.PRNGKey(seed))
        elif isinstance(codec, transport.SplitLink):
            # link OBJECT: caller owns clamping/init (as for codec objects);
            # slice the forward channel's params out of the link tree
            self.link_spec = codec.spec()
            if codec.draft is not None:
                draft_codec = codec.draft.codec
                if codec_params is not None:
                    draft_params = codec.draft_params(codec_params)
            if codec_params is not None:
                codec_params = codec.fwd_params(codec_params)
            codec = codec.fwd.codec
        if prefill_mode not in ("chunked", "decode"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r} "
                             "(expected 'chunked' | 'decode')")
        if kv_layout not in ("contiguous", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r} "
                             "(expected 'contiguous' | 'paged')")
        if kv_read not in ("gather", "kernel"):
            raise ValueError(f"unknown kv_read {kv_read!r} "
                             "(expected 'gather' | 'kernel')")
        if kv_read == "kernel" and kv_layout != "paged":
            raise ValueError(
                "kv_read='kernel' requires kv_layout='paged': the Pallas "
                "paged-attention kernel is a page-table walk, and a "
                "contiguous cache has no table to walk")
        if preemption and prefill_mode != "chunked":
            raise ValueError("preemption requires prefill_mode='chunked' "
                             "(eviction re-queues the request for chunked "
                             "re-prefill of its generated context)")
        # ---- speculative decoding (repro.serving.spec) -------------------
        # spec_decode may be a SpecConfig, True (defaults), or None; a link
        # spec carrying a "draft:" segment auto-enables it with defaults.
        if spec_decode is True:
            spec_decode = SpecConfig()
        if spec_decode is None and draft_codec is not None:
            spec_decode = SpecConfig()
        self.spec_cfg: SpecConfig | None = spec_decode
        if spec_decode is not None:
            if prefill_mode != "chunked":
                raise ValueError(
                    "spec_decode requires prefill_mode='chunked': the verify "
                    "round is a k-position chunk dispatch")
            if not greedy:
                raise ValueError(
                    "spec_decode requires greedy=True: greedy verification "
                    "is what makes speculative output bit-identical to "
                    "vanilla decode (sampled verification would need the "
                    "rejection-sampling correction, which this engine does "
                    "not implement)")
            if cfg.sliding_window and spec_decode.ladder[-1] > cfg.sliding_window:
                raise ValueError(
                    f"spec_decode ladder max k={spec_decode.ladder[-1]} "
                    f"exceeds sliding_window={cfg.sliding_window}: a verify "
                    f"round must not write any ring slot twice; use a "
                    f"smaller ladder")
            if spec_decode.draft is not None:
                # SpecConfig's draft spec overrides a link's draft: segment
                draft_codec = codecs_lib.clamp_R(
                    codecs_lib.build(spec_decode.draft, D=cfg.d_model),
                    num_slots)
                draft_params = None
            if draft_codec is not None and draft_params is None:
                # a distinct key: the draft channel's superposition basis
                # must not collide with the forward channel's
                draft_params = draft_codec.init(jax.random.PRNGKey(seed + 1))
            self._k_ctl = AdaptiveK(spec_decode)
        else:
            draft_codec = draft_params = None
            self._k_ctl = None
        self.draft_codec = draft_codec
        self.draft_params = draft_params
        self.preemption = preemption
        self.codec = codec
        self.codec_params = codec_params
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.prefill_mode = prefill_mode
        self.kv_layout = kv_layout
        self.interleave = max(0, interleave)
        # each ring slot must be written at most once per chunk (SWA caches
        # are rings of length sliding_window)
        if cfg.sliding_window:
            chunk_size = min(chunk_size, cfg.sliding_window)
        self.chunk_size = max(1, min(chunk_size, max_len))
        self.sync_every = max(1, sync_every)
        self.rng = jax.random.PRNGKey(seed)

        self.paged: PagedLayout | None = None
        self.allocator: PageAllocator | None = None
        # which cache class actually backs full-length pages: MLA latents
        # always; attn only without a sliding window (SWA attn lives in the
        # statically-owned ring pools).  A pure-SWA or attention-free model
        # must not gate admission on a pool no leaf is allocated from.
        kinds = {k for layer in cfg.block_pattern for k in layer}
        self._linear_backed = ("mla" in kinds
                               or ("attn" in kinds and not cfg.sliding_window))
        self.kv_read = kv_read
        if kv_read == "kernel":
            if "attn" not in kinds:
                raise ValueError(
                    "kv_read='kernel' covers GQA ('attn') decode reads only, "
                    f"but block_pattern {cfg.block_pattern!r} has no attn "
                    "sublayer — every cache read would silently stay on the "
                    "gather path; use kv_read='gather'")
            fallbacks = []
            if "mla" in kinds:
                fallbacks.append("MLA latent reads")
            if cfg.first_dense_layers:
                fallbacks.append("the unstacked first-dense superblock")
            if prefill_mode == "chunked":
                fallbacks.append("chunked-prefill reads")
            if self.spec_cfg is not None:
                fallbacks.append("speculative verify/commit reads")
            if fallbacks:
                # loud by design: the silent-fallback bug class this tier
                # fixes.  The uncovered reads stay on gather_pages and are
                # still bit-identical — but the operator must know the
                # kernel is not serving them.
                warnings.warn(
                    "kv_read='kernel': " + ", ".join(fallbacks) + " stay on "
                    "the gather read path (kernel tier covers stacked GQA "
                    "decode only)", stacklevel=2)
        if kv_layout == "paged":
            len_swa = min(max_len, cfg.sliding_window) if cfg.sliding_window else 0
            pps = -(-max_len // page_size)
            pps_swa = -(-len_swa // page_size) if len_swa else 0
            if num_pages is None:
                num_pages = num_slots * pps      # fully provisioned pool
            # SWA rings are window-bounded already; each slot keeps its ring
            # pages for its lifetime (static table), only full-length pages
            # are allocated per request.
            self.paged = PagedLayout(page_size, max_len, num_pages,
                                     len_swa, num_slots * pps_swa)
            self.allocator = PageAllocator(num_pages)
            self._table = np.zeros((num_slots, pps), np.int32)
        self.cache = lm_lib.init_decode_cache(params, cfg, num_slots, max_len,
                                              paged=self.paged)
        if self.paged is not None:
            self.cache["pages"] = jnp.asarray(self._table)
            if self.paged.len_swa:
                self.cache["pages_swa"] = jnp.asarray(
                    np.arange(num_slots * self.paged.pages_per_slot_swa,
                              dtype=np.int32)
                    .reshape(num_slots, self.paged.pages_per_slot_swa))
        self.slots = [_Slot() for _ in range(num_slots)]
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._tokens_decoded = 0
        self._dirty = True            # force the first boundary to run
        # payload_wire_bytes accumulates the ACTUAL cut-layer bytes shipped
        # (per executed decode step / prefill chunk, scale+mask bytes
        # included) — under an Adaptive-R codec this follows the R schedule.
        # Per-direction accounting (repro.transport): serving is forward-
        # only, so wire_bytes_fwd == payload_wire_bytes and wire_bytes_bwd
        # stays 0 — the keys exist so engine stats line up with the train
        # logs' fwd/bwd protocol.  eos_early_exits counts decode windows cut
        # short because a slot finished while the page pool was starved
        # (the boundary then frees its pages immediately instead of holding
        # them for the rest of the window).
        # speculative counters (0 while spec_decode is off): wire_bytes_draft
        # is the draft channel's total — the server->client feedback payload
        # plus the client->server draft token ids, per verify round; fwd
        # bytes stay at the ONE _account_fwd_bytes entry (a verify round
        # ships NO forward payload — decode-time token ids are already
        # server-visible, so the server replays the bottom stack itself).
        # spec_accepted counts tokens emitted through verify rounds,
        # spec_rejected the draft positions the verify threw away, and
        # spec_rollbacks the rounds that truncated (accepted < k).
        self.stats = {"dispatches": 0, "decode_steps": 0, "prefill_chunks": 0,
                      "payload_wire_bytes": 0, "wire_bytes_fwd": 0,
                      "wire_bytes_bwd": 0, "wire_bytes_draft": 0,
                      "eos_early_exits": 0, "evictions": 0, "withdrawn": 0,
                      "spec_windows": 0, "spec_rounds": 0, "spec_accepted": 0,
                      "spec_rejected": 0, "spec_rollbacks": 0}
        # effective-execution-mode surfacing (the silent-fallback fix):
        # kv_read_execution_mode says how the paged read ACTUALLY runs on
        # this host ("gather" | "pallas-compiled" | "pallas-interpret") and
        # codec_execution_mode the same for the HRR codec ("none" without
        # one) — benchmarks must record these tags, and bench_roofline
        # refuses interpret-mode rows labeled as compiled kernels.
        if kv_read == "kernel":
            from repro.kernels import circconv
            self.stats["kv_read_execution_mode"] = circconv.execution_mode()
        else:
            self.stats["kv_read_execution_mode"] = "gather"
        self.stats["kv_read"] = kv_read
        self.stats["codec_execution_mode"] = _codec_execution_mode(self.codec)
        # the served R schedule under an adaptive codec, as {R: count} with
        # one count per EXECUTED decode step + one per prefill chunk, so
        # total() == decode_steps + prefill_chunks (not dispatches — a
        # window dispatch adds up to sync_every counts).  A Counter, not a
        # log: a long-lived engine serves millions of steps.  Kept out of
        # stats so stats stay scalar-valued.
        self.r_served: Counter[int] = Counter()
        # the served k schedule under spec_decode, as {k: verify rounds}
        # (k=1 windows are vanilla decode and counted by decode_steps only)
        self.k_served: Counter[int] = Counter()
        # streamed-token harvest: (uid, start, [tokens]) bursts collected
        # at host syncs the engine already performs (boundaries, early
        # retires) — drained by pop_stream_events() for the frontdoor's
        # TOKENS frames
        self.stream_events: list[tuple[int, int, list[int]]] = []
        self._stream_mark: dict[int, int] = {}
        self._adaptive = isinstance(self.codec, codecs_lib.AdaptiveC3SL)
        self.state = self._init_state()
        self._build_programs()
        # opt-in runtime invariant checks (repro.analysis.sanitize); None
        # in production — every check costs host syncs or extra dispatches
        self._sanitizer = None

    # ------------------------------------------------------------------
    # compiled programs
    # ------------------------------------------------------------------

    def _init_state(self):
        """Device-resident slot state: advanced inside the jitted step, read
        back only at admit/retire boundaries."""
        B = self.num_slots
        z = lambda dt: jnp.zeros((B,), dt)  # noqa: E731
        st = {
            "pos": z(jnp.int32),         # next cache position to write
            "last_tok": z(jnp.int32),    # decode input for the next step
            "active": z(bool),           # prompt fully ingested, generating
            "done": z(bool),             # finished, awaiting retire
            "out_len": z(jnp.int32),     # generated tokens so far
            "max_new": jnp.ones((B,), jnp.int32),
            "out_buf": jnp.zeros((B, self.max_len + 1), jnp.int32),
        }
        if self.spec_cfg is not None:
            # the draft head's feedback feature (the cut-layer feature at
            # each slot's last verified position, as the draft channel
            # delivered it) + the per-slot speculative counters the retire
            # path folds into Request.accepted/rejected/rollbacks
            st["draft_feat"] = jnp.zeros((B, self.cfg.d_model), jnp.float32)
            st["accepted"] = z(jnp.int32)
            st["rejected"] = z(jnp.int32)
            st["rollbacks"] = z(jnp.int32)
        return st

    def _build_programs(self):
        """Compile the engine's programs.  With an Adaptive-R codec this
        builds ONE program set per R bucket (each a separate compiled
        branch over that bucket's static codec + params); dispatch picks the
        bucket HOST-SIDE per window/chunk, so an R switch never retraces —
        pinned by the compile-counter test in tests/test_adaptive_codec.py."""
        paged = self.paged
        self._window_len = max(self.sync_every, self.interleave, 1)
        self._programs = codecs_lib.build_program_table(
            self.codec, self.codec_params, self._make_programs)
        # speculative verify/commit programs, one per (engine R bucket,
        # draft R bucket, k > 1) — jit is lazy, so unvisited combinations
        # cost nothing until first dispatch, and a HOST-side (R, draft-R, k)
        # switch lands on a pre-built entry: zero post-warmup recompiles,
        # same contract the vanilla bucket table pins.  k = 1 IS the
        # vanilla window program (speculation off) and has no entry here.
        self._spec_programs: dict = {}
        if self.spec_cfg is not None:
            for dkey, dc, dp in self._draft_buckets():
                for key, c, cp in self._codec_buckets():
                    for k in self.spec_cfg.ladder:
                        if k > 1:
                            self._spec_programs[(key, dkey, k)] = \
                                self._make_spec_program(c, cp, dc, dp, k)

        def reset_fn(cache, mask):
            """Layout-aware zeroing of the rows `mask` marks.  The cache
            layout is known by KEY: "stack" leaves carry (num_superblocks,
            B, ...), "first" leaves (B, ...), "memory" (encoder output) is
            never per-slot state — no shape guessing against dims that
            happen to equal num_slots (heads, cache length, ...).  Paged
            pools (attn/mla leaves) are left alone: reads past a slot's
            written positions are masked, so stale pages are invisible;
            only per-slot recurrent state needs zeroing."""
            def zero(subtree, axis):
                def z(leaf):
                    m = mask.reshape((1,) * axis + (-1,)
                                     + (1,) * (leaf.ndim - axis - 1))
                    return jnp.where(m, 0, leaf)
                return jax.tree.map(z, subtree)

            def zero_block(block, axis):
                if paged is None:
                    return zero(block, axis)
                return {key: (sub if key.rsplit("_", 1)[-1] in ("attn", "mla")
                              else zero(sub, axis))
                        for key, sub in block.items()}

            new = dict(cache)
            new["stack"] = zero_block(cache["stack"], 1)
            if "first" in cache:
                new["first"] = zero_block(cache["first"], 0)
            return new

        self._reset = jax.jit(reset_fn, donate_argnums=(0,))

    def _make_programs(self, codec, codec_params) -> dict:
        """One codec's compiled program set: the fused decode window, the
        chunked-prefill dispatch, and the legacy prefill-as-decode step."""
        cfg = self.cfg
        greedy, eos_id, max_len = self.greedy, self.eos_id, self.max_len
        paged, kv_read = self.paged, self.kv_read

        def pick(logits, key):
            if greedy:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)

        def finish_check(state, nxt, out_len, pos):
            fin = (out_len >= state["max_new"]) | (pos >= max_len)
            if eos_id is not None:
                fin |= nxt == eos_id
            return fin

        def step_fn(params, cache, state, key):
            """One fused decode step: model forward + ALL slot bookkeeping.
            Cache/state writes are masked to `live` rows, so decoding can
            run while other slots are empty or mid-prefill (interleaving)
            without stomping their cache pages or recurrent state."""
            live = state["active"] & ~state["done"]
            logits, cache = lm_lib.decode_step(
                params, cache, state["last_tok"][:, None], state["pos"], cfg,
                codec=codec, codec_params=codec_params, paged=paged, live=live,
                kv_read=kv_read)
            nxt = jnp.where(live, pick(logits[:, -1], key), state["last_tok"])
            B, cap = state["out_buf"].shape
            col = jnp.where(live, jnp.minimum(state["out_len"], cap - 1), cap)
            out_buf = state["out_buf"].at[jnp.arange(B), col].set(nxt, mode="drop")
            out_len = state["out_len"] + live.astype(jnp.int32)
            pos = state["pos"] + live.astype(jnp.int32)
            done = state["done"] | (live & finish_check(state, nxt, out_len, pos))
            return cache, {**state, "pos": pos, "last_tok": nxt, "done": done,
                           "out_len": out_len, "out_buf": out_buf}

        def window_fn(params, cache, state, keys, n, stop_on_done):
            """Up to n (<= W) fused decode steps in ONE dispatch; exits
            device-side as soon as no slot is live, so a drained batch
            pays nothing for the rest of its window.  ``stop_on_done``
            (traced bool — no retrace when it flips) additionally exits the
            moment ANY slot finishes: the host sets it while the page pool
            is starving a queued request, so the finished slot's pages are
            freed at the next boundary instead of being held for the rest
            of the window (boundaries retire every done slot, so entry
            state always has done == False)."""
            def cond(carry):
                i, _, state = carry
                live = jnp.any(state["active"] & ~state["done"])
                eos_cut = stop_on_done & jnp.any(state["done"])
                return (i < n) & live & ~eos_cut

            def body(carry):
                i, cache, state = carry
                cache, state = step_fn(params, cache, state, keys[i])
                return i + 1, cache, state

            return jax.lax.while_loop(cond, body, (jnp.int32(0), cache, state))

        def prefill_fn(params, cache, state, tokens, valid, completes, key):
            """Ingest one prompt chunk for the rows `valid` marks; rows whose
            prompt ends in this chunk (`completes`) commit their first
            generated token from the last prompt position's logits."""
            logits, cache = lm_lib.prefill_chunk(
                params, cache, tokens, state["pos"], cfg,
                codec=codec, codec_params=codec_params, valid=valid,
                paged=paged)
            nxt = jnp.where(completes, pick(logits, key), state["last_tok"])
            B, cap = state["out_buf"].shape
            col = jnp.where(completes, jnp.minimum(state["out_len"], cap - 1), cap)
            out_buf = state["out_buf"].at[jnp.arange(B), col].set(nxt, mode="drop")
            out_len = state["out_len"] + completes.astype(jnp.int32)
            pos = state["pos"] + valid.sum(-1).astype(jnp.int32)
            done = state["done"] | (completes
                                    & finish_check(state, nxt, out_len, pos))
            return cache, {**state, "pos": pos, "last_tok": nxt, "done": done,
                           "active": state["active"] | completes,
                           "out_len": out_len, "out_buf": out_buf}

        def legacy_step_fn(params, cache, tokens, pos, key, live):
            logits, cache = lm_lib.decode_step(params, cache, tokens, pos, cfg,
                                               codec=codec,
                                               codec_params=codec_params,
                                               paged=paged, live=live,
                                               kv_read=kv_read)
            return pick(logits[:, -1], key), cache

        return {"window": jax.jit(window_fn, donate_argnums=(1, 2)),
                "prefill": jax.jit(prefill_fn, donate_argnums=(1, 2)),
                "legacy": jax.jit(legacy_step_fn)}

    # ------------------------------------------------------------------
    # speculative verify/commit programs (repro.serving.spec)
    # ------------------------------------------------------------------

    def _codec_buckets(self):
        """(program key, concrete codec, params) per engine R bucket —
        the same host-side keying ``_bucket()`` dispatches on."""
        if self._adaptive:
            return [(R, self.codec.buckets[R],
                     self.codec.params_for(self.codec_params, R))
                    for R in self.codec.ladder]
        return [(None, self.codec, self.codec_params)]

    def _draft_buckets(self):
        """Same, for the draft channel's codec (one (None, None, None)
        entry when feedback ships raw / the head needs none)."""
        dc = self.draft_codec
        if isinstance(dc, codecs_lib.AdaptiveC3SL):
            return [(R, dc.buckets[R], dc.params_for(self.draft_params, R))
                    for R in dc.ladder]
        return [(None, dc, self.draft_params)]

    def _make_spec_program(self, codec, codec_params, d_codec, d_params,
                           k: int):
        """One (codec bucket, draft bucket, k) speculative window program:
        a while_loop of verify/commit rounds, each advancing every live
        slot by 1..k tokens in-graph.

        Round shape (see repro.serving.spec for the invariants):

        1. round-trip each slot's feedback feature through the DRAFT codec
           and propose k-1 draft tokens (exactly what the client computes
           from the feedback payload — drafts are deterministic argmax, so
           simulating the client in-graph is bit-exact);
        2. VERIFY: k-position chunk forward over [last_tok, drafts] on the
           committed cache — per-position greedy targets; the cache this
           phase writes is DISCARDED (lm.verify_chunk never returns it);
        3. accept the longest matching prefix, group-lockstep under the
           batch-wise codec, capped at EOS/budget (spec.accept_lengths);
        4. COMMIT: re-ingest only the accepted tokens through the
           valid-masked chunk_forward write path — rollback is pure
           position truncation, rejected positions write nothing anywhere.

        Greedy verification makes the emitted stream bit-identical to the
        vanilla window program's (pinned in tests/test_spec_decode.py).
        """
        cfg = self.cfg
        eos_id, max_len = self.eos_id, self.max_len
        paged = self.paged
        group = getattr(codec, "R", 1) if codec is not None else 1
        head_mode = self.spec_cfg.draft_head
        needs_feedback = self.spec_cfg.needs_feedback

        def round_fn(params, cache, state):
            live = state["active"] & ~state["done"]
            B = live.shape[0]
            rows = jnp.arange(B)
            feat = state["draft_feat"]
            if needs_feedback and d_codec is not None:
                # the feedback payload crosses the draft channel: dead rows
                # contribute zero to its superposition (same hygiene as the
                # forward channel), live rows come back with the draft R's
                # cross-talk — which can only cost acceptance, not
                # correctness (the verify consumes raw tokens, never the
                # lossy feature)
                feat = jnp.where(live[:, None], feat, 0.0)
                feat = d_codec.decode(d_params,
                                      d_codec.encode(d_params, feat))
            drafts = spec_lib.propose_drafts(params, feat,
                                             state["last_tok"], k, head_mode)
            toks_v = jnp.concatenate([state["last_tok"][:, None], drafts],
                                     axis=1)
            valid_v = live[:, None] & jnp.ones((1, k), bool)
            logits, feat_seq = lm_lib.verify_chunk(
                params, cache, toks_v, state["pos"], cfg, codec=codec,
                codec_params=codec_params, valid=valid_v, paged=paged)
            g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            e = spec_lib.accept_lengths(
                toks_v, g, live, group=group, eos_id=eos_id,
                rem_new=state["max_new"] - state["out_len"],
                rem_pos=max_len - state["pos"])
            out_buf = state["out_buf"]
            cap = out_buf.shape[1]
            for j in range(k):
                write = live & (j < e)
                col = jnp.where(write,
                                jnp.minimum(state["out_len"] + j, cap - 1),
                                cap)
                out_buf = out_buf.at[rows, col].set(g[:, j], mode="drop")
            e_live = jnp.where(live, e, 0)
            out_len = state["out_len"] + e_live
            pos = state["pos"] + e_live
            toks_c = jnp.concatenate([state["last_tok"][:, None],
                                      g[:, :k - 1]], axis=1)
            valid_c = live[:, None] & (jnp.arange(k)[None, :] < e[:, None])
            _, cache, _ = lm_lib.chunk_forward(
                params, cache, toks_c, state["pos"], cfg, codec=codec,
                codec_params=codec_params, valid=valid_c, paged=paged)
            last_emitted = g[rows, e - 1]
            last_tok = jnp.where(live, last_emitted, state["last_tok"])
            new_feat = jnp.where(live[:, None], feat_seq[rows, e - 1],
                                 state["draft_feat"])
            fin = (out_len >= state["max_new"]) | (pos >= max_len)
            if eos_id is not None:
                fin |= last_emitted == eos_id
            done = state["done"] | (live & fin)
            rej = jnp.where(live, k - e, 0)
            roll = (live & (e < k)).astype(jnp.int32)
            state = {**state, "pos": pos, "last_tok": last_tok, "done": done,
                     "out_len": out_len, "out_buf": out_buf,
                     "draft_feat": new_feat,
                     "accepted": state["accepted"] + e_live,
                     "rejected": state["rejected"] + rej,
                     "rollbacks": state["rollbacks"] + roll}
            return cache, state, (e_live.sum(), rej.sum(), roll.sum())

        def spec_window_fn(params, cache, state, n_rounds):
            def cond(carry):
                i, _, _, _, _, state = carry
                return ((i < n_rounds)
                        & jnp.any(state["active"] & ~state["done"]))

            def body(carry):
                i, acc, rej, rol, cache, state = carry
                cache, state, (a, r, ro) = round_fn(params, cache, state)
                return i + 1, acc + a, rej + r, rol + ro, cache, state

            z = jnp.int32(0)
            return jax.lax.while_loop(cond, body, (z, z, z, z, cache, state))

        return jax.jit(spec_window_fn, donate_argnums=(1, 2))

    # ------------------------------------------------------------------
    # codec-schedule dispatch + wire accounting
    # ------------------------------------------------------------------

    def _bucket(self):
        """Host-side program-set key for this dispatch: the adaptive codec's
        current R bucket, or None for a static (or absent) codec."""
        return codecs_lib.program_key(self.codec)

    def _current_codec(self):
        """The codec actually applied by the next dispatch (the bucket codec
        under Adaptive-R — never the wrapper, which must stay out of jit)."""
        if self.codec is None:
            return None
        return self.codec.current if self._adaptive else self.codec

    def observe_snr(self, snr_db, loss_slack=None):
        """Feed the Adaptive-R controller between dispatches (no-op for
        static codecs).  The serving path has no in-graph SNR probe, so the
        signal comes from outside — the training side's schedule, an SLA
        monitor, or a pinned R."""
        if self._adaptive:
            self.codec.observe(snr_db, loss_slack)

    def _account_fwd_bytes(self, nbytes: int):
        """The ONE place cut-layer bytes enter the stats: serving ships the
        forward direction only, so the legacy total and the per-direction
        fwd counter advance together by definition."""
        self.stats["payload_wire_bytes"] += nbytes
        self.stats["wire_bytes_fwd"] += nbytes

    def _step_wire_bytes(self) -> int:
        """Cut-layer bytes ONE decode step ships across the active batch."""
        c = self._current_codec()
        if c is None:
            return 0
        return codecs_lib.payload_wire_bytes(c, c.payload_shape(self.num_slots))

    def _chunk_wire_bytes(self) -> int:
        """Cut-layer bytes ONE prefill chunk ships (the sequence-grouped 3-D
        payload: chunk_size positions x num_slots/R groups x D)."""
        c = self._current_codec()
        if c is None:
            return 0
        shape = codecs_lib.chunk_payload_shape(c, self.num_slots,
                                               self.chunk_size)
        return codecs_lib.payload_wire_bytes(c, shape)

    def _draft_round_wire_bytes(self, k: int) -> int:
        """Draft-channel bytes ONE verify round ships, both ways: the
        server->client feedback payload (the cut-layer feature batch at
        the draft codec's R; zero for the "copy" head, raw f32 without a
        draft codec) plus the client->server draft token ids (k-1 per
        slot at the smallest dtype covering the vocab).  The FORWARD
        channel ships nothing during a verify round — the server already
        knows every decode-time token id and replays the bottom stack
        itself — which is exactly the amortization being bought."""
        tok_b = spec_lib.token_wire_bytes(self.cfg.vocab_size)
        ids = (k - 1) * self.num_slots * tok_b
        if not self.spec_cfg.needs_feedback:
            return ids
        dc = self.draft_codec
        if dc is None:
            return ids + self.num_slots * self.cfg.d_model * 4
        c = dc.current if isinstance(dc, codecs_lib.AdaptiveC3SL) else dc
        return ids + codecs_lib.payload_wire_bytes(
            c, c.payload_shape(self.num_slots))

    def wire_per_token(self) -> dict:
        """Wire bytes per GENERATED token across the serving channels —
        the speculative amortization metric (satellite: first-class
        per-token accounting, cross-checked in bench_serving).  Counts
        tokens of RETIRED requests (the denominator the engine can attest
        to); call after draining for exact totals."""
        n = self._tokens_decoded
        fwd = self.stats["wire_bytes_fwd"]
        draft = self.stats["wire_bytes_draft"]
        return {"generated_tokens": n,
                "wire_bytes_fwd": fwd,
                "wire_bytes_draft": draft,
                "wire_bytes_per_token": (fwd + draft) / max(n, 1)}

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def submit(self, req: Request):
        if not req.prompt:
            raise ValueError(f"request {req.uid}: empty prompt")
        if len(req.prompt) >= self.max_len:
            # a full cache leaves no position for the decode loop to write:
            # the request would be admitted, prefilled, and cut off after the
            # single prefill-predicted token regardless of max_new_tokens
            raise ValueError(
                f"request {req.uid}: prompt length {len(req.prompt)} leaves "
                f"no decode positions in the engine's max_len={self.max_len} "
                f"cache (need prompt length <= max_len - 1); truncate the "
                f"prompt or build the engine with a larger max_len")
        if self.paged is not None and self._linear_backed:
            need = self.paged.pages_for(len(req.prompt) + req.max_new_tokens)
            if need > self.paged.num_pages:
                raise ValueError(
                    f"request {req.uid}: needs {need} cache pages but the "
                    f"pool only has {self.paged.num_pages}; shorten the "
                    f"request or build the engine with more num_pages")
        req.t_submit = time.monotonic()
        self.queue.append(req)
        self._dirty = True            # a later run() must re-check admission

    def withdraw(self, uid: int):
        """Pull a queued or running request OUT of the engine (front-door
        disconnect handling): its slot/pages free immediately and the
        returned ``Request`` carries the tokens emitted so far, so a later
        ``submit`` of the same object re-prefills prompt + emitted tokens
        and greedy decode resumes bit-identically (the same machinery slot
        preemption uses).  Returns None when the uid is finished or
        unknown — finished results flow through the normal retire path."""
        for k, req in enumerate(self.queue):
            if req.uid == uid:
                del self.queue[k]
                self.stats["withdrawn"] += 1
                return req
        for i, slot in enumerate(self.slots):
            if slot.req is None or slot.req.uid != uid:
                continue
            req = slot.req
            self.stats["withdrawn"] += 1
            if self.prefill_mode == "chunked":
                st = {k: np.array(v)
                      for k, v in jax.device_get(self.state).items()}
                n = int(st["out_len"][i])
                req.out = [int(t) for t in st["out_buf"][i, :n]]
                self._fold_spec_counters(i, req, st)
                st["active"][i] = st["done"][i] = False
                st["pos"][i] = st["last_tok"][i] = st["out_len"][i] = 0
                st["out_buf"][i, :] = 0
                self.state = jax.device_put(st)
            self._stream_mark.pop(uid, None)
            req.evictions += 1
            req.done = False
            slot.req = None
            slot.feed = []
            slot.ingested = 0
            slot.pos = slot.in_prompt = 0
            self._free_slot_pages(i)
            self._dirty = True
            return req
        return None

    @property
    def active(self) -> int:
        return sum(s.req is not None for s in self.slots)

    @property
    def cache_bytes(self) -> int:
        """RESIDENT device bytes held by the KV cache (pools + tables +
        states) — the paged-vs-contiguous benchmark's memory metric.
        Excludes per-step transients (the paged read's gathered view of
        one layer's cache; see benchmarks/README.md)."""
        return sum(leaf.nbytes for leaf in jax.tree.leaves(self.cache))

    def attach_sanitizer(self, sanitizer) -> None:
        """Install per-tick invariant checks (an object with an
        ``on_tick(engine)`` method — see
        :class:`repro.analysis.sanitize.EngineSanitizer`).  A violated
        invariant raises out of tick()/run(); pass None to detach."""
        self._sanitizer = sanitizer

    def run(self, max_steps: int = 10_000) -> list[Request]:
        if self.prefill_mode == "decode":
            return self._run_legacy(max_steps)
        steps = 0
        while steps < max_steps:
            self._boundary()
            if not (self.queue or self.active):
                break
            steps += self._tick_body(max_steps - steps)
            if self._sanitizer is not None:
                self._sanitizer.on_tick(self)
        self._boundary()
        return self.finished

    def tick(self) -> bool:
        """One admission/compute iteration — the incremental form of
        :meth:`run` for callers that interleave engine work with other
        activity (the front-door server's asyncio loop, open-loop arrival
        benchmarks).  Runs one boundary, then at most one prefill pass /
        decode window, then a second boundary so finished requests land in
        ``self.finished`` before control returns.  Returns False when the
        engine is idle (no queued or resident work) — the caller's cue to
        sleep instead of spinning."""
        if self.prefill_mode == "decode":
            return bool(self.step())
        self._boundary()
        if not (self.queue or self.active):
            return False
        self._tick_body(self.sync_every)
        if self._sanitizer is not None:
            # before the trailing boundary: done-but-unretired slots are
            # still resident, so the dead/live cut probe sees the mix
            self._sanitizer.on_tick(self)
        self._boundary()
        return True

    def _tick_body(self, budget: int) -> int:
        """One scheduler iteration (between boundaries): prefill according
        to the interleave policy, then decode.  Returns executed decode
        steps (0 for a pure-prefill iteration)."""
        if self._pending_prefill():
            self._prefill_one_chunk()
            if self.interleave != 0:
                # the host knows which slots have finished their prompt —
                # don't dispatch a window that would exit at step 0
                if any(s.req is not None and s.ingested >= len(s.feed)
                       for s in self.slots):
                    return self._decode_window(min(self.interleave, budget))
                return 0
            # PR2 behavior: admitted prompts prefill to completion
            while self._pending_prefill():
                self._prefill_one_chunk()
        return self._decode_window(min(self.sync_every, budget))

    # ------------------------------------------------------------------
    # fast path internals
    # ------------------------------------------------------------------

    def _spec_k(self) -> int:
        """The k the NEXT decode window speculates at (1 = vanilla).  A
        starved page pool drops to vanilla windows: they support the
        per-token EOS early exit that frees a finished slot's reservation
        mid-window, which matters more than amortization right then."""
        if self.spec_cfg is None or self._pool_starved():
            return 1
        return self._k_ctl.current_k

    def _spec_window(self, n: int, k: int) -> int:
        """Dispatch one speculative window: ceil(n/k) verify/commit rounds
        in ONE jitted while_loop; returns tokens emitted.  The host reads
        four scalars at the window end (rounds + the three counters) —
        the same per-window sync cadence as the vanilla path's
        ``executed = int(i)``, no per-round syncs."""
        n_rounds = -(-min(n, self._window_len) // k)
        bucket = self._bucket()
        dkey = codecs_lib.program_key(self.draft_codec)
        i, acc, rej, rol, self.cache, self.state = \
            self._spec_programs[(bucket, dkey, k)](
                self.params, self.cache, self.state, jnp.int32(n_rounds))
        rounds, acc, rej, rol = (int(v) for v in
                                 jax.device_get((i, acc, rej, rol)))
        self.stats["dispatches"] += 1
        self.stats["decode_steps"] += acc
        self.stats["spec_windows"] += 1
        self.stats["spec_rounds"] += rounds
        self.stats["spec_accepted"] += acc
        self.stats["spec_rejected"] += rej
        self.stats["spec_rollbacks"] += rol
        # forward channel: ZERO bytes (server-side bottom-stack replay);
        # the draft channel carries the round's feedback + draft ids
        self.stats["wire_bytes_draft"] += rounds * \
            self._draft_round_wire_bytes(k)
        if bucket is not None:
            # keep r_served.total() == decode_steps + prefill_chunks: one
            # count per token served through the bucket's codec
            self.r_served[bucket] += acc
        self.k_served[k] += rounds
        if acc + rej:
            self._k_ctl.observe(acc / (acc + rej))
        if acc:
            self._dirty = True
        return acc

    def _decode_window(self, n: int) -> int:
        """Dispatch one jitted decode window of up to n steps; returns the
        number of steps the device actually executed before draining.
        Under spec_decode with current k > 1, the window is a speculative
        verify/commit loop instead (bit-identical greedy outputs)."""
        if n <= 0:
            return 0
        k = self._spec_k()
        if k > 1:
            return self._spec_window(n, k)
        n = min(n, self._window_len)
        keys = jax.random.split(self.rng, self._window_len + 1)
        self.rng = keys[0]
        bucket = self._bucket()
        stop_on_done = self._pool_starved()
        i, self.cache, self.state = self._programs[bucket]["window"](
            self.params, self.cache, self.state, keys[1:], jnp.int32(n),
            jnp.bool_(stop_on_done))
        self.stats["dispatches"] += 1
        executed = int(i)
        self.stats["decode_steps"] += executed
        self._account_fwd_bytes(executed * self._step_wire_bytes())
        if stop_on_done and executed < n:
            # a slot finished while the page pool was starving the head of
            # the queue.  Retire it from THIS host sync: its outputs are
            # captured at their actual emitted length and its whole
            # PageAllocator reservation is freed right here, instead of the
            # worst-case prompt+max_new pages staying held until the next
            # retire sweep.  The extra device round-trip only happens on
            # the already-rare starved-pool early exit.
            st = {k: np.array(v)
                  for k, v in jax.device_get(self.state).items()}
            if bool(np.any(st["active"] & ~st["done"])):
                # the early exit actually cut short a window that still had
                # live slots (vs the batch simply draining)
                self.stats["eos_early_exits"] += 1
            self._collect_stream(st)
            if self._retire_done(st):
                self.state = jax.device_put(st)
        if bucket is not None:
            self.r_served[bucket] += executed
        if executed:
            self._dirty = True
        return executed

    def _pool_starved(self) -> bool:
        """True when the head-of-queue request is blocked on pages — the
        condition under which a mid-window EOS is worth exiting early for."""
        if self.paged is None or not self._linear_backed or not self.queue:
            return False
        head = self.queue[0]
        need = self.paged.pages_for(len(head.prompt) + head.max_new_tokens)
        return need > self.allocator.free_pages

    def pool_accounting(self) -> dict:
        """Page-pool occupancy snapshot: every page is either on the free
        list or owned by exactly one slot (the invariant the EOS-free test
        pins).  Zeros for the contiguous layout."""
        if self.paged is None:
            return {"free": 0, "in_use": 0, "total": 0}
        in_use = sum(len(s.pages) for s in self.slots)
        return {"free": self.allocator.free_pages, "in_use": in_use,
                "total": self.paged.num_pages}

    def _pending_prefill(self) -> bool:
        return any(s.req is not None and s.ingested < len(s.feed)
                   for s in self.slots)

    def _prefill_one_chunk(self):
        """One chunk of up to chunk_size prompt tokens for EVERY slot still
        prefilling, in a single dispatch (ragged tails padded under the
        length mask; rows not prefilling are fully masked)."""
        B, C = self.num_slots, self.chunk_size
        tokens = np.zeros((B, C), np.int32)
        valid = np.zeros((B, C), bool)
        completes = np.zeros((B,), bool)
        any_rows = False
        for i, slot in enumerate(self.slots):
            if slot.req is None or slot.ingested >= len(slot.feed):
                continue
            seg = slot.feed[slot.ingested:slot.ingested + C]
            tokens[i, :len(seg)] = seg
            valid[i, :len(seg)] = True
            slot.ingested += len(seg)
            completes[i] = slot.ingested >= len(slot.feed)
            any_rows = True
        if not any_rows:
            return
        self.rng, key = jax.random.split(self.rng)
        bucket = self._bucket()
        self.cache, self.state = self._programs[bucket]["prefill"](
            self.params, self.cache, self.state, jnp.asarray(tokens),
            jnp.asarray(valid), jnp.asarray(completes), key)
        self.stats["dispatches"] += 1
        self.stats["prefill_chunks"] += 1
        self._account_fwd_bytes(self._chunk_wire_bytes())
        if bucket is not None:
            self.r_served[bucket] += 1
        if completes.any():
            # the completing dispatch commits the row's first token: stamp
            # TTFT here, so the metric has per-chunk resolution at EVERY
            # interleave setting.  Dispatch is async — block until the
            # token actually exists, or enqueue time would flatter
            # schedules that batch many dispatches between host syncs.
            jax.block_until_ready(self.state["out_len"])
            now = time.monotonic()
            for i in np.flatnonzero(completes):
                if self.slots[i].req.t_first is None:
                    self.slots[i].req.t_first = now
            self._dirty = True

    def _retire_done(self, st, now: float | None = None) -> bool:
        """Retire every slot whose done flag is set in the host state copy
        ``st``: capture its outputs at their ACTUAL emitted length and free
        its whole page reservation.  Called from the boundary sweep and —
        so a starved pool gets the pages at the earliest host-visible
        instant — from the decode window's EOS early exit."""
        if now is None:
            now = time.monotonic()
        touched = False
        for i, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            if slot.req.t_first is None and st["out_len"][i] > 0:
                slot.req.t_first = now
            if st["done"][i]:
                n = int(st["out_len"][i])
                slot.req.out = [int(t) for t in st["out_buf"][i, :n]]
                slot.req.done = True
                self.finished.append(slot.req)
                self._tokens_decoded += n
                self._fold_spec_counters(i, slot.req, st)
                self._stream_mark.pop(slot.req.uid, None)
                slot.req = None
                slot.feed = []
                self._free_slot_pages(i)
                st["active"][i] = st["done"][i] = False
                st["pos"][i] = st["last_tok"][i] = st["out_len"][i] = 0
                st["out_buf"][i, :] = 0
                touched = True
        return touched

    def _fold_spec_counters(self, i: int, req: Request, st):
        """Fold slot i's device-side speculative counters into the request
        (retire/evict/withdraw — totals survive preemption) and zero the
        slot's speculative state so the next resident starts clean."""
        if "accepted" not in st:
            return
        req.accepted += int(st["accepted"][i])
        req.rejected += int(st["rejected"][i])
        req.rollbacks += int(st["rollbacks"][i])
        st["accepted"][i] = st["rejected"][i] = st["rollbacks"][i] = 0
        st["draft_feat"][i, :] = 0

    def _collect_stream(self, st):
        """Harvest tokens emitted since each resident request's stream
        watermark into ``stream_events`` — piggybacks on host state copies
        the engine already makes (boundaries, early retires), so streaming
        costs no extra device round trips.  Drain with
        :meth:`pop_stream_events`."""
        for i, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            uid = slot.req.uid
            n = int(st["out_len"][i])
            mark = self._stream_mark.get(uid, 0)
            if n > mark:
                self.stream_events.append(
                    (uid, mark, [int(t) for t in st["out_buf"][i, mark:n]]))
                self._stream_mark[uid] = n

    def pop_stream_events(self) -> list[tuple[int, int, list[int]]]:
        """Drain the (uid, start, tokens) bursts collected since the last
        call — the frontdoor turns each into one incremental TOKENS frame.
        ``start`` is the burst's absolute offset in the request's output:
        a receiver that missed a burst (dropped on a dying connection)
        detects the gap instead of silently splicing."""
        ev, self.stream_events = self.stream_events, []
        return ev

    def _evict(self, i: int, st):
        """Preempt slot ``i`` mid-flight: capture the tokens it has emitted
        so far, free its page reservation, and re-queue the request right
        behind the preempting head (position 1 — it resumes before other
        queued work, so a single high-priority arrival cannot starve it).
        On re-admission the request re-prefills prompt + emitted tokens
        (``slot.feed``), so greedy decode resumes bit-identically."""
        slot = self.slots[i]
        req = slot.req
        n = int(st["out_len"][i])
        req.out = [int(t) for t in st["out_buf"][i, :n]]
        req.evictions += 1
        self.stats["evictions"] += 1
        self._fold_spec_counters(i, req, st)
        slot.req = None
        slot.feed = []
        slot.ingested = 0
        self._free_slot_pages(i)
        st["active"][i] = st["done"][i] = False
        st["pos"][i] = st["last_tok"][i] = st["out_len"][i] = 0
        st["out_buf"][i, :] = 0
        self.queue.insert(1, req)

    def _preempt_for(self, st, head: Request) -> bool:
        """Try to make room for the blocked head-of-queue request by
        evicting strictly-lower-priority running slots (least progress
        first — the cheapest re-prefill).  Evicts nothing when even the
        full victim set cannot cover the head's page reservation.  Returns
        True when at least one eviction happened (admission should retry)."""
        if not self.preemption:
            return False
        victims = [i for i, s in enumerate(self.slots)
                   if s.req is not None and s.req.priority < head.priority]
        if not victims:
            return False
        victims.sort(key=lambda i: (self.slots[i].req.priority,
                                    int(st["pos"][i])))
        paged = self.paged is not None and self._linear_backed
        if paged:
            need = self.paged.pages_for(len(head.prompt)
                                        + head.max_new_tokens)
            if need > self.allocator.free_pages + sum(
                    len(self.slots[i].pages) for i in victims):
                return False       # hopeless: keep the victims running
        evicted = False
        for i in victims:
            have_slot = any(s.req is None for s in self.slots)
            have_pages = not paged or need <= self.allocator.free_pages
            if have_slot and have_pages:
                break
            self._evict(i, st)
            evicted = True
        return evicted

    def _boundary(self):
        """Admit/retire boundary: the ONLY place the fast path syncs with
        the device outside the per-window cadence.  In paged mode this is
        also where pages move: retire frees a slot's pages, admission
        waits (FIFO — no overtaking) until the head request's reservation
        fits the pool — unless ``preemption`` is on and the head outranks
        running slots, in which case low-priority slots are evicted (pages
        freed, request re-queued for re-prefill) to admit it.  Skipped
        entirely while the host knows nothing could have changed (no
        decode steps executed, no prompt completed, no new submissions
        since the last boundary) — interleaved prefill of a long prompt
        must not pay a blocking device_get per chunk."""
        if not self._dirty:
            return
        self._dirty = False
        st = {k: np.array(v) for k, v in jax.device_get(self.state).items()}
        self._collect_stream(st)
        touched = self._retire_done(st)
        admitted: list[int] = []
        while self.queue:
            head = self.queue[0]
            i = next((j for j, s in enumerate(self.slots) if s.req is None),
                     None)
            if i is None or not self._alloc_slot_pages(i, head):
                if not self._preempt_for(st, head):
                    break                  # FIFO: wait for pages to free
                touched = True
                continue                   # room was made — retry the head
            slot = self.slots[i]
            slot.req = self.queue.popleft()
            slot.ingested = 0
            # re-admitted (evicted) requests re-prefill their emitted
            # tokens too, and resume with out_len/out_buf pre-seeded so
            # the prefill-completing dispatch commits token k+1
            slot.feed = list(slot.req.prompt) + list(slot.req.out)
            k = len(slot.req.out)
            st["active"][i] = st["done"][i] = False
            st["pos"][i] = st["last_tok"][i] = 0
            st["out_len"][i] = k
            st["max_new"][i] = slot.req.max_new_tokens
            st["out_buf"][i, :] = 0
            if k:
                st["out_buf"][i, :k] = slot.req.out
            # stream watermark: tokens in req.out were already delivered
            # (or re-prefilled after eviction) — only NEW emissions stream
            self._stream_mark.setdefault(slot.req.uid, k)
            admitted.append(i)
            touched = True
        if touched:
            self.state = jax.device_put(st)
        if admitted:
            if self.paged is not None:
                self.cache = {**self.cache, "pages": jnp.asarray(self._table)}
            mask = np.zeros((self.num_slots,), bool)
            mask[admitted] = True
            self.cache = self._reset(self.cache, jnp.asarray(mask))

    # ------------------------------------------------------------------
    # page bookkeeping (host side; no-ops for the contiguous layout)
    # ------------------------------------------------------------------

    def _alloc_slot_pages(self, i: int, req: Request) -> bool:
        if self.paged is None or not self._linear_backed:
            return True           # no leaf draws from the full-length pool
        need = self.paged.pages_for(len(req.prompt) + req.max_new_tokens)
        got = self.allocator.alloc(need)
        if got is None:
            return False
        self.slots[i].pages = got
        self._table[i, :] = 0
        self._table[i, :len(got)] = got
        return True

    def _free_slot_pages(self, i: int):
        if self.paged is None:
            return
        self.allocator.free(self.slots[i].pages)
        self.slots[i].pages = []
        self._table[i, :] = 0

    # ------------------------------------------------------------------
    # legacy path (prefill-as-decode, one host sync per token) — kept as
    # the benchmark baseline and for equivalence tests
    # ------------------------------------------------------------------

    def _reset_slot_cache(self, idx: int):
        """Zero one slot's cache rows so a recycled slot starts clean."""
        mask = np.zeros((self.num_slots,), bool)
        mask[idx] = True
        self.cache = self._reset(self.cache, jnp.asarray(mask))

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.req is None and self.queue:
                if not self._alloc_slot_pages(i, self.queue[0]):
                    break
                slot.req = self.queue.popleft()
                slot.pos = 0
                slot.in_prompt = 0
                slot.feed = list(slot.req.prompt) + list(slot.req.out)
                if self.paged is not None:
                    self.cache = {**self.cache,
                                  "pages": jnp.asarray(self._table)}
                self._reset_slot_cache(i)

    def step(self):
        """One legacy engine step: every active slot ingests/decodes one
        token ("prefill as decode"), then a host sync."""
        self._admit()
        if self.active == 0:
            return False
        tokens = np.zeros((self.num_slots, 1), np.int32)
        pos = np.zeros((self.num_slots,), np.int32)
        occupied = np.zeros((self.num_slots,), bool)
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            occupied[i] = True
            if s.in_prompt < len(s.feed):
                tokens[i, 0] = s.feed[s.in_prompt]
            else:
                tokens[i, 0] = s.req.out[-1]
            pos[i] = s.pos
        self.rng, key = jax.random.split(self.rng)
        # contiguous: unmasked writes (empty rows scribble on their own
        # zeroed strip, exactly the PR2 baseline the equivalence tests pin);
        # paged: empty rows hold no pages, so their writes MUST be masked
        live = jnp.asarray(occupied) if self.paged is not None else None
        bucket = self._bucket()
        nxt, self.cache = self._programs[bucket]["legacy"](
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(pos), key, live)
        self.stats["dispatches"] += 1
        # one fused batch step per dispatch — same unit as the chunked
        # path's decode_steps (NOT per-slot generated tokens)
        self.stats["decode_steps"] += 1
        self._account_fwd_bytes(self._step_wire_bytes())
        if bucket is not None:
            self.r_served[bucket] += 1
        nxt = np.asarray(nxt)
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            s.pos += 1
            fed_prompt = s.in_prompt < len(s.feed)
            if fed_prompt:
                s.in_prompt += 1
            # the prediction counts once the WHOLE prompt is in: the last
            # prompt token's logits give the first generated token
            if not fed_prompt or s.in_prompt == len(s.feed):
                tok = int(nxt[i])
                s.req.out.append(tok)
                if s.req.t_first is None:
                    s.req.t_first = time.monotonic()
                self._tokens_decoded += 1
                if (self.eos_id is not None and tok == self.eos_id) \
                        or len(s.req.out) >= s.req.max_new_tokens \
                        or s.pos >= self.max_len:
                    s.req.done = True
            if s.req.done:
                self.finished.append(s.req)
                s.req = None
                self._free_slot_pages(i)
        return True

    def _run_legacy(self, max_steps: int) -> list[Request]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
