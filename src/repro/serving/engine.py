"""Continuous-batching serving engine (vLLM-lite, pure JAX).

Fixed pool of `num_slots` decode slots sharing one stacked KV cache; every
slot advances at its OWN position (decode_step takes a (B,) position
vector).  When a sequence finishes (EOS or max_new_tokens), its slot is
recycled for the next queued request mid-flight — no draining the batch.

Prompt ingestion is token-by-token through the decode path ("prefill as
decode"), which keeps one compiled program for everything; a chunked
prefill program is the obvious follow-up optimization and is sketched in
EXPERIMENTS.md.  The C3-SL codec applies to each step's cut-layer features
across the active slots, exactly as in repro.launch.serve.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs as codecs_lib
from repro.configs.base import ModelConfig
from repro.models import lm as lm_lib


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list            # token ids
    max_new_tokens: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Request | None = None
    pos: int = 0             # next cache position to write
    in_prompt: int = 0       # tokens of the prompt already ingested


class BatchedEngine:
    def __init__(self, params, cfg: ModelConfig, *, num_slots: int = 8,
                 max_len: int = 256, eos_id: int | None = None,
                 codec=None, codec_params=None, greedy: bool = True,
                 seed: int = 0):
        # `codec` may be a ready codec object or a registry spec string
        # (e.g. "c3sl:R=4|int8"); specs are built against the decode cut
        # layer (D = d_model) and clamped to the slot count.  "none" means
        # codec off, matching the launch CLIs.
        if isinstance(codec, str):
            if codec == "none":
                codec = codec_params = None
            else:
                codec = codecs_lib.clamp_R(
                    codecs_lib.build(codec, D=cfg.d_model), num_slots)
                if codec_params is None:
                    codec_params = codec.init(jax.random.PRNGKey(seed))
        self.codec = codec
        self.params = params
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.greedy = greedy
        self.rng = jax.random.PRNGKey(seed)
        self.cache = lm_lib.init_decode_cache(params, cfg, num_slots, max_len)
        self.slots = [_Slot() for _ in range(num_slots)]
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._tokens_decoded = 0

        def step_fn(params, cache, tokens, pos, key):
            logits, cache = lm_lib.decode_step(params, cache, tokens, pos, cfg,
                                               codec=codec,
                                               codec_params=codec_params)
            nxt_greedy = jnp.argmax(logits[:, -1], axis=-1)
            nxt_sample = jax.random.categorical(key, logits[:, -1], axis=-1)
            return (nxt_greedy if greedy else nxt_sample).astype(jnp.int32), cache

        self._step = jax.jit(step_fn)

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _reset_slot_cache(self, idx: int):
        """Zero one slot's cache row so a recycled slot starts clean."""
        def zero_row(leaf):
            if leaf.ndim >= 2 and leaf.shape[1] == self.num_slots:
                return leaf.at[:, idx].set(0)   # stacked (N, B, ...)
            if leaf.ndim >= 1 and leaf.shape[0] == self.num_slots:
                return leaf.at[idx].set(0)      # unstacked (B, ...)
            return leaf
        self.cache = jax.tree.map(zero_row, self.cache)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot.req is None and self.queue:
                slot.req = self.queue.popleft()
                slot.pos = 0
                slot.in_prompt = 0
                self._reset_slot_cache(i)

    @property
    def active(self) -> int:
        return sum(s.req is not None for s in self.slots)

    def step(self):
        """One engine step: every active slot ingests/decodes one token."""
        self._admit()
        if self.active == 0:
            return False
        tokens = np.zeros((self.num_slots, 1), np.int32)
        pos = np.zeros((self.num_slots,), np.int32)
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            if s.in_prompt < len(s.req.prompt):
                tokens[i, 0] = s.req.prompt[s.in_prompt]
            else:
                tokens[i, 0] = s.req.out[-1]
            pos[i] = s.pos
        self.rng, key = jax.random.split(self.rng)
        nxt, self.cache = self._step(self.params, self.cache,
                                     jnp.asarray(tokens), jnp.asarray(pos), key)
        nxt = np.asarray(nxt)
        for i, s in enumerate(self.slots):
            if s.req is None:
                continue
            s.pos += 1
            fed_prompt = s.in_prompt < len(s.req.prompt)
            if fed_prompt:
                s.in_prompt += 1
            # the prediction counts once the WHOLE prompt is in: the last
            # prompt token's logits give the first generated token
            if not fed_prompt or s.in_prompt == len(s.req.prompt):
                tok = int(nxt[i])
                s.req.out.append(tok)
                self._tokens_decoded += 1
                if (self.eos_id is not None and tok == self.eos_id) \
                        or len(s.req.out) >= s.req.max_new_tokens \
                        or s.pos >= self.max_len:
                    s.req.done = True
            if s.req.done:
                self.finished.append(s.req)
                s.req = None
        return True

    def run(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
