"""Speculative decoding over the split link: config, controller, helpers.

The serving engine decodes one token per cut-layer round trip; with a
draft/verify channel it amortizes the link instead.  Per round, a cheap
CLIENT-side draft head proposes ``k - 1`` tokens from the last verified
cut-layer feature (shipped server->client through the link's ``draft:``
channel at its own, coarser R), and the server advances the decode
window k positions in ONE jitted dispatch that both verifies the drafts
against the target model's greedy tokens and commits the longest
accepted prefix.  Greedy verification makes the emitted stream
BIT-IDENTICAL to vanilla decode — the draft channel's compression loss
can only lower the ACCEPTANCE RATE, never change an output token.

The verify round is two-phase inside one dispatch:

* **verify** — ``lm.verify_chunk`` runs the k-position chunk forward on
  the committed cache and returns per-position logits; the cache this
  phase would have written is DISCARDED in-graph, so nothing speculative
  ever lands in the KV cache, ring-SWA buffers, or recurrent state.
* **commit** — the accepted prefix is re-ingested through the existing
  ``valid``-masked ``lm.chunk_forward`` write path.  Rejection rollback
  is therefore pure position truncation: no snapshot, no page copy, and
  no partially-written page is ever visible to a later C3-SL
  superposition (the PR 7 dead-slot hazard class).

Acceptance is GROUP-LOCKSTEP under a batch-wise codec: C3-SL superposes
R consecutive slots, so one slot accepting past its group partners would
change the partners' superposition contents vs vanilla decode.  The
accepted length is the min over each codec group's live rows (group
size 1 — fully per-slot — without a codec).

:class:`AdaptiveK` schedules k over a power-of-two ladder from the
measured acceptance rate with an EMA deadband, exactly the
``AdaptiveC3SL`` SNR-ladder shape; k = 1 degenerates to the vanilla
decode window (speculation off), so ramping down is always safe.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

DRAFT_HEADS = ("tied", "copy")

_LADDER = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Engine-facing speculative-decoding configuration.

    ``k`` — verify-window positions per round (1 input + k-1 drafts);
    each round emits between 1 and k tokens.  ``ladder`` — the k values
    :class:`AdaptiveK` may schedule (every entry gets its own pre-built
    program, so switches never recompile); k=1 is the vanilla window.
    ``draft`` — codec spec for the draft feedback channel (overrides a
    link spec's ``draft:`` segment; None ships raw f32 feedback).
    ``draft_head`` — "tied" (tied-embedding head over the fed-back cut
    feature) or "copy" (repeat the last token; needs NO feedback, so the
    draft channel ships only token ids).  ``adaptive`` enables the
    acceptance-rate controller; otherwise k stays pinned.
    """
    k: int = 4
    ladder: tuple[int, ...] = _LADDER
    draft: str | None = None
    draft_head: str = "tied"
    adaptive: bool = False
    target_accept: float = 0.5
    ema: float = 0.9
    hysteresis: float = 0.1

    def __post_init__(self):
        ladder = tuple(sorted(set(int(k) for k in self.ladder)))
        object.__setattr__(self, "ladder", ladder)
        if not ladder or ladder[0] < 1:
            raise ValueError(f"ladder must be >= 1, got {self.ladder}")
        for k in ladder:
            if k & (k - 1):
                raise ValueError(
                    f"ladder entries must be powers of two (one pre-built "
                    f"program per k), got {self.ladder}")
        if self.k not in ladder:
            raise ValueError(f"k={self.k} not in ladder {ladder}")
        if self.draft_head not in DRAFT_HEADS:
            raise ValueError(f"unknown draft_head {self.draft_head!r} "
                             f"(expected one of {DRAFT_HEADS})")
        if not 0.0 <= self.ema < 1.0:
            raise ValueError(f"ema must be in [0, 1), got {self.ema}")
        if self.hysteresis < 0.0:
            raise ValueError(f"hysteresis must be >= 0, got "
                             f"{self.hysteresis}")
        if not 0.0 < self.target_accept <= 1.0:
            raise ValueError(f"target_accept must be in (0, 1], got "
                             f"{self.target_accept}")

    @property
    def needs_feedback(self) -> bool:
        """Does the draft head consume the fed-back cut feature?  The
        "copy" head drafts from token ids alone — its draft channel
        ships no feedback payload at all."""
        return self.draft_head != "copy"


class AdaptiveK:
    """Acceptance-rate-driven k scheduler (EMA deadband over a ladder).

    Mirrors ``AdaptiveC3SL``'s controller shape: ``observe`` folds one
    window's acceptance rate into an EMA and returns the k to use NEXT —
    ramping up while acceptance clears ``target + hysteresis`` (drafts
    are being accepted; amortize more per round trip) and down below
    ``target - hysteresis`` (verify compute is being wasted on rejected
    positions).  ``pin``/``unpin`` fix the schedule for equivalence
    tests or an external controller.  Dropping to k = 1 IS speculation
    off — the engine serves the vanilla window program for that bucket.
    """

    def __init__(self, cfg: SpecConfig):
        self.cfg = cfg
        self.ladder = cfg.ladder
        self._k = cfg.k
        self._pinned: int | None = None if cfg.adaptive else cfg.k
        self._ema_accept: float | None = None

    @property
    def current_k(self) -> int:
        return self._k

    @property
    def ema_accept(self) -> float | None:
        return self._ema_accept

    def pin(self, k: int) -> "AdaptiveK":
        if k not in self.ladder:
            raise ValueError(f"k={k} not in ladder {self.ladder}")
        self._pinned = self._k = k
        return self

    def unpin(self) -> "AdaptiveK":
        self._pinned = None
        return self

    def observe(self, accept_rate: float | None) -> int:
        """Feed one window's measured acceptance rate (accepted tokens /
        (rounds * k), in [1/k, 1]); returns the k for the NEXT window."""
        if accept_rate is not None:
            a = float(accept_rate)
            self._ema_accept = (a if self._ema_accept is None
                                else self.cfg.ema * self._ema_accept
                                + (1.0 - self.cfg.ema) * a)
        if self._pinned is not None:
            return self._k
        if self._ema_accept is None:
            return self._k
        i = self.ladder.index(self._k)
        if (self._ema_accept > self.cfg.target_accept + self.cfg.hysteresis
                and i + 1 < len(self.ladder)):
            self._k = self.ladder[i + 1]
        elif (self._ema_accept < self.cfg.target_accept - self.cfg.hysteresis
                and i > 0):
            self._k = self.ladder[i - 1]
        return self._k


def token_wire_bytes(vocab_size: int) -> int:
    """Bytes one draft token id costs on the wire: the smallest unsigned
    integer dtype covering the vocabulary."""
    if vocab_size <= 1 << 8:
        return 1
    if vocab_size <= 1 << 16:
        return 2
    return 4


def propose_drafts(params, draft_feat, last_tok, k: int, mode: str):
    """In-graph draft proposal: (B, k-1) int32 token ids.

    ``mode="tied"`` reuses the TARGET model's embedding/head as the
    draft model (zero extra params, runnable client-side): the first
    draft reads the fed-back cut-layer feature plus the last verified
    token's embedding through the output head, and later drafts chain
    through embedding->head alone.  ``mode="copy"`` repeats the last
    verified token — the degenerate repetition draft that needs no
    feedback feature at all.  Drafts are deterministic (argmax), so the
    client and server agree on the proposal without extra wire traffic.
    """
    if k <= 1:
        return jnp.zeros((last_tok.shape[0], 0), jnp.int32)
    if mode == "copy":
        return jnp.tile(last_tok[:, None], (1, k - 1))
    if mode != "tied":
        raise ValueError(f"unknown draft head {mode!r} "
                         f"(expected one of {DRAFT_HEADS})")
    emb, head = params["embed"], params["head"]
    d = jnp.argmax((draft_feat + emb[last_tok]) @ head, axis=-1)
    d = d.astype(jnp.int32)
    drafts = [d]
    for _ in range(k - 2):
        d = jnp.argmax(emb[d] @ head, axis=-1).astype(jnp.int32)
        drafts.append(d)
    return jnp.stack(drafts, axis=1)


def accept_lengths(fed, targets, live, *, group: int, eos_id,
                   rem_new, rem_pos):
    """In-graph accepted-prefix lengths, group-lockstep.  (B,) int32.

    ``fed`` (B, k) — the tokens the verify chunk consumed (last verified
    token followed by the k-1 drafts); ``targets`` (B, k) — the target
    model's greedy tokens for those positions.  ``targets[:, j]`` is a
    valid greedy continuation only while every earlier draft matched its
    target, so the raw accepted length is (longest matching prefix) + 1
    — the classic speculative-decoding rule, here with three caps:

    * first EOS among the targets (vanilla stops THERE; accepting past
      it would emit tokens vanilla never produced),
    * the row's remaining token budget (``rem_new``/``rem_pos``),
    * the min over the row's codec group (size ``group``): C3-SL mixes R
      consecutive rows per superposition, so a row advancing past its
      group partners would change the partners' group contents vs
      vanilla decode.  Dead rows never cap their group.

    Live rows always accept at least 1 token (position 0 consumed the
    already-verified last token, so ``targets[:, 0]`` is exact).
    """
    B, k = targets.shape
    matched = (fed[:, 1:] == targets[:, :-1])          # draft j == target j
    raw = jnp.cumprod(matched.astype(jnp.int32), axis=1).sum(axis=1) + 1
    limit = raw
    if eos_id is not None:
        is_eos = targets == eos_id
        eos_at = jnp.where(is_eos.any(axis=1),
                           is_eos.argmax(axis=1).astype(jnp.int32) + 1, k)
        limit = jnp.minimum(limit, eos_at)
    limit = jnp.minimum(limit, jnp.maximum(rem_new, 1))
    limit = jnp.minimum(limit, jnp.maximum(rem_pos, 1))
    limit = jnp.where(live, limit, k)                  # dead rows never cap
    if group > 1:
        e = limit.reshape(B // group, group).min(axis=1)
        limit = jnp.repeat(e, group)
    return limit.astype(jnp.int32)
