from repro.data.pipeline import (SyntheticImageDataset, SyntheticTokenDataset,
                                 input_specs, make_batch_iterator)
