"""Deterministic synthetic data pipelines + dry-run input specs.

Offline environment: no dataset downloads.  Two learnable synthetic tasks:

  * SyntheticImageDataset — class-conditional image distribution (random
    class templates + noise); CIFAR-shaped, used for the paper repro.
  * SyntheticTokenDataset — LM sequences from a deterministic mixture of
    per-class n-gram-ish generators, so next-token loss is reducible.

`input_specs(cfg, shape)` produces the ShapeDtypeStruct batches every
dry-run lowers against (the one carve-out for vlm/audio: precomputed
patch/frame embeddings per DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# synthetic images (paper repro)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SyntheticImageDataset:
    n_classes: int = 10
    shape: tuple = (3, 32, 32)
    noise: float = 0.6
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.templates = rng.normal(size=(self.n_classes, *self.shape)).astype(np.float32)

    def batch(self, batch_size: int, step: int):
        rng = np.random.default_rng((self.seed, step))
        y = rng.integers(0, self.n_classes, size=batch_size)
        x = self.templates[y] + self.noise * rng.normal(
            size=(batch_size, *self.shape)).astype(np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(y, jnp.int32)}


# ---------------------------------------------------------------------------
# synthetic tokens
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SyntheticTokenDataset:
    vocab_size: int
    seq_len: int
    seed: int = 0
    n_patterns: int = 64

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # deterministic successor table: tok -> likely next tok (learnable)
        self.successor = rng.integers(0, self.vocab_size, size=self.vocab_size)

    def batch(self, batch_size: int, step: int):
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((batch_size, self.seq_len + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.vocab_size, size=batch_size)
        for t in range(1, self.seq_len + 1):
            follow = self.successor[toks[:, t - 1]]
            rand = rng.integers(0, self.vocab_size, size=batch_size)
            use_follow = rng.random(batch_size) < 0.8
            toks[:, t] = np.where(use_follow, follow, rand)
        return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
                "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


def make_batch_iterator(dataset, batch_size: int, start_step: int = 0) -> Iterator:
    step = start_step
    while True:
        yield dataset.batch(batch_size, step)
        step += 1


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct only — zero allocation)
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k":    dict(seq_len=4096,    global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768,   global_batch=32,  kind="prefill"),
    "decode_32k":  dict(seq_len=32768,   global_batch=128, kind="decode"),
    "long_500k":   dict(seq_len=524288,  global_batch=1,   kind="decode"),
}


def input_specs(cfg: ModelConfig, shape_name: str, dtype=jnp.bfloat16):
    """Dry-run batch spec for (arch, input-shape).

    train/prefill: {"tokens","labels"(train only),["frontend"]}.
    decode: {"tokens" (B,1)} — the KV cache spec comes from
    lm.abstract_decode_cache.
    """
    spec = SHAPES[shape_name]
    B, S = spec["global_batch"], spec["seq_len"]
    sds = jax.ShapeDtypeStruct
    if spec["kind"] == "decode":
        batch = {"tokens": sds((B, 1), jnp.int32)}
        return batch
    out = {}
    if cfg.frontend and not cfg.is_encdec:
        # vlm: patches take frontend_seq of the total sequence
        s_text = S - cfg.frontend_seq
        out["tokens"] = sds((B, s_text), jnp.int32)
        out["frontend"] = sds((B, cfg.frontend_seq, cfg.frontend_dim), dtype)
        if spec["kind"] == "train":
            out["labels"] = sds((B, s_text), jnp.int32)
        return out
    out["tokens"] = sds((B, S), jnp.int32)
    if cfg.is_encdec:
        out["frontend"] = sds((B, cfg.frontend_seq, cfg.frontend_dim), dtype)
    if spec["kind"] == "train":
        out["labels"] = sds((B, S), jnp.int32)
    return out
