"""Paged KV cache geometry + device-side gather/scatter addressing.

Instead of one contiguous ``(B, max_len, ...)`` strip per slot, every
per-position cache leaf becomes a shared pool of fixed-size pages
``(num_pages, page_size, ...)``; a per-slot page table ``(B, P)`` of
physical page ids maps each slot's logical positions onto the pool.  A
slot then only ties up ``ceil(need / page_size)`` pages — short and long
requests share HBM instead of every slot reserving ``max_len`` positions.

The addressing runs INSIDE the jitted step:

* ``gather_pages`` materializes a slot-major ``(B, T, ...)`` view of the
  pool that is element-for-element the contiguous cache layout, so the
  attention math downstream of it is the *same code* (same masks, same
  reductions) as the contiguous path — that is what makes paged reads
  bit-identical to the contiguous baseline.  (A TPU production path
  would fuse the gather into a paged-attention kernel; this is the
  HLO-level expression of the same addressing.)
* ``scatter_rows`` / ``scatter_chunk`` write decode tokens / prefill
  chunks through the page table with ``mode="drop"`` masking, so rows
  that are not live (or padded chunk tails) write nothing — there is no
  trash page, and a freed-and-reallocated page never sees stray writes
  from its old owner.

Two logical cache classes share one pool geometry: full-length caches
(GQA without a window, MLA) with ``len_linear`` positions per slot, and
sliding-window ring buffers with ``len_swa`` positions.  They use
separate page tables because a slot needs a different page count in
each; ring slots keep the contiguous path's ``pos % len_swa`` addressing
on top of the table.

Page *allocation* is host-side policy and lives with the serving engine
(``repro.serving.paging``); this module is only the device-side layout.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Static geometry of a paged cache (closed over by jitted programs).

    ``len_linear`` / ``len_swa`` are the LOGICAL positions per slot (what
    the contiguous layout would allocate: ``max_len``, and
    ``min(max_len, sliding_window)``); ``num_pages`` / ``num_pages_swa``
    size the physical pools.  ``len_swa = 0`` means no sliding-window
    caches in the model.
    """
    page_size: int
    len_linear: int
    num_pages: int
    len_swa: int = 0
    num_pages_swa: int = 0

    def __post_init__(self):
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {self.num_pages}")

    @property
    def pages_per_slot(self) -> int:
        """Page-table width for full-length caches."""
        return -(-self.len_linear // self.page_size)

    @property
    def pages_per_slot_swa(self) -> int:
        """Page-table width for sliding-window ring caches."""
        return -(-self.len_swa // self.page_size)

    def pages_for(self, positions: int) -> int:
        """Pages a slot must hold to cover ``positions`` cache positions."""
        return -(-min(positions, self.len_linear) // self.page_size)


def gather_pages(pool, table, length: int):
    """Slot-major view of a paged pool: (num_pages, ps, ...) -> (B, length, ...).

    ``view[b, t] == pool[table[b, t // ps], t % ps]`` — exactly the
    contiguous cache layout for slot b, so downstream attention math is
    unchanged.  Logical pages past a slot's allocation read whatever page
    their table entry names (0 when unallocated); callers mask those
    positions exactly like the contiguous path masks unwritten ones.

    Trailing-page semantics (audited; the kernel read path reproduces
    them exactly): ``length`` is NOT required to be a page multiple.  The
    last page a slot uses is always read IN FULL and then sliced —
    ``length % ps != 0`` means positions in ``[length - length % ps,
    length)`` come from a page whose tail entries (``>= length % ps``)
    are cut off by the ``[:, :length]`` slice, while a ``length`` exactly
    on a page boundary reads its final page whole with nothing sliced.
    Either way every position ``t < length`` that the slot has not yet
    WRITTEN (``t > pos[b]``) still appears in the view — as stale pool
    contents or page-0 rows — and is hidden downstream by the causal
    ``idx <= pos`` mask, never by this function.  The in-kernel path
    (repro.kernels.paged_attention) mirrors this by fetching whole pages
    into scratch, slicing ``[:length]``, and applying the identical mask,
    so both boundary parities are covered by the same regression tests
    (tests/test_paged_kernel.py).
    """
    B, P = table.shape
    ps = pool.shape[1]
    view = pool[table]                                   # (B, P, ps, ...)
    return view.reshape(B, P * ps, *pool.shape[2:])[:, :length]


def scatter_rows(pool, table, slots, vals, *, live=None):
    """Write one position per slot: vals (B, 1, ...) at logical slot (B,).

    Rows where ``live`` is False write nothing (offset pushed past the
    page -> ``mode="drop"``), so done/empty/mid-prefill rows never touch
    pages they do not own.
    """
    B, P = table.shape
    ps = pool.shape[1]
    page = jnp.take_along_axis(table, jnp.clip(slots // ps, 0, P - 1)[:, None],
                               axis=1)[:, 0]
    # positions past the table (e.g. pos == max_len) DROP, exactly like the
    # contiguous layout's slot -> T scatter — never remap into the last page
    off = jnp.where(slots < P * ps, slots % ps, ps)
    if live is not None:
        off = jnp.where(live, off, ps)                   # out of page -> drop
    return pool.at[page, off].set(vals[:, 0], mode="drop")


def scatter_chunk(pool, table, slots, valid, vals):
    """Write a prefill chunk: vals (B, C, ...) at logical slots (B, C).

    ``valid`` (B, C) marks real tokens; padded tails are dropped.  Chunk
    positions are distinct within a row and rows own disjoint pages, so
    the scatter has no write collisions.
    """
    B, P = table.shape
    ps = pool.shape[1]
    lp = jnp.clip(slots // ps, 0, P - 1)
    page = jnp.take_along_axis(table, lp, axis=1)        # (B, C)
    off = jnp.where(valid & (slots < P * ps), slots % ps, ps)  # else drop
    return pool.at[page, off].set(vals, mode="drop")
