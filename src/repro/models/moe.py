"""Mixture-of-Experts layer: top-k router with capacity, scatter dispatch.

Production layout: expert params carry a leading E axis that the sharding
rules place on the "model" mesh axis (expert parallelism); the dispatch
scatter/gather then lowers to all-to-all under GSPMD.

Dispatch is the Switch/GShard capacity scheme: tokens beyond
capacity = ceil(top_k * N / E * capacity_factor) are dropped (their residual
passes through).  FLOPs are therefore proportional to *active* experts,
which keeps the roofline's MODEL_FLOPS/HLO_FLOPs ratio honest.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_mlp, dense_init, init_mlp


def init_moe(rng, d_model: int, d_ff: int, num_experts: int, *,
             num_shared_experts: int = 0, dtype=jnp.float32):
    ks = jax.random.split(rng, 5)
    E = num_experts
    scale_in = d_model ** -0.5
    scale_ff = d_ff ** -0.5
    p = {
        "router": dense_init(ks[0], d_model, E, dtype),
        "w_gate": (jax.random.normal(ks[1], (E, d_model, d_ff)) * scale_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d_model, d_ff)) * scale_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, d_ff, d_model)) * scale_ff).astype(dtype),
    }
    if num_shared_experts:
        p["shared"] = init_mlp(ks[4], d_model, d_ff * num_shared_experts, dtype=dtype)
    return p


def apply_moe(p, x: jax.Array, *, top_k: int, capacity_factor: float = 1.25):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E = p["router"].shape[-1]
    N = B * S
    xf = x.reshape(N, d)

    logits = (xf @ p["router"]).astype(jnp.float32)          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)      # (N, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- capacity dispatch -------------------------------------------------
    cap = max(int(top_k * N * capacity_factor / E), 1)
    e_flat = expert_idx.reshape(-1)                          # (N*k,)
    oh = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)          # (N*k, E)
    pos = (jnp.cumsum(oh, axis=0) - 1)                       # running count per expert
    pos_in_e = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]
    keep = pos_in_e < cap
    dest = jnp.where(keep, e_flat * cap + pos_in_e, E * cap)  # overflow slot

    src = jnp.repeat(xf, top_k, axis=0)                      # (N*k, d) token copies
    dispatched = jnp.zeros((E * cap + 1, d), xf.dtype).at[dest].add(src)
    dispatched = dispatched[:-1].reshape(E, cap, d)

    # ---- expert FFN (batched over E; E axis is expert-parallel) ------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", dispatched, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", dispatched, p["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])       # (E, cap, d)

    # ---- combine ------------------------------------------------------------
    out_flat = jnp.concatenate([out_e.reshape(E * cap, d),
                                jnp.zeros((1, d), xf.dtype)], axis=0)
    gathered = out_flat[dest]                                # (N*k, d)
    w = (gate_vals.reshape(-1) * keep).astype(xf.dtype)
    y = (gathered * w[:, None]).reshape(N, top_k, d).sum(axis=1)

    if "shared" in p:
        y = y + apply_mlp(p["shared"], xf)

    # ---- Switch-style load-balance auxiliary loss ---------------------------
    frac_tokens = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = probs.mean(axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)

    return y.reshape(B, S, d), aux
