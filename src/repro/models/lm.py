"""Top-level models: CausalLM (dense/moe/hybrid/ssm/vlm) and EncDecLM (audio).

Pure-functional: `init_lm_params` builds the param pytree (usable under
jax.eval_shape for allocation-free dry-runs), `lm_loss` / `decode_step` are
the train/serve entry points the launchers jit.

C3-SL integration (single-program mode): when a codec is supplied, the layer
stack is split at the superblock midpoint; the cut activation (B, S, d) is
flattened to (B, S*d) per-sample features and round-tripped through the
codec — batch-wise grouping over B, exactly the paper's Algorithm 1 with
D = S*d_model.  (The pod-pipeline mode in repro.core.split does the same
across the pod mesh axis with the payload on the wire.)

Modality frontends (vlm/audio) are stubs per the brief: batches carry
precomputed patch/frame embeddings; a linear projector maps them to d_model.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import stack as stack_lib
from repro.models.layers import embed_init, dense_init, softmax_cross_entropy
from repro.models.stack import _apply_norm, _init_norm

ENC_PATTERN = (("attn", "mlp"),)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_lm_params(rng, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(rng, 8)
    p: dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "stack": stack_lib.init_stack(ks[1], cfg, dtype),
        "final_norm": _init_norm(cfg, dtype),
        "head": dense_init(ks[2], cfg.d_model, cfg.vocab_size, dtype),
    }
    if cfg.first_dense_layers:
        p["first"] = stack_lib.init_superblock(ks[3], cfg, dtype, dense_mlp=True)
    if cfg.frontend:
        p["frontend_proj"] = dense_init(ks[4], cfg.frontend_dim, cfg.d_model, dtype)
    if cfg.is_encdec:
        import dataclasses
        enc_cfg = dataclasses.replace(cfg, block_pattern=ENC_PATTERN,
                                      num_layers=cfg.encoder_layers,
                                      first_dense_layers=0)
        p["encoder"] = {"stack": stack_lib.init_stack(ks[5], enc_cfg, dtype),
                        "norm": _init_norm(cfg, dtype)}
    return p


def abstract_params(cfg: ModelConfig, dtype=jnp.float32):
    """ShapeDtypeStruct params — no allocation (dry-run path)."""
    return jax.eval_shape(lambda r: init_lm_params(r, cfg, dtype),
                          jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _encoder_cfg(cfg: ModelConfig):
    import dataclasses
    return dataclasses.replace(cfg, block_pattern=ENC_PATTERN,
                               num_layers=cfg.encoder_layers, first_dense_layers=0)


def _embed_inputs(params, cfg: ModelConfig, batch):
    """Token (+frontend) embedding.  Returns (h (B,S,d), positions (B,S))."""
    tokens = batch["tokens"]
    h = params["embed"][tokens]
    if cfg.frontend and not cfg.is_encdec:
        # VLM: [patch embeddings ; text tokens], total length = frontend_seq + S_text
        fe = batch["frontend"] @ params["frontend_proj"]
        h = jnp.concatenate([fe.astype(h.dtype), h], axis=1)
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    return h, positions


def _run_encoder(params, cfg: ModelConfig, frontend_emb, remat=True):
    enc_cfg = _encoder_cfg(cfg)
    h = frontend_emb @ params["frontend_proj"]
    B, S = h.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    h, _ = stack_lib.apply_stack(params["encoder"]["stack"], enc_cfg, h, positions,
                                 remat=remat)
    return _apply_norm(cfg, params["encoder"]["norm"], h)


def _split_stacked(stacked, n_front: int):
    front = jax.tree.map(lambda a: a[:n_front], stacked)
    back = jax.tree.map(lambda a: a[n_front:], stacked)
    return front, back


def lm_forward(params, batch, cfg: ModelConfig, *, codec=None, codec_params=None,
               sliding_window=None, remat=True, last_only=False,
               with_metrics=False, bwd_probe=None, erasure=None):
    """Returns (logits (B,S,V), aux_loss) — or (logits, aux_loss, metrics)
    with ``with_metrics=True``, where metrics carries ``cut_snr`` (the
    retrieval SNR in dB at the cut layer, the Adaptive-R controller's signal;
    absent without a codec).  last_only=True slices the final position BEFORE
    the head matmul (serving prefill: never materializes the (B, S, V)
    logits).

    ``codec`` may be a static codec or a static ``repro.transport.SplitLink``
    (per-direction cut-layer codecs); for an asymmetric link, ``bwd_probe``
    is the gradient-SNR tap — differentiate the loss w.r.t. it and the
    resulting "gradient" is the measured server→client gradient-retrieval
    SNR in dB (see ``repro.transport.channel.grad_roundtrip``).

    ``erasure`` (``{"fwd": keep[, "bwd": keep]}``) injects cut-payload loss
    into the round-trip: masks are runtime arguments with bucket-static
    shapes (see ``repro.transport.link.roundtrip``), ``None`` is
    structurally the pre-fault trace."""
    sliding_window = sliding_window if sliding_window is not None else cfg.sliding_window
    memory = None
    if cfg.is_encdec:
        memory = _run_encoder(params, cfg, batch["frontend"], remat=remat)
    h, positions = _embed_inputs(params, cfg, batch)
    aux = jnp.array(0.0, jnp.float32)
    if cfg.first_dense_layers:
        h, a = stack_lib.apply_superblock(params["first"], cfg, h, positions,
                                          memory=memory, sliding_window=sliding_window)
        aux = aux + a

    run = functools.partial(stack_lib.apply_stack, cfg=cfg, positions=positions,
                            memory=memory, sliding_window=sliding_window, remat=remat)
    metrics = {}
    if codec is None:
        h, a = run(params["stack"], h=h)
        aux = aux + a
    else:
        n_cut = cfg.num_superblocks // 2
        front, back = _split_stacked(params["stack"], n_cut)
        h, a1 = run(front, h=h)
        B, S, d = h.shape
        Zf = h.reshape(B, S * d)
        from repro.transport.link import roundtrip
        if with_metrics:
            Zhat, snr = roundtrip(codec, codec_params, Zf, with_snr=True,
                                  bwd_probe=bwd_probe, erasure=erasure)
            metrics["cut_snr"] = snr
        else:
            Zhat = roundtrip(codec, codec_params, Zf, bwd_probe=bwd_probe,
                             erasure=erasure)
        h = Zhat.reshape(B, S, d)
        h, a2 = run(back, h=h)
        aux = aux + a1 + a2

    if last_only:
        h = h[:, -1:, :]
    h = _apply_norm(cfg, params["final_norm"], h)
    logits = h @ params["head"]
    if with_metrics:
        return logits, aux, metrics
    return logits, aux


def lm_loss(params, batch, cfg: ModelConfig, *, codec=None, codec_params=None,
            sliding_window=None, remat=True, with_metrics=False,
            bwd_probe=None, erasure=None):
    """Mean next-token CE (+ MoE aux).  labels == -1 are masked (vlm pads
    frontend positions).  ``with_metrics=True`` returns (loss, metrics) with
    the cut-layer ``cut_snr`` (see lm_forward) — the signal the Adaptive-R
    codec scheduler consumes in repro.launch.train.  ``codec`` may be a
    static ``SplitLink``; ``bwd_probe`` taps the gradient-retrieval SNR and
    ``erasure`` injects cut-payload loss (see lm_forward)."""
    out = lm_forward(params, batch, cfg, codec=codec,
                     codec_params=codec_params,
                     sliding_window=sliding_window, remat=remat,
                     with_metrics=with_metrics, bwd_probe=bwd_probe,
                     erasure=erasure)
    logits, aux = out[0], out[1]
    labels = batch["labels"]
    if cfg.frontend and not cfg.is_encdec:
        pad = jnp.full((labels.shape[0], cfg.frontend_seq), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    mask = labels >= 0
    ce = softmax_cross_entropy(logits, jnp.maximum(labels, 0), mask)
    loss = ce + cfg.aux_loss_weight * aux
    if with_metrics:
        return loss, out[2]
    return loss


# ---------------------------------------------------------------------------
# serving (one-token decode with cache)
# ---------------------------------------------------------------------------

def init_decode_cache(params, cfg: ModelConfig, batch: int, length: int,
                      dtype=jnp.float32, frontend_emb=None, paged=None):
    """Decode cache pytree.  With ``paged`` (a repro.models.paging.PagedLayout)
    the per-position leaves become shared page pools and the cache carries
    the per-slot page tables under "pages" (full-length caches) and
    "pages_swa" (sliding-window rings) — int32 (B, P) arrays of physical
    page ids the serving engine rewrites at admit/retire boundaries."""
    cache: dict[str, Any] = {
        "stack": stack_lib.init_stack_cache(cfg, batch, length, dtype,
                                            paged=paged)}
    if cfg.first_dense_layers:
        cache["first"] = stack_lib.init_superblock_cache(cfg, batch, length,
                                                         dtype, paged=paged)
    if cfg.is_encdec:
        assert frontend_emb is not None
        cache["memory"] = _run_encoder(params, cfg, frontend_emb, remat=False)
    if paged is not None:
        cache["pages"] = jnp.zeros((batch, paged.pages_per_slot), jnp.int32)
        if paged.len_swa:
            cache["pages_swa"] = jnp.zeros((batch, paged.pages_per_slot_swa),
                                           jnp.int32)
    return cache


def abstract_decode_cache(cfg: ModelConfig, batch: int, length: int,
                          dtype=jnp.float32):
    """Cache ShapeDtypeStructs without touching params (dry-run path)."""
    cache: dict[str, Any] = {
        "stack": jax.eval_shape(
            lambda: stack_lib.init_stack_cache(cfg, batch, length, dtype))}
    if cfg.first_dense_layers:
        cache["first"] = jax.eval_shape(
            lambda: stack_lib.init_superblock_cache(cfg, batch, length, dtype))
    if cfg.is_encdec:
        cache["memory"] = jax.ShapeDtypeStruct(
            (batch, cfg.frontend_seq, cfg.d_model), dtype)
    return cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig, *,
                codec=None, codec_params=None, paged=None, live=None,
                return_cut=False, kv_read="gather"):
    """tokens (B, 1) int32; pos scalar int32.  Returns (logits (B,1,V), cache').

    With a codec, the cut-layer feature (B, d_model) is compressed batch-wise
    across the decode batch — the serving-path C3-SL integration.  ``paged``
    (static PagedLayout, matching the cache built with it) switches the
    per-position cache leaves to pool+page-table addressing; ``live`` (B,)
    masks every cache/state write for rows that are not decoding AND zeroes
    their cut-layer contribution to the batch-wise codec, so a dead slot's
    stale cache state can never perturb live rows through cross-talk.

    ``return_cut=True`` (static) additionally returns the (B, d_model)
    cut-layer feature exactly as it enters ``codec.encode`` — the
    post-live-mask tensor — so the sanitizer tier can check the
    superposition-hygiene invariant (dead rows contribute exactly zero)
    against the REAL code path rather than a reimplementation.  None on
    the codec-free path, which has no cut.

    ``kv_read="kernel"`` (static) routes the stacked superblocks' GQA cache
    reads through the Pallas paged-attention kernel (bit-identical to the
    gather read — see repro.kernels.paged_attention).  The unstacked
    first-dense superblock stays on the gather read: its cache is a
    separate, non-scanned pytree the kernel tier does not cover yet.
    """
    h = params["embed"][tokens]
    memory = cache.get("memory")
    pages, pages_swa = cache.get("pages"), cache.get("pages_swa")
    kw = dict(memory=memory, paged=paged, pages=pages, pages_swa=pages_swa,
              live=live)
    new_cache = dict(cache)
    cut = None
    if cfg.first_dense_layers:
        h, new_cache["first"] = stack_lib.apply_superblock_decode(
            params["first"], cache["first"], cfg, h, pos, **kw)

    if codec is None:
        h, new_cache["stack"] = stack_lib.apply_stack_decode(
            params["stack"], cache["stack"], cfg, h, pos, kv_read=kv_read,
            **kw)
    else:
        n_cut = cfg.num_superblocks // 2
        p_front, p_back = _split_stacked(params["stack"], n_cut)
        c_front, c_back = _split_stacked(cache["stack"], n_cut)
        h, nc_front = stack_lib.apply_stack_decode(p_front, c_front, cfg, h, pos,
                                                   kv_read=kv_read, **kw)
        B, _, d = h.shape
        if live is not None:
            # A non-live row's cut-layer feature is attention over whatever
            # its (possibly stale) page-table rows point at — i.e. garbage
            # that depends on allocation history.  It must not leak into the
            # batch-wise superposition: zero it so dead slots contribute
            # nothing and live outputs are a function of live state only.
            h = jnp.where(live[:, None, None], h, 0.0)
        cut = h.reshape(B, d)
        payload = codec.encode(codec_params, cut)
        h = codec.decode(codec_params, payload).reshape(B, 1, d)
        h, nc_back = stack_lib.apply_stack_decode(p_back, c_back, cfg, h, pos,
                                                  kv_read=kv_read, **kw)
        new_cache["stack"] = jax.tree.map(
            lambda f, b: jnp.concatenate([f, b], axis=0), nc_front, nc_back)

    h = _apply_norm(cfg, params["final_norm"], h)
    if return_cut:
        return h @ params["head"], new_cache, cut
    return h @ params["head"], new_cache


# ---------------------------------------------------------------------------
# serving (chunked prefill: C prompt tokens per dispatch)
# ---------------------------------------------------------------------------

def chunk_forward(params, cache, tokens, pos, cfg: ModelConfig, *,
                  codec=None, codec_params=None, valid=None, paged=None):
    """Shared C-positions-per-dispatch forward: the write path under both
    chunked prefill and the speculative verify/commit round.

    tokens (B,C) int32; pos (B,) int32 per-row start positions; valid (B,C)
    bool marks real tokens — False entries (ragged chunk tails, rows that
    are not ingesting, rejected draft positions) write nothing to the KV
    cache and advance no recurrent state.  Returns
    ``(h, new_cache, cut_seq)`` with ``h`` (B,C,d) the PRE-NORM final
    hidden states and ``cut_seq`` the (B,C,d) cut-layer features exactly
    as they entered the codec (post valid-mask; None without a codec).

    With a codec, the cut-layer features are compressed batch-wise PER
    POSITION: transposing into the ``sequence_group_encode`` layout
    (C,B,d) makes each group of R consecutive rows R slots at the same
    position — the same group shape the decode path forms from its (B,d)
    features (B divisible by R).  Non-valid positions contribute exact
    ZEROS to the superposition — mirroring decode's ``live`` masking — so
    padding and rejected speculation never inject cache-history-dependent
    cross-talk.
    """
    B, C = tokens.shape
    if valid is None:
        valid = jnp.ones((B, C), bool)
    h = params["embed"][tokens]
    memory = cache.get("memory")
    pages, pages_swa = cache.get("pages"), cache.get("pages_swa")
    kw = dict(memory=memory, paged=paged, pages=pages, pages_swa=pages_swa)
    new_cache = dict(cache)
    cut_seq = None
    if cfg.first_dense_layers:
        h, new_cache["first"] = stack_lib.apply_superblock_prefill(
            params["first"], cache["first"], cfg, h, pos, valid, **kw)

    if codec is None:
        h, new_cache["stack"] = stack_lib.apply_stack_prefill(
            params["stack"], cache["stack"], cfg, h, pos, valid, **kw)
    else:
        from repro.codecs.c3sl import (sequence_group_decode,
                                       sequence_group_encode)
        n_cut = cfg.num_superblocks // 2
        p_front, p_back = _split_stacked(params["stack"], n_cut)
        c_front, c_back = _split_stacked(cache["stack"], n_cut)
        h, nc_front = stack_lib.apply_stack_prefill(p_front, c_front, cfg, h,
                                                    pos, valid, **kw)
        # same containment as decode_step: positions that are not real
        # prompt tokens (idle slots, ragged chunk tails) carry garbage
        # features that would otherwise superpose onto live rows — and vary
        # with cache/page history.  Zero them before the encode.
        h = jnp.where(valid[:, :, None], h, 0.0)
        cut_seq = h
        payload = sequence_group_encode(codec, codec_params, h.swapaxes(0, 1))
        h = sequence_group_decode(codec, codec_params, payload,
                                  C, B).swapaxes(0, 1)
        h, nc_back = stack_lib.apply_stack_prefill(p_back, c_back, cfg, h,
                                                   pos, valid, **kw)
        new_cache["stack"] = jax.tree.map(
            lambda f, b: jnp.concatenate([f, b], axis=0), nc_front, nc_back)
    return h, new_cache, cut_seq


def prefill_chunk(params, cache, tokens, pos, cfg: ModelConfig, *,
                  codec=None, codec_params=None, valid=None, paged=None):
    """Ingest C prompt tokens per row in ONE dispatch (vs C decode dispatches).

    tokens (B,C) int32; pos (B,) int32 per-row start positions; valid (B,C)
    bool marks real tokens — False entries (ragged chunk tails, or rows that
    are not prefilling at all) write nothing to the KV cache and advance no
    recurrent state.  Returns (logits (B,V) at each row's LAST VALID
    position, new_cache); rows with no valid token get garbage logits the
    caller must ignore.

    Chunked prefill reproduces prefill-as-decode outputs token-for-token
    when the codec group CONTENTS also match, i.e. every slot ingests in
    lockstep (full batch, equal prompt lengths); with ragged prompts the
    two paths group different LIVE contents per step, so outputs agree
    only up to codec cross-talk — same as any occupancy change does under
    batch-wise compression.  See :func:`chunk_forward` for the masking
    and per-position grouping semantics.
    """
    B, C = tokens.shape
    if valid is None:
        valid = jnp.ones((B, C), bool)
    h, new_cache, _ = chunk_forward(params, cache, tokens, pos, cfg,
                                    codec=codec, codec_params=codec_params,
                                    valid=valid, paged=paged)
    last = jnp.maximum(valid.sum(-1).astype(jnp.int32) - 1, 0)
    h_last = h[jnp.arange(B), last]                              # (B,d)
    h_last = _apply_norm(cfg, params["final_norm"], h_last)
    return h_last @ params["head"], new_cache


def verify_chunk(params, cache, tokens, pos, cfg: ModelConfig, *,
                 codec=None, codec_params=None, valid=None, paged=None):
    """Speculative VERIFY phase: k-position forward, per-position logits,
    cache writes DISCARDED.

    tokens (B,k) carries each row's last verified token followed by its
    k-1 draft proposals; ``valid`` should mark live rows (all k positions
    — acceptance is decided from the returned logits, after the fact).
    Returns ``(logits (B,k,V), feat (B,k,d))`` where ``feat`` is the
    cut-layer feature sequence (post valid-mask, exactly what the codec
    encoded) or, without a codec, the pre-norm final hidden states — the
    position-(e-1) row of it is the draft head's feedback feature for the
    next round.

    The updated cache is intentionally NOT returned: no speculative write
    may survive — the commit phase re-ingests only the accepted prefix
    through :func:`chunk_forward` with a ``j < e`` valid mask, so
    rejection rollback is pure position truncation and partially-written
    pages can never leak into later superpositions.  Per-position logits
    at position j are exact (equal to vanilla decode's) whenever every
    earlier position's input matched vanilla's — the acceptance rule only
    consumes logits inside that prefix.
    """
    h, _, cut_seq = chunk_forward(params, cache, tokens, pos, cfg,
                                  codec=codec, codec_params=codec_params,
                                  valid=valid, paged=paged)
    feat = cut_seq if codec is not None else h
    hn = _apply_norm(cfg, params["final_norm"], h)
    return hn @ params["head"], feat


# ---------------------------------------------------------------------------
# pod-pipeline adapter (repro.core.split.make_pod_pipeline_loss_fn callables)
# ---------------------------------------------------------------------------

def make_pipeline_fns(cfg: ModelConfig):
    """(embed_fn, stage_fn, head_loss_fn) for the 2-stage pod pipeline.

    `params["blocks"]` must be the stacked superblocks reshaped to a leading
    stage axis of 2: tree.map(lambda a: a.reshape(2, N//2, *a.shape[1:])).
    """

    def embed_fn(embed_p, x_mb):
        h = embed_p["embed"][x_mb]
        return h

    def stage_fn(blocks_local, h):
        B, S = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        h, _ = stack_lib.apply_stack(blocks_local, cfg, h, positions, remat=True)
        return h

    def head_loss_fn(head_p, h, y_mb):
        h = _apply_norm(cfg, head_p["final_norm"], h)
        logits = h @ head_p["head"]
        return softmax_cross_entropy(logits, jnp.maximum(y_mb, 0), y_mb >= 0)

    return embed_fn, stage_fn, head_loss_fn


def split_stack_for_pipeline(stacked, n_stages: int = 2):
    return jax.tree.map(
        lambda a: a.reshape(n_stages, a.shape[0] // n_stages, *a.shape[1:]), stacked)
