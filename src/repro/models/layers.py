"""Shared neural-net building blocks (pure functions, explicit params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Initializer = jax.nn.initializers.Initializer


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(rng, d_in: int, d_out: int, dtype=jnp.float32):
    return (jax.random.normal(rng, (d_in, d_out), jnp.float32) * d_in ** -0.5).astype(dtype)


def embed_init(rng, vocab: int, d: int, dtype=jnp.float32):
    return (jax.random.normal(rng, (vocab, d), jnp.float32) * d ** -0.5).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(rng, d_model: int, d_ff: int, gated: bool = True, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(rng, 3)
    p = {"w_up": dense_init(k1, d_model, d_ff, dtype),
         "w_down": dense_init(k2, d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(k3, d_model, d_ff, dtype)
    return p


def apply_mlp(p, x: jax.Array) -> jax.Array:
    up = x @ p["w_up"]
    if "w_gate" in p:
        up = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        up = jax.nn.gelu(up)
    return up @ p["w_down"]


# ---------------------------------------------------------------------------
# RoPE (full / partial / GLM "2d" = partial-0.5)
# ---------------------------------------------------------------------------

def rope_frequencies(rotary_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, rotary_dim, 2, dtype=jnp.float32) / rotary_dim))


def apply_rope(x: jax.Array, positions: jax.Array, rotary_dim: int,
               theta: float = 10000.0) -> jax.Array:
    """x (..., S, H, hd); positions (..., S). Rotates the first rotary_dim dims."""
    if rotary_dim == 0:
        return x
    dt = x.dtype
    freqs = rope_frequencies(rotary_dim, theta)             # (rot/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    cos = jnp.cos(angles)[..., :, None, :]                  # (..., S, 1, rot/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x_rot, x_pass = x[..., :rotary_dim], x[..., rotary_dim:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(dt), x_pass], axis=-1) if x_pass.shape[-1] \
        else out.astype(dt)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: jax.Array | None = None) -> jax.Array:
    """logits (..., V), labels (...) int — mean CE over unmasked positions.

    One-hot-einsum formulation (t5x-style): under a vocab-sharded head this
    partitions cleanly (partial sums + small all-reduce) instead of the
    all-gather a take_along_axis gather would force.
    """
    V = logits.shape[-1]
    m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted.astype(jnp.float32)), axis=-1))
    onehot = jax.nn.one_hot(labels, V, dtype=logits.dtype)
    picked = jnp.einsum("...v,...v->...", shifted, onehot,
                        preferred_element_type=jnp.float32)
    ll = picked - lse
    if mask is None:
        return -ll.mean()
    mask = mask.astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
