"""VGG-16 and ResNet-50 split models — the paper's experimental setup.

Split points (C3-SL Sec. 4.1, confirmed by its Table 1 parameter counts):
  * VGG-16 on CIFAR-10:  split at the 4th max-pool -> cut feature
    (512, 2, 2), D = 2048  (paper: R*D params, R=2 -> 4.1e3  ✓)
  * ResNet-50 on CIFAR-100: split at the output of the 3rd residual stage
    (ImageNet-style stem) -> cut feature (1024, 2, 2), D = 4096
    (paper: R=2 -> 8.2e3 ✓)

BatchNorm runs in batch-stats mode (no running averages) — sufficient for
the reproduction experiments and keeps the params pure.
Layout NCHW throughout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def conv2d(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding, dimension_numbers=("NCHW", "OIHW", "NCHW"))


def _bn(x, p):
    mean = x.mean(axis=(0, 2, 3), keepdims=True)
    var = x.var(axis=(0, 2, 3), keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + 1e-5)
    return xn * p["scale"][None, :, None, None] + p["bias"][None, :, None, None]


def _init_conv(rng, c_in, c_out, k):
    fan = c_in * k * k
    return jax.random.normal(rng, (c_out, c_in, k, k)) * (2.0 / fan) ** 0.5


def _init_bn(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def max_pool(x, k=2):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 1, k, k), (1, 1, k, k), "VALID")


# ---------------------------------------------------------------------------
# VGG-16
# ---------------------------------------------------------------------------

VGG16_LAYOUT = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
                512, 512, 512, "M", 512, 512, 512, "M"]
VGG_SPLIT_AFTER_POOL = 4  # paper: output of the 4th max-pool


def init_vgg16(rng, n_classes: int = 10, in_ch: int = 3):
    params = {"convs": [], "bns": []}
    c = in_ch
    for item in VGG16_LAYOUT:
        if item == "M":
            continue
        rng, k = jax.random.split(rng)
        params["convs"].append(_init_conv(k, c, item, 3))
        params["bns"].append(_init_bn(item))
        c = item
    rng, k = jax.random.split(rng)
    params["fc"] = {"w": jax.random.normal(k, (512, n_classes)) * 512 ** -0.5,
                    "b": jnp.zeros((n_classes,))}
    return params


def _vgg_convs(params, x, start_pool: int, end_pool: int):
    """Run VGG conv layers between max-pool counts [start_pool, end_pool)."""
    ci = 0
    pools = 0
    for item in VGG16_LAYOUT:
        if item == "M":
            if start_pool <= pools < end_pool:
                x = max_pool(x)
            pools += 1
            continue
        if start_pool <= pools < end_pool:
            x = jax.nn.relu(_bn(conv2d(x, params["convs"][ci]), params["bns"][ci]))
        ci += 1
    return x


def vgg16_front(params, x):
    """x (B,3,32,32) -> cut feature (B, 512, 2, 2)."""
    return _vgg_convs(params, x, 0, VGG_SPLIT_AFTER_POOL)


def vgg16_back(params, z):
    x = _vgg_convs(params, z, VGG_SPLIT_AFTER_POOL, 5)
    x = x.mean(axis=(2, 3))  # (B, 512)
    return x @ params["fc"]["w"] + params["fc"]["b"]


VGG_CUT_SHAPE = (512, 2, 2)   # D = 2048


# ---------------------------------------------------------------------------
# ResNet-50
# ---------------------------------------------------------------------------

RESNET50_STAGES = (3, 4, 6, 3)
RESNET50_WIDTHS = (64, 128, 256, 512)  # bottleneck mid-widths; out = 4x


def _init_bottleneck(rng, c_in, width, stride):
    ks = jax.random.split(rng, 4)
    p = {
        "conv1": _init_conv(ks[0], c_in, width, 1), "bn1": _init_bn(width),
        "conv2": _init_conv(ks[1], width, width, 3), "bn2": _init_bn(width),
        "conv3": _init_conv(ks[2], width, width * 4, 1), "bn3": _init_bn(width * 4),
    }
    if stride != 1 or c_in != width * 4:
        p["proj"] = _init_conv(ks[3], c_in, width * 4, 1)
        p["bn_proj"] = _init_bn(width * 4)
    return p


def _apply_bottleneck(p, x, stride):
    y = jax.nn.relu(_bn(conv2d(x, p["conv1"]), p["bn1"]))
    y = jax.nn.relu(_bn(conv2d(y, p["conv2"], stride=stride), p["bn2"]))
    y = _bn(conv2d(y, p["conv3"]), p["bn3"])
    if "proj" in p:
        x = _bn(conv2d(x, p["proj"], stride=stride), p["bn_proj"])
    return jax.nn.relu(x + y)


def init_resnet50(rng, n_classes: int = 100, in_ch: int = 3):
    rng, k = jax.random.split(rng)
    params = {"stem": _init_conv(k, in_ch, 64, 7), "bn_stem": _init_bn(64),
              "stages": []}
    c = 64
    for si, (n_blocks, width) in enumerate(zip(RESNET50_STAGES, RESNET50_WIDTHS)):
        blocks = []
        for bi in range(n_blocks):
            rng, k = jax.random.split(rng)
            stride = 2 if (bi == 0 and si > 0) else 1
            blocks.append(_init_bottleneck(k, c, width, stride))
            c = width * 4
        params["stages"].append(blocks)
    rng, k = jax.random.split(rng)
    params["fc"] = {"w": jax.random.normal(k, (2048, n_classes)) * 2048 ** -0.5,
                    "b": jnp.zeros((n_classes,))}
    return params


def _resnet_stage(params, x, si):
    for bi, bp in enumerate(params["stages"][si]):
        stride = 2 if (bi == 0 and si > 0) else 1
        x = _apply_bottleneck(bp, x, stride)
    return x


def resnet50_front(params, x):
    """x (B,3,32,32) -> cut (B, 1024, 2, 2): stem + stages 1-3."""
    x = jax.nn.relu(_bn(conv2d(x, params["stem"], stride=2), params["bn_stem"]))
    x = max_pool(x)                 # 32 -> 16 -> 8
    for si in range(3):
        x = _resnet_stage(params, x, si)   # 8 -> 8 -> 4 -> 2
    return x


def resnet50_back(params, z):
    x = _resnet_stage(params, z, 3)
    x = x.mean(axis=(2, 3))
    return x @ params["fc"]["w"] + params["fc"]["b"]


RESNET_CUT_SHAPE = (1024, 2, 2)  # D = 4096


# conv feature D values the paper's Table 1 analytics use
VGG_D = 512 * 2 * 2        # 2048
RESNET_D = 1024 * 2 * 2    # 4096
