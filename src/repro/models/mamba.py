"""Mamba (S6) selective state-space block.

Train/prefill path: chunked associative scan (jax.lax.associative_scan inside
a remat'd lax.scan over chunks) so peak memory is O(B * chunk * d_inner *
d_state) instead of O(B * S * ...).  Decode path: O(1) recurrent state
{h (B, d_inner, d_state), conv (B, d_conv-1, d_inner)}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_mamba(rng, d_model: int, d_inner: int, *, d_state: int = 16,
               d_conv: int = 4, dt_rank: int | None = None, dtype=jnp.float32):
    dt_rank = dt_rank or max(d_model // 16, 1)
    ks = jax.random.split(rng, 6)
    return {
        "w_in": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner)) * d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_x": dense_init(ks[2], d_inner, dt_rank + 2 * d_state, dtype),
        "w_dt": dense_init(ks[3], dt_rank, d_inner, dtype),
        "dt_bias": jnp.zeros((d_inner,), dtype),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                                          (d_inner, d_state))).astype(dtype),
        "D": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[4], d_inner, d_model, dtype),
    }


def _ssm_inputs(p, x_conv, *, d_state: int):
    """x_conv (B, S, di) -> dt, Bmat, Cmat, A."""
    dt_rank = p["w_dt"].shape[0]
    proj = x_conv @ p["w_x"]
    dt_low = proj[..., :dt_rank]
    Bmat = proj[..., dt_rank:dt_rank + d_state]
    Cmat = proj[..., dt_rank + d_state:]
    dt = jax.nn.softplus(dt_low @ p["w_dt"] + p["dt_bias"])    # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (di,ds)
    return dt, Bmat, Cmat, A


def _causal_conv(x, w, b):
    """Depthwise causal conv: x (B,S,di), w (K,di)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def apply_mamba(p, x: jax.Array, *, d_state: int = 16, chunk: int = 256) -> jax.Array:
    """x (B, S, d_model) -> (B, S, d_model), causal."""
    B, S, _ = x.shape
    di = p["w_in"].shape[-1] // 2
    xz = x @ p["w_in"]
    x_in, z = xz[..., :di], xz[..., di:]
    x_conv = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"]))
    dt, Bmat, Cmat, A = _ssm_inputs(p, x_conv, d_state=d_state)

    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    n_chunks = S // chunk

    def chunk_body(h0, inputs):
        # discretize INSIDE the (remat'd) chunk so the f32 (B,chunk,di,ds)
        # tensors never exist for the full sequence at once
        dt_c, B_c, C_c, x_c = inputs                                          # (B,chunk,.)
        dA_c = jnp.exp(dt_c[..., None].astype(jnp.float32) * A)              # (B,chunk,di,ds)
        dBx_c = (dt_c * x_c)[..., None].astype(jnp.float32) \
            * B_c[:, :, None, :].astype(jnp.float32)

        def combine(a, b):
            (a1, b1), (a2, b2) = a, b
            return a1 * a2, b1 * a2 + b2

        cumA, s = jax.lax.associative_scan(combine, (dA_c, dBx_c), axis=1)
        h_all = s + cumA * h0[:, None]                                        # (B,chunk,di,ds)
        y_c = jnp.einsum("bcds,bcs->bcd", h_all, C_c.astype(jnp.float32))
        # stack the per-chunk outputs at model precision: the f32 ys would
        # otherwise dominate prefill memory (jamba: 7 mamba layers/superblock)
        return h_all[:, -1], y_c.astype(dt_c.dtype)

    def reshape_c(t):
        return t.reshape(B, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    h0 = jnp.zeros((B, di, d_state), jnp.float32)
    _, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0,
                         (reshape_c(dt), reshape_c(Bmat), reshape_c(Cmat),
                          reshape_c(x_conv)))
    y = ys.swapaxes(0, 1).reshape(B, S, di).astype(x.dtype)
    y = y + p["D"] * x_conv
    return (y * jax.nn.silu(z)) @ p["w_out"]


def init_mamba_state(batch: int, d_inner: int, *, d_state: int = 16,
                     d_conv: int = 4, dtype=jnp.float32):
    return {"h": jnp.zeros((batch, d_inner, d_state), jnp.float32),
            "conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype)}


def apply_mamba_decode(p, x, state, *, d_state: int = 16):
    """One-token step. x (B, 1, d_model) -> (y (B,1,d_model), new_state)."""
    B = x.shape[0]
    di = p["w_in"].shape[-1] // 2
    K = p["conv_w"].shape[0]
    xz = x[:, 0] @ p["w_in"]
    x_in, z = xz[..., :di], xz[..., di:]
    window = jnp.concatenate([state["conv"], x_in[:, None, :]], axis=1)       # (B,K,di)
    x_conv = jax.nn.silu(jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"])
    dt, Bmat, Cmat, A = _ssm_inputs(p, x_conv[:, None, :], d_state=d_state)
    dt, Bmat, Cmat = dt[:, 0], Bmat[:, 0], Cmat[:, 0]
    dA = jnp.exp(dt[..., None].astype(jnp.float32) * A)                       # (B,di,ds)
    dBx = (dt * x_conv)[..., None].astype(jnp.float32) * Bmat[:, None, :].astype(jnp.float32)
    h = dA * state["h"] + dBx
    y = jnp.einsum("bds,bs->bd", h, Cmat.astype(jnp.float32)).astype(x.dtype)
    y = y + p["D"] * x_conv
    out = (y * jax.nn.silu(z)) @ p["w_out"]
    return out[:, None, :], {"h": h, "conv": window[:, 1:]}
