"""Attention variants: GQA (+bias, sliding window), cross-attention, MLA.

All functions are pure; params are plain dicts.  Shapes:
    x (B, S, D); q heads H, kv heads KV, head dim hd.
Decode functions take a KV cache and one new token (B, 1, D) at position
`pos` (scalar int32), returning (y, new_cache).  Sliding-window caches are
ring buffers of length `window`.

Every decode/prefill function supports two cache layouts:

* contiguous (default) — cache leaves are per-slot strips (B, T, ...).
* paged — cache leaves are shared pools (num_pages, page_size, ...) and
  ``pages`` carries the per-slot page table (B, P); ``length`` gives the
  logical per-slot cache length T the contiguous layout would have.
  Reads gather the pool into the exact contiguous (B, T, ...) view
  (repro.models.paging.gather_pages) so masks and SDPA are the same code
  on both layouts — that is what keeps paged outputs bit-identical.
  GQA decode additionally takes ``kv_read="kernel"``: the Pallas
  paged-attention kernel walks the page table in-kernel (no contiguous
  gather) while reproducing the gather path's values bit-for-bit.

Decode functions also take ``live`` (B,) bool: rows marked False write
NOTHING to the cache (the serving engine decodes while other slots are
mid-prefill or empty; unmasked writes would stomp their pages).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import paging
from repro.models.layers import apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def init_gqa(rng, d_model: int, num_heads: int, num_kv_heads: int, head_dim: int,
             qkv_bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(rng, 4)
    p = {
        "w_q": dense_init(ks[0], d_model, num_heads * head_dim, dtype),
        "w_k": dense_init(ks[1], d_model, num_kv_heads * head_dim, dtype),
        "w_v": dense_init(ks[2], d_model, num_kv_heads * head_dim, dtype),
        "w_o": dense_init(ks[3], num_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["b_q"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["b_k"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["b_v"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    return p


def _qkv(p, x, num_heads, num_kv_heads, head_dim):
    B, S, _ = x.shape
    q = x @ p["w_q"] + p.get("b_q", 0.0)
    k = x @ p["w_k"] + p.get("b_k", 0.0)
    v = x @ p["w_v"] + p.get("b_v", 0.0)
    return (q.reshape(B, S, num_heads, head_dim),
            k.reshape(B, S, num_kv_heads, head_dim),
            v.reshape(B, S, num_kv_heads, head_dim))


def _sdpa(q, k, v, mask):
    """q (B,Sq,H,hd), k (B,Sk,KV,hd), v (B,Sk,KV,hd_v) — hd_v may differ
    (MLA).  mask broadcastable (B,1,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    hd_v = v.shape[-1]
    groups = H // KV
    qg = q.reshape(B, Sq, KV, groups, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores * (hd ** -0.5)
    scores = jnp.where(mask[:, :, None, :, :] if mask.ndim == 4 else mask,
                       scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Sq, H * hd_v)


def causal_mask(Sq: int, Sk: int, window: int | None = None,
                q0: int = 0, k0: int = 0):
    """(1, 1, Sq, Sk) boolean for a (q, k) tile at absolute offsets (q0, k0)."""
    qpos = q0 + jnp.arange(Sq)[:, None]
    kpos = k0 + jnp.arange(Sk)[None, :]
    m = kpos <= qpos
    if window is not None:
        m &= kpos > qpos - window
    return m[None, None]


# Above this sequence length, attention runs q-chunked with per-chunk remat
# so the live score tensor is (B, H, q_chunk, kv_len) instead of (B, H, S, S).
# (The TPU production path would be a Pallas flash kernel; this is the
# HLO-level equivalent that bounds memory identically.)
CHUNK_THRESHOLD = 2048
Q_CHUNK = 1024


def _sdpa_causal(q, k, v, window: int | None = None, q_chunk: int = Q_CHUNK):
    """Causal SDPA, q-chunked above CHUNK_THRESHOLD.  Static chunk bounds:
    chunk i attends kv[max(0, i*qc - window + 1) : (i+1)*qc)."""
    S = q.shape[1]
    if S <= CHUNK_THRESHOLD:
        return _sdpa(q, k, v, causal_mask(S, S, window))
    qc = min(q_chunk, S)
    while S % qc:
        qc -= 1

    def one_chunk(q_i, k_i, v_i, mask):
        return _sdpa(q_i, k_i, v_i, mask)

    one_chunk = jax.checkpoint(one_chunk)
    outs = []
    for i in range(S // qc):
        q0 = i * qc
        kv_end = q0 + qc
        kv_start = 0 if window is None else max(0, q0 - window + 1)
        # align start down to the chunk grid (keeps slice sizes uniform-ish)
        kv_start -= kv_start % qc
        mask = causal_mask(qc, kv_end - kv_start, window, q0=q0, k0=kv_start)
        outs.append(one_chunk(q[:, q0:kv_end], k[:, kv_start:kv_end],
                              v[:, kv_start:kv_end], mask))
    return jnp.concatenate(outs, axis=1)


def apply_gqa(p, x, positions, *, num_heads, num_kv_heads, head_dim,
              rotary_dim, rope_theta=10000.0, sliding_window=None):
    B, S, D = x.shape
    q, k, v = _qkv(p, x, num_heads, num_kv_heads, head_dim)
    q = apply_rope(q, positions, rotary_dim, rope_theta)
    k = apply_rope(k, positions, rotary_dim, rope_theta)
    return _sdpa_causal(q, k, v, sliding_window) @ p["w_o"]


def apply_cross_attention(p, x, memory, *, num_heads, num_kv_heads, head_dim):
    """x (B,Sq,D) attends to memory (B,Sk,D); no mask, no rope."""
    B, Sq, _ = x.shape
    Sk = memory.shape[1]
    q = (x @ p["w_q"] + p.get("b_q", 0.0)).reshape(B, Sq, num_heads, head_dim)
    k = (memory @ p["w_k"] + p.get("b_k", 0.0)).reshape(B, Sk, num_kv_heads, head_dim)
    v = (memory @ p["w_v"] + p.get("b_v", 0.0)).reshape(B, Sk, num_kv_heads, head_dim)
    mask = jnp.ones((1, 1, Sq, Sk), bool)
    return _sdpa(q, k, v, mask) @ p["w_o"]


def init_gqa_cache(batch: int, length: int, num_kv_heads: int, head_dim: int,
                   dtype=jnp.float32, quant: bool = False):
    """KV cache.  quant=True stores int8 values + per-(pos, kv-head) scales
    (2x less HBM than bf16; scales are folded into scores/probs at use so
    the dequantized cache is never materialized)."""
    shape = (batch, length, num_kv_heads, head_dim)
    if quant:
        sshape = (batch, length, num_kv_heads, 1)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _quantize_kv(x):
    """x (B,1,KV,hd) -> (int8 values, (B,1,KV,1) scales)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _sdpa_quant(q, k_q, k_scale, v_q, v_scale, mask, compute_dtype):
    """SDPA over an int8 cache: scales fold into scores/probs, so only the
    int8 tensors stream from HBM."""
    B, Sq, H, hd = q.shape
    KV = k_q.shape[2]
    groups = H // KV
    qg = q.reshape(B, Sq, KV, groups, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k_q.astype(jnp.float32))
    scores = scores * k_scale[:, :, :, 0].transpose(0, 2, 1)[:, :, None, None, :]
    scores = scores * (hd ** -0.5)
    scores = jnp.where(mask[:, :, None, :, :] if mask.ndim == 4 else mask,
                       scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = probs * v_scale[:, :, :, 0].transpose(0, 2, 1)[:, :, None, None, :]
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v_q.astype(jnp.float32))
    return out.reshape(B, Sq, H * hd).astype(compute_dtype)


def _per_row_update(cache_kv, new_kv, slots):
    """Write new_kv (B,1,KV,hd) into cache (B,T,KV,hd) at per-row slots (B,)."""
    return jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice_in_dim(c, n, s, axis=0)
    )(cache_kv, new_kv, slots)


def _write_rows(cache, new, slots, T, *, pages, live):
    """Decode-step cache write (one position per row) on either layout.

    ``new`` maps leaf name -> (B, 1, ...) values.  Paged: scatter through
    the page table.  Contiguous with ``live``: rows not live scatter to
    slot T -> dropped.  Contiguous without ``live``: the original
    dynamic-update path (bit-for-bit the legacy baseline)."""
    if pages is not None:
        return {n: paging.scatter_rows(cache[n], pages, slots, val, live=live)
                for n, val in new.items()}
    if live is not None:
        b_idx = jnp.arange(slots.shape[0])
        wslot = jnp.where(live, slots, T)
        return {n: cache[n].at[b_idx, wslot].set(val[:, 0], mode="drop")
                for n, val in new.items()}
    return {n: _per_row_update(cache[n], val, slots) for n, val in new.items()}


def _write_chunk(cache, new, slots, valid, T, *, pages):
    """Prefill-chunk cache write: ``new`` maps leaf name -> (B, C, ...)
    values at logical slots (B, C); ``valid`` False (padded tails, rows not
    prefilling) drops the write on both layouts."""
    if pages is not None:
        return {n: paging.scatter_chunk(cache[n], pages, slots, valid, val)
                for n, val in new.items()}
    idx = jnp.where(valid, slots, T)
    b_idx = jnp.arange(slots.shape[0])[:, None]
    return {n: cache[n].at[b_idx, idx].set(val, mode="drop")
            for n, val in new.items()}


def _view(cache, pages, T):
    """The (B, T, ...) per-slot view attention reads: the cache itself on
    the contiguous layout, a gather of the pools on the paged one."""
    if pages is None:
        return cache
    return {n: paging.gather_pages(cache[n], pages, T) for n in cache}


def apply_gqa_decode(p, x, cache, pos, *, num_heads, num_kv_heads, head_dim,
                     rotary_dim, rope_theta=10000.0, sliding_window=None,
                     pages=None, length=None, live=None, kv_read="gather"):
    """One-token decode. x (B,1,D); cache k/v (B,T,KV,hd) (T=window for SWA),
    or pooled (num_pages, ps, KV, hd) when ``pages`` is given.

    pos may be a scalar (lockstep batch) or (B,) int32 (continuous batching:
    every slot at its own position).  ``live`` (B,) masks cache writes (a
    non-live row attends garbage the caller must ignore but writes nothing).
    Returns (y (B,1,D), new_cache).

    ``kv_read`` selects how a PAGED cache is read: ``"gather"``
    materializes the contiguous view (paging.gather_pages) and reuses the
    contiguous SDPA; ``"kernel"`` walks the page table inside the Pallas
    paged-attention kernel (repro.kernels.paged_attention) — no contiguous
    gather, bit-identical outputs by construction (the kernel runs the
    literal _sdpa/_sdpa_quant op sequence on the same values).
    """
    B = x.shape[0]
    paged = pages is not None
    if kv_read not in ("gather", "kernel"):
        raise ValueError(f"unknown kv_read {kv_read!r} "
                         "(expected 'gather' | 'kernel')")
    if kv_read == "kernel" and not paged:
        raise ValueError("kv_read='kernel' requires the paged cache layout "
                         "(the kernel is a page-table walk; contiguous "
                         "caches have no table to walk)")
    T = length if paged else cache["k"].shape[1]
    q, k, v = _qkv(p, x, num_heads, num_kv_heads, head_dim)
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    positions = pos_b[:, None]
    q = apply_rope(q, positions, rotary_dim, rope_theta)
    k = apply_rope(k, positions, rotary_dim, rope_theta)
    slots = pos_b % T if sliding_window is not None else pos_b
    quant = "k_scale" in cache
    if quant:
        k_q, k_s = _quantize_kv(k)
        v_q, v_s = _quantize_kv(v)
        new = {"k": k_q, "v": v_q, "k_scale": k_s, "v_scale": v_s}
    else:
        new = {"k": k, "v": v}
    new_cache = _write_rows(cache, new, slots, T, pages=pages, live=live)
    if kv_read == "kernel":
        # in-kernel page-table walk: reads the SAME post-write pools the
        # gather path would view, applies the same mask math in-kernel
        from repro.kernels import ops as kops
        att = kops.paged_attention_decode(q, new_cache, pages, pos_b,
                                          length=T,
                                          sliding_window=sliding_window,
                                          compute_dtype=x.dtype)
        return att @ p["w_o"], new_cache
    view = _view(new_cache, pages, T)
    idx = jnp.arange(T)[None, :]
    if sliding_window is not None:
        # ring buffer: valid entries are the last min(pos+1, T) writes
        age = (slots[:, None] - idx) % T
        valid = age < jnp.minimum(pos_b + 1, T)[:, None]
    else:
        valid = idx <= pos_b[:, None]
    mask = valid[:, None, None, :]
    if quant:
        y = _sdpa_quant(q, view["k"], view["k_scale"],
                        view["v"], view["v_scale"], mask,
                        x.dtype) @ p["w_o"]
    else:
        y = _sdpa(q, view["k"], view["v"], mask) @ p["w_o"]
    return y, new_cache


def apply_gqa_prefill(p, x, cache, pos, valid, *, num_heads, num_kv_heads,
                      head_dim, rotary_dim, rope_theta=10000.0,
                      sliding_window=None, pages=None, length=None):
    """Chunked prefill: ingest C tokens per row in ONE dispatch.

    x (B,C,D); cache k/v (B,T,KV,hd) (T=window for SWA) or pooled with page
    table ``pages``; pos (B,) per-row start positions; valid (B,C) marks
    real tokens (False = ragged-tail padding or rows not prefilling: no
    cache write, no attention contribution).  Returns (y (B,C,D), new_cache).

    Attention runs over [pre-chunk cache ; chunk keys] — never the
    post-write cache — so ring buffers stay correct: a chunk write that
    reuses a ring slot cannot shadow the old occupant some earlier query
    should still see.  For SWA the chunk size must be <= T (each ring slot
    written at most once per chunk).
    """
    B, C, D = x.shape
    paged = pages is not None
    T = length if paged else cache["k"].shape[1]
    if sliding_window is not None and C > T:
        raise ValueError(f"chunk size {C} exceeds ring-buffer length {T}")
    q, k, v = _qkv(p, x, num_heads, num_kv_heads, head_dim)
    pos = jnp.asarray(pos, jnp.int32)
    qpos = pos[:, None] + jnp.arange(C, dtype=jnp.int32)         # (B,C) absolute
    q = apply_rope(q, qpos, rotary_dim, rope_theta)
    k = apply_rope(k, qpos, rotary_dim, rope_theta)

    # pre-chunk cache validity: slot s last held absolute position
    # last_s = (pos-1) - ((pos-1-s) mod T)  (< 0 => never written).  For a
    # linear cache (T >= max_len) this reduces to last_s = s iff s < pos.
    s_idx = jnp.arange(T, dtype=jnp.int32)
    last = (pos[:, None] - 1) - ((pos[:, None] - 1 - s_idx) % T)  # (B,T)
    m_cache = jnp.broadcast_to((last >= 0)[:, None, :], (B, C, T))
    m_chunk = (qpos[:, :, None] >= qpos[:, None, :]) & valid[:, None, :]
    if sliding_window is not None:
        m_cache = m_cache & (last[:, None, :] > qpos[:, :, None] - sliding_window)
        m_chunk = m_chunk & (qpos[:, None, :] > qpos[:, :, None] - sliding_window)
    mask = jnp.concatenate([m_cache, m_chunk], axis=-1)[:, None]  # (B,1,C,T+C)

    cview = _view(cache, pages, T)
    quant = "k_scale" in cache
    if quant:
        # dequantized *view* for the prefill matmuls (transient, prefill-only;
        # the decode hot loop keeps streaming int8 via _sdpa_quant)
        ck = (cview["k"].astype(jnp.float32) * cview["k_scale"]).astype(x.dtype)
        cv = (cview["v"].astype(jnp.float32) * cview["v_scale"]).astype(x.dtype)
    else:
        ck, cv = cview["k"], cview["v"]
    y = _sdpa(q, jnp.concatenate([ck, k], axis=1),
              jnp.concatenate([cv, v], axis=1), mask) @ p["w_o"]

    # write the chunk; padded tokens scatter to index T == out of bounds -> drop
    slot = qpos % T if sliding_window is not None else qpos
    if quant:
        k_q, k_s = _quantize_kv(k)
        v_q, v_s = _quantize_kv(v)
        new = {"k": k_q, "v": v_q, "k_scale": k_s, "v_scale": v_s}
    else:
        new = {"k": k, "v": v}
    return y, _write_chunk(cache, new, slot, valid, T, pages=pages)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(rng, d_model: int, num_heads: int, *, kv_lora_rank: int,
             qk_nope_dim: int, qk_rope_dim: int, v_head_dim: int, dtype=jnp.float32):
    ks = jax.random.split(rng, 6)
    H = num_heads
    return {
        "w_q": dense_init(ks[0], d_model, H * (qk_nope_dim + qk_rope_dim), dtype),
        "w_dkv": dense_init(ks[1], d_model, kv_lora_rank, dtype),
        "kv_norm": jnp.ones((kv_lora_rank,), dtype),
        "w_uk": dense_init(ks[2], kv_lora_rank, H * qk_nope_dim, dtype),
        "w_uv": dense_init(ks[3], kv_lora_rank, H * v_head_dim, dtype),
        "w_kpe": dense_init(ks[4], d_model, qk_rope_dim, dtype),
        "w_o": dense_init(ks[5], H * v_head_dim, d_model, dtype),
    }


def _mla_qc(p, x, positions, *, num_heads, qk_nope_dim, qk_rope_dim, rope_theta):
    from repro.models.layers import rms_norm
    B, S, _ = x.shape
    H = num_heads
    q = (x @ p["w_q"]).reshape(B, S, H, qk_nope_dim + qk_rope_dim)
    q_nope, q_rope = q[..., :qk_nope_dim], q[..., qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, qk_rope_dim, rope_theta)
    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"])                  # (B,S,L)
    k_pe = apply_rope((x @ p["w_kpe"])[:, :, None, :], positions,
                      qk_rope_dim, rope_theta)[:, :, 0, :]          # (B,S,rope)
    return q_nope, q_rope, c_kv, k_pe


def apply_mla(p, x, positions, *, num_heads, kv_lora_rank, qk_nope_dim,
              qk_rope_dim, v_head_dim, rope_theta=10000.0, sliding_window=None):
    B, S, _ = x.shape
    H = num_heads
    q_nope, q_rope, c_kv, k_pe = _mla_qc(
        p, x, positions, num_heads=H, qk_nope_dim=qk_nope_dim,
        qk_rope_dim=qk_rope_dim, rope_theta=rope_theta)
    k_nope = (c_kv @ p["w_uk"]).reshape(B, S, H, qk_nope_dim)
    v = (c_kv @ p["w_uv"]).reshape(B, S, H, v_head_dim)
    # concat the rope component (k_pe shared across heads) so the fused
    # q_cat . k_cat score equals the MLA score; reuses the chunked SDPA.
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, qk_rope_dim))],
        axis=-1)
    return _sdpa_causal(q_cat, k_cat, v, sliding_window) @ p["w_o"]


def init_mla_cache(batch: int, length: int, kv_lora_rank: int, qk_rope_dim: int,
                   dtype=jnp.float32):
    """MLA's win: the cache stores the COMPRESSED c_kv + shared k_pe."""
    return {"c_kv": jnp.zeros((batch, length, kv_lora_rank), dtype),
            "k_pe": jnp.zeros((batch, length, qk_rope_dim), dtype)}


def apply_mla_decode(p, x, cache, pos, *, num_heads, kv_lora_rank, qk_nope_dim,
                     qk_rope_dim, v_head_dim, rope_theta=10000.0,
                     pages=None, length=None, live=None):
    """Absorbed-matrices MLA decode: scores live in the kv_lora space.
    pos: scalar or (B,) int32 (continuous batching); ``pages``/``length``
    select the paged cache layout, ``live`` masks cache writes."""
    B = x.shape[0]
    H = num_heads
    paged = pages is not None
    T = length if paged else cache["c_kv"].shape[1]
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))
    q_nope, q_rope, c_kv_new, k_pe_new = _mla_qc(
        p, x, pos_b[:, None], num_heads=H,
        qk_nope_dim=qk_nope_dim, qk_rope_dim=qk_rope_dim, rope_theta=rope_theta)
    new = {"c_kv": c_kv_new, "k_pe": k_pe_new}
    new_cache = _write_rows(cache, new, pos_b, T, pages=pages, live=live)
    view = _view(new_cache, pages, T)
    c_kv = view["c_kv"]
    k_pe = view["k_pe"]
    # absorb W_uk into q: q_eff (B,H,L)
    w_uk = p["w_uk"].reshape(kv_lora_rank, H, qk_nope_dim)
    q_eff = jnp.einsum("bhd,lhd->bhl", q_nope[:, 0], w_uk)
    scale = (qk_nope_dim + qk_rope_dim) ** -0.5
    scores = (jnp.einsum("bhl,btl->bht", q_eff, c_kv)
              + jnp.einsum("bhd,btd->bht", q_rope[:, 0], k_pe)).astype(jnp.float32)
    scores = scores * scale
    valid = jnp.arange(T)[None, None, :] <= pos_b[:, None, None]
    probs = jax.nn.softmax(jnp.where(valid, scores, NEG_INF), axis=-1).astype(x.dtype)
    o_c = jnp.einsum("bht,btl->bhl", probs, c_kv)                  # (B,H,L)
    w_uv = p["w_uv"].reshape(kv_lora_rank, H, v_head_dim)
    out = jnp.einsum("bhl,lhv->bhv", o_c, w_uv).reshape(B, 1, H * v_head_dim)
    return out @ p["w_o"], new_cache


def apply_mla_prefill(p, x, cache, pos, valid, *, num_heads, kv_lora_rank,
                      qk_nope_dim, qk_rope_dim, v_head_dim, rope_theta=10000.0,
                      pages=None, length=None):
    """Chunked absorbed-matrices MLA prefill: C tokens per row, one dispatch.

    x (B,C,D); cache c_kv (B,T,L) / k_pe (B,T,rope), or pooled with page
    table ``pages``; pos (B,) start positions; valid (B,C) as in
    apply_gqa_prefill.  Scores live in the kv_lora space over
    [pre-chunk cache ; chunk latents].
    """
    B, C, _ = x.shape
    H = num_heads
    paged = pages is not None
    T = length if paged else cache["c_kv"].shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    qpos = pos[:, None] + jnp.arange(C, dtype=jnp.int32)          # (B,C)
    q_nope, q_rope, c_kv_new, k_pe_new = _mla_qc(
        p, x, qpos, num_heads=H, qk_nope_dim=qk_nope_dim,
        qk_rope_dim=qk_rope_dim, rope_theta=rope_theta)
    cview = _view(cache, pages, T)
    c_all = jnp.concatenate([cview["c_kv"], c_kv_new], axis=1)    # (B,T+C,L)
    pe_all = jnp.concatenate([cview["k_pe"], k_pe_new], axis=1)
    w_uk = p["w_uk"].reshape(kv_lora_rank, H, qk_nope_dim)
    q_eff = jnp.einsum("bchd,lhd->bchl", q_nope, w_uk)
    scale = (qk_nope_dim + qk_rope_dim) ** -0.5
    scores = (jnp.einsum("bchl,btl->bhct", q_eff, c_all)
              + jnp.einsum("bchd,btd->bhct", q_rope, pe_all)).astype(jnp.float32)
    scores = scores * scale
    t_idx = jnp.arange(T, dtype=jnp.int32)
    m_cache = jnp.broadcast_to((t_idx[None, :] < pos[:, None])[:, None, :],
                               (B, C, T))
    m_chunk = (qpos[:, :, None] >= qpos[:, None, :]) & valid[:, None, :]
    mask = jnp.concatenate([m_cache, m_chunk], axis=-1)[:, None]  # (B,1,C,T+C)
    probs = jax.nn.softmax(jnp.where(mask, scores, NEG_INF), axis=-1).astype(x.dtype)
    o_c = jnp.einsum("bhct,btl->bchl", probs, c_all)
    w_uv = p["w_uv"].reshape(kv_lora_rank, H, v_head_dim)
    out = jnp.einsum("bchl,lhv->bchv", o_c, w_uv).reshape(B, C, H * v_head_dim)
    new = {"c_kv": c_kv_new, "k_pe": k_pe_new}
    return out @ p["w_o"], _write_chunk(cache, new, qpos, valid, T, pages=pages)
