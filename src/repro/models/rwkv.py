"""RWKV-6 ("Finch") block: time-mix with data-dependent decay + channel-mix.

Recurrence per head (k-dim x v-dim outer-product state S):
    y_t = r_t . (S_{t-1} + (u * k_t) (x) v_t)
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
with w_t = exp(-exp(w0 + tanh(x_w A) B)) — the data-dependent decay that
defines RWKV-6.  Train path: sequential scan over time inside remat'd chunks
(memory O(B * chunk * H * hd^2) transient during backward).  Decode: O(1)
state per layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_rwkv_timemix(rng, d_model: int, num_heads: int, *, decay_lora: int = 64,
                      dtype=jnp.float32):
    ks = jax.random.split(rng, 9)
    hd = d_model // num_heads
    p = {
        "mix_r": jnp.full((d_model,), 0.5, dtype),
        "mix_k": jnp.full((d_model,), 0.5, dtype),
        "mix_v": jnp.full((d_model,), 0.5, dtype),
        "mix_w": jnp.full((d_model,), 0.5, dtype),
        "mix_g": jnp.full((d_model,), 0.5, dtype),
        "w_r": dense_init(ks[0], d_model, d_model, dtype),
        "w_k": dense_init(ks[1], d_model, d_model, dtype),
        "w_v": dense_init(ks[2], d_model, d_model, dtype),
        "w_g": dense_init(ks[3], d_model, d_model, dtype),
        "w_o": dense_init(ks[4], d_model, d_model, dtype),
        # data-dependent decay (LoRA)
        "w0": jnp.full((d_model,), -0.6, dtype),
        "w_dec_a": dense_init(ks[5], d_model, decay_lora, dtype),
        "w_dec_b": (jax.random.normal(ks[6], (decay_lora, d_model)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[7], (num_heads, hd)) * 0.1).astype(dtype),
        "ln_scale": jnp.ones((d_model,), dtype),
    }
    return p


def _shift(x):
    """Token shift: x_{t-1} with zeros at t=0.  x (B,S,D)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _timemix_inputs(p, x, num_heads: int):
    B, S, D = x.shape
    hd = D // num_heads
    xp = _shift(x)

    def mix(m):
        return x + p[m] * (xp - x)

    r = (mix("mix_r") @ p["w_r"]).reshape(B, S, num_heads, hd)
    k = (mix("mix_k") @ p["w_k"]).reshape(B, S, num_heads, hd)
    v = (mix("mix_v") @ p["w_v"]).reshape(B, S, num_heads, hd)
    g = jax.nn.silu(mix("mix_g") @ p["w_g"])
    dec = p["w0"] + jnp.tanh(mix("mix_w") @ p["w_dec_a"]) @ p["w_dec_b"]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(B, S, num_heads, hd)
    return r, k, v, g, w


def _wkv_step(S_state, inputs, u):
    """S (B,H,hd,hd); r,k,v,w (B,H,hd)."""
    r, k, v, w = inputs
    kv = k[..., :, None] * v[..., None, :]                       # (B,H,hdk,hdv)
    y = jnp.einsum("bhk,bhkv->bhv", r, S_state + u[..., :, None] * kv)
    S_new = w[..., :, None] * S_state + kv
    return S_new, y


def _groupnorm_gate_out(p, y, g, x_dtype, B, S, num_heads, hd):
    D = num_heads * hd
    y = y.reshape(B, S, num_heads, hd)
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, S, D)
    y = y.astype(x_dtype) * p["ln_scale"]
    return (y * g) @ p["w_o"]


def apply_rwkv_timemix(p, x: jax.Array, *, num_heads: int, chunk: int = 64,
                       mode: str = "chunked") -> jax.Array:
    """RWKV-6 time-mix.

    mode="chunked" (default, §Perf iteration 1): GLA-style chunkwise matmul
    form — intra-chunk attention-like masked matmuls on the MXU + O(S/chunk)
    inter-chunk state propagation.  vs the paper-faithful "sequential" form
    (one outer-product state update per timestep) this cuts HBM round-trips
    per layer by ~chunk and moves the arithmetic to the MXU.  Exact same
    math (tests assert equivalence); f32-safe via midpoint-centered
    log-decay factorization.
    """
    B, S, D = x.shape
    hd = D // num_heads
    r, k, v, g, w = _timemix_inputs(p, x, num_heads)
    u = p["u"].astype(jnp.float32)

    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    n_chunks = S // chunk

    if mode == "sequential":
        def reshape_c(t):  # (B,S,H,hd) -> (n_chunks, chunk, B, H, hd)
            return t.reshape(B, n_chunks, chunk, num_heads, hd).transpose(1, 2, 0, 3, 4)

        rc, kc, vc, wc = map(lambda t: reshape_c(t.astype(jnp.float32)),
                             (r, k, v, w))

        def chunk_body(S0, inputs):
            rs, ks, vs, ws = inputs  # (chunk, B, H, hd)
            S_end, ys = jax.lax.scan(lambda s, i: _wkv_step(s, i, u), S0,
                                     (rs, ks, vs, ws))
            return S_end, ys

        S0 = jnp.zeros((B, num_heads, hd, hd), jnp.float32)
        _, ys = jax.lax.scan(jax.checkpoint(chunk_body), S0, (rc, kc, vc, wc))
        y = ys.reshape(n_chunks * chunk, B, num_heads, hd).transpose(1, 0, 2, 3)
        return _groupnorm_gate_out(p, y.astype(jnp.float32), g, x.dtype,
                                   B, S, num_heads, hd)

    # ---- chunked matmul form -------------------------------------------------
    C = chunk

    def reshape_n(t):  # (B,S,H,hd) -> (n, B, C, H, hd)
        return t.reshape(B, n_chunks, C, num_heads, hd).transpose(1, 0, 2, 3, 4) \
            .astype(jnp.float32)

    rn, kn, vn, wn = map(reshape_n, (r, k, v, w))
    lw = jnp.log(jnp.maximum(wn, 1e-38))              # (n,B,C,H,hd), <= 0
    c = jnp.cumsum(lw, axis=2)                        # within-chunk log decay

    # y_t reads S_{t-1}: contribution of s<t is decayed by w_{s+1}..w_{t-1},
    # i.e. exp(c_{t-1} - c_s) — use the shifted cumsum on the query side
    c_prev = jnp.pad(c[:, :, :-1], ((0, 0), (0, 0), (1, 0), (0, 0), (0, 0)))
    # midpoint centering keeps both factors' exponents <= half-chunk decay
    c_mid = c[:, :, C // 2:C // 2 + 1]
    r_tilde = rn * jnp.exp(c_prev - c_mid)            # (n,B,C,H,hd)
    k_tilde = kn * jnp.exp(c_mid - c)
    c_end = c[:, :, -1:]

    # intra-chunk scores A[t,s] = sum_d r_t k_s exp(c_{t-1} - c_s), s<t
    A = jnp.einsum("nbthd,nbshd->nbhts", r_tilde, k_tilde)
    tri = jnp.tril(jnp.ones((C, C), bool), k=-1)[None, None, None]
    A = jnp.where(tri, A, 0.0)
    # current-token "bonus" diagonal: r_t . (u * k_t)
    diag = jnp.einsum("nbthd,hd,nbthd->nbth", rn, u, kn)

    y_intra = jnp.einsum("nbhts,nbshd->nbthd", A, vn) \
        + diag[..., None] * vn

    # inter-chunk: y_t += (r_t * exp(c_{t-1})) @ S_chunk_start;  state update:
    # S' = exp(c_end) * S + sum_s k_s exp(c_end - c_s) (x) v_s   (all <= 1)
    r_in = rn * jnp.exp(c_prev)                       # exponents <= 0
    k_out = kn * jnp.exp(c_end - c)

    def chunk_body(S0, inputs):
        # S0 (B,H,hd_k,hd_v); decay applies along hd_k
        r_in_c, k_out_c, v_c, decay_c = inputs        # decay_c (B,H,hd_k)
        y_int = jnp.einsum("bthd,bhde->bthe", r_in_c, S0)
        S_new = S0 * decay_c[..., None] \
            + jnp.einsum("bshd,bshe->bhde", k_out_c, v_c)
        # the scan stacks these carries for backward — without the
        # constraint they materialize with H unsharded (§Perf rwkv iter 2)
        from repro.sharding.constraints import constrain
        S_new = constrain(S_new, ("data", "model"))
        return S_new, y_int

    decay_end = jnp.exp(c_end[:, :, 0])               # (n,B,H,hd_k)

    S0 = jnp.zeros((B, num_heads, hd, hd), jnp.float32)
    # (§Perf rwkv iter 3, REFUTED: bf16 xs storage bought only 8.7% memory
    # for a 3e-3 relative error — the f32 buffers were mostly aliased, not
    # independent traffic.  Kept f32.)
    _, y_inter = jax.lax.scan(
        jax.checkpoint(chunk_body), S0, (r_in, k_out, vn, decay_end))

    y = (y_intra + y_inter).transpose(1, 0, 2, 3, 4).reshape(B, S, num_heads, hd)
    return _groupnorm_gate_out(p, y, g, x.dtype, B, S, num_heads, hd)


def init_rwkv_channelmix(rng, d_model: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(rng, 2)
    return {
        "mix_k": jnp.full((d_model,), 0.5, dtype),
        "mix_r": jnp.full((d_model,), 0.5, dtype),
        "w_k": dense_init(ks[0], d_model, d_ff, dtype),
        "w_v": dense_init(ks[1], d_ff, d_model, dtype),
        "w_r": dense_init(jax.random.fold_in(ks[0], 1), d_model, d_model, dtype),
    }


def apply_rwkv_channelmix(p, x: jax.Array) -> jax.Array:
    xp = _shift(x)
    xk = x + p["mix_k"] * (xp - x)
    xr = x + p["mix_r"] * (xp - x)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"])


# ---------------------------------------------------------------------------
# decode (O(1) state)
# ---------------------------------------------------------------------------

def init_rwkv_state(batch: int, d_model: int, num_heads: int, dtype=jnp.float32):
    hd = d_model // num_heads
    return {
        "wkv": jnp.zeros((batch, num_heads, hd, hd), jnp.float32),
        "x_prev_tm": jnp.zeros((batch, d_model), dtype),   # time-mix token shift
        "x_prev_cm": jnp.zeros((batch, d_model), dtype),   # channel-mix token shift
    }


def apply_rwkv_timemix_decode(p, x, state, *, num_heads: int):
    """x (B,1,D) one token; state carries token-shift + wkv."""
    B, _, D = x.shape
    hd = D // num_heads
    xt = x[:, 0]
    xp = state["x_prev_tm"]

    def mix(m):
        return xt + p[m] * (xp - xt)

    r = (mix("mix_r") @ p["w_r"]).reshape(B, num_heads, hd).astype(jnp.float32)
    k = (mix("mix_k") @ p["w_k"]).reshape(B, num_heads, hd).astype(jnp.float32)
    v = (mix("mix_v") @ p["w_v"]).reshape(B, num_heads, hd).astype(jnp.float32)
    g = jax.nn.silu(mix("mix_g") @ p["w_g"])
    dec = p["w0"] + jnp.tanh(mix("mix_w") @ p["w_dec_a"]) @ p["w_dec_b"]
    w = jnp.exp(-jnp.exp(dec.astype(jnp.float32))).reshape(B, num_heads, hd)
    S_new, y = _wkv_step(state["wkv"], (r, k, v, w), p["u"].astype(jnp.float32))
    y = y.reshape(B, num_heads, hd)
    mu = y.mean(-1, keepdims=True)
    var = y.var(-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, D).astype(x.dtype) * p["ln_scale"]
    out = (y * g) @ p["w_o"]
    new_state = dict(state, wkv=S_new, x_prev_tm=xt)
    return out[:, None, :], new_state


def apply_rwkv_channelmix_decode(p, x, state):
    B, _, D = x.shape
    xt = x[:, 0]
    xp = state["x_prev_cm"]
    xk = xt + p["mix_k"] * (xp - xt)
    xr = xt + p["mix_r"] * (xp - xt)
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    out = jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"])
    return out[:, None, :], dict(state, x_prev_cm=xt)
