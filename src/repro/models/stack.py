"""Superblock layer-stack engine.

The layer stack of every architecture is `num_superblocks` repetitions of
`cfg.block_pattern` (a tuple of layers, each a tuple of sublayer kinds).
Parameters for one superblock are a flat dict keyed "l{layer}_{idx}_{kind}";
the full stack stacks every leaf with a leading superblock axis and runs
`jax.lax.scan` over it (with remat in training), which keeps the HLO size
independent of depth — essential for the 88-layer dry-runs.

Sublayer kinds: attn, mla, mlp, moe, mamba, rwkv_tm, rwkv_cm, cross.
Every sublayer is pre-norm residual: h = h + f(norm(h)).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models import mamba as mamba_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models.layers import apply_mlp, init_mlp, layer_norm, rms_norm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_norm(cfg: ModelConfig, dtype):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.ones((cfg.d_model,), dtype)}


def _apply_norm(cfg: ModelConfig, p, x):
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def init_sublayer(rng, kind: str, cfg: ModelConfig, dtype, *, dense_mlp: bool = False):
    """Params for one sublayer, including its pre-norm."""
    p: dict[str, Any] = {"norm": _init_norm(cfg, dtype)}
    if kind == "attn" or kind == "cross":
        p.update(attn_lib.init_gqa(rng, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                                   cfg.head_dim_, cfg.qkv_bias, dtype))
    elif kind == "mla":
        p.update(attn_lib.init_mla(rng, cfg.d_model, cfg.num_heads,
                                   kv_lora_rank=cfg.kv_lora_rank,
                                   qk_nope_dim=cfg.qk_nope_dim,
                                   qk_rope_dim=cfg.qk_rope_dim,
                                   v_head_dim=cfg.v_head_dim, dtype=dtype))
    elif kind == "mlp":
        p.update(init_mlp(rng, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype))
    elif kind == "moe" and dense_mlp:
        p.update(init_mlp(rng, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype))
    elif kind == "moe":
        p.update(moe_lib.init_moe(rng, cfg.d_model, cfg.moe_d_ff or cfg.d_ff,
                                  cfg.num_experts,
                                  num_shared_experts=cfg.num_shared_experts,
                                  dtype=dtype))
    elif kind == "mamba":
        p.update(mamba_lib.init_mamba(rng, cfg.d_model, cfg.d_inner,
                                      d_state=cfg.d_state, d_conv=cfg.d_conv,
                                      dtype=dtype))
    elif kind == "rwkv_tm":
        p.update(rwkv_lib.init_rwkv_timemix(rng, cfg.d_model, cfg.num_heads, dtype=dtype))
    elif kind == "rwkv_cm":
        p.update(rwkv_lib.init_rwkv_channelmix(rng, cfg.d_model, cfg.d_ff, dtype=dtype))
    else:
        raise ValueError(kind)
    return p


def init_superblock(rng, cfg: ModelConfig, dtype, *, pattern=None, dense_mlp=False):
    pattern = pattern or cfg.block_pattern
    p = {}
    for li, layer in enumerate(pattern):
        for si, kind in enumerate(layer):
            rng, sub = jax.random.split(rng)
            p[f"l{li}_{si}_{kind}"] = init_sublayer(sub, kind, cfg, dtype,
                                                    dense_mlp=dense_mlp)
    return p


def init_stack(rng, cfg: ModelConfig, dtype):
    """Stacked superblock params: every leaf has leading dim num_superblocks."""
    rngs = jax.random.split(rng, cfg.num_superblocks)
    return jax.vmap(lambda r: init_superblock(r, cfg, dtype))(rngs)


# ---------------------------------------------------------------------------
# apply (train / prefill)
# ---------------------------------------------------------------------------

def apply_sublayer(kind: str, p, cfg: ModelConfig, h, positions, *,
                   memory=None, sliding_window=None):
    """Returns (residual_update, aux_loss)."""
    x = _apply_norm(cfg, p["norm"], h)
    aux = jnp.array(0.0, jnp.float32)
    if kind == "attn":
        y = attn_lib.apply_gqa(p, x, positions, num_heads=cfg.num_heads,
                               num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim_,
                               rotary_dim=cfg.rotary_dim, rope_theta=cfg.rope_theta,
                               sliding_window=sliding_window)
    elif kind == "mla":
        y = attn_lib.apply_mla(p, x, positions, num_heads=cfg.num_heads,
                               kv_lora_rank=cfg.kv_lora_rank,
                               qk_nope_dim=cfg.qk_nope_dim,
                               qk_rope_dim=cfg.qk_rope_dim,
                               v_head_dim=cfg.v_head_dim,
                               rope_theta=cfg.rope_theta,
                               sliding_window=sliding_window)
    elif kind == "cross":
        y = attn_lib.apply_cross_attention(p, x, memory, num_heads=cfg.num_heads,
                                           num_kv_heads=cfg.num_kv_heads,
                                           head_dim=cfg.head_dim_)
    elif kind == "mlp":
        y = apply_mlp(p, x)
    elif kind == "moe":
        if "router" in p:
            y, aux = moe_lib.apply_moe(p, x, top_k=cfg.experts_per_token,
                                       capacity_factor=cfg.capacity_factor)
        else:  # first_dense_layers replacement
            y = apply_mlp(p, x)
    elif kind == "mamba":
        y = mamba_lib.apply_mamba(p, x, d_state=cfg.d_state)
    elif kind == "rwkv_tm":
        y = rwkv_lib.apply_rwkv_timemix(p, x, num_heads=cfg.num_heads,
                                        mode=cfg.rwkv_mode)
    elif kind == "rwkv_cm":
        y = rwkv_lib.apply_rwkv_channelmix(p, x)
    else:
        raise ValueError(kind)
    return y, aux


def apply_superblock(p_sb, cfg: ModelConfig, h, positions, *, pattern=None,
                     memory=None, sliding_window=None):
    pattern = pattern or cfg.block_pattern
    aux_total = jnp.array(0.0, jnp.float32)
    for li, layer in enumerate(pattern):
        for si, kind in enumerate(layer):
            y, aux = apply_sublayer(kind, p_sb[f"l{li}_{si}_{kind}"], cfg, h,
                                    positions, memory=memory,
                                    sliding_window=sliding_window)
            h = h + y
            aux_total = aux_total + aux
    return h, aux_total


def _activation_constraint(h):
    """Sequence-shard the residual stream stored at superblock boundaries
    (Megatron-SP style): (B, S, D) -> P(batch_axes, "model", None).  The
    attention/mixer internals re-gather as needed; what matters is that the
    per-layer *stored* copies (the remat scan carries) are sharded, or the
    88-layer models blow past HBM.  No-op outside a (data, model) mesh or on
    non-divisible shapes."""
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return h
    if am is None or am.empty or h.ndim != 3:
        return h
    from jax.sharding import AxisType, PartitionSpec as P
    # only axes still under automatic partitioning (inside shard_map some
    # axes are Manual and must not appear in constraints)
    names = {n for n, t in zip(am.axis_names, am.axis_types)
             if t != AxisType.Manual}
    if "model" not in names or "data" not in names:
        return h
    batch_ax = ("pod", "data") if "pod" in names else ("data",)
    bsz = 1
    for a in batch_ax:
        bsz *= am.shape[a]
    B, S, _ = h.shape
    if B % bsz or S % am.shape["model"]:
        return h
    return jax.lax.with_sharding_constraint(h, P(batch_ax, "model", None))


def apply_stack(stacked, cfg: ModelConfig, h, positions, *, memory=None,
                sliding_window=None, remat: bool = True):
    """Scan over superblocks.  Returns (h, total_aux_loss)."""

    def body(carry, p_sb):
        h, aux = carry
        h, a = apply_superblock(p_sb, cfg, h, positions, memory=memory,
                                sliding_window=sliding_window)
        # constrain the carry OUTPUT: this is the tensor lax.scan saves per
        # iteration for the backward pass — it must be sequence-sharded or
        # deep models blow past HBM (see DESIGN.md §distribution)
        h = _activation_constraint(h)
        return (h, aux + a), None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.array(0.0, jnp.float32)), stacked)
    return h, aux


# ---------------------------------------------------------------------------
# decode (one token, stacked caches)
# ---------------------------------------------------------------------------

def init_sublayer_cache(kind: str, cfg: ModelConfig, batch: int, length: int,
                        dtype, *, paged=None):
    """One sublayer's decode cache.  With ``paged`` (a PagedLayout), the
    per-position kinds (attn/mla) become shared page POOLS
    (num_pages, page_size, ...) instead of per-slot (B, T, ...) strips —
    the same leaf constructors, re-dimensioned.  Stateful kinds
    (mamba/rwkv) keep their per-slot O(1) state either way."""
    if kind == "attn":
        if paged is not None:
            np_, sw = ((paged.num_pages_swa, True) if cfg.sliding_window
                       else (paged.num_pages, False))
            return attn_lib.init_gqa_cache(np_, paged.page_size,
                                           cfg.num_kv_heads, cfg.head_dim_,
                                           dtype, quant=cfg.kv_cache_quant)
        T = min(length, cfg.sliding_window) if cfg.sliding_window else length
        return attn_lib.init_gqa_cache(batch, T, cfg.num_kv_heads, cfg.head_dim_,
                                       dtype, quant=cfg.kv_cache_quant)
    if kind == "mla":
        if paged is not None:
            return attn_lib.init_mla_cache(paged.num_pages, paged.page_size,
                                           cfg.kv_lora_rank, cfg.qk_rope_dim,
                                           dtype)
        return attn_lib.init_mla_cache(batch, length, cfg.kv_lora_rank,
                                       cfg.qk_rope_dim, dtype)
    if kind == "mamba":
        return mamba_lib.init_mamba_state(batch, cfg.d_inner, d_state=cfg.d_state,
                                          d_conv=cfg.d_conv, dtype=dtype)
    if kind == "rwkv_tm":
        hd = cfg.d_model // cfg.num_heads
        return {"wkv": jnp.zeros((batch, cfg.num_heads, hd, hd), jnp.float32),
                "x_prev": jnp.zeros((batch, cfg.d_model), dtype)}
    if kind == "rwkv_cm":
        return {"x_prev": jnp.zeros((batch, cfg.d_model), dtype)}
    return {}  # mlp / moe / cross are stateless (cross re-reads memory)


def init_superblock_cache(cfg: ModelConfig, batch: int, length: int, dtype,
                          pattern=None, *, paged=None):
    pattern = pattern or cfg.block_pattern
    return {f"l{li}_{si}_{kind}": init_sublayer_cache(kind, cfg, batch, length,
                                                      dtype, paged=paged)
            for li, layer in enumerate(pattern)
            for si, kind in enumerate(layer)}


def init_stack_cache(cfg: ModelConfig, batch: int, length: int, dtype, *,
                     paged=None):
    one = init_superblock_cache(cfg, batch, length, dtype, paged=paged)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.num_superblocks, *a.shape)), one)


def _paged_args(kind: str, cfg: ModelConfig, paged, pages, pages_swa):
    """(pages, length) kwargs for an attn/mla sublayer: SWA attn caches use
    the ring table + window length, everything else the full-length table."""
    if paged is None:
        return {"pages": None, "length": None}
    if kind == "attn" and cfg.sliding_window:
        return {"pages": pages_swa, "length": paged.len_swa}
    return {"pages": pages, "length": paged.len_linear}


def apply_sublayer_decode(kind: str, p, cache, cfg: ModelConfig, h, pos, *,
                          memory=None, paged=None, pages=None, pages_swa=None,
                          live=None, kv_read="gather"):
    x = _apply_norm(cfg, p["norm"], h)
    if kind == "attn":
        # kv_read="kernel" only reaches GQA decode on the paged layout;
        # MLA (below) and every prefill path stay on the gather read —
        # the serving engine warns about those fallbacks up front.
        y, new_cache = attn_lib.apply_gqa_decode(
            p, x, cache, pos, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim_,
            rotary_dim=cfg.rotary_dim, rope_theta=cfg.rope_theta,
            sliding_window=cfg.sliding_window, live=live,
            kv_read=kv_read if paged is not None else "gather",
            **_paged_args(kind, cfg, paged, pages, pages_swa))
    elif kind == "mla":
        y, new_cache = attn_lib.apply_mla_decode(
            p, x, cache, pos, num_heads=cfg.num_heads,
            kv_lora_rank=cfg.kv_lora_rank, qk_nope_dim=cfg.qk_nope_dim,
            qk_rope_dim=cfg.qk_rope_dim, v_head_dim=cfg.v_head_dim,
            rope_theta=cfg.rope_theta, live=live,
            **_paged_args(kind, cfg, paged, pages, pages_swa))
    elif kind == "cross":
        y = attn_lib.apply_cross_attention(p, x, memory, num_heads=cfg.num_heads,
                                           num_kv_heads=cfg.num_kv_heads,
                                           head_dim=cfg.head_dim_)
        new_cache = cache
    elif kind == "mlp":
        y, new_cache = apply_mlp(p, x), cache
    elif kind == "moe":
        if "router" in p:
            # decode: capacity = all tokens (dropping a decode token is a
            # user-visible quality bug, so serving never drops)
            y, _ = moe_lib.apply_moe(p, x, top_k=cfg.experts_per_token,
                                     capacity_factor=float(cfg.num_experts))
        else:
            y = apply_mlp(p, x)
        new_cache = cache
    elif kind == "mamba":
        y, new_cache = mamba_lib.apply_mamba_decode(p, x, cache, d_state=cfg.d_state)
    elif kind == "rwkv_tm":
        st = {"wkv": cache["wkv"], "x_prev_tm": cache["x_prev"]}
        y, st = rwkv_lib.apply_rwkv_timemix_decode(p, x, st, num_heads=cfg.num_heads)
        new_cache = {"wkv": st["wkv"], "x_prev": st["x_prev_tm"]}
    elif kind == "rwkv_cm":
        st = {"x_prev_cm": cache["x_prev"]}
        y, st = rwkv_lib.apply_rwkv_channelmix_decode(p, x, st)
        new_cache = {"x_prev": st["x_prev_cm"]}
    else:
        raise ValueError(kind)
    if live is not None and kind in ("mamba", "rwkv_tm", "rwkv_cm"):
        # recurrent state commits only for live rows (a mid-prefill slot's
        # state must not advance on interleaved decode steps)
        new_cache = jax.tree.map(
            lambda n, o: jnp.where(
                live.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
            new_cache, cache)
    return y, new_cache


def apply_superblock_decode(p_sb, cache_sb, cfg: ModelConfig, h, pos, *,
                            pattern=None, memory=None, paged=None, pages=None,
                            pages_swa=None, live=None, kv_read="gather"):
    pattern = pattern or cfg.block_pattern
    new_cache = {}
    for li, layer in enumerate(pattern):
        for si, kind in enumerate(layer):
            key = f"l{li}_{si}_{kind}"
            y, new_cache[key] = apply_sublayer_decode(
                kind, p_sb[key], cache_sb[key], cfg, h, pos, memory=memory,
                paged=paged, pages=pages, pages_swa=pages_swa, live=live,
                kv_read=kv_read)
            h = h + y
    return h, new_cache


def apply_stack_decode(stacked, cache, cfg: ModelConfig, h, pos, *, memory=None,
                       paged=None, pages=None, pages_swa=None, live=None,
                       kv_read="gather"):
    """One-token decode through the whole stack; cache leaves have leading
    superblock dim.  Returns (h, new_cache).  Page tables (``pages`` /
    ``pages_swa``) are shared by every superblock — the scan closes over
    them; only the pools are scanned."""

    def body(h, xs):
        p_sb, cache_sb = xs
        h, new_cache_sb = apply_superblock_decode(p_sb, cache_sb, cfg, h, pos,
                                                  memory=memory, paged=paged,
                                                  pages=pages,
                                                  pages_swa=pages_swa,
                                                  live=live, kv_read=kv_read)
        return h, new_cache_sb

    h, new_cache = jax.lax.scan(body, h, (stacked, cache))
    return h, new_cache


# ---------------------------------------------------------------------------
# chunked prefill (C tokens per row, per-row start positions, ragged tails)
# ---------------------------------------------------------------------------

def _prefill_stateful(kind: str, p, cache, cfg: ModelConfig, x, valid):
    """Recurrent sublayers advance sequentially INSIDE the program: a
    lax.scan over the chunk's C positions reusing the O(1) decode step,
    committing state only where ``valid`` (padded positions leave state and
    token-shift buffers untouched).  One dispatch regardless of C."""

    def step(state, inp):
        x_j, v_j = inp                                   # (B,d), (B,)
        if kind == "mamba":
            y, ns = mamba_lib.apply_mamba_decode(p, x_j[:, None], state,
                                                 d_state=cfg.d_state)
        elif kind == "rwkv_tm":
            st = {"wkv": state["wkv"], "x_prev_tm": state["x_prev"]}
            y, st = rwkv_lib.apply_rwkv_timemix_decode(p, x_j[:, None], st,
                                                       num_heads=cfg.num_heads)
            ns = {"wkv": st["wkv"], "x_prev": st["x_prev_tm"]}
        else:  # rwkv_cm
            st = {"x_prev_cm": state["x_prev"]}
            y, st = rwkv_lib.apply_rwkv_channelmix_decode(p, x_j[:, None], st)
            ns = {"x_prev": st["x_prev_cm"]}
        ns = jax.tree.map(
            lambda n, o: jnp.where(v_j.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
            ns, state)
        return ns, y[:, 0]

    new_cache, ys = jax.lax.scan(step, cache, (x.swapaxes(0, 1), valid.T))
    return ys.swapaxes(0, 1), new_cache


def apply_sublayer_prefill(kind: str, p, cache, cfg: ModelConfig, h, pos,
                           valid, *, memory=None, paged=None, pages=None,
                           pages_swa=None):
    """Chunked-prefill sublayer step.  h (B,C,d); pos (B,) start positions;
    valid (B,C) marks real tokens.  Returns (residual update, new_cache).
    Padded positions never touch caches or recurrent state; their outputs
    are garbage the caller must mask/ignore."""
    x = _apply_norm(cfg, p["norm"], h)
    if kind == "attn":
        y, new_cache = attn_lib.apply_gqa_prefill(
            p, x, cache, pos, valid, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.head_dim_,
            rotary_dim=cfg.rotary_dim, rope_theta=cfg.rope_theta,
            sliding_window=cfg.sliding_window,
            **_paged_args(kind, cfg, paged, pages, pages_swa))
    elif kind == "mla":
        y, new_cache = attn_lib.apply_mla_prefill(
            p, x, cache, pos, valid, num_heads=cfg.num_heads,
            kv_lora_rank=cfg.kv_lora_rank, qk_nope_dim=cfg.qk_nope_dim,
            qk_rope_dim=cfg.qk_rope_dim, v_head_dim=cfg.v_head_dim,
            rope_theta=cfg.rope_theta,
            **_paged_args(kind, cfg, paged, pages, pages_swa))
    elif kind == "cross":
        y = attn_lib.apply_cross_attention(p, x, memory, num_heads=cfg.num_heads,
                                           num_kv_heads=cfg.num_kv_heads,
                                           head_dim=cfg.head_dim_)
        new_cache = cache
    elif kind == "mlp":
        y, new_cache = apply_mlp(p, x), cache
    elif kind == "moe":
        if "router" in p:
            # full capacity, exactly like decode: serving never drops tokens,
            # which also keeps every position independent of its chunk-mates
            y, _ = moe_lib.apply_moe(p, x, top_k=cfg.experts_per_token,
                                     capacity_factor=float(cfg.num_experts))
        else:
            y = apply_mlp(p, x)
        new_cache = cache
    elif kind in ("mamba", "rwkv_tm", "rwkv_cm"):
        y, new_cache = _prefill_stateful(kind, p, cache, cfg, x, valid)
    else:
        raise ValueError(kind)
    return y, new_cache


def apply_superblock_prefill(p_sb, cache_sb, cfg: ModelConfig, h, pos, valid, *,
                             pattern=None, memory=None, paged=None, pages=None,
                             pages_swa=None):
    pattern = pattern or cfg.block_pattern
    new_cache = {}
    for li, layer in enumerate(pattern):
        for si, kind in enumerate(layer):
            key = f"l{li}_{si}_{kind}"
            y, new_cache[key] = apply_sublayer_prefill(
                kind, p_sb[key], cache_sb[key], cfg, h, pos, valid,
                memory=memory, paged=paged, pages=pages, pages_swa=pages_swa)
            h = h + y
    return h, new_cache


def apply_stack_prefill(stacked, cache, cfg: ModelConfig, h, pos, valid, *,
                        memory=None, paged=None, pages=None, pages_swa=None):
    """Chunked prefill through the whole stack; cache leaves have leading
    superblock dim.  Returns (h (B,C,d), new_cache)."""

    def body(h, xs):
        p_sb, cache_sb = xs
        h, new_cache_sb = apply_superblock_prefill(p_sb, cache_sb, cfg, h, pos,
                                                   valid, memory=memory,
                                                   paged=paged, pages=pages,
                                                   pages_swa=pages_swa)
        return h, new_cache_sb

    h, new_cache = jax.lax.scan(body, h, (stacked, cache))
    return h, new_cache
