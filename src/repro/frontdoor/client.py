"""Asyncio client for the split-serving front door.

The edge-client side of the wire protocol: one TCP connection, a HELLO
handshake pinning the cut-layer codec spec, then any number of in-flight
``SUBMIT``s multiplexed by request id.  ``BUSY`` replies (admission
shedding) surface as :class:`BusyError` with the server's retry hint;
:meth:`generate` wraps submit+wait in the retry loop an edge client would
run.

    client = await FrontDoorClient.open(host, port, tenant="edge-7",
                                        codec="c3sl:R=4|int8")
    out = await client.generate([1, 2, 3], max_new=16)
    print(out["tokens"], out["ttft_s"])
    await client.close()
"""
from __future__ import annotations

import asyncio
import itertools

import numpy as np

from repro.frontdoor import protocol as proto
from repro.frontdoor.protocol import MsgType, ProtocolError


class FrontDoorError(Exception):
    """Server refused the connection or the request (not retriable)."""


class BusyError(Exception):
    """Admission shed the request; retry after ``retry_after_ms``."""

    def __init__(self, reason: str, retry_after_ms: int):
        super().__init__(f"server busy ({reason}); "
                         f"retry in {retry_after_ms}ms")
        self.reason = reason
        self.retry_after_ms = retry_after_ms


class FrontDoorClient:
    def __init__(self, reader, writer, *, tenant: str, server_info: dict):
        self._reader = reader
        self._writer = writer
        self.tenant = tenant
        self.server_info = server_info       # HELLO_OK header
        self._rids = itertools.count()
        self._acks: dict[int, asyncio.Future] = {}
        self._results: dict[int, asyncio.Future] = {}
        self._stats: list[asyncio.Future] = []
        self._bye: asyncio.Future | None = None
        self._conn_error: Exception | None = None
        self._read_task = asyncio.create_task(self._read_loop())

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    @classmethod
    async def open(cls, host: str, port: int, *, tenant: str,
                   codec: str = "none") -> "FrontDoorClient":
        reader, writer = await asyncio.open_connection(host, port)
        await proto.send_frame(writer, MsgType.HELLO,
                               {"tenant": tenant, "codec": codec})
        frame = await proto.read_frame(reader)
        if frame is None:
            raise FrontDoorError("server closed the connection mid-handshake")
        mtype, header, _, _ = frame
        if mtype == MsgType.ERROR:
            writer.close()
            raise FrontDoorError(header.get("reason", "handshake refused"))
        if mtype != MsgType.HELLO_OK:
            writer.close()
            raise FrontDoorError(f"expected HELLO_OK, got {mtype.name}")
        return cls(reader, writer, tenant=tenant, server_info=header)

    async def close(self):
        """BYE handshake, then tear the connection down."""
        if self._bye is None and self._conn_error is None:
            self._bye = asyncio.get_running_loop().create_future()
            try:
                await proto.send_frame(self._writer, MsgType.BYE, {})
                await asyncio.wait_for(asyncio.shield(self._bye), timeout=10)
            except (ConnectionError, asyncio.TimeoutError):
                pass
        self._read_task.cancel()
        try:
            await self._read_task
        except (asyncio.CancelledError, Exception):
            pass
        self._writer.close()
        try:
            await self._writer.wait_closed()
        except Exception:
            pass

    # ------------------------------------------------------------------
    # RPCs
    # ------------------------------------------------------------------

    async def submit(self, prompt, *, max_new: int = 16,
                     priority: int | None = None) -> int:
        """One SUBMIT; returns the rid once ACCEPTED.  Raises BusyError on
        admission shedding, FrontDoorError on a server-side refusal."""
        self._check_conn()
        rid = next(self._rids)
        header = {"rid": rid, "max_new": max_new}
        if priority is not None:
            header["priority"] = priority
        arr_header, payload = proto.pack_array(
            np.asarray(list(prompt), dtype=np.int32))
        header.update(arr_header)
        loop = asyncio.get_running_loop()
        self._acks[rid] = loop.create_future()
        self._results[rid] = loop.create_future()
        await proto.send_frame(self._writer, MsgType.SUBMIT, header, payload)
        try:
            await self._acks[rid]
        except BaseException:
            self._results.pop(rid, None)
            raise
        finally:
            self._acks.pop(rid, None)
        return rid

    async def result(self, rid: int) -> dict:
        """Await one rid's RESULT: {"tokens", "ttft_s", "evictions"}."""
        fut = self._results[rid]
        try:
            return await fut
        finally:
            self._results.pop(rid, None)

    async def generate(self, prompt, *, max_new: int = 16,
                       priority: int | None = None, retries: int = 64,
                       backoff_s: float = 0.02) -> dict:
        """submit + result with the BUSY retry loop an edge client runs."""
        for attempt in range(retries):
            try:
                rid = await self.submit(prompt, max_new=max_new,
                                        priority=priority)
                break
            except BusyError as e:
                await asyncio.sleep(max(e.retry_after_ms / 1e3,
                                        backoff_s * (attempt + 1)))
        else:
            raise FrontDoorError(f"server still busy after {retries} tries")
        return await self.result(rid)

    async def stats(self) -> dict:
        """The server's per-tenant QoS + engine counters snapshot."""
        self._check_conn()
        fut = asyncio.get_running_loop().create_future()
        self._stats.append(fut)
        await proto.send_frame(self._writer, MsgType.STATS, {})
        return await fut

    # ------------------------------------------------------------------
    # frame dispatch
    # ------------------------------------------------------------------

    def _check_conn(self):
        if self._conn_error is not None:
            raise FrontDoorError(f"connection dead: {self._conn_error}")

    async def _read_loop(self):
        try:
            while True:
                frame = await proto.read_frame(self._reader)
                if frame is None:
                    raise ConnectionError("server closed the connection")
                self._dispatch(*frame[:3])
        except asyncio.CancelledError:
            raise
        except Exception as e:
            self._conn_error = e
            for fut in (*self._acks.values(), *self._results.values(),
                        *self._stats,
                        *((self._bye,) if self._bye else ())):
                if not fut.done():
                    fut.set_exception(FrontDoorError(str(e)))

    def _dispatch(self, mtype: MsgType, header: dict, payload: bytes):
        rid = header.get("rid")
        if mtype == MsgType.ACCEPTED:
            fut = self._acks.get(rid)
            if fut and not fut.done():
                fut.set_result(rid)
        elif mtype == MsgType.BUSY:
            fut = self._acks.get(rid)
            self._results.pop(rid, None)
            if fut and not fut.done():
                fut.set_exception(BusyError(header.get("reason", "busy"),
                                            header.get("retry_after_ms", 50)))
        elif mtype == MsgType.RESULT:
            fut = self._results.get(rid)
            if fut and not fut.done():
                tokens = proto.unpack_array(header, payload)
                fut.set_result({"tokens": [int(t) for t in tokens],
                                "ttft_s": header.get("ttft_s"),
                                "evictions": header.get("evictions", 0)})
        elif mtype == MsgType.ERROR:
            err = FrontDoorError(header.get("reason", "server error"))
            if rid is not None:
                for book in (self._acks, self._results):
                    fut = book.get(rid)
                    if fut and not fut.done():
                        fut.set_exception(err)
                self._results.pop(rid, None)
            else:
                raise ProtocolError(str(err))   # connection-level failure
        elif mtype == MsgType.STATS_OK:
            if self._stats:
                fut = self._stats.pop(0)
                if not fut.done():
                    fut.set_result(header.get("stats", {}))
        elif mtype == MsgType.BYE_OK:
            if self._bye and not self._bye.done():
                self._bye.set_result(True)
        else:
            raise ProtocolError(f"unexpected {mtype.name} frame from server")
