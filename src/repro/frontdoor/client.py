"""Asyncio client for the split-serving front door.

The edge-client side of the wire protocol: one TCP connection through
the reliable :class:`~repro.frontdoor.stream.FrameStream` layer, a HELLO
handshake pinning the cut-layer codec spec, then any number of in-flight
``SUBMIT``s multiplexed by request id.  ``BUSY`` replies (admission
shedding) surface as :class:`BusyError` with the server's retry hint;
:meth:`generate` wraps submit+wait in the retry loop an edge client would
run — exponential backoff with deterministic jitter, bounded by both a
retry count and an optional wall-clock ``deadline_s`` (exhausting either
raises the typed :class:`DeadlineExceeded`).

Failure recovery: when the connection dies mid-session (server restart,
injected chaos disconnect, NACK budget exhausted) and ``reconnect`` is
on, the client reconnects and presents its session token; the server
re-admits the work it withdrew at detach (greedy output bit-identical to
an uninterrupted run) and flushes any parked results.  SUBMITs that were
never ACKed are re-sent on the new connection — the server treats a
repeated rid idempotently — so no request is lost or doubled across the
disconnect.

    client = await FrontDoorClient.open(host, port, tenant="edge-7",
                                        codec="c3sl:R=4|int8")
    out = await client.generate([1, 2, 3], max_new=16)
    print(out["tokens"], out["ttft_s"])
    await client.close()
"""
from __future__ import annotations

import asyncio
import itertools
import random
import time
import zlib

import numpy as np

from repro.frontdoor import protocol as proto
from repro.frontdoor.protocol import MsgType, ProtocolError
from repro.frontdoor.stream import FrameStream


class FrontDoorError(Exception):
    """Server refused the connection or the request (not retriable)."""


class DeadlineExceeded(FrontDoorError):
    """The retry budget (attempts or wall-clock deadline) ran out."""


class BusyError(Exception):
    """Admission shed the request; retry after ``retry_after_ms``."""

    def __init__(self, reason: str, retry_after_ms: int):
        super().__init__(f"server busy ({reason}); "
                         f"retry in {retry_after_ms}ms")
        self.reason = reason
        self.retry_after_ms = retry_after_ms


class FrontDoorClient:
    def __init__(self, host: str, port: int, *, tenant: str,
                 codec: str = "none", draft: str | None = None,
                 on_tokens=None, faults=None, reconnect: bool = True,
                 reconnect_tries: int = 4, reconnect_backoff_s: float = 0.05,
                 handshake_timeout_s: float = 10.0,
                 handshake_ping_s: float = 0.5):
        self.host, self.port = host, port
        self.tenant = tenant
        self.codec = codec
        self.draft = draft                   # pin the draft-channel spec too
        self.on_tokens = on_tokens           # (rid, [tokens]) per burst
        self.faults = faults                 # FaultPlan on the c2s direction
        self.reconnect = reconnect
        self.reconnect_tries = reconnect_tries
        self.reconnect_backoff_s = reconnect_backoff_s
        self.handshake_timeout_s = handshake_timeout_s
        self.handshake_ping_s = handshake_ping_s
        self.server_info: dict = {}          # last HELLO_OK header
        self.session: str | None = None      # server-minted resume token
        self._rids = itertools.count()
        self._epoch = 0                      # connection attempts (fault key)
        self._stream: FrameStream | None = None
        self._read_task: asyncio.Task | None = None
        self._acks: dict[int, asyncio.Future] = {}
        self._results: dict[int, asyncio.Future] = {}
        # incremental TOKENS bursts by rid — best-effort preview (a burst
        # riding a dying connection is dropped, not retransmitted), so
        # this may be a PROPER prefix of the RESULT after a reconnect
        self._streamed: dict[int, list[int]] = {}
        # un-ACKed SUBMITs by rid, re-sent verbatim after a reconnect
        self._unacked: dict[int, tuple[dict, bytes]] = {}
        self._stats: list[asyncio.Future] = []
        self._bye: asyncio.Future | None = None
        self._conn_error: Exception | None = None
        self._conn_lock = asyncio.Lock()
        self._closed = False
        # deterministic jitter: seeded per tenant, so a fleet of tenants
        # decorrelates its BUSY retries while any one run stays replayable
        self._rng = random.Random(zlib.crc32(tenant.encode("utf-8")))

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    @classmethod
    async def open(cls, host: str, port: int, *, tenant: str,
                   codec: str = "none", **kwargs) -> "FrontDoorClient":
        client = cls(host, port, tenant=tenant, codec=codec, **kwargs)
        await client._connect()
        return client

    async def _connect(self):
        """Dial + handshake once; raises on refusal or timeout."""
        reader, writer = await asyncio.open_connection(self.host, self.port)
        stream = FrameStream(reader, writer, direction="c2s",
                             faults=self.faults, epoch=self._epoch)
        self._epoch += 1
        hello = {"tenant": self.tenant, "codec": self.codec}
        if self.draft is not None:
            hello["draft"] = self.draft
        if self.session is not None:
            hello["resume"] = self.session
        try:
            await stream.send(MsgType.HELLO, hello)
            # ping on silence so a dropped HELLO / HELLO_OK is recovered
            # via the watermark gap-NACK instead of the whole deadline
            deadline = time.monotonic() + self.handshake_timeout_s
            while True:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise asyncio.TimeoutError("handshake deadline")
                try:
                    got = await stream.recv(
                        timeout=min(max(self.handshake_ping_s, 0.05), left))
                    break
                except asyncio.TimeoutError:
                    await stream.ping()
        except (ConnectionError, OSError, asyncio.TimeoutError):
            stream.close()
            raise
        if got is None:
            stream.close()
            raise FrontDoorError("server closed the connection mid-handshake")
        mtype, header, _, _, _seq = got
        if mtype == MsgType.ERROR:
            stream.close()
            raise FrontDoorError(header.get("reason", "handshake refused"))
        if mtype != MsgType.HELLO_OK:
            stream.close()
            raise FrontDoorError(f"expected HELLO_OK, got {mtype.name}")
        self.server_info = header
        self.session = header.get("session", self.session)
        self._stream = stream
        self._conn_error = None
        self._read_task = asyncio.create_task(self._read_loop(stream))

    async def close(self):
        """BYE handshake, then tear the connection down."""
        self._closed = True
        if (self._bye is None and self._conn_error is None
                and self._stream is not None):
            self._bye = asyncio.get_running_loop().create_future()
            try:
                await self._stream.send(MsgType.BYE, {})
                await asyncio.wait_for(asyncio.shield(self._bye), timeout=10)
            except (ConnectionError, OSError, asyncio.TimeoutError,
                    FrontDoorError):
                # best-effort: a lost BYE_OK (or a connection that died
                # under the BYE) must not block teardown
                pass
        if self._read_task is not None:
            self._read_task.cancel()
            try:
                await self._read_task
            except (asyncio.CancelledError, Exception):  # lint-ok: R5 reaping a task WE just cancelled: its CancelledError is the expected result, not our own cancellation
                pass
        if self._stream is not None:
            self._stream.close()
            await self._stream.wait_closed()

    # ------------------------------------------------------------------
    # reconnect-with-resume
    # ------------------------------------------------------------------

    async def _send_data(self, mtype: MsgType, header: dict,
                         payload: bytes = b""):
        """Send one data frame, transparently reconnecting (and resuming
        the session) when the connection is dead or dies underneath the
        send — e.g. an injected chaos disconnect fires ON the send."""
        for _ in range(self.reconnect_tries + 1):
            self._check_conn()
            stream = self._stream
            try:
                return await stream.send(mtype, header, payload)
            except (ConnectionError, OSError) as e:
                await self._ensure_conn(stream, e)
        raise FrontDoorError("connection kept failing mid-send")

    async def _ensure_conn(self, failed: FrameStream, err: Exception):
        """Reconnect once per FAILED stream: concurrent callers (the read
        loop, a mid-send failure) serialize on the lock and whoever loses
        the race finds the fresh stream already installed."""
        async with self._conn_lock:
            if self._stream is not failed:
                return                        # somebody else already fixed it
            if self._closed or not self.reconnect:
                self._fail_all(err)
                raise FrontDoorError(f"connection dead: {err}")
            if self._read_task is not None \
                    and self._read_task is not asyncio.current_task():
                self._read_task.cancel()
                try:
                    await self._read_task
                except (asyncio.CancelledError, Exception):  # lint-ok: R5 reaping a task WE just cancelled before reconnecting
                    pass
            failed.close()
            last: Exception = err
            for attempt in range(self.reconnect_tries):
                try:
                    await self._connect()
                    break
                except FrontDoorError:
                    # server REFUSED the resume (token expired / tenant
                    # mismatch): retrying cannot help
                    self._fail_all(err)
                    raise
                except (ConnectionError, OSError,
                        asyncio.TimeoutError) as e:
                    last = e
                    await asyncio.sleep(self.reconnect_backoff_s
                                        * (attempt + 1))
            else:
                self._fail_all(last)
                raise FrontDoorError(f"reconnect failed: {last}")
            # replay SUBMITs the server never ACKed; repeated rids are
            # idempotent server-side, so a lost-ACK (vs lost-SUBMIT) race
            # cannot double-submit
            for rid, (header, payload) in list(self._unacked.items()):
                await self._stream.send(MsgType.SUBMIT, header, payload)

    def _fail_all(self, err: Exception):
        self._conn_error = err
        for fut in (*self._acks.values(), *self._results.values(),
                    *self._stats, *((self._bye,) if self._bye else ())):
            if not fut.done():
                fut.set_exception(FrontDoorError(str(err)))

    # ------------------------------------------------------------------
    # RPCs
    # ------------------------------------------------------------------

    async def submit(self, prompt, *, max_new: int = 16,
                     priority: int | None = None) -> int:
        """One SUBMIT; returns the rid once ACCEPTED.  Raises BusyError on
        admission shedding, FrontDoorError on a server-side refusal."""
        self._check_conn()
        rid = next(self._rids)
        header = {"rid": rid, "max_new": max_new}
        if priority is not None:
            header["priority"] = priority
        arr_header, payload = proto.pack_array(
            np.asarray(list(prompt), dtype=np.int32))
        header.update(arr_header)
        loop = asyncio.get_running_loop()
        self._acks[rid] = loop.create_future()
        self._results[rid] = loop.create_future()
        self._unacked[rid] = (header, payload)
        try:
            await self._send_data(MsgType.SUBMIT, header, payload)
            await self._acks[rid]
        except BaseException:
            self._results.pop(rid, None)
            self._unacked.pop(rid, None)
            self._streamed.pop(rid, None)
            raise
        finally:
            self._acks.pop(rid, None)
        return rid

    async def result(self, rid: int) -> dict:
        """Await one rid's RESULT: {"tokens", "streamed", "ttft_s",
        "ttlt_s", "accepted", "rejected", "rollbacks", "evictions"}.
        ``streamed`` is the incremental TOKENS preview actually received —
        always a prefix of ``tokens`` (and a proper prefix if a burst rode
        a dying connection)."""
        fut = self._results[rid]
        try:
            return await fut
        finally:
            self._results.pop(rid, None)

    async def generate(self, prompt, *, max_new: int = 16,
                       priority: int | None = None, retries: int = 64,
                       backoff_s: float = 0.02, max_backoff_s: float = 0.5,
                       deadline_s: float | None = None) -> dict:
        """submit + result with the BUSY retry loop an edge client runs:
        exponential backoff (never below the server's retry hint) with
        deterministic per-tenant jitter, stopping with
        :class:`DeadlineExceeded` when the attempts or the wall-clock
        ``deadline_s`` budget runs out."""
        t0 = time.monotonic()
        for attempt in range(retries):
            try:
                rid = await self.submit(prompt, max_new=max_new,
                                        priority=priority)
                break
            except BusyError as e:
                delay = max(e.retry_after_ms / 1e3,
                            min(backoff_s * 2.0 ** attempt, max_backoff_s))
                delay *= 0.5 + self._rng.random()  # jitter in [0.5x, 1.5x)
                if deadline_s is not None:
                    left = deadline_s - (time.monotonic() - t0)
                    if left <= delay:
                        raise DeadlineExceeded(
                            f"server still busy after {attempt + 1} tries "
                            f"and {deadline_s}s deadline") from e
                await asyncio.sleep(delay)
        else:
            raise DeadlineExceeded(f"server still busy after {retries} tries")
        return await self.result(rid)

    async def stats(self) -> dict:
        """The server's per-tenant QoS + engine counters snapshot."""
        self._check_conn()
        fut = asyncio.get_running_loop().create_future()
        self._stats.append(fut)
        await self._send_data(MsgType.STATS, {})
        return await fut

    # ------------------------------------------------------------------
    # frame dispatch
    # ------------------------------------------------------------------

    def _check_conn(self):
        if self._conn_error is not None:
            raise FrontDoorError(f"connection dead: {self._conn_error}")

    async def _read_loop(self, stream: FrameStream):
        try:
            while True:
                got = await stream.recv()
                if got is None:
                    raise ConnectionError("server closed the connection")
                mtype, header, payload, _nbytes, _seq = got
                self._dispatch(mtype, header, payload)
        except asyncio.CancelledError:
            raise
        except Exception as e:
            if self._closed or not self.reconnect:
                self._fail_all(e)
                return
            # pending STATS are FIFO-matched to THIS connection's replies;
            # they cannot survive a reconnect (results/acks can — resume
            # restores them)
            for fut in self._stats:
                if not fut.done():
                    fut.set_exception(FrontDoorError(str(e)))
            self._stats.clear()
            try:
                await self._ensure_conn(stream, e)
            except FrontDoorError:
                pass                          # futures already failed

    def _dispatch(self, mtype: MsgType, header: dict, payload: bytes):
        rid = header.get("rid")
        if mtype == MsgType.ACCEPTED:
            self._unacked.pop(rid, None)
            fut = self._acks.get(rid)
            if fut and not fut.done():
                fut.set_result(rid)
        elif mtype == MsgType.BUSY:
            self._unacked.pop(rid, None)
            fut = self._acks.get(rid)
            self._results.pop(rid, None)
            self._streamed.pop(rid, None)
            if fut and not fut.done():
                fut.set_exception(BusyError(header.get("reason", "busy"),
                                            header.get("retry_after_ms", 50)))
        elif mtype == MsgType.TOKENS:
            burst = [int(t) for t in proto.unpack_array(header, payload)]
            have = self._streamed.setdefault(rid, [])
            off = header.get("off", len(have))
            # bursts carry their absolute offset: a burst that was lost on
            # a dying connection leaves a GAP — keep the contiguous prefix
            # instead of silently splicing tokens at the wrong positions
            if off <= len(have) and off + len(burst) > len(have):
                fresh = burst[len(have) - off:]
                have.extend(fresh)
                if self.on_tokens is not None:
                    self.on_tokens(rid, fresh)
        elif mtype == MsgType.RESULT:
            self._unacked.pop(rid, None)
            streamed = self._streamed.pop(rid, [])
            fut = self._results.get(rid)
            if fut and not fut.done():
                tokens = proto.unpack_array(header, payload)
                fut.set_result({"tokens": [int(t) for t in tokens],
                                "streamed": streamed,
                                "ttft_s": header.get("ttft_s"),
                                "ttlt_s": header.get("ttlt_s"),
                                "accepted": header.get("accepted", 0),
                                "rejected": header.get("rejected", 0),
                                "rollbacks": header.get("rollbacks", 0),
                                "evictions": header.get("evictions", 0)})
        elif mtype == MsgType.ERROR:
            err = FrontDoorError(header.get("reason", "server error"))
            if rid is not None:
                self._unacked.pop(rid, None)
                self._streamed.pop(rid, None)
                for book in (self._acks, self._results):
                    fut = book.get(rid)
                    if fut and not fut.done():
                        fut.set_exception(err)
                self._results.pop(rid, None)
            else:
                raise ProtocolError(str(err))   # connection-level failure
        elif mtype == MsgType.STATS_OK:
            if self._stats:
                fut = self._stats.pop(0)
                if not fut.done():
                    fut.set_result(header.get("stats", {}))
        elif mtype == MsgType.BYE_OK:
            if self._bye and not self._bye.done():
                self._bye.set_result(True)
        else:
            raise ProtocolError(f"unexpected {mtype.name} frame from server")
