"""Per-tenant QoS accounting for the front door.

Every tenant gets a :class:`TenantQoS` record holding log-bucket
histograms of TTFT, per-request decode tokens/s, and per-request wire
bytes, plus scalar counters (requests, tokens, bytes in/out, BUSY
rejections, evictions).  The registry's :meth:`QoSRegistry.snapshot` is
what the ``STATS`` RPC ships — plain JSON-able dicts, no numpy.

Histograms are fixed log-spaced buckets (no unbounded per-request lists):
a long-lived server serves millions of requests, so percentiles are read
off the cumulative bucket counts (upper-bound estimate, clamped to the
exact observed min/max).
"""
from __future__ import annotations

import math


class LogHistogram:
    """Fixed log-spaced buckets over [lo, hi); O(1) record, O(buckets)
    percentile.  Values outside the range land in the edge buckets."""

    def __init__(self, lo: float = 1e-4, hi: float = 1e5,
                 per_decade: int = 10):
        self.lo, self.per_decade = lo, per_decade
        self.n = max(1, int(math.ceil(math.log10(hi / lo) * per_decade)))
        self.counts = [0] * self.n
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def _bucket(self, v: float) -> int:
        if v <= self.lo:
            return 0
        i = int(math.log10(v / self.lo) * self.per_decade)
        return min(i, self.n - 1)

    def record(self, v: float):
        self.counts[self._bucket(v)] += 1
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)

    def percentile(self, p: float) -> float | None:
        """Upper bucket bound at cumulative fraction ``p`` (0..100),
        clamped to the exact observed [min, max]."""
        if self.count == 0:
            return None
        need = p / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= need and c:
                upper = self.lo * 10.0 ** ((i + 1) / self.per_decade)
                return min(max(upper, self.vmin), self.vmax)
        return self.vmax

    def snapshot(self) -> dict:
        if self.count == 0:
            return {"count": 0}
        return {"count": self.count,
                "mean": self.total / self.count,
                "min": self.vmin, "max": self.vmax,
                "p50": self.percentile(50.0),
                "p99": self.percentile(99.0)}


class TenantQoS:
    """One tenant's accounting: histograms + scalar counters."""

    def __init__(self, tenant: str):
        self.tenant = tenant
        self.ttft_s = LogHistogram()              # submit -> first token
        self.ttlt_s = LogHistogram()              # submit -> last token
        self.tokens_per_s = LogHistogram(lo=1e-2, hi=1e7)   # decode rate
        self.wire_bytes = LogHistogram(lo=1.0, hi=1e10)     # per request
        self.requests = 0          # completed requests
        self.tokens_out = 0        # generated tokens delivered
        self.bytes_in = 0          # frame bytes received from this tenant
        self.bytes_out = 0         # frame bytes sent to this tenant
        self.busy_rejections = 0   # SUBMITs shed with BUSY
        self.errors = 0            # SUBMITs refused with ERROR
        self.evictions = 0         # preemptions suffered by this tenant
        self.disconnects = 0       # connections that died mid-session
        self.resumes = 0           # sessions reattached after a disconnect
        self.expired = 0           # detached sessions past the resume TTL
        self.retransmits = 0       # frames re-sent to this tenant (NACKed)
        self.nacks = 0             # NACKs received from this tenant's stream

    def record_result(self, *, ttft_s: float | None, gen_tokens: int,
                      decode_s: float, wire_bytes: int, evictions: int = 0,
                      ttlt_s: float | None = None):
        self.requests += 1
        self.tokens_out += gen_tokens
        self.evictions += evictions
        if ttft_s is not None:
            self.ttft_s.record(ttft_s)
        if ttlt_s is not None:
            self.ttlt_s.record(ttlt_s)
        if gen_tokens and decode_s > 0:
            self.tokens_per_s.record(gen_tokens / decode_s)
        self.wire_bytes.record(wire_bytes)

    def snapshot(self) -> dict:
        return {"requests": self.requests,
                "tokens_out": self.tokens_out,
                "bytes_in": self.bytes_in,
                "bytes_out": self.bytes_out,
                "busy_rejections": self.busy_rejections,
                "errors": self.errors,
                "evictions": self.evictions,
                "disconnects": self.disconnects,
                "resumes": self.resumes,
                "expired": self.expired,
                "retransmits": self.retransmits,
                "nacks": self.nacks,
                "ttft_s": self.ttft_s.snapshot(),
                "ttlt_s": self.ttlt_s.snapshot(),
                "tokens_per_s": self.tokens_per_s.snapshot(),
                "wire_bytes": self.wire_bytes.snapshot()}


class QoSRegistry:
    """All tenants' QoS records, created on first touch."""

    def __init__(self):
        self._tenants: dict[str, TenantQoS] = {}

    def tenant(self, name: str) -> TenantQoS:
        if name not in self._tenants:
            self._tenants[name] = TenantQoS(name)
        return self._tenants[name]

    def snapshot(self) -> dict:
        return {name: t.snapshot()
                for name, t in sorted(self._tenants.items())}
