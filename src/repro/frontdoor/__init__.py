"""repro.frontdoor — the multi-tenant split-serving front door.

The networked tier over :class:`repro.serving.engine.BatchedEngine`: many
edge clients stream cut-layer payloads (token prompts today; the frame
format carries dtype+shape so activation payloads ride the same frames)
over length-prefixed asyncio TCP frames to one server, which continuously
batches them into engine slots with admission control (per-tenant
concurrency caps, queue-depth shedding with retriable ``BUSY``),
per-tenant QoS accounting (TTFT / tokens-per-second / wire-byte
histograms via the ``STATS`` RPC), and — with engine ``preemption=True``
— priority eviction of low-priority slots under pool oversubscription.

The wire is fault-tolerant: every frame carries a CRC32 and a sequence
number, :class:`FrameStream` recovers damaged/dropped frames by
NACK/retransmit, connections have handshake and heartbeat deadlines, and
a dead connection detaches its session for ``resume_ttl_s`` — the client
reconnects with its session token and the server re-admits the withdrawn
work with greedy output bit-identical to an uninterrupted run.

See ``src/repro/frontdoor/README.md`` for the architecture sketch (frame
format, admission states, preemption policy, failure handling).
"""
from repro.faults import ChannelErasure, FaultPlan
from repro.frontdoor.admission import (ADMIT, BUSY_QUEUE, BUSY_TENANT,
                                       AdmissionController, TenantPolicy)
from repro.frontdoor.client import (BusyError, DeadlineExceeded,
                                    FrontDoorClient, FrontDoorError)
from repro.frontdoor.protocol import (CTRL_SEQ, FrameCorruption, MsgType,
                                      ProtocolError, decode_frame,
                                      encode_frame, pack_array, read_frame,
                                      send_frame, unpack_array)
from repro.frontdoor.qos import LogHistogram, QoSRegistry, TenantQoS
from repro.frontdoor.server import (FrontDoorServer, canonical_codec_spec,
                                    engine_codec_specs)
from repro.frontdoor.stream import FrameStream

__all__ = [
    "MsgType", "ProtocolError", "FrameCorruption", "CTRL_SEQ",
    "encode_frame", "decode_frame",
    "read_frame", "send_frame", "pack_array", "unpack_array",
    "FrameStream",
    "TenantPolicy", "AdmissionController", "ADMIT", "BUSY_TENANT",
    "BUSY_QUEUE",
    "LogHistogram", "TenantQoS", "QoSRegistry",
    "FrontDoorServer", "canonical_codec_spec", "engine_codec_specs",
    "FrontDoorClient", "FrontDoorError", "BusyError", "DeadlineExceeded",
    "FaultPlan", "ChannelErasure",
]
