"""The multi-tenant split-serving front door server.

Turns an in-process :class:`repro.serving.engine.BatchedEngine` into a
networked server: N concurrent client connections stream length-prefixed
frames (``repro.frontdoor.protocol``) over asyncio TCP/loopback, a
continuous batcher drains accepted requests into engine slots, and
per-tenant QoS accounting (``repro.frontdoor.qos``) is exposed through a
``STATS`` RPC.

Concurrency model: everything — connection handlers, admission, engine
stepping — runs on ONE event loop thread.  Handlers only run between
engine dispatches (``engine.tick()`` is synchronous), so no locks guard
the engine or the books; the engine must not be driven by anything else
while the server owns it.  ``auto_tick=False`` parks the compute loop so
tests can stage every submission first and then :meth:`drain`
deterministically — that is what makes the loopback-vs-direct
bit-identical equivalence tests possible under a batch-wise codec (slot
occupancy affects C3-SL superposition cross-talk, so the dispatch
schedule must match exactly).

The HELLO handshake pins the cut-layer codec contract: the client's spec
string is canonicalized exactly like the engine's (same registry build,
same D, same slot clamp) and must equal the engine's canonical spec — or,
for an adaptive engine, may name one of its R buckets (the server's
controller owns the schedule; a bucket client is pinned to a compatible
wire format).  Any other spec is refused with ``ERROR`` at connect time:
codec mismatch is a handshake failure, never silently decoded garbage.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import time

import numpy as np

from repro import codecs as codecs_lib
from repro.frontdoor import protocol as proto
from repro.frontdoor.admission import (ADMIT, BUSY_QUEUE, AdmissionController)
from repro.frontdoor.protocol import MsgType, ProtocolError
from repro.frontdoor.qos import QoSRegistry
from repro.serving.engine import BatchedEngine, Request


def canonical_codec_spec(spec, D: int, num_slots: int) -> str:
    """The canonical form of a cut-layer codec spec as the ENGINE would
    serve it: link specs resolve to their forward channel, runtime dims
    filled (D), R clamped to the slot count, then the registry's
    round-trip spec string.  Two specs are wire-compatible iff their
    canonical forms are equal."""
    from repro import transport
    if spec is None or spec == "none":
        return "none"
    if transport.is_link_spec(spec):
        spec = transport.build_link(spec, D=D).fwd.codec
    codec = codecs_lib.build(spec, D=D) if isinstance(spec, str) else spec
    return codecs_lib.clamp_R(codec, num_slots).spec()


def engine_codec_specs(engine: BatchedEngine) -> tuple[str, set[str]]:
    """The engine's canonical spec plus the set of additionally-compatible
    specs (an adaptive engine's per-bucket static specs)."""
    if engine.codec is None:
        return "none", set()
    spec = engine.codec.spec()
    compat = set()
    if isinstance(engine.codec, codecs_lib.AdaptiveC3SL):
        compat = {c.spec() for c in engine.codec.buckets.values()}
    return spec, compat


@dataclasses.dataclass
class _Conn:
    writer: asyncio.StreamWriter
    tenant: str
    open: bool = True


@dataclasses.dataclass
class _Route:
    """Where a submitted request's result goes, plus its QoS timestamps."""
    conn: _Conn
    rid: int
    tenant: str
    bytes_in: int            # SUBMIT frame bytes (per-request wire cost)


class FrontDoorServer:
    def __init__(self, engine: BatchedEngine, *, host: str = "127.0.0.1",
                 port: int = 0, admission: AdmissionController | None = None,
                 qos: QoSRegistry | None = None, auto_tick: bool = True,
                 idle_sleep_s: float = 0.002, busy_retry_ms: int = 25):
        self.engine = engine
        self.host, self.port = host, port
        self.admission = admission or AdmissionController()
        self.qos = qos or QoSRegistry()
        self.auto_tick = auto_tick
        self.idle_sleep_s = idle_sleep_s
        self.busy_retry_ms = busy_retry_ms
        self._spec, self._compat_specs = engine_codec_specs(engine)
        self._uids = itertools.count()
        self._routes: dict[int, _Route] = {}
        self._server: asyncio.base_events.Server | None = None
        self._tick_task: asyncio.Task | None = None
        self._closing = False

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    async def start(self) -> tuple[str, int]:
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.host, self.port = self._server.sockets[0].getsockname()[:2]
        if self.auto_tick:
            self._tick_task = asyncio.create_task(self._tick_loop())
        return self.host, self.port

    async def stop(self, *, drain: bool = True):
        """Clean shutdown: optionally finish all admitted work (results
        delivered), then stop ticking and close the listener."""
        if drain:
            await self.drain()
        self._closing = True
        if self._tick_task is not None:
            self._tick_task.cancel()
            try:
                await self._tick_task
            except asyncio.CancelledError:
                pass
            self._tick_task = None
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def drain(self):
        """Tick until the engine is idle and every finished request has
        been delivered (or its connection is gone)."""
        eng = self.engine
        while eng.queue or eng.active or eng.finished or self._routes:
            worked = await self._pump()
            if not worked:
                if not (eng.queue or eng.active or eng.finished):
                    break                      # routes of dead conns only
                await asyncio.sleep(0)

    async def _tick_loop(self):
        while not self._closing:
            worked = await self._pump()
            # yield even after useful work so handlers get to run between
            # dispatches; park on the idle sleep otherwise
            await asyncio.sleep(0 if worked else self.idle_sleep_s)

    async def _pump(self) -> bool:
        """One engine tick plus result delivery; True if anything moved."""
        eng = self.engine
        worked = False
        if eng.queue or eng.active:
            worked = eng.tick()
        worked |= await self._deliver()
        return worked

    async def _deliver(self) -> bool:
        eng = self.engine
        if not eng.finished:
            return False
        finished, eng.finished = list(eng.finished), []
        now = time.monotonic()
        for req in finished:
            route = self._routes.pop(req.uid, None)
            if route is None:
                continue                      # not ours (direct submit)
            self.admission.release(route.tenant)
            tq = self.qos.tenant(route.tenant)
            ttft = (req.t_first - req.t_submit
                    if req.t_first is not None else None)
            decode_s = (now - req.t_first) if req.t_first is not None else 0.0
            header = {"rid": route.rid, "ttft_s": ttft,
                      "evictions": req.evictions}
            arr_header, payload = proto.pack_array(
                np.asarray(req.out, dtype=np.int32))
            header.update(arr_header)
            sent = 0
            if route.conn.open:
                try:
                    sent = await proto.send_frame(route.conn.writer,
                                                  MsgType.RESULT, header,
                                                  payload)
                    tq.bytes_out += sent
                except (ConnectionError, RuntimeError):
                    route.conn.open = False
            tq.record_result(ttft_s=ttft, gen_tokens=len(req.out),
                             decode_s=decode_s,
                             wire_bytes=route.bytes_in + sent,
                             evictions=req.evictions)
        return True

    # ------------------------------------------------------------------
    # connection handling
    # ------------------------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter):
        conn: _Conn | None = None
        try:
            conn = await self._handshake(reader, writer)
            if conn is None:
                return
            while True:
                frame = await proto.read_frame(reader)
                if frame is None:
                    break                     # peer went away
                mtype, header, payload, nbytes = frame
                self.qos.tenant(conn.tenant).bytes_in += nbytes
                if mtype == MsgType.SUBMIT:
                    await self._submit(conn, header, payload, nbytes)
                elif mtype == MsgType.STATS:
                    out = await proto.send_frame(
                        conn.writer, MsgType.STATS_OK,
                        {"stats": self.stats()})
                    self.qos.tenant(conn.tenant).bytes_out += out
                elif mtype == MsgType.BYE:
                    await proto.send_frame(conn.writer, MsgType.BYE_OK, {})
                    break
                else:
                    raise ProtocolError(f"unexpected {mtype.name} frame "
                                        "after handshake")
        except ProtocolError as e:
            # fail LOUDLY, then kill the connection: a framing/dtype error
            # means client and server no longer agree on the wire format
            try:
                await proto.send_frame(writer, MsgType.ERROR,
                                       {"reason": str(e)})
            except (ConnectionError, RuntimeError):
                pass
        except (ConnectionError, asyncio.CancelledError):
            pass
        finally:
            if conn is not None:
                conn.open = False
            writer.close()
            try:
                await writer.wait_closed()
            except Exception:
                pass

    async def _handshake(self, reader, writer) -> _Conn | None:
        frame = await proto.read_frame(reader)
        if frame is None:
            return None
        mtype, header, _, nbytes = frame
        if mtype != MsgType.HELLO:
            raise ProtocolError(f"expected HELLO, got {mtype.name}")
        tenant = header.get("tenant")
        if not isinstance(tenant, str) or not tenant:
            raise ProtocolError("HELLO carries no tenant id")
        spec = header.get("codec", "none")
        try:
            canon = canonical_codec_spec(spec, self.engine.cfg.d_model,
                                         self.engine.num_slots)
        except Exception as e:
            raise ProtocolError(f"unbuildable codec spec {spec!r}: {e}")
        if canon != self._spec and canon not in self._compat_specs:
            compat = sorted({self._spec, *self._compat_specs})
            raise ProtocolError(
                f"codec mismatch: client {spec!r} (canonical {canon!r}) vs "
                f"engine {self._spec!r}; compatible specs: {compat} — "
                "refusing the connection rather than decoding garbage")
        conn = _Conn(writer=writer, tenant=tenant)
        tq = self.qos.tenant(tenant)
        tq.bytes_in += nbytes
        tq.bytes_out += await proto.send_frame(
            writer, MsgType.HELLO_OK,
            {"codec": self._spec, "num_slots": self.engine.num_slots,
             "max_len": self.engine.max_len,
             "kv_layout": self.engine.kv_layout,
             "preemption": self.engine.preemption})
        return conn

    async def _submit(self, conn: _Conn, header: dict, payload: bytes,
                      nbytes: int):
        tq = self.qos.tenant(conn.tenant)
        rid = header.get("rid")
        if not isinstance(rid, int):
            raise ProtocolError("SUBMIT carries no integer rid")
        tokens = proto.unpack_array(header, payload)
        if tokens.ndim != 1 or tokens.dtype.name != "int32":
            raise ProtocolError(f"SUBMIT payload must be a 1-D int32 token "
                                f"array, got {tokens.dtype.name}"
                                f"{tokens.shape}")
        verdict = self.admission.try_admit(conn.tenant)
        if verdict != ADMIT:
            tq.busy_rejections += 1
            retry = self.busy_retry_ms * (4 if verdict == BUSY_QUEUE else 1)
            tq.bytes_out += await proto.send_frame(
                conn.writer, MsgType.BUSY,
                {"rid": rid, "reason": verdict, "retry_after_ms": retry})
            return
        policy = self.admission.policy(conn.tenant)
        req = Request(uid=next(self._uids),
                      prompt=[int(t) for t in tokens],
                      max_new_tokens=int(header.get("max_new", 16)),
                      priority=int(header.get("priority", policy.priority)))
        try:
            self.engine.submit(req)
        except ValueError as e:
            # engine-level refusal (empty/overlong prompt, footprint above
            # the whole pool): an ERROR the client must not retry verbatim
            self.admission.release(conn.tenant)
            tq.errors += 1
            tq.bytes_out += await proto.send_frame(
                conn.writer, MsgType.ERROR, {"rid": rid, "reason": str(e)})
            return
        self._routes[req.uid] = _Route(conn=conn, rid=rid,
                                       tenant=conn.tenant, bytes_in=nbytes)
        tq.bytes_out += await proto.send_frame(conn.writer, MsgType.ACCEPTED,
                                               {"rid": rid})

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """The STATS RPC body: per-tenant QoS plus the engine's serving
        counters (cut-layer wire bytes, served-R schedule, eviction and
        early-exit counts, page-pool occupancy)."""
        eng = self.engine
        return {"tenants": self.qos.snapshot(),
                "engine": {**eng.stats,
                           "r_served": {str(k): v
                                        for k, v in sorted(
                                            eng.r_served.items())},
                           "codec": self._spec,
                           "active_slots": eng.active,
                           "queued": len(eng.queue),
                           "pool": eng.pool_accounting()},
                "admission": {"inflight_total": self.admission.inflight_total,
                              "inflight": dict(self.admission.inflight),
                              "max_queue_depth":
                                  self.admission.max_queue_depth}}
