"""The multi-tenant split-serving front door server.

Turns an in-process :class:`repro.serving.engine.BatchedEngine` into a
networked server: N concurrent client connections stream length-prefixed
frames (``repro.frontdoor.protocol``) over asyncio TCP/loopback through
the reliable :class:`~repro.frontdoor.stream.FrameStream` layer
(sequencing + CRC + NACK/retransmit), a continuous batcher drains
accepted requests into engine slots, and per-tenant QoS accounting
(``repro.frontdoor.qos``) is exposed through a ``STATS`` RPC.

Concurrency model: everything — connection handlers, admission, engine
stepping — runs on ONE event loop thread.  Handlers only run between
engine dispatches (``engine.tick()`` is synchronous), so no locks guard
the engine or the books; the engine must not be driven by anything else
while the server owns it.  ``auto_tick=False`` parks the compute loop so
tests can stage every submission first and then :meth:`drain`
deterministically — that is what makes the loopback-vs-direct
bit-identical equivalence tests possible under a batch-wise codec (slot
occupancy affects C3-SL superposition cross-talk, so the dispatch
schedule must match exactly).

The HELLO handshake pins the cut-layer codec contract: the client's spec
string is canonicalized exactly like the engine's (same registry build,
same D, same slot clamp) and must equal the engine's canonical spec — or,
for an adaptive engine, may name one of its R buckets (the server's
controller owns the schedule; a bucket client is pinned to a compatible
wire format).  Any other spec is refused with ``ERROR`` at connect time:
codec mismatch is a handshake failure, never silently decoded garbage.

Failure handling (see src/repro/frontdoor/README.md):

* **Deadlines** — the handshake must complete within
  ``handshake_timeout_s`` (a half-open client can no longer hold a
  connection slot forever), and the per-connection read loop wakes every
  ``heartbeat_s`` of silence to PING; ``max_misses`` silent heartbeat
  intervals in a row declare the peer dead.

* **Detach / resume** — every handshake mints (or resumes) a session
  token.  When a connection dies with work outstanding, the session
  DETACHES: live requests are pulled out of the engine
  (``engine.withdraw`` — same capture machinery as slot preemption),
  their admission units are released immediately (the inflight counter
  is correct the moment the connection ends, on every failure path), and
  finished-but-undelivered results are parked.  A client reconnecting
  with the token within ``resume_ttl_s`` gets its withdrawn requests
  re-admitted and re-submitted — the engine re-prefills prompt + emitted
  tokens, so greedy output is bit-identical to an uninterrupted run —
  and its parked results flushed.  Past the TTL the session is swept and
  its parked work dropped.

* **Shutdown** — :meth:`stop` cancels every in-flight connection task
  and tears down all sessions, so no orphaned asyncio tasks or unclosed
  transports survive the server.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import time

import numpy as np

from repro import codecs as codecs_lib
from repro.faults import ChannelErasure
from repro.frontdoor import protocol as proto
from repro.frontdoor.admission import (ADMIT, BUSY_QUEUE, AdmissionController)
from repro.frontdoor.protocol import MsgType, ProtocolError
from repro.frontdoor.qos import QoSRegistry
from repro.frontdoor.stream import FrameStream
from repro.serving.engine import BatchedEngine, Request


def canonical_codec_spec(spec, D: int, num_slots: int) -> str:
    """The canonical form of a cut-layer codec spec as the ENGINE would
    serve it: link specs resolve to their forward channel, runtime dims
    filled (D), R clamped to the slot count, then the registry's
    round-trip spec string.  Two specs are wire-compatible iff their
    canonical forms are equal."""
    from repro import transport
    if spec is None or spec == "none":
        return "none"
    if transport.is_link_spec(spec):
        spec = transport.build_link(spec, D=D).fwd.codec
    codec = codecs_lib.build(spec, D=D) if isinstance(spec, str) else spec
    return codecs_lib.clamp_R(codec, num_slots).spec()


def engine_codec_specs(engine: BatchedEngine) -> tuple[str, set[str]]:
    """The engine's canonical spec plus the set of additionally-compatible
    specs (an adaptive engine's per-bucket static specs)."""
    if engine.codec is None:
        return "none", set()
    spec = engine.codec.spec()
    compat = set()
    if isinstance(engine.codec, codecs_lib.AdaptiveC3SL):
        compat = {c.spec() for c in engine.codec.buckets.values()}
    return spec, compat


@dataclasses.dataclass
class _Conn:
    stream: FrameStream
    tenant: str
    open: bool = True


@dataclasses.dataclass
class _Session:
    """One client's server-side continuity across connections."""
    token: str
    tenant: str
    conn: _Conn | None                       # live connection, None detached
    rids: dict = dataclasses.field(default_factory=dict)   # rid -> uid
    # rids whose RESULT was already delivered (bounded, insertion-ordered).
    # A replayed SUBMIT can race the parked-result flush on resume: by the
    # time it arrives the rid is gone from ``rids``, and without this set
    # it would be admitted AGAIN — a ghost request burning a slot and,
    # under a batch-wise codec, perturbing other requests' outputs.
    done_rids: dict = dataclasses.field(default_factory=dict)
    # finished results that could not be delivered: (rid, header, payload)
    parked: list = dataclasses.field(default_factory=list)

    def mark_delivered(self, rid, keep: int = 256):
        self.rids.pop(rid, None)
        self.done_rids[rid] = None
        while len(self.done_rids) > keep:
            del self.done_rids[next(iter(self.done_rids))]
    # requests pulled out of the engine at detach, awaiting resume:
    # (rid, Request) — the Request carries prompt + emitted tokens
    withdrawn: list = dataclasses.field(default_factory=list)
    detached_at: float | None = None
    epochs: int = 0                          # connections this session saw


@dataclasses.dataclass
class _Route:
    """Where a submitted request's result goes, plus its QoS timestamps."""
    sess: _Session
    rid: int
    tenant: str
    bytes_in: int            # SUBMIT frame bytes (per-request wire cost)


class FrontDoorServer:
    def __init__(self, engine: BatchedEngine, *, host: str = "127.0.0.1",
                 port: int = 0, admission: AdmissionController | None = None,
                 qos: QoSRegistry | None = None, auto_tick: bool = True,
                 idle_sleep_s: float = 0.002, busy_retry_ms: int = 25,
                 faults=None, handshake_timeout_s: float = 10.0,
                 heartbeat_s: float = 5.0, max_misses: int = 3,
                 resume_ttl_s: float = 30.0):
        self.engine = engine
        self.host, self.port = host, port
        self.admission = admission or AdmissionController()
        self.qos = qos or QoSRegistry()
        self.auto_tick = auto_tick
        self.idle_sleep_s = idle_sleep_s
        self.busy_retry_ms = busy_retry_ms
        self.faults = faults                 # FaultPlan on the s2c direction
        self.handshake_timeout_s = handshake_timeout_s
        self.heartbeat_s = heartbeat_s
        self.max_misses = max_misses
        self.resume_ttl_s = resume_ttl_s
        self._spec, self._compat_specs = engine_codec_specs(engine)
        # speculative-decoding contract (None when the engine decodes
        # vanilla): the draft channel's canonical codec spec plus the
        # pinned k/head, advertised in HELLO_OK and validated against any
        # draft spec the client supplies — a draft-channel mismatch is a
        # handshake failure exactly like a cut-layer codec mismatch.
        self._draft_spec = None
        if engine.spec_cfg is not None:
            self._draft_spec = (engine.draft_codec.spec()
                                if engine.draft_codec is not None else "none")
        self._uids = itertools.count()
        self._tokens = itertools.count()
        self._epochs = itertools.count()     # s2c fault epoch per connection
        self._routes: dict[int, _Route] = {}
        self._sessions: dict[str, _Session] = {}
        self._conn_tasks: set[asyncio.Task] = set()
        self._server: asyncio.base_events.Server | None = None
        self._tick_task: asyncio.Task | None = None
        self._tick_error: BaseException | None = None
        self._closing = False

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------

    async def start(self) -> tuple[str, int]:
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.host, self.port = self._server.sockets[0].getsockname()[:2]
        if self.auto_tick:
            self._tick_task = asyncio.create_task(self._tick_loop())
        return self.host, self.port

    @property
    def tick_error(self) -> BaseException | None:
        """The exception that killed the tick loop, if any — checked by
        selfcheck (and surfaced by stop(), which re-raises it)."""
        return self._tick_error

    async def stop(self, *, drain: bool = True):
        """Clean shutdown: optionally finish all admitted work (results
        delivered), then stop ticking, cancel every in-flight connection
        task, tear down all sessions, and close the listener — no
        orphaned tasks or held admission units survive."""
        if drain:
            await self.drain()
        self._closing = True
        if self._tick_task is not None:
            self._tick_task.cancel()
            try:
                await self._tick_task
            except asyncio.CancelledError:  # lint-ok: R5 reaping the tick task WE just cancelled at shutdown
                pass
            self._tick_task = None
        for task in list(self._conn_tasks):
            task.cancel()
        if self._conn_tasks:
            await asyncio.gather(*self._conn_tasks, return_exceptions=True)
        self._conn_tasks.clear()
        # any route still live (its connection task was cancelled before a
        # detach could run) holds one admission unit — release them all,
        # then drop the session books
        for route in self._routes.values():
            self.admission.release(route.tenant)
        self._routes.clear()
        self._sessions.clear()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def drain(self):
        """Tick until the engine is idle and every finished request has
        been delivered (or its connection is gone)."""
        eng = self.engine
        if self._tick_error is not None:
            return            # engine crashed: nothing will drain; stop()
        while eng.queue or eng.active or eng.finished or self._routes:
            worked = await self._pump()
            if not worked:
                if not (eng.queue or eng.active or eng.finished):
                    break                      # routes of dead conns only
                await asyncio.sleep(0)

    async def _tick_loop(self):
        try:
            while not self._closing:
                worked = await self._pump()
                # yield even after useful work so handlers get to run between
                # dispatches; park on the idle sleep otherwise
                await asyncio.sleep(0 if worked else self.idle_sleep_s)
        except asyncio.CancelledError:
            raise
        except BaseException as e:
            # An engine (or sanitizer-invariant) exception used to kill
            # this task SILENTLY: tenants hung forever on results that
            # would never come.  Record it and fail every connection fast
            # so callers (selfcheck, real clients) observe the crash.
            self._tick_error = e
            for task in list(self._conn_tasks):
                task.cancel()
            raise

    async def _pump(self) -> bool:
        """One engine tick plus result delivery; True if anything moved."""
        eng = self.engine
        worked = False
        if eng.queue or eng.active:
            worked = eng.tick()
        worked |= await self._stream_tokens()
        worked |= await self._deliver()
        self._sweep_expired()
        return worked

    async def _stream_tokens(self) -> bool:
        """Forward the engine's incremental token bursts as TOKENS frames.

        Each burst is the tokens one request emitted since its last burst
        (one per verify round under speculative decoding — that is what
        makes the client-visible latency profile show the k-token
        amortization).  Delivery is best-effort: RESULT still carries the
        FULL output, so a dead connection just drops the preview — the
        burst is NOT parked."""
        events = self.engine.pop_stream_events()
        if not events:
            return False
        for uid, start, tokens in events:
            route = self._routes.get(uid)
            if route is None:
                continue                      # not ours (direct submit)
            conn = route.sess.conn
            if conn is None or not conn.open:
                continue
            header = {"rid": route.rid, "off": start, "n": len(tokens)}
            arr_header, payload = proto.pack_array(
                np.asarray(tokens, dtype=np.int32))
            header.update(arr_header)
            try:
                sent = await conn.stream.send(MsgType.TOKENS, header,
                                              payload)
                self.qos.tenant(route.tenant).bytes_out += sent
            except (ConnectionError, RuntimeError, OSError):
                conn.open = False
        return True

    async def _deliver(self) -> bool:
        eng = self.engine
        if not eng.finished:
            return False
        finished, eng.finished = list(eng.finished), []
        now = time.monotonic()
        for req in finished:
            route = self._routes.pop(req.uid, None)
            if route is None:
                continue                      # not ours (direct submit)
            self.admission.release(route.tenant)
            tq = self.qos.tenant(route.tenant)
            ttft = (req.t_first - req.t_submit
                    if req.t_first is not None else None)
            decode_s = (now - req.t_first) if req.t_first is not None else 0.0
            ttlt = now - req.t_submit
            header = {"rid": route.rid, "ttft_s": ttft, "ttlt_s": ttlt,
                      "evictions": req.evictions,
                      "accepted": req.accepted, "rejected": req.rejected,
                      "rollbacks": req.rollbacks}
            arr_header, payload = proto.pack_array(
                np.asarray(req.out, dtype=np.int32))
            header.update(arr_header)
            sent = 0
            conn = route.sess.conn
            delivered = False
            if conn is not None and conn.open:
                try:
                    sent = await conn.stream.send(MsgType.RESULT, header,
                                                  payload)
                    tq.bytes_out += sent
                    delivered = True
                except (ConnectionError, RuntimeError, OSError):
                    conn.open = False
            if delivered:
                route.sess.mark_delivered(route.rid)
            else:
                # park for a reattach — the session keeps the result until
                # the client resumes or the resume TTL sweeps it
                route.sess.parked.append((route.rid, header, payload))
            tq.record_result(ttft_s=ttft, gen_tokens=len(req.out),
                             decode_s=decode_s,
                             wire_bytes=route.bytes_in + sent,
                             evictions=req.evictions, ttlt_s=ttlt)
        return True

    # ------------------------------------------------------------------
    # session continuity
    # ------------------------------------------------------------------

    def _detach(self, sess: _Session, reason: str):
        """The connection died with the session possibly holding work.
        Pull its live requests out of the engine and release their
        admission units RIGHT NOW — the inflight counter must be correct
        the moment the connection ends, whatever killed it — then park
        the session for ``resume_ttl_s``."""
        if sess.conn is not None:
            sess.conn.open = False
            sess.conn = None
        sess.detached_at = time.monotonic()
        self.qos.tenant(sess.tenant).disconnects += 1
        for uid, route in list(self._routes.items()):
            if route.sess is not sess:
                continue
            req = self.engine.withdraw(uid)
            if req is None:
                # finished but undelivered: _deliver will release its
                # admission unit and park the result on this session
                continue
            del self._routes[uid]
            self.admission.release(sess.tenant)
            sess.withdrawn.append((route.rid, req))

    async def _resume(self, sess: _Session, conn: _Conn):
        """Reattach a detached session: re-admit + re-submit everything
        that was withdrawn (the engine re-prefills prompt + emitted
        tokens, so greedy decode is bit-identical to an uninterrupted
        run), then flush parked results."""
        sess.conn = conn
        sess.detached_at = None
        sess.epochs += 1
        tq = self.qos.tenant(sess.tenant)
        tq.resumes += 1
        withdrawn, sess.withdrawn = sess.withdrawn, []
        for rid, req in withdrawn:
            verdict = self.admission.try_admit(sess.tenant)
            if verdict != ADMIT:
                # someone took the capacity while we were detached; the
                # client gets a typed refusal instead of a silent hang
                sess.rids.pop(rid, None)
                tq.errors += 1
                tq.bytes_out += await conn.stream.send(
                    MsgType.ERROR,
                    {"rid": rid, "reason": f"resume re-admission refused "
                                           f"({verdict})"})
                continue
            self.engine.submit(req)
            self._routes[req.uid] = _Route(sess=sess, rid=rid,
                                           tenant=sess.tenant, bytes_in=0)
        parked, sess.parked = sess.parked, []
        for rid, header, payload in parked:
            tq.bytes_out += await conn.stream.send(MsgType.RESULT, header,
                                                   payload)
            sess.mark_delivered(rid)

    def _sweep_expired(self):
        """Detached sessions past the resume TTL: drop their parked
        results and withdrawn requests (admission was already released at
        detach) and forget the token."""
        if self.resume_ttl_s is None:
            return
        now = time.monotonic()
        for token, sess in list(self._sessions.items()):
            if sess.detached_at is None:
                continue
            if now - sess.detached_at > self.resume_ttl_s:
                self.qos.tenant(sess.tenant).expired += 1
                del self._sessions[token]

    def _end_session(self, sess: _Session):
        """Clean BYE: anything still outstanding is abandoned by the
        client — withdraw it and release its admission units."""
        for uid, route in list(self._routes.items()):
            if route.sess is not sess:
                continue
            self.engine.withdraw(uid)
            del self._routes[uid]
            self.admission.release(sess.tenant)
        self._sessions.pop(sess.token, None)

    # ------------------------------------------------------------------
    # connection handling
    # ------------------------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter):
        task = asyncio.current_task()
        self._conn_tasks.add(task)
        stream = FrameStream(reader, writer, direction="s2c",
                             faults=self.faults, epoch=next(self._epochs))
        conn: _Conn | None = None
        sess: _Session | None = None
        clean = False
        try:
            try:
                conn, sess = await asyncio.wait_for(
                    self._handshake(stream), self.handshake_timeout_s)
            except asyncio.TimeoutError:
                return                        # half-open peer: free the slot
            if conn is None:
                return
            misses = 0
            while True:
                try:
                    got = await stream.recv(timeout=self.heartbeat_s)
                except asyncio.TimeoutError:
                    misses += 1
                    if misses > self.max_misses:
                        raise ConnectionError(
                            f"peer silent for {misses} heartbeat intervals")
                    await stream.ping()       # PONG carries the peer's
                    continue                  # send watermark -> gap NACKs
                misses = 0
                if got is None:
                    break                     # peer went away (EOF)
                mtype, header, payload, nbytes, _seq = got
                self.qos.tenant(conn.tenant).bytes_in += nbytes
                if mtype == MsgType.SUBMIT:
                    await self._submit(sess, conn, header, payload, nbytes)
                elif mtype == MsgType.STATS:
                    out = await conn.stream.send(MsgType.STATS_OK,
                                                 {"stats": self.stats()})
                    self.qos.tenant(conn.tenant).bytes_out += out
                elif mtype == MsgType.BYE:
                    await conn.stream.send(MsgType.BYE_OK, {})
                    clean = True
                    break
                else:
                    raise ProtocolError(f"unexpected {mtype.name} frame "
                                        "after handshake")
        except (ChannelErasure, ConnectionError, asyncio.TimeoutError):
            pass                              # abnormal end -> detach below
        except ProtocolError as e:
            # fail LOUDLY, then kill the connection: a framing/dtype error
            # means client and server no longer agree on the wire format
            try:
                await stream.send(MsgType.ERROR, {"reason": str(e)})
            except (ConnectionError, RuntimeError, OSError):
                pass
        except asyncio.CancelledError:
            # server shutdown: stop() releases the books after cancelling
            raise
        finally:
            self._conn_tasks.discard(task)
            if conn is not None:
                conn.open = False
                tq = self.qos.tenant(conn.tenant)
                tq.retransmits += stream.counters["retransmits"]
                tq.nacks += stream.counters["nacks"]
            if sess is not None:
                if clean:
                    self._end_session(sess)
                elif sess.conn is conn:       # not already resumed elsewhere
                    self._detach(sess, "connection lost")
            stream.close()
            try:
                await stream.wait_closed()
            except asyncio.CancelledError:  # lint-ok: R5 teardown path: this handler task is already being cancelled by stop(); the socket close must still finish
                pass

    async def _handshake(self, stream: FrameStream):
        # a dropped HELLO must not stall the full handshake deadline: ping
        # on silence — the peer's PONG carries its send watermark, the gap
        # NACK recovers the frame (the outer wait_for still bounds this)
        while True:
            try:
                got = await stream.recv(timeout=max(self.heartbeat_s, 0.05))
                break
            except asyncio.TimeoutError:
                await stream.ping()
        if got is None:
            return None, None
        mtype, header, _, nbytes, _seq = got
        if mtype != MsgType.HELLO:
            raise ProtocolError(f"expected HELLO, got {mtype.name}")
        tenant = header.get("tenant")
        if not isinstance(tenant, str) or not tenant:
            raise ProtocolError("HELLO carries no tenant id")
        spec = header.get("codec", "none")
        try:
            canon = canonical_codec_spec(spec, self.engine.cfg.d_model,
                                         self.engine.num_slots)
        except Exception as e:
            raise ProtocolError(f"unbuildable codec spec {spec!r}: {e}")
        if canon != self._spec and canon not in self._compat_specs:
            compat = sorted({self._spec, *self._compat_specs})
            raise ProtocolError(
                f"codec mismatch: client {spec!r} (canonical {canon!r}) vs "
                f"engine {self._spec!r}; compatible specs: {compat} — "
                "refusing the connection rather than decoding garbage")
        draft = header.get("draft")
        if draft is not None:
            # the client pins the draft channel too — same refusal rule
            if self._draft_spec is None:
                raise ProtocolError(
                    f"client pinned draft spec {draft!r} but the engine "
                    "does not speculate — refusing the connection")
            try:
                dcanon = canonical_codec_spec(draft, self.engine.cfg.d_model,
                                              self.engine.num_slots)
            except Exception as e:
                raise ProtocolError(f"unbuildable draft spec {draft!r}: {e}")
            if dcanon != self._draft_spec:
                raise ProtocolError(
                    f"draft-channel mismatch: client {draft!r} (canonical "
                    f"{dcanon!r}) vs engine {self._draft_spec!r} — refusing "
                    "the connection rather than decoding garbage")
        conn = _Conn(stream=stream, tenant=tenant)
        resume = header.get("resume")
        resumed = False
        if resume is not None:
            sess = self._sessions.get(resume)
            if sess is None:
                raise ProtocolError(
                    f"resume token {resume!r} unknown or expired (sessions "
                    f"detach for at most {self.resume_ttl_s}s)")
            if sess.tenant != tenant:
                raise ProtocolError(
                    f"resume token {resume!r} belongs to another tenant")
            if sess.conn is not None:
                sess.conn.open = False        # stale half-open predecessor
            resumed = True
        else:
            token = f"{tenant}#{next(self._tokens)}"
            sess = _Session(token=token, tenant=tenant, conn=conn)
            self._sessions[token] = sess
        tq = self.qos.tenant(tenant)
        tq.bytes_in += nbytes
        hello_ok = {"codec": self._spec, "num_slots": self.engine.num_slots,
                    "max_len": self.engine.max_len,
                    "kv_layout": self.engine.kv_layout,
                    "preemption": self.engine.preemption,
                    "session": sess.token, "resumed": resumed,
                    "heartbeat_s": self.heartbeat_s}
        if self._draft_spec is not None:
            scfg = self.engine.spec_cfg
            hello_ok.update({"draft": self._draft_spec,
                             "spec_k": scfg.k, "draft_head": scfg.draft_head,
                             "spec_adaptive": scfg.adaptive})
        tq.bytes_out += await stream.send(MsgType.HELLO_OK, hello_ok)
        if resumed:
            await self._resume(sess, conn)
        return conn, sess

    async def _submit(self, sess: _Session, conn: _Conn, header: dict,
                      payload: bytes, nbytes: int):
        tq = self.qos.tenant(conn.tenant)
        rid = header.get("rid")
        if not isinstance(rid, int):
            raise ProtocolError("SUBMIT carries no integer rid")
        if rid in sess.rids or rid in sess.done_rids:
            # idempotent re-SUBMIT after a reconnect: the request is
            # already in flight (or parked), or its result was already
            # delivered (the replay raced the parked-result flush) —
            # re-ACK instead of doubling it
            tq.bytes_out += await conn.stream.send(MsgType.ACCEPTED,
                                                   {"rid": rid})
            return
        tokens = proto.unpack_array(header, payload)
        if tokens.ndim != 1 or tokens.dtype.name != "int32":
            raise ProtocolError(f"SUBMIT payload must be a 1-D int32 token "
                                f"array, got {tokens.dtype.name}"
                                f"{tokens.shape}")
        verdict = self.admission.try_admit(conn.tenant)
        if verdict != ADMIT:
            tq.busy_rejections += 1
            retry = self.busy_retry_ms * (4 if verdict == BUSY_QUEUE else 1)
            tq.bytes_out += await conn.stream.send(
                MsgType.BUSY,
                {"rid": rid, "reason": verdict, "retry_after_ms": retry})
            return
        policy = self.admission.policy(conn.tenant)
        req = Request(uid=next(self._uids),
                      prompt=[int(t) for t in tokens],
                      max_new_tokens=int(header.get("max_new", 16)),
                      priority=int(header.get("priority", policy.priority)))
        try:
            self.engine.submit(req)
        except ValueError as e:
            # engine-level refusal (empty/overlong prompt, footprint above
            # the whole pool): an ERROR the client must not retry verbatim
            self.admission.release(conn.tenant)
            tq.errors += 1
            tq.bytes_out += await conn.stream.send(
                MsgType.ERROR, {"rid": rid, "reason": str(e)})
            return
        self._routes[req.uid] = _Route(sess=sess, rid=rid,
                                       tenant=conn.tenant, bytes_in=nbytes)
        sess.rids[rid] = req.uid
        tq.bytes_out += await conn.stream.send(MsgType.ACCEPTED,
                                               {"rid": rid})

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------

    def stats(self) -> dict:
        """The STATS RPC body: per-tenant QoS plus the engine's serving
        counters (cut-layer wire bytes, served-R schedule, eviction and
        early-exit counts, page-pool occupancy)."""
        eng = self.engine
        return {"tenants": self.qos.snapshot(),
                "engine": {**eng.stats,
                           "r_served": {str(k): v
                                        for k, v in sorted(
                                            eng.r_served.items())},
                           "k_served": {str(k): v
                                        for k, v in sorted(
                                            eng.k_served.items())},
                           "wire_per_token": eng.wire_per_token(),
                           "draft": self._draft_spec,
                           "codec": self._spec,
                           "active_slots": eng.active,
                           "queued": len(eng.queue),
                           "pool": eng.pool_accounting()},
                "admission": {"inflight_total": self.admission.inflight_total,
                              "inflight": dict(self.admission.inflight),
                              "max_queue_depth":
                                  self.admission.max_queue_depth},
                "sessions": {"open": sum(s.conn is not None
                                         for s in self._sessions.values()),
                             "detached": sum(s.conn is None
                                             for s in self._sessions.values())}}
