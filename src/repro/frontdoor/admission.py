"""Admission control for the front door.

Policy layer between the wire and the engine queue, reusing the engine's
``PageAllocator`` admission underneath (a request the engine can never
hold — prompt too long, page footprint above the whole pool — is refused
with ``ERROR`` before it is queued).  On top of that it enforces:

* **per-tenant concurrency caps** — at most ``TenantPolicy.max_inflight``
  requests of one tenant admitted-but-unfinished at a time; excess gets a
  retriable ``BUSY`` so one chatty tenant cannot monopolize the slots;
* **queue-depth shedding** — when the total admitted backlog reaches
  ``max_queue_depth``, every tenant gets ``BUSY`` (with a retry hint)
  instead of the queue growing without bound.

``TenantPolicy.priority`` is the engine slot priority stamped on the
tenant's requests — with engine ``preemption=True`` a higher-priority
tenant's blocked head evicts lower-priority slots (see
``repro.serving.engine``).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Per-tenant QoS knobs (the server's defaults when unlisted)."""
    max_inflight: int = 8      # concurrent admitted requests for the tenant
    priority: int = 0          # engine slot priority (preemption ranking)


ADMIT = "admit"
BUSY_TENANT = "tenant_cap"     # this tenant is at its concurrency cap
BUSY_QUEUE = "queue_depth"     # the whole server backlog is shedding


class AdmissionController:
    """Book-keeps in-flight counts; decides admit vs shed per SUBMIT."""

    def __init__(self, *, max_queue_depth: int = 64,
                 default_policy: TenantPolicy | None = None,
                 policies: dict[str, TenantPolicy] | None = None):
        self.max_queue_depth = max_queue_depth
        self.default_policy = default_policy or TenantPolicy()
        self.policies = dict(policies or {})
        self.inflight_total = 0
        self.inflight: dict[str, int] = {}

    def policy(self, tenant: str) -> TenantPolicy:
        return self.policies.get(tenant, self.default_policy)

    def try_admit(self, tenant: str) -> str:
        """ADMIT (and count the request) or a BUSY_* shed reason."""
        if self.inflight_total >= self.max_queue_depth:
            return BUSY_QUEUE
        if self.inflight.get(tenant, 0) >= self.policy(tenant).max_inflight:
            return BUSY_TENANT
        self.inflight_total += 1
        self.inflight[tenant] = self.inflight.get(tenant, 0) + 1
        return ADMIT

    def release(self, tenant: str):
        """A previously admitted request finished (or was dropped)."""
        if self.inflight.get(tenant, 0) <= 0 or self.inflight_total <= 0:
            raise RuntimeError(f"release without admit for tenant {tenant!r}")
        self.inflight[tenant] -= 1
        self.inflight_total -= 1
