"""Front-door loopback selfcheck — the CI ``frontdoor-smoke`` job.

One process: a tiny-model engine behind a :class:`FrontDoorServer` on an
ephemeral loopback port, three concurrent tenants (one speaking the
engine's full ADAPTIVE spec, two pinned to a compatible R bucket), each
streaming a few requests through the BUSY-retry path.  Asserts every
result is well-formed, the per-tenant STATS are non-empty for all three
tenants, and the shutdown is clean (BYE handshakes, drained engine,
stopped listener).

    PYTHONPATH=src python -m repro.frontdoor.selfcheck [--requests N]
"""
from __future__ import annotations

import argparse
import asyncio

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.frontdoor.admission import AdmissionController, TenantPolicy
from repro.frontdoor.client import FrontDoorClient
from repro.frontdoor.server import FrontDoorServer
from repro.models import lm as lm_lib
from repro.serving.engine import BatchedEngine

ENGINE_SPEC = "adaptive:c3sl:R=4,min_R=2|int8"
BUCKET_SPEC = "c3sl:R=2|int8"


def build_engine(num_slots: int = 4, max_len: int = 64) -> BatchedEngine:
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    return BatchedEngine(params, cfg, num_slots=num_slots, max_len=max_len,
                         codec=ENGINE_SPEC, greedy=True, seed=0,
                         kv_layout="paged", page_size=8,
                         num_pages=num_slots * (max_len // 8),
                         preemption=True)


async def _tenant(host, port, tenant, codec, requests, vocab, seed):
    client = await FrontDoorClient.open(host, port, tenant=tenant,
                                        codec=codec)
    rng = np.random.RandomState(seed)
    results = []
    try:
        for i in range(requests):
            prompt = [int(t) for t in rng.randint(1, vocab, 4 + 2 * i)]
            out = await client.generate(prompt, max_new=4)
            assert out["tokens"], f"{tenant} got an empty result"
            assert all(0 <= t < vocab for t in out["tokens"]), out
            results.append(out)
        stats = await client.stats()
    finally:
        await client.close()
    return tenant, results, stats


async def amain(requests: int = 3) -> dict:
    eng = build_engine()
    server = FrontDoorServer(
        eng,
        admission=AdmissionController(
            max_queue_depth=16,
            default_policy=TenantPolicy(max_inflight=4)))
    host, port = await server.start()
    print(f"[selfcheck] front door on {host}:{port} "
          f"(engine codec {server.stats()['engine']['codec']!r})")
    tenants = [("tenant-adaptive", ENGINE_SPEC),
               ("tenant-bucket-1", BUCKET_SPEC),
               ("tenant-bucket-2", BUCKET_SPEC)]
    outs = await asyncio.gather(*(
        _tenant(host, port, name, codec, requests, eng.cfg.vocab_size, 7 + i)
        for i, (name, codec) in enumerate(tenants)))
    stats = outs[-1][2]          # last tenant's STATS snapshot
    await server.stop()

    for name, results, _ in outs:
        assert len(results) == requests, (name, len(results))
    for name, _ in tenants:
        t = stats["tenants"].get(name)
        assert t and t["requests"] >= 1, f"empty stats for {name}: {t}"
        assert t["tokens_out"] > 0 and t["bytes_in"] > 0, t
        assert t["ttft_s"]["count"] >= 1, t
    assert not eng.queue and eng.active == 0, "engine not drained"
    acct = eng.pool_accounting()
    assert acct["free"] == acct["total"], acct
    print(f"[selfcheck] {3 * requests} requests across 3 tenants OK; "
          "per-tenant stats non-empty; clean shutdown")
    for name, t in stats["tenants"].items():
        ttft = t["ttft_s"]
        print(f"[selfcheck]   {name}: {t['requests']} reqs, "
              f"{t['tokens_out']} tokens, ttft p50 "
              f"{ttft.get('p50', float('nan')) * 1e3:.1f}ms, "
              f"wire {t['bytes_in']}B in / {t['bytes_out']}B out")
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=3,
                    help="requests per tenant")
    args = ap.parse_args()
    asyncio.run(amain(args.requests))
    print("[selfcheck] PASS")


if __name__ == "__main__":
    main()
