"""Front-door loopback selfcheck — the CI ``frontdoor-smoke`` job.

One process: a tiny-model engine behind a :class:`FrontDoorServer` on an
ephemeral loopback port, three tenants (one speaking the engine's full
ADAPTIVE spec, two pinned to a compatible R bucket), each streaming a few
requests through the BUSY-retry path.  Asserts every result is
well-formed, the per-tenant STATS are non-empty for all three tenants,
and the shutdown is clean (BYE handshakes, drained engine, stopped
listener).  Any failed tenant exits NONZERO.

``--chaos`` runs the fault-injected variant (the CI ``chaos-smoke``
job): three tenants run SEQUENTIALLY — one request in flight at a time,
so slot occupancy (and with it the batch-wise codec's cross-talk) is
schedule-independent — first fault-free to record the reference tokens,
then again under a seeded :class:`~repro.faults.FaultPlan` that drops
and corrupts frames in both directions and forces one disconnect per
direction (exercising NACK/retransmit, heartbeat gap detection, and
reconnect-with-resume).  The chaos run must complete every request with
tokens BIT-IDENTICAL to the fault-free reference.  The chaos engine
serves a STATIC bucket spec: what is being pinned is transport
determinism (recovered frames and resumed sessions decode the exact same
tokens), and an adaptive controller would break the comparison for the
wrong reason — its R schedule is deliberately sensitive to the extra
re-prefill steps a disconnect induces, so schedule drift under faults is
expected behavior, not a transport bug.

    PYTHONPATH=src python -m repro.frontdoor.selfcheck [--requests N] [--chaos]
"""
from __future__ import annotations

import argparse
import asyncio
import sys

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.faults import FaultPlan
from repro.frontdoor.admission import AdmissionController, TenantPolicy
from repro.frontdoor.client import FrontDoorClient
from repro.frontdoor.server import FrontDoorServer
from repro.models import lm as lm_lib
from repro.serving.engine import BatchedEngine

ENGINE_SPEC = "adaptive:c3sl:R=4,min_R=2|int8"
BUCKET_SPEC = "c3sl:R=2|int8"

TENANTS = [("tenant-adaptive", ENGINE_SPEC),
           ("tenant-bucket-1", BUCKET_SPEC),
           ("tenant-bucket-2", BUCKET_SPEC)]

# the chaos variant pins transport determinism on a static bucket engine
# (see the module docstring); every tenant speaks the engine's spec
CHAOS_TENANTS = [("tenant-a", BUCKET_SPEC), ("tenant-b", BUCKET_SPEC),
                 ("tenant-c", BUCKET_SPEC)]


#: draft-channel spec for the --spec-decode run: batch-wise like the cut
#: codec, int8 on the wire — the cheap server->client feedback channel
SPEC_DRAFT = "c3sl:R=2|int8"


def build_engine(num_slots: int = 4, max_len: int = 64,
                 spec: str = ENGINE_SPEC,
                 sync_every: int = 8, spec_decode=None) -> BatchedEngine:
    # sanitize mode shrinks sync_every below max_new so decode spans tick
    # boundaries: the per-tick cut probe then observes slots mid-decode
    # (a dead/live mix) instead of every window running to completion
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    return BatchedEngine(params, cfg, num_slots=num_slots, max_len=max_len,
                         codec=spec, greedy=True, seed=0,
                         kv_layout="paged", page_size=8,
                         num_pages=num_slots * (max_len // 8),
                         sync_every=sync_every, preemption=True,
                         spec_decode=spec_decode)


def chaos_plan() -> FaultPlan:
    """The seeded chaos schedule: frame drops + corruption both ways, one
    forced disconnect per direction (c2s seq 2 fires during a SUBMIT —
    reconnect + idempotent re-SUBMIT; s2c seq 3 fires around a RESULT —
    park + flush-on-resume)."""
    return FaultPlan(seed=7,
                     rates={"drop": 0.08, "corrupt": 0.04},
                     schedule={"c2s": {2: "disconnect"},
                               "s2c": {3: "disconnect"}})


async def _tenant(host, port, tenant, codec, requests, vocab, seed,
                  faults=None, draft=None):
    client = await FrontDoorClient.open(host, port, tenant=tenant,
                                        codec=codec, draft=draft,
                                        faults=faults)
    rng = np.random.RandomState(seed)
    results = []
    try:
        for i in range(requests):
            prompt = [int(t) for t in rng.randint(1, vocab, 4 + 2 * i)]
            out = await client.generate(prompt, max_new=4)
            assert out["tokens"], f"{tenant} got an empty result"
            assert all(0 <= t < vocab for t in out["tokens"]), out
            # incremental TOKENS frames must preview the final output
            assert out["streamed"] == out["tokens"][:len(out["streamed"])], \
                (tenant, out["streamed"], out["tokens"])
            results.append(out)
        stats = await client.stats()
    finally:
        await client.close()
    return tenant, results, stats


def _arm_sanitizers(eng):
    """Attach the runtime sanitizer tier to a selfcheck engine: per-tick
    invariant checks (a trip raises out of the server's tick loop, which
    cancels every tenant and exits the selfcheck NONZERO via stop()) plus
    the event-loop stall detector (diagnostic only — jit warmup blocks
    the loop legitimately)."""
    from repro.analysis.sanitize import EngineSanitizer, SlowCallbackDetector
    san = EngineSanitizer(eng)
    eng.attach_sanitizer(san)
    det = SlowCallbackDetector().install()
    return san, det


async def _report_sanitizers(san, det, *, require_cut_checks: bool):
    await det.stop()
    print(f"[selfcheck] sanitize: {san.ticks} ticks checked "
          f"(pool {san.counts['pool']}, slot-state "
          f"{san.counts['slot_state']}, cut-zeroing "
          f"{san.counts['cut_zeroing']}); {det.report()}")
    if require_cut_checks:
        assert san.counts["cut_zeroing"] > 0, (
            "the live-slot-zeroing invariant was never exercised — no "
            "tick observed a dead/live slot mix; the sanitize run is "
            "vacuous")


async def amain(requests: int = 3, sanitize: bool = False) -> dict:
    eng = build_engine(sync_every=2 if sanitize else 8)
    san = det = None
    if sanitize:
        san, det = _arm_sanitizers(eng)
    server = FrontDoorServer(
        eng,
        admission=AdmissionController(
            max_queue_depth=16,
            default_policy=TenantPolicy(max_inflight=4)))
    host, port = await server.start()
    print(f"[selfcheck] front door on {host}:{port} "
          f"(engine codec {server.stats()['engine']['codec']!r})")
    outs = await asyncio.gather(*(
        _tenant(host, port, name, codec, requests, eng.cfg.vocab_size, 7 + i)
        for i, (name, codec) in enumerate(TENANTS)),
        return_exceptions=True)
    failed = [(TENANTS[i][0], r) for i, r in enumerate(outs)
              if isinstance(r, BaseException)]
    if failed:
        await server.stop(drain=False)
        for name, err in failed:
            print(f"[selfcheck] FAILED tenant {name}: {err!r}",
                  file=sys.stderr)
        sys.exit(1)
    stats = outs[-1][2]          # last tenant's STATS snapshot
    await server.stop()
    assert server.tick_error is None, server.tick_error
    if sanitize:
        await _report_sanitizers(san, det, require_cut_checks=True)

    for name, results, _ in outs:
        assert len(results) == requests, (name, len(results))
    for name, _ in TENANTS:
        t = stats["tenants"].get(name)
        assert t and t["requests"] >= 1, f"empty stats for {name}: {t}"
        assert t["tokens_out"] > 0 and t["bytes_in"] > 0, t
        assert t["ttft_s"]["count"] >= 1, t
    assert not eng.queue and eng.active == 0, "engine not drained"
    acct = eng.pool_accounting()
    assert acct["free"] == acct["total"], acct
    print(f"[selfcheck] {3 * requests} requests across 3 tenants OK; "
          f"per-tenant stats non-empty; clean shutdown")
    for name, t in stats["tenants"].items():
        ttft = t["ttft_s"]
        print(f"[selfcheck]   {name}: {t['requests']} reqs, "
              f"{t['tokens_out']} tokens, ttft p50 "
              f"{ttft.get('p50', float('nan')) * 1e3:.1f}ms, "
              f"wire {t['bytes_in']}B in / {t['bytes_out']}B out")
    return stats


async def _sequential_run(requests: int, faults: FaultPlan | None,
                          sanitize: bool = False, spec_decode=None,
                          draft: str | None = None) -> dict:
    """One full sequential pass (every tenant, every request, one at a
    time) against a FRESH static-bucket engine; returns
    {tenant: [token lists]} plus the final server stats under the
    "_stats" key and the total streamed-token-preview count under
    "_streamed"."""
    eng = build_engine(spec=BUCKET_SPEC, sync_every=2 if sanitize else 8,
                       spec_decode=spec_decode)
    san = det = None
    if sanitize:
        san, det = _arm_sanitizers(eng)
    server = FrontDoorServer(
        eng,
        admission=AdmissionController(
            max_queue_depth=16,
            default_policy=TenantPolicy(max_inflight=4)),
        faults=faults,
        heartbeat_s=0.2, max_misses=10, resume_ttl_s=10.0)
    host, port = await server.start()
    tokens: dict = {}
    stats = None
    streamed = 0
    try:
        for i, (name, codec) in enumerate(CHAOS_TENANTS):
            name_, results, stats = await _tenant(
                host, port, name, codec, requests, eng.cfg.vocab_size, 7 + i,
                faults=faults, draft=draft)
            tokens[name_] = [r["tokens"] for r in results]
            streamed += sum(len(r["streamed"]) for r in results)
    finally:
        await server.stop()
    assert server.tick_error is None, server.tick_error
    if sanitize:
        # sequential tenants leave 3 of 4 slots empty while one decodes,
        # so the cut probe always sees a dead/live mix here
        await _report_sanitizers(san, det, require_cut_checks=True)
    assert not eng.queue and eng.active == 0, "engine not drained"
    tokens["_stats"] = stats
    tokens["_streamed"] = streamed
    return tokens


async def amain_chaos(requests: int = 3, sanitize: bool = False) -> None:
    print("[selfcheck] chaos: recording the fault-free sequential reference")
    ref = await _sequential_run(requests, faults=None, sanitize=sanitize)
    plan = chaos_plan()
    print(f"[selfcheck] chaos: replaying under {plan}")
    got = await _sequential_run(requests, faults=plan, sanitize=sanitize)
    bad = []
    for name, _ in CHAOS_TENANTS:
        if got[name] != ref[name]:
            bad.append((name, ref[name], got[name]))
    if bad:
        for name, want, have in bad:
            print(f"[selfcheck] CHAOS MISMATCH for {name}:\n"
                  f"  fault-free: {want}\n  chaos:      {have}",
                  file=sys.stderr)
        sys.exit(1)
    stats = got["_stats"]
    recovered = sum(t.get("retransmits", 0) + t.get("nacks", 0)
                    + t.get("resumes", 0)
                    for t in stats["tenants"].values())
    assert recovered > 0, ("chaos run recovered nothing — the fault plan "
                           f"never fired? stats: {stats['tenants']}")
    n = sum(len(got[name]) for name, _ in CHAOS_TENANTS)
    print(f"[selfcheck] chaos: {n} requests bit-identical to the fault-free "
          f"reference through drops/corruption/disconnects "
          f"({recovered} recovery events)")


async def amain_spec(requests: int = 3) -> None:
    """The CI ``spec-smoke`` job: speculative decoding end-to-end over
    the front door.  Sequential tenants (schedule-independent occupancy,
    same reasoning as the chaos run) decode once on a vanilla
    static-bucket engine to record the reference, then again with a
    draft/verify channel at each k — greedy verification must make every
    speculative run BIT-IDENTICAL to the vanilla one, while the engine
    counters prove speculation actually happened (verify rounds ran,
    drafts were accepted/rejected, TOKENS frames streamed bursts)."""
    from repro.serving.spec import SpecConfig
    print("[selfcheck] spec: recording the non-speculative reference")
    ref = await _sequential_run(requests, faults=None)
    for k in (2, 4):
        print(f"[selfcheck] spec: replaying with k={k} "
              f"(draft {SPEC_DRAFT!r}, pinned by the client handshake)")
        got = await _sequential_run(
            requests, faults=None,
            spec_decode=SpecConfig(k=k, draft=SPEC_DRAFT), draft=SPEC_DRAFT)
        bad = [(name, ref[name], got[name]) for name, _ in CHAOS_TENANTS
               if got[name] != ref[name]]
        if bad:
            for name, want, have in bad:
                print(f"[selfcheck] SPEC MISMATCH for {name} at k={k}:\n"
                      f"  vanilla:     {want}\n  speculative: {have}",
                      file=sys.stderr)
            sys.exit(1)
        est = got["_stats"]["engine"]
        acc, rej = est["spec_accepted"], est["spec_rejected"]
        assert est["spec_rounds"] > 0 and acc + rej > 0, (
            f"k={k} run never speculated: {est}")
        assert got["_streamed"] > 0, (
            f"k={k} run streamed no TOKENS previews")
        wpt = est["wire_per_token"]
        rate = acc / (acc + rej)
        print(f"[selfcheck] spec: k={k} bit-identical; acceptance "
              f"{rate:.2f} over {est['spec_rounds']} rounds, "
              f"{wpt['wire_bytes_per_token']:.1f} wire B/token, "
              f"{got['_streamed']} tokens streamed incrementally")
    n = len(CHAOS_TENANTS) * requests
    print(f"[selfcheck] spec: {n} requests per run bit-identical to "
          f"vanilla decode at every k")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=3,
                    help="requests per tenant")
    ap.add_argument("--chaos", action="store_true",
                    help="seeded fault-injection run: sequential tenants, "
                         "outputs must be bit-identical to fault-free")
    ap.add_argument("--spec-decode", action="store_true",
                    help="speculative-decoding run: sequential tenants "
                         "decode over a draft/verify channel; outputs must "
                         "be bit-identical to the vanilla engine")
    ap.add_argument("--sanitize", action="store_true",
                    help="run the loopback tenants under the runtime "
                         "sanitizer tier (per-tick engine invariants + "
                         "event-loop stall detection); any invariant trip "
                         "exits nonzero")
    args = ap.parse_args()
    if args.chaos:
        asyncio.run(amain_chaos(args.requests, sanitize=args.sanitize))
    elif args.spec_decode:
        asyncio.run(amain_spec(args.requests))
    else:
        asyncio.run(amain(args.requests, sanitize=args.sanitize))
    print("[selfcheck] PASS")


if __name__ == "__main__":
    main()
