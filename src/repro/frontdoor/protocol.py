"""Wire protocol for the split-serving front door.

Length-prefixed frames over a byte stream (asyncio TCP / loopback):

    +---------+------+------+----------------+------------------+
    | !I len  | !B t | !I h | header (JSON)  | payload (raw)    |
    +---------+------+------+----------------+------------------+

``len`` counts every byte after the length field itself; ``t`` is the
:class:`MsgType`; ``h`` is the JSON header's byte length.  The header is a
flat JSON object (tenant id, codec spec string, request id, dtype, shape,
...); the payload is raw little-endian array bytes described by the
header's ``dtype``/``shape`` fields.  Anything malformed — bad magic-free
framing is impossible, but truncated frames, oversized lengths, non-JSON
headers, dtype/shape vs payload-size mismatches — raises
:class:`ProtocolError` and the connection dies LOUDLY instead of decoding
garbage.

The handshake (``HELLO``) carries the client's cut-layer codec spec; the
server refuses (``ERROR`` + close) any client whose canonical spec does
not match the engine's, so a client/server codec mismatch is a connect
error, not silently mis-decoded activations.

Message flow::

    client                             server
      HELLO {tenant, codec}       ->
                                  <-   HELLO_OK {codec, num_slots, ...}
                                       (or ERROR {reason} + close)
      SUBMIT {rid, max_new, ...}
             + int32 token payload ->
                                  <-   ACCEPTED {rid}
                                       | BUSY {rid, retry_after_ms}
                                       | ERROR {rid, reason}
                                  <-   RESULT {rid, ttft_s, ...}
                                       + int32 token payload
      STATS {}                    ->
                                  <-   STATS_OK {stats}
      BYE {}                      ->
                                  <-   BYE_OK {} + close
"""
from __future__ import annotations

import asyncio
import enum
import json
import struct

import numpy as np

# 64 MiB: far above any cut-layer payload this repo ships, small enough
# that a corrupted length prefix cannot make the reader buffer gigabytes.
MAX_FRAME_BYTES = 64 * 1024 * 1024

_LEN = struct.Struct("!I")
_HDR = struct.Struct("!BI")      # msg type, header length


class ProtocolError(Exception):
    """Malformed frame / header / payload — the connection must die."""


class MsgType(enum.IntEnum):
    HELLO = 1
    HELLO_OK = 2
    SUBMIT = 3
    ACCEPTED = 4
    BUSY = 5
    RESULT = 6
    ERROR = 7
    STATS = 8
    STATS_OK = 9
    BYE = 10
    BYE_OK = 11


def encode_frame(mtype: MsgType, header: dict, payload: bytes = b"") -> bytes:
    """One wire frame: length prefix, type, JSON header, raw payload."""
    hdr = json.dumps(header, separators=(",", ":")).encode("utf-8")
    body_len = _HDR.size + len(hdr) + len(payload)
    if body_len > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame of {body_len} bytes exceeds the "
                            f"{MAX_FRAME_BYTES}-byte frame limit")
    return b"".join((_LEN.pack(body_len),
                     _HDR.pack(int(mtype), len(hdr)), hdr, payload))


def decode_frame(body: bytes) -> tuple[MsgType, dict, bytes]:
    """Decode one frame body (everything after the length prefix)."""
    if len(body) < _HDR.size:
        raise ProtocolError(f"frame body of {len(body)} bytes is shorter "
                            f"than the {_HDR.size}-byte type+header prefix")
    t, hlen = _HDR.unpack_from(body)
    try:
        mtype = MsgType(t)
    except ValueError as e:
        raise ProtocolError(f"unknown message type {t}") from e
    if _HDR.size + hlen > len(body):
        raise ProtocolError(f"header length {hlen} overruns the "
                            f"{len(body)}-byte frame body")
    try:
        header = json.loads(body[_HDR.size:_HDR.size + hlen])
    except ValueError as e:
        raise ProtocolError(f"non-JSON header in {mtype.name} frame") from e
    if not isinstance(header, dict):
        raise ProtocolError(f"{mtype.name} header must be a JSON object, "
                            f"got {type(header).__name__}")
    return mtype, header, body[_HDR.size + hlen:]


async def read_frame(reader: asyncio.StreamReader):
    """Read one frame; returns (mtype, header, payload, wire_bytes) or
    None on a clean EOF at a frame boundary."""
    try:
        raw_len = await reader.readexactly(_LEN.size)
    except (asyncio.IncompleteReadError, ConnectionResetError):
        return None                          # peer closed between frames
    (body_len,) = _LEN.unpack(raw_len)
    if body_len > MAX_FRAME_BYTES:
        raise ProtocolError(f"declared frame length {body_len} exceeds the "
                            f"{MAX_FRAME_BYTES}-byte frame limit")
    try:
        body = await reader.readexactly(body_len)
    except asyncio.IncompleteReadError as e:
        raise ProtocolError(f"connection died {len(e.partial)} bytes into a "
                            f"{body_len}-byte frame body") from e
    mtype, header, payload = decode_frame(body)
    return mtype, header, payload, _LEN.size + body_len


async def send_frame(writer: asyncio.StreamWriter, mtype: MsgType,
                     header: dict, payload: bytes = b"") -> int:
    """Write one frame and drain; returns the bytes put on the wire."""
    frame = encode_frame(mtype, header, payload)
    writer.write(frame)
    await writer.drain()
    return len(frame)


# ---------------------------------------------------------------------------
# array payloads: dtype + shape ride in the header, bytes in the payload
# ---------------------------------------------------------------------------

_WIRE_DTYPES = ("int32", "int8", "uint8", "float32", "float16")


def pack_array(arr) -> tuple[dict, bytes]:
    """Header fields + payload bytes for an ndarray (C-order, little-end)."""
    arr = np.ascontiguousarray(arr)
    if arr.dtype.name not in _WIRE_DTYPES:
        raise ProtocolError(f"dtype {arr.dtype.name!r} is not a wire dtype "
                            f"(expected one of {_WIRE_DTYPES})")
    return ({"dtype": arr.dtype.name, "shape": list(arr.shape)},
            arr.tobytes())


def unpack_array(header: dict, payload: bytes) -> np.ndarray:
    """Rebuild the array a frame carries, failing LOUDLY on any mismatch
    between the declared dtype/shape and the actual payload size."""
    dtype, shape = header.get("dtype"), header.get("shape")
    if dtype not in _WIRE_DTYPES:
        raise ProtocolError(f"header dtype {dtype!r} is not a wire dtype "
                            f"(expected one of {_WIRE_DTYPES})")
    if (not isinstance(shape, list)
            or not all(isinstance(d, int) and d >= 0 for d in shape)):
        raise ProtocolError(f"header shape {shape!r} is not a list of "
                            "non-negative ints")
    dt = np.dtype(dtype)
    want = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
    if want != len(payload):
        raise ProtocolError(
            f"payload size mismatch: header {dtype}{tuple(shape)} needs "
            f"{want} bytes but the frame carries {len(payload)} — refusing "
            "to decode garbage (codec/dtype drift between client and server?)")
    return np.frombuffer(payload, dtype=dt).reshape(shape)
