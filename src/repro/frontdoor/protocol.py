"""Wire protocol for the split-serving front door.

Length-prefixed frames over a byte stream (asyncio TCP / loopback):

    +---------+------+--------+--------+------+----------------+---------+
    | !I len  | !B t | !I seq | !I crc | !I h | header (JSON)  | payload |
    +---------+------+--------+--------+------+----------------+---------+

``len`` counts every byte after the length field itself; ``t`` is the
:class:`MsgType`; ``seq`` is the sender's per-connection data-frame
sequence number (control frames — NACK/PING/PONG — carry the sentinel
:data:`CTRL_SEQ` and bypass sequencing); ``crc`` is the CRC32 of the
frame body computed with the crc field zeroed; ``h`` is the JSON
header's byte length.  The header is a flat JSON object (tenant id,
codec spec string, request id, dtype, shape, ...); the payload is raw
little-endian array bytes described by the header's ``dtype``/``shape``
fields.

Integrity model (two failure classes, two behaviors):

* **wire damage** — a CRC mismatch, or a body shorter than the fixed
  header (a truncated-but-length-consistent frame).  The full body was
  consumed, so the stream is still in sync: these raise
  :class:`FrameCorruption` (a :class:`ChannelErasure`), and the
  reliability layer (``repro.frontdoor.stream.FrameStream``) NACKs the
  expected sequence number and the sender retransmits from its replay
  ring.  A corrupted LENGTH prefix is indistinguishable from stream
  desync and is out of scope — that kills the connection and the
  reconnect-with-resume path takes over.

* **peer bugs** — a frame whose CRC is VALID but whose content is
  malformed (unknown type, header overrun, non-JSON header, dtype/shape
  vs payload-size mismatches).  The peer really sent that; these raise
  plain :class:`ProtocolError` and the connection dies LOUDLY instead of
  decoding garbage.

The handshake (``HELLO``) carries the client's cut-layer codec spec; the
server refuses (``ERROR`` + close) any client whose canonical spec does
not match the engine's, so a client/server codec mismatch is a connect
error, not silently mis-decoded activations.  A HELLO may also carry a
``resume`` session token (see ``repro.frontdoor.server``) to reattach a
disconnected session.

Message flow::

    client                             server
      HELLO {tenant, codec[, resume]} ->
                                  <-   HELLO_OK {codec, session, ...}
                                       (or ERROR {reason} + close)
      SUBMIT {rid, max_new, ...}
             + int32 token payload ->
                                  <-   ACCEPTED {rid}
                                       | BUSY {rid, retry_after_ms}
                                       | ERROR {rid, reason}
                                  <-   TOKENS {rid, off, n}
                                       + int32 token payload  (0 or more:
                                       incremental bursts as the engine
                                       emits them — one per verify round
                                       under speculative decoding; ``off``
                                       is the burst's absolute offset in
                                       the output, so a receiver detects
                                       a lost burst as a gap)
                                  <-   RESULT {rid, ttft_s, ...}
                                       + int32 token payload  (the FULL
                                       output; TOKENS frames are a
                                       prefix of it, so a client may
                                       ignore either)
      STATS {}                    ->
                                  <-   STATS_OK {stats}
      BYE {}                      ->
                                  <-   BYE_OK {} + close

    control (either direction, CTRL_SEQ, handled inside FrameStream):
      NACK {seq, upto}   — retransmit data frames [seq, upto)
      PING {sent}        — liveness probe + sender's send-seq watermark
      PONG {sent}        — reply, same watermark semantics
"""
from __future__ import annotations

import asyncio
import enum
import json
import struct
import zlib

import numpy as np

from repro.faults import ChannelErasure

# 64 MiB: far above any cut-layer payload this repo ships, small enough
# that a corrupted length prefix cannot make the reader buffer gigabytes.
MAX_FRAME_BYTES = 64 * 1024 * 1024

_LEN = struct.Struct("!I")
_HDR = struct.Struct("!BIII")    # msg type, seq, crc32, header length

#: sequence sentinel for control frames (NACK/PING/PONG) — they bypass
#: sequencing, replay, and fault injection (an out-of-band signaling path)
CTRL_SEQ = 0xFFFFFFFF


class ProtocolError(Exception):
    """Malformed frame / header / payload — the connection must die."""


class FrameCorruption(ChannelErasure, ProtocolError):
    """A frame arrived damaged (CRC mismatch / truncated body) but the
    stream is still in sync — recoverable by NACK/retransmit."""

    def __init__(self, msg: str, seq: int | None = None):
        super().__init__(msg)
        self.seq = seq


class MsgType(enum.IntEnum):
    HELLO = 1
    HELLO_OK = 2
    SUBMIT = 3
    ACCEPTED = 4
    BUSY = 5
    RESULT = 6
    ERROR = 7
    STATS = 8
    STATS_OK = 9
    BYE = 10
    BYE_OK = 11
    NACK = 12
    PING = 13
    PONG = 14
    TOKENS = 15

#: message types that ride outside the data sequence space
CTRL_TYPES = frozenset({MsgType.NACK, MsgType.PING, MsgType.PONG})


def _body_crc(mtype: int, seq: int, hdr: bytes, payload: bytes) -> int:
    """CRC32 over the body with the crc field zeroed."""
    head = _HDR.pack(mtype, seq, 0, len(hdr))
    return zlib.crc32(payload, zlib.crc32(hdr, zlib.crc32(head))) & 0xFFFFFFFF


def encode_frame(mtype: MsgType, header: dict, payload: bytes = b"",
                 seq: int = CTRL_SEQ) -> bytes:
    """One wire frame: length prefix, type, seq, crc, JSON header, raw
    payload.  ``seq`` defaults to the control sentinel; the reliability
    layer stamps real sequence numbers on data frames."""
    hdr = json.dumps(header, separators=(",", ":")).encode("utf-8")
    body_len = _HDR.size + len(hdr) + len(payload)
    if body_len > MAX_FRAME_BYTES:
        raise ProtocolError(f"frame of {body_len} bytes exceeds the "
                            f"{MAX_FRAME_BYTES}-byte frame limit")
    crc = _body_crc(int(mtype), seq, hdr, payload)
    return b"".join((_LEN.pack(body_len),
                     _HDR.pack(int(mtype), seq, crc, len(hdr)),
                     hdr, payload))


def decode_frame(body: bytes) -> tuple[MsgType, dict, bytes, int]:
    """Decode one frame body (everything after the length prefix) into
    ``(mtype, header, payload, seq)``.

    Wire damage (short body, CRC mismatch) raises
    :class:`FrameCorruption`; content the peer verifiably sent but that
    is malformed raises plain :class:`ProtocolError`.
    """
    if len(body) < _HDR.size:
        raise FrameCorruption(
            f"frame body of {len(body)} bytes is shorter than the "
            f"{_HDR.size}-byte fixed header — truncated on the wire")
    t, seq, crc, hlen = _HDR.unpack_from(body)
    hdr_payload = body[_HDR.size:]
    # crc covers the whole body with the crc field zeroed; verify before
    # trusting ANY field (type/seq/hlen are themselves covered)
    want = zlib.crc32(hdr_payload,
                      zlib.crc32(_HDR.pack(t, seq, 0, hlen))) & 0xFFFFFFFF
    if crc != want:
        raise FrameCorruption(
            f"frame crc mismatch (claimed {crc:#010x}, computed "
            f"{want:#010x}) — damaged on the wire", seq=seq)
    try:
        mtype = MsgType(t)
    except ValueError as e:
        raise ProtocolError(f"unknown message type {t}") from e
    if hlen > len(hdr_payload):
        raise ProtocolError(f"header length {hlen} overruns the "
                            f"{len(body)}-byte frame body")
    try:
        header = json.loads(hdr_payload[:hlen])
    except ValueError as e:
        raise ProtocolError(f"non-JSON header in {mtype.name} frame") from e
    if not isinstance(header, dict):
        raise ProtocolError(f"{mtype.name} header must be a JSON object, "
                            f"got {type(header).__name__}")
    return mtype, header, hdr_payload[hlen:], seq


async def read_frame(reader: asyncio.StreamReader, timeout: float | None = None):
    """Read one frame; returns (mtype, header, payload, wire_bytes, seq)
    or None on a clean EOF at a frame boundary.  ``timeout`` bounds the
    WHOLE read (deadline against half-open peers); expiry raises
    ``asyncio.TimeoutError`` with the stream still at a frame boundary
    only if no bytes were consumed — callers treat expiry mid-frame as a
    dead connection."""
    if timeout is not None:
        return await asyncio.wait_for(_read_frame(reader), timeout)
    return await _read_frame(reader)


async def _read_frame(reader: asyncio.StreamReader):
    try:
        raw_len = await reader.readexactly(_LEN.size)
    except (asyncio.IncompleteReadError, ConnectionResetError):
        return None                          # peer closed between frames
    (body_len,) = _LEN.unpack(raw_len)
    if body_len > MAX_FRAME_BYTES:
        raise ProtocolError(f"declared frame length {body_len} exceeds the "
                            f"{MAX_FRAME_BYTES}-byte frame limit")
    try:
        body = await reader.readexactly(body_len)
    except asyncio.IncompleteReadError as e:
        raise ProtocolError(f"connection died {len(e.partial)} bytes into a "
                            f"{body_len}-byte frame body") from e
    mtype, header, payload, seq = decode_frame(body)
    return mtype, header, payload, _LEN.size + body_len, seq


async def send_frame(writer: asyncio.StreamWriter, mtype: MsgType,
                     header: dict, payload: bytes = b"",
                     seq: int = CTRL_SEQ) -> int:
    """Write one frame and drain; returns the bytes put on the wire."""
    frame = encode_frame(mtype, header, payload, seq=seq)
    writer.write(frame)
    await writer.drain()
    return len(frame)


# ---------------------------------------------------------------------------
# array payloads: dtype + shape ride in the header, bytes in the payload
# ---------------------------------------------------------------------------

_WIRE_DTYPES = ("int32", "int8", "uint8", "float32", "float16")


def pack_array(arr) -> tuple[dict, bytes]:
    """Header fields + payload bytes for an ndarray (C-order, little-end)."""
    arr = np.ascontiguousarray(arr)
    if arr.dtype.name not in _WIRE_DTYPES:
        raise ProtocolError(f"dtype {arr.dtype.name!r} is not a wire dtype "
                            f"(expected one of {_WIRE_DTYPES})")
    return ({"dtype": arr.dtype.name, "shape": list(arr.shape)},
            arr.tobytes())


def unpack_array(header: dict, payload: bytes) -> np.ndarray:
    """Rebuild the array a frame carries, failing LOUDLY on any mismatch
    between the declared dtype/shape and the actual payload size."""
    dtype, shape = header.get("dtype"), header.get("shape")
    if dtype not in _WIRE_DTYPES:
        raise ProtocolError(f"header dtype {dtype!r} is not a wire dtype "
                            f"(expected one of {_WIRE_DTYPES})")
    if (not isinstance(shape, list)
            or not all(isinstance(d, int) and d >= 0 for d in shape)):
        raise ProtocolError(f"header shape {shape!r} is not a list of "
                            "non-negative ints")
    dt = np.dtype(dtype)
    want = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
    if want != len(payload):
        raise ProtocolError(
            f"payload size mismatch: header {dtype}{tuple(shape)} needs "
            f"{want} bytes but the frame carries {len(payload)} — refusing "
            "to decode garbage (codec/dtype drift between client and server?)")
    return np.frombuffer(payload, dtype=dt).reshape(shape)
