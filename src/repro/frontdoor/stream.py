"""Reliable framed stream: sequencing, replay, NACK/retransmit, liveness.

``FrameStream`` wraps one asyncio reader/writer pair and gives the front
door an erasure-tolerant wire:

* **send** stamps each data frame with a per-connection sequence number
  and keeps the clean encoding in a bounded replay ring.  An installed
  :class:`~repro.faults.FaultPlan` applies to the FIRST transmission
  only — drop (never written), corrupt (byte flip, caught by the frame
  CRC), truncate (length prefix fixed up so the stream stays in sync but
  the CRC fails), duplicate, delay, or a forced ``disconnect`` (transport
  abort, exercising reconnect-with-resume).  Retransmissions go out
  clean, so a NACK loop converges deterministically.

* **recv** delivers data frames strictly in sequence order.  A damaged
  frame (:class:`~repro.frontdoor.protocol.FrameCorruption`) or a
  sequence gap triggers a ``NACK {seq, upto}`` asking the peer to
  retransmit the missing range from its ring; out-of-order arrivals are
  buffered.  Duplicates (from the duplicate fault or a redundant
  retransmit) are dropped silently.  Control frames (NACK / PING / PONG)
  are consumed internally and never surface to the caller.

* **liveness**: ``ping()`` sends ``PING {sent}`` carrying the sender's
  send-sequence watermark; the peer auto-replies ``PONG {sent}``.  Both
  carry the watermark so a receiver learns about frames it never saw —
  the dropped-LAST-frame case a pure gap detector cannot catch (no later
  frame ever arrives to reveal the gap).

Each missing sequence number gets a bounded NACK budget; exhausting it
raises :class:`~repro.faults.ChannelErasure`, which callers treat as a
dead connection (the resume path takes over from there).
"""
from __future__ import annotations

import asyncio

from repro.faults import ChannelErasure
from repro.frontdoor.protocol import (CTRL_SEQ, CTRL_TYPES, FrameCorruption,
                                      MsgType, _LEN, encode_frame, read_frame)

#: upper bound on one injected ``delay`` fault (seconds) — keeps chaos
#: runs slow-ish, never hung
MAX_INJECTED_DELAY_S = 0.02


class FrameStream:
    """One direction-tagged reliable stream over (reader, writer).

    ``direction`` is the fault-plan tag (``"c2s"`` for the client's
    stream, ``"s2c"`` for the server's); ``epoch`` is the connection
    attempt (0 for the first connect), so scheduled faults fire once and
    rate-drawn faults redraw per reconnect.
    """

    def __init__(self, reader: asyncio.StreamReader,
                 writer: asyncio.StreamWriter, *, direction: str,
                 faults=None, epoch: int = 0, replay: int = 256,
                 retry_budget: int = 16):
        self.reader = reader
        self.writer = writer
        self.direction = direction
        self.faults = None if (faults is None or faults.is_zero()) else faults
        self.epoch = int(epoch)
        self.retry_budget = int(retry_budget)
        self._replay_cap = int(replay)
        self._replay: dict[int, bytes] = {}       # seq -> clean frame bytes
        self._send_seq = 0                        # next data seq to stamp
        self._recv_next = 0                       # next data seq to deliver
        self._pending: dict[int, tuple] = {}      # buffered out-of-order
        self._nacks_sent: dict[int, int] = {}     # seq -> NACK attempts
        self.peer_sent = 0                        # peer's send-seq watermark
        self._lock = asyncio.Lock()               # serializes writes
        self.counters = {"bytes_in": 0, "bytes_out": 0, "frames_in": 0,
                         "frames_out": 0, "retransmits": 0, "nacks": 0,
                         "corrupt_seen": 0, "dup_dropped": 0, "injected": {}}

    # ---- send path -------------------------------------------------------

    async def send(self, mtype: MsgType, header: dict,
                   payload: bytes = b"") -> int:
        """Send one frame.  Data frames are sequenced, replayable, and
        fault-injectable; control types bypass all three."""
        if mtype in CTRL_TYPES:
            return await self._write(encode_frame(mtype, header, payload))
        async with self._lock:
            seq = self._send_seq
            self._send_seq += 1
            frame = encode_frame(mtype, header, payload, seq=seq)
            self._replay[seq] = frame
            while len(self._replay) > self._replay_cap:
                self._replay.pop(min(self._replay))
        if self.faults is None:
            return await self._write(frame)
        return await self._send_faulty(frame, seq)

    async def _write(self, frame: bytes) -> int:
        self.writer.write(frame)
        await self.writer.drain()
        self.counters["bytes_out"] += len(frame)
        self.counters["frames_out"] += 1
        return len(frame)

    async def _send_faulty(self, frame: bytes, seq: int) -> int:
        events = self.faults.frame_events(self.direction, seq, self.epoch)
        writes, disconnect = 1, False
        for ev in events:
            self.counters["injected"][ev.kind] = \
                self.counters["injected"].get(ev.kind, 0) + 1
            if ev.kind == "drop":
                writes = 0
            elif ev.kind == "duplicate":
                writes = max(writes, 2)
            elif ev.kind == "delay":
                await asyncio.sleep(ev.arg * MAX_INJECTED_DELAY_S)
            elif ev.kind == "corrupt":
                body = bytearray(frame[_LEN.size:])
                body[int(ev.arg * len(body)) % len(body)] ^= 0xFF
                frame = frame[:_LEN.size] + bytes(body)
            elif ev.kind == "truncate":
                body = frame[_LEN.size:]
                keep = int(ev.arg * len(body))
                frame = _LEN.pack(keep) + body[:keep]
            elif ev.kind == "disconnect":
                disconnect = True
        sent = 0
        for _ in range(writes):
            sent += await self._write(frame)
        if disconnect:
            transport = self.writer.transport
            if transport is not None:
                transport.abort()
            raise ConnectionResetError(
                f"injected disconnect on {self.direction} at seq {seq}")
        return sent

    async def _retransmit(self, lo: int, hi: int) -> None:
        """Serve a peer NACK from the replay ring — always clean."""
        for seq in range(lo, hi):
            frame = self._replay.get(seq)
            if frame is not None:
                await self._write(frame)
                self.counters["retransmits"] += 1
            # evicted from the ring: nothing to serve; the peer's NACK
            # budget turns that into a ChannelErasure on its side

    # ---- liveness --------------------------------------------------------

    async def ping(self) -> None:
        await self.send(MsgType.PING, {"sent": self._send_seq})

    # ---- recv path -------------------------------------------------------

    async def _nack(self, lo: int, hi: int) -> None:
        budget_key = lo
        n = self._nacks_sent.get(budget_key, 0) + 1
        self._nacks_sent[budget_key] = n
        if n > self.retry_budget:
            raise ChannelErasure(
                f"frame seq {lo} on {self.direction!r} not recovered after "
                f"{self.retry_budget} NACKs — giving the connection up",
                direction=self.direction, step=lo, attempts=n)
        self.counters["nacks"] += 1
        await self.send(MsgType.NACK, {"seq": lo, "upto": hi})

    def _note_watermark(self) -> int | None:
        """After learning the peer's send watermark, the missing range (if
        any) is everything from our next expected seq up to it."""
        if self.peer_sent > self._recv_next:
            return self.peer_sent
        return None

    async def recv(self, timeout: float | None = None):
        """Next in-order DATA frame as (mtype, header, payload, nbytes,
        seq); None on clean EOF.  ``timeout`` bounds each socket read —
        a control frame arriving re-arms it (the peer is alive), so
        ``asyncio.TimeoutError`` here means genuine silence."""
        while True:
            if self._recv_next in self._pending:
                item = self._pending.pop(self._recv_next)
                self._nacks_sent.pop(self._recv_next, None)
                self._recv_next += 1
                return item
            try:
                got = await read_frame(self.reader, timeout=timeout)
            except FrameCorruption:
                # body fully consumed, stream still in sync: ask for the
                # next undelivered frame again (the damaged one is either
                # it or a later one the gap logic will re-request)
                self.counters["corrupt_seen"] += 1
                await self._nack(self._recv_next, self._recv_next + 1)
                continue
            if got is None:
                return None
            mtype, header, payload, nbytes, seq = got
            self.counters["bytes_in"] += nbytes
            self.counters["frames_in"] += 1
            if seq == CTRL_SEQ:
                if mtype == MsgType.NACK:
                    await self._retransmit(int(header.get("seq", 0)),
                                           int(header.get("upto", 0)))
                elif mtype == MsgType.PING:
                    self.peer_sent = max(self.peer_sent,
                                         int(header.get("sent", 0)))
                    await self.send(MsgType.PONG, {"sent": self._send_seq})
                    gap_hi = self._note_watermark()
                    if gap_hi is not None:
                        await self._nack(self._recv_next, gap_hi)
                elif mtype == MsgType.PONG:
                    self.peer_sent = max(self.peer_sent,
                                         int(header.get("sent", 0)))
                    gap_hi = self._note_watermark()
                    if gap_hi is not None:
                        await self._nack(self._recv_next, gap_hi)
                else:
                    # a data type carrying CTRL_SEQ: peer bug
                    from repro.frontdoor.protocol import ProtocolError
                    raise ProtocolError(
                        f"data frame {mtype.name} carries the control "
                        "sequence sentinel")
                continue
            if seq < self._recv_next:
                self.counters["dup_dropped"] += 1
                continue
            if seq > self._recv_next:
                self._pending[seq] = (mtype, header, payload, nbytes, seq)
                await self._nack(self._recv_next, seq)
                continue
            self._nacks_sent.pop(seq, None)
            self._recv_next += 1
            return mtype, header, payload, nbytes, seq

    # ---- teardown --------------------------------------------------------

    def close(self) -> None:
        if not self.writer.is_closing():
            self.writer.close()

    async def wait_closed(self) -> None:
        try:
            await self.writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError, OSError):
            pass
