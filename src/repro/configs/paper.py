"""The paper's own experimental configurations (C3-SL Sec. 4.1).

These drive the Table 1 / Table 2 reproduction benchmarks:
  * VGG-16 on CIFAR-10,  split at the 4th max-pool  -> D = 2048
  * ResNet-50 on CIFAR-100, split after stage 3     -> D = 4096
  * batch size 64, Adam lr=1e-4, R in {2,4,8,16}
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperSplitConfig:
    name: str
    model: str            # "vgg16" | "resnet50"
    dataset: str          # "cifar10" | "cifar100"
    n_classes: int
    cut_shape: tuple      # (C, H, W) at the split
    batch_size: int = 64
    lr: float = 1e-4

    @property
    def D(self) -> int:
        c, h, w = self.cut_shape
        return c * h * w


VGG16_CIFAR10 = PaperSplitConfig(
    name="vgg16-cifar10", model="vgg16", dataset="cifar10", n_classes=10,
    cut_shape=(512, 2, 2))

RESNET50_CIFAR100 = PaperSplitConfig(
    name="resnet50-cifar100", model="resnet50", dataset="cifar100",
    n_classes=100, cut_shape=(1024, 2, 2))

PAPER_RS = (2, 4, 8, 16)

# Paper Table 1 reference values (for the analytic reproduction check)
TABLE1 = {
    # (config, R): (accuracy_%, params_x1e3, flops_x1e9)
    ("vgg16-cifar10", "vanilla"): (89.9, None, None),
    ("vgg16-cifar10", 2): (90.3, 4.1, 0.54),
    ("vgg16-cifar10", 4): (90.0, 8.2, 0.54),
    ("vgg16-cifar10", 8): (89.9, 16.4, 0.54),
    ("vgg16-cifar10", 16): (89.6, 32.8, 0.54),
    ("resnet50-cifar100", "vanilla"): (63.1, None, None),
    ("resnet50-cifar100", 2): (63.4, 8.2, 2.15),
    ("resnet50-cifar100", 4): (63.3, 16.4, 2.15),
    ("resnet50-cifar100", 8): (62.8, 32.8, 2.15),
    ("resnet50-cifar100", 16): (62.3, 65.5, 2.15),
}

TABLE1_BOTTLENET = {
    ("vgg16-cifar10", 2): (90.5, 2360.0, 1.21),
    ("vgg16-cifar10", 4): (90.4, 2098.2, 0.67),
    ("vgg16-cifar10", 8): (89.8, 1049.3, 0.34),
    ("vgg16-cifar10", 16): (89.6, 524.9, 0.17),
    ("resnet50-cifar100", 2): (63.6, 9438.7, 4.83),
    ("resnet50-cifar100", 4): (62.9, 8390.7, 2.68),
    ("resnet50-cifar100", 8): (62.6, 4195.8, 1.34),
    ("resnet50-cifar100", 16): (62.5, 2098.4, 0.67),
}
