"""Model/architecture config dataclass + registry.

A config fully describes one architecture.  The repeating unit of the layer
stack is `block_pattern`: a tuple of layers, each layer a tuple of sublayer
kinds, e.g.

    dense:   ((("attn", "mlp"),))                      x num_layers
    moe:     ((("attn", "moe"),))                      x num_layers
    jamba:   1 attn + 7 mamba layers, MoE every 2nd    x (num_layers / 8)
    rwkv:    ((("rwkv_tm", "rwkv_cm"),))               x num_layers
    enc-dec: decoder layers are ("attn","cross","mlp")

`num_layers` must divide evenly into superblocks of len(block_pattern).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

SUBLAYER_KINDS = ("attn", "mla", "mlp", "moe", "mamba", "rwkv_tm", "rwkv_cm", "cross")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int                   # decoder/backbone depth (per stack)
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None       # default d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    partial_rotary: float = 1.0       # fraction of head_dim rotated ("2d RoPE" = 0.5)
    block_pattern: tuple = ((("attn", "mlp")),)
    norm: str = "rmsnorm"
    gated_mlp: bool = True
    # --- MLA (DeepSeek-V2) ---
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    first_dense_layers: int = 0       # leading layers use dense MLP instead of MoE
    capacity_factor: float = 1.25     # train-time expert capacity (decode never drops)
    aux_loss_weight: float = 0.01
    # --- SSM ---
    d_state: int = 16
    d_conv: int = 4
    mamba_expand: int = 2
    rwkv_mode: str = "chunked"        # "chunked" (matmul form) | "sequential"
    # --- encoder-decoder ---
    encoder_layers: int = 0           # > 0 => enc-dec; encoder is ("attn","mlp")
    # --- modality frontend stub ---
    frontend: str | None = None       # "vision" | "audio"
    frontend_dim: int = 0             # raw patch/frame embedding dim
    frontend_seq: int = 0             # patches/frames per sample
    # --- attention variants ---
    sliding_window: int | None = None
    kv_cache_quant: bool = False      # int8 KV cache (beyond-paper, serving)
    # --- citation ---
    source: str = ""

    def __post_init__(self):
        object.__setattr__(self, "block_pattern",
                           tuple(tuple(l) for l in self.block_pattern))
        for layer in self.block_pattern:
            for k in layer:
                assert k in SUBLAYER_KINDS, k
        pat = len(self.block_pattern)
        assert (self.num_layers - self.first_dense_layers) % pat == 0, \
            (self.name, self.num_layers, pat)

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def rotary_dim(self) -> int:
        rd = int(self.head_dim_ * self.partial_rotary)
        return rd - rd % 2

    @property
    def d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def num_superblocks(self) -> int:
        return (self.num_layers - self.first_dense_layers) // len(self.block_pattern)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        kinds = {k for l in self.block_pattern for k in l}
        return not (kinds & {"attn", "mla", "cross"})

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM/hybrid always; attention archs only with
        a sliding window (enc-dec excluded, see DESIGN.md)."""
        if self.is_encdec:
            return False
        return True  # dense archs run long_500k via the sliding-window variant

    def param_count(self) -> int:
        """Total parameters (analytic)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim_
        n = 0

        def attn_params():
            return d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d

        def mla_params():
            return (d * self.num_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * self.kv_lora_rank
                    + self.kv_lora_rank * self.num_heads * self.qk_nope_dim
                    + self.kv_lora_rank * self.num_heads * self.v_head_dim
                    + d * self.qk_rope_dim
                    + self.num_heads * self.v_head_dim * d)

        def mlp_params(f=None):
            f = f or ff
            return d * f * (3 if self.gated_mlp else 2)

        def moe_params():
            f = self.moe_d_ff or ff
            shared = mlp_params(f * self.num_shared_experts) if self.num_shared_experts else 0
            return d * self.num_experts + self.num_experts * 3 * d * f + shared

        def mamba_params():
            di = self.d_inner
            dtr = max(d // 16, 1)
            return (d * 2 * di + self.d_conv * di + di * (dtr + 2 * self.d_state)
                    + dtr * di + di * self.d_state + di * d)

        def rwkv_tm_params():
            return 5 * d * d + 2 * d * 64  # 5 projections + decay lora

        def rwkv_cm_params():
            return 2 * d * ff + d * d  # w_k (d,ff) + w_v (ff,d) + w_r (d,d)

        per_kind = {"attn": attn_params, "mla": mla_params, "mlp": mlp_params,
                    "moe": moe_params, "mamba": mamba_params,
                    "rwkv_tm": rwkv_tm_params, "rwkv_cm": rwkv_cm_params,
                    "cross": attn_params}
        for layer in self.block_pattern:
            for k in layer:
                n += per_kind[k]()
        n *= self.num_superblocks
        n += self.first_dense_layers * (
            (mla_params() if "mla" in self.block_pattern[0] else attn_params())
            + mlp_params())
        n += V * d * 2  # embed + head
        if self.is_encdec:
            n += self.encoder_layers * (attn_params() + mlp_params())
        if self.frontend:
            n += self.frontend_dim * d
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed-active experts)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        f = self.moe_d_ff or self.d_ff
        moe_layers = sum(1 for l in self.block_pattern for k in l if k == "moe")
        moe_layers *= self.num_superblocks
        inactive = moe_layers * (self.num_experts - self.experts_per_token) * 3 * self.d_model * f
        return full - inactive


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        from repro.configs import archs  # noqa: F401  (populates registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs() -> list[str]:
    from repro.configs import archs  # noqa: F401
    return sorted(_REGISTRY)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test variant of the same family: 2 superblocks, tiny dims."""
    pat = len(cfg.block_pattern)
    small = dict(
        num_layers=2 * pat + cfg.first_dense_layers if cfg.first_dense_layers else 2 * pat,
        d_model=256,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        kv_lora_rank=64 if cfg.kv_lora_rank else 0,
        qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        moe_d_ff=128 if cfg.moe_d_ff else 0,
        num_shared_experts=min(cfg.num_shared_experts, 1),
        encoder_layers=2 if cfg.encoder_layers else 0,
        frontend_dim=128 if cfg.frontend else 0,
        frontend_seq=8 if cfg.frontend else 0,
        sliding_window=None,
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
