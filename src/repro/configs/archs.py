"""The 10 assigned architectures (exact dims from the assignment, sources in
brackets) plus the paper's own VGG/ResNet split configs live in paper.py.

Every entry is registered under its assignment id and selectable via
``--arch <id>`` in the launchers.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, register

DENSE = (("attn", "mlp"),)


@register("deepseek-7b")
def deepseek_7b() -> ModelConfig:
    # [dense] llama-arch [arXiv:2401.02954]
    return ModelConfig(
        name="deepseek-7b", family="dense", num_layers=30, d_model=4096,
        num_heads=32, num_kv_heads=32, d_ff=11008, vocab_size=102400,
        head_dim=128, block_pattern=DENSE, source="arXiv:2401.02954")


@register("phi3.5-moe-42b-a6.6b")
def phi35_moe() -> ModelConfig:
    # [moe] 16 experts top-2 [hf:microsoft/Phi-3.5-MoE-instruct]
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b", family="moe", num_layers=32, d_model=4096,
        num_heads=32, num_kv_heads=8, d_ff=6400, vocab_size=32064,
        head_dim=128, block_pattern=(("attn", "moe"),),
        num_experts=16, experts_per_token=2, moe_d_ff=6400,
        source="hf:microsoft/Phi-3.5-MoE-instruct")


@register("jamba-1.5-large-398b")
def jamba_15_large() -> ModelConfig:
    # [hybrid] Mamba+attn 1:7 interleave, MoE 16e top-2 every 2nd layer
    # [arXiv:2403.19887]; 72 layers = 9 superblocks x 8 layers
    pattern = tuple(
        ("attn" if i == 0 else "mamba", "moe" if i % 2 == 1 else "mlp")
        for i in range(8))
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid", num_layers=72, d_model=8192,
        num_heads=64, num_kv_heads=8, d_ff=24576, vocab_size=65536,
        head_dim=128, block_pattern=pattern,
        num_experts=16, experts_per_token=2, moe_d_ff=24576,
        d_state=16, d_conv=4, mamba_expand=2,
        sliding_window=None, source="arXiv:2403.19887")


@register("qwen2.5-32b")
def qwen25_32b() -> ModelConfig:
    # [dense] GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B family]
    return ModelConfig(
        name="qwen2.5-32b", family="dense", num_layers=64, d_model=5120,
        num_heads=40, num_kv_heads=8, d_ff=27648, vocab_size=152064,
        head_dim=128, qkv_bias=True, block_pattern=DENSE,
        rope_theta=1e6, source="hf:Qwen/Qwen2.5-32B")


@register("deepseek-v2-lite-16b")
def deepseek_v2_lite() -> ModelConfig:
    # [moe] MLA kv_lora=512, 2 shared + 64 routed top-6 [arXiv:2405.04434]
    # (assignment note "160 routed" conflicts with its own "64e"; we follow
    # the DeepSeek-V2-Lite paper config: 64 routed + 2 shared, top-6)
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe", num_layers=27, d_model=2048,
        num_heads=16, num_kv_heads=16, d_ff=10944, vocab_size=102400,
        block_pattern=(("mla", "moe"),), first_dense_layers=1,
        kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
        num_experts=64, experts_per_token=6, moe_d_ff=1408, num_shared_experts=2,
        source="arXiv:2405.04434")


@register("pixtral-12b")
def pixtral_12b() -> ModelConfig:
    # [vlm] pixtral-ViT stub + mistral-nemo backbone
    # [hf:mistralai/Pixtral-12B-2409]; frontend supplies patch embeddings
    return ModelConfig(
        name="pixtral-12b", family="vlm", num_layers=40, d_model=5120,
        num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=131072,
        head_dim=128, block_pattern=DENSE, rope_theta=1e6,
        frontend="vision", frontend_dim=1024, frontend_seq=1024,
        source="hf:mistralai/Pixtral-12B-2409")


@register("seamless-m4t-large-v2")
def seamless_m4t() -> ModelConfig:
    # [audio] enc-dec, multimodal [arXiv:2308.11596]; 24-layer speech encoder
    # (stubbed frame embeddings) + 24-layer text decoder with cross-attention
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio", num_layers=24, d_model=1024,
        num_heads=16, num_kv_heads=16, d_ff=8192, vocab_size=256206,
        head_dim=64, block_pattern=(("attn", "cross", "mlp"),),
        encoder_layers=24, gated_mlp=False, norm="layernorm",
        frontend="audio", frontend_dim=1024, frontend_seq=1024,
        source="arXiv:2308.11596")


@register("mistral-large-123b")
def mistral_large() -> ModelConfig:
    # [dense] [hf:mistralai/Mistral-Large-Instruct-2407]
    return ModelConfig(
        name="mistral-large-123b", family="dense", num_layers=88, d_model=12288,
        num_heads=96, num_kv_heads=8, d_ff=28672, vocab_size=32768,
        head_dim=128, block_pattern=DENSE, rope_theta=1e6,
        source="hf:mistralai/Mistral-Large-Instruct-2407")


@register("rwkv6-1.6b")
def rwkv6_16b() -> ModelConfig:
    # [ssm] Finch — data-dependent decay [arXiv:2404.05892]; 32 heads x 64
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm", num_layers=24, d_model=2048,
        num_heads=32, num_kv_heads=32, d_ff=7168, vocab_size=65536,
        head_dim=64, block_pattern=(("rwkv_tm", "rwkv_cm"),),
        norm="layernorm", source="arXiv:2404.05892")


@register("chatglm3-6b")
def chatglm3_6b() -> ModelConfig:
    # [dense] RoPE 2d (partial rotary 0.5), GQA kv=2 [arXiv:2406.12793]
    return ModelConfig(
        name="chatglm3-6b", family="dense", num_layers=28, d_model=4096,
        num_heads=32, num_kv_heads=2, d_ff=13696, vocab_size=65024,
        head_dim=128, partial_rotary=0.5, qkv_bias=True,
        block_pattern=DENSE, source="arXiv:2406.12793")


ALL_ARCHS = [
    "deepseek-7b", "phi3.5-moe-42b-a6.6b", "jamba-1.5-large-398b", "qwen2.5-32b",
    "deepseek-v2-lite-16b", "pixtral-12b", "seamless-m4t-large-v2",
    "mistral-large-123b", "rwkv6-1.6b", "chatglm3-6b",
]
