"""One direction of the split-learning wire: codec + controller + accounting.

A ``Channel`` owns everything one direction of the cut-layer exchange needs:

* the codec that re-represents the payload on the wire (a static codec or
  an ``AdaptiveC3SL`` wrapper scheduling R from measured SNR),
* the controller feedback entry point (``observe``) when it is adaptive,
* exact wire-byte accounting for an already-shaped payload
  (``wire_bytes`` — scale/mask bytes of chained wire stages included).

Two channels compose into a ``SplitLink`` (repro.transport.link): ``fwd``
carries the client→server activation payload, ``bwd`` the server→client
gradient payload.  The backward channel is realized as a custom-VJP seam
(:func:`grad_roundtrip`): identity in the forward pass, and in the backward
pass the cotangent — the gradient payload that would cross the wire — is
round-tripped through the backward codec (its own R / wire stages), with the
measured gradient-retrieval SNR surfaced through a probe argument's
cotangent so a second deadband controller can schedule the backward R
without a second pass.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.codecs import AdaptiveC3SL, payload_wire_bytes, program_key
from repro.core import hrr


@functools.lru_cache(maxsize=None)
def _grad_seam(bwd_codec):
    """The backward channel's custom-VJP seam, specialized to ONE static
    codec (codecs are frozen dataclasses, so the cache key is the codec).

    Forward: identity on the payload.  Backward: the cotangent ``g`` (the
    gradient payload crossing server→client) is grouped row-wise and
    round-tripped through ``bwd_codec`` — its own R and wire stages — and
    the probe argument's cotangent carries ``retrieval_snr(g, ghat)``, the
    gradient-side controller's feedback signal.
    """

    @jax.custom_vjp
    def seam(payload, bwd_params, probe):
        del bwd_params, probe
        return payload

    def fwd(payload, bwd_params, probe):
        del probe
        return payload, (bwd_params,)

    def bwd(res, g):
        (bwd_params,) = res
        D = g.shape[-1]
        g2 = g.reshape(-1, D)
        ghat = bwd_codec.decode(bwd_params, bwd_codec.encode(bwd_params, g2))
        snr = hrr.retrieval_snr(g2, ghat)
        zeros = jax.tree.map(jnp.zeros_like, bwd_params)
        return ghat.reshape(g.shape), zeros, snr

    seam.defvjp(fwd, bwd)
    return seam


def grad_roundtrip(bwd_codec, payload, bwd_params, probe=None):
    """Identity on ``payload``; compresses its GRADIENT through ``bwd_codec``.

    ``probe`` (scalar f32) is a gradient tap: differentiate the surrounding
    loss w.r.t. it (``jax.grad(..., argnums=...)``) and the "gradient" you
    get back is the measured gradient-retrieval SNR in dB — the backward
    ``AdaptiveC3SL`` controller's feedback, measured in the same backward
    pass that ships the payload.  ``bwd_codec`` must be a STATIC codec (an
    adaptive wrapper's bucket), same jit-safety contract as everywhere else.
    """
    if probe is None:
        probe = jnp.float32(0.0)
    return _grad_seam(bwd_codec)(payload, bwd_params, probe)


@dataclasses.dataclass
class Channel:
    """One direction of the split link: a codec plus its schedule state.

    ``codec`` is either a static codec (possibly a ``Chain``) or an
    ``AdaptiveC3SL`` wrapper; the channel is the one place that knows which,
    so callers talk directions ("the forward channel's current bucket")
    instead of isinstance checks.
    """
    direction: str                 # "fwd" | "bwd" (display/accounting tag)
    codec: object

    @property
    def adaptive(self) -> bool:
        return isinstance(self.codec, AdaptiveC3SL)

    @property
    def current(self):
        """The static codec serving the next dispatch (the adaptive
        wrapper's current bucket, or the codec itself)."""
        return self.codec.current if self.adaptive else self.codec

    @property
    def current_R(self) -> int:
        return getattr(self.current, "R", 1)

    def program_key(self):
        """Host-side compiled-program key: current bucket R, None if static."""
        return program_key(self.codec)

    def observe(self, snr_db=None, loss_slack=None) -> int:
        """Feed this direction's controller one step's signals (no-op for a
        static codec); returns the R serving the NEXT dispatch."""
        if self.adaptive:
            return self.codec.observe(snr_db, loss_slack)
        return self.current_R

    def params_for(self, params, key=None):
        """Slice one bucket's params (identity for a static codec)."""
        if self.adaptive:
            return self.codec.params_for(params, key)
        return params

    def wire_bytes(self, rows: int) -> int:
        """Exact bytes this direction ships for ``rows`` feature rows —
        the current bucket's payload shape fed to its last wire stage."""
        c = self.current
        return payload_wire_bytes(c, c.payload_shape(rows))

    def spec(self) -> str:
        return self.codec.spec()

    def __repr__(self) -> str:
        return f"Channel({self.direction!r}, {self.spec()!r})"
