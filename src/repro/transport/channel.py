"""One direction of the split-learning wire: codec + controller + accounting.

A ``Channel`` owns everything one direction of the cut-layer exchange needs:

* the codec that re-represents the payload on the wire (a static codec or
  an ``AdaptiveC3SL`` wrapper scheduling R from measured SNR),
* the controller feedback entry point (``observe``) when it is adaptive,
* exact wire-byte accounting for an already-shaped payload
  (``wire_bytes`` — scale/mask bytes of chained wire stages included).

Two channels compose into a ``SplitLink`` (repro.transport.link): ``fwd``
carries the client→server activation payload, ``bwd`` the server→client
gradient payload.  The backward channel is realized as a custom-VJP seam
(:func:`grad_roundtrip`): identity in the forward pass, and in the backward
pass the cotangent — the gradient payload that would cross the wire — is
round-tripped through the backward codec (its own R / wire stages), with the
measured gradient-retrieval SNR surfaced through a probe argument's
cotangent so a second deadband controller can schedule the backward R
without a second pass.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.codecs import AdaptiveC3SL, payload_wire_bytes, program_key
from repro.core import hrr


@functools.lru_cache(maxsize=None)
def _grad_seam(bwd_codec):
    """The backward channel's custom-VJP seam, specialized to ONE static
    codec (codecs are frozen dataclasses, so the cache key is the codec).

    Forward: identity on the payload.  Backward: the cotangent ``g`` (the
    gradient payload crossing server→client) is grouped row-wise and
    round-tripped through ``bwd_codec`` — its own R and wire stages — and
    the probe argument's cotangent carries ``retrieval_snr(g, ghat)``, the
    gradient-side controller's feedback signal.
    """

    @jax.custom_vjp
    def seam(payload, bwd_params, probe):
        del bwd_params, probe
        return payload

    def fwd(payload, bwd_params, probe):
        del probe
        return payload, (bwd_params,)

    def bwd(res, g):
        (bwd_params,) = res
        D = g.shape[-1]
        g2 = g.reshape(-1, D)
        ghat = bwd_codec.decode(bwd_params, bwd_codec.encode(bwd_params, g2))
        snr = hrr.retrieval_snr(g2, ghat)
        zeros = jax.tree.map(jnp.zeros_like, bwd_params)
        return ghat.reshape(g.shape), zeros, snr

    seam.defvjp(fwd, bwd)
    return seam


def masked_decode(codec, params, payload, keep):
    """Erasure-aware decode dispatch: codecs that implement
    ``decode_masked`` (C3-SL's renormalized unbind, Chain, adaptive
    buckets) get the mask natively; anything else decodes the zeroed
    payload (lost elements contribute nothing, no renormalization)."""
    fn = getattr(codec, "decode_masked", None)
    if fn is None:
        return codec.decode(params, payload * keep)
    return fn(params, payload, keep)


@functools.lru_cache(maxsize=None)
def _grad_seam_masked(bwd_codec):
    """The erasure-aware variant of :func:`_grad_seam`: the backward
    payload's keep mask rides as a runtime argument (static shape per
    bucket — no recompiles), the cotangent round-trip decodes through
    ``masked_decode``, and the probe cotangent carries the
    erasure-DEGRADED gradient SNR the backward controller observes."""

    @jax.custom_vjp
    def seam(payload, bwd_params, probe, keep):
        del bwd_params, probe, keep
        return payload

    def fwd(payload, bwd_params, probe, keep):
        del probe
        return payload, (bwd_params, keep)

    def bwd(res, g):
        bwd_params, keep = res
        D = g.shape[-1]
        g2 = g.reshape(-1, D)
        ghat = masked_decode(bwd_codec, bwd_params,
                             bwd_codec.encode(bwd_params, g2), keep)
        snr = hrr.retrieval_snr(g2, ghat)
        zeros = jax.tree.map(jnp.zeros_like, bwd_params)
        return ghat.reshape(g.shape), zeros, snr, jnp.zeros_like(keep)

    seam.defvjp(fwd, bwd)
    return seam


def grad_roundtrip(bwd_codec, payload, bwd_params, probe=None, keep=None):
    """Identity on ``payload``; compresses its GRADIENT through ``bwd_codec``.

    ``probe`` (scalar f32) is a gradient tap: differentiate the surrounding
    loss w.r.t. it (``jax.grad(..., argnums=...)``) and the "gradient" you
    get back is the measured gradient-retrieval SNR in dB — the backward
    ``AdaptiveC3SL`` controller's feedback, measured in the same backward
    pass that ships the payload.  ``bwd_codec`` must be a STATIC codec (an
    adaptive wrapper's bucket), same jit-safety contract as everywhere else.

    ``keep`` (optional, backward-payload-shaped) is the backward
    direction's erasure mask: the gradient round-trip decodes through the
    mask-aware path and the probe SNR degrades accordingly.  ``keep=None``
    routes through the exact pre-fault seam (structurally identical trace).
    """
    if probe is None:
        probe = jnp.float32(0.0)
    if keep is None:
        return _grad_seam(bwd_codec)(payload, bwd_params, probe)
    return _grad_seam_masked(bwd_codec)(payload, bwd_params, probe, keep)


@dataclasses.dataclass
class Channel:
    """One direction of the split link: a codec plus its schedule state.

    ``codec`` is either a static codec (possibly a ``Chain``) or an
    ``AdaptiveC3SL`` wrapper; the channel is the one place that knows which,
    so callers talk directions ("the forward channel's current bucket")
    instead of isinstance checks.
    """
    direction: str                 # "fwd" | "bwd" (display/accounting tag)
    codec: object
    faults: object = None          # repro.faults.FaultPlan (None = clean)
    recovery: object = None        # repro.faults.RecoveryPolicy
    _step: int = dataclasses.field(default=0, repr=False, compare=False)

    @property
    def adaptive(self) -> bool:
        return isinstance(self.codec, AdaptiveC3SL)

    @property
    def current(self):
        """The static codec serving the next dispatch (the adaptive
        wrapper's current bucket, or the codec itself)."""
        return self.codec.current if self.adaptive else self.codec

    @property
    def current_R(self) -> int:
        return getattr(self.current, "R", 1)

    def program_key(self):
        """Host-side compiled-program key: current bucket R, None if static."""
        return program_key(self.codec)

    def observe(self, snr_db=None, loss_slack=None) -> int:
        """Feed this direction's controller one step's signals (no-op for a
        static codec); returns the R serving the NEXT dispatch."""
        if self.adaptive:
            return self.codec.observe(snr_db, loss_slack)
        return self.current_R

    def params_for(self, params, key=None):
        """Slice one bucket's params (identity for a static codec)."""
        if self.adaptive:
            return self.codec.params_for(params, key)
        return params

    def install_faults(self, plan, recovery=None) -> "Channel":
        """Install a ``repro.faults.FaultPlan`` (and optional
        ``RecoveryPolicy``) on this direction; resets the step counter so
        the injected schedule replays from step 0.  Returns self."""
        self.faults = plan
        self.recovery = recovery
        self._step = 0
        return self

    def next_erasure(self, rows: int | None = None, shape=None):
        """Draw the NEXT step's erasure mask for this direction under the
        installed plan, advancing the channel's per-direction step
        counter.  Returns ``(keep, info)`` — both ``None`` with no plan
        (or a zero plan), so clean runs stay structurally fault-free;
        otherwise ``keep`` is the float32 element mask of the current
        bucket's payload shape (all-ones on loss-free steps) and ``info``
        the retransmission accounting from
        :func:`repro.faults.negotiate_payload`.  Raises
        ``ChannelErasure`` when the recovery budget cannot repair the
        step."""
        step = self._step
        self._step += 1
        if self.faults is None or self.faults.is_zero():
            return None, None
        if shape is None:
            if rows is None:
                raise ValueError("next_erasure needs rows or an explicit "
                                 "payload shape")
            c = self.current
            shape = c.payload_shape(rows)
        from repro.faults import negotiate_payload
        return negotiate_payload(self.faults, self.direction, step,
                                 tuple(shape), self.recovery)

    def wire_bytes(self, rows: int) -> int:
        """Exact bytes this direction ships for ``rows`` feature rows —
        the current bucket's payload shape fed to its last wire stage."""
        c = self.current
        return payload_wire_bytes(c, c.payload_shape(rows))

    def spec(self) -> str:
        return self.codec.spec()

    def __repr__(self) -> str:
        return f"Channel({self.direction!r}, {self.spec()!r})"
