"""Split-learning step machinery over the transport layer.

Moved here from ``repro.core.split`` (which remains a thin re-export shim):
the logical-split loss builder and the codec round-trip dispatch, now
link-aware — a ``SplitLink`` at the cut layer compresses the two directions
independently (see ``repro.transport.link``), while bare codecs take the
exact pre-transport code path.
"""
from __future__ import annotations

from typing import Callable

from repro.transport.link import SplitLink, roundtrip


def apply_codec(codec, params, Z, *, with_snr=False, bwd_probe=None,
                erasure=None):
    """Round-trip Z through a codec or SplitLink, preserving Z's shape.

    Dispatch is protocol-level via ``codec.feature_layout``: "nchw" codecs
    (BottleNet++) consume (B, C, H, W) natively; "flat" codecs work on
    flattened (B, D).  Wrapper codecs (the Adaptive-R scheduler, SplitLink)
    expose the same attribute, so they dispatch identically.

    ``with_snr=True`` additionally returns the retrieval SNR (dB) of the
    round-trip — the forward Adaptive-R controller's feedback signal.
    ``bwd_probe`` is the asymmetric link's gradient-SNR tap (see
    ``repro.transport.channel.grad_roundtrip``); ignored otherwise.
    ``erasure`` is the per-direction payload keep-mask dict (see
    ``repro.transport.link.roundtrip``) — flat codecs/links only.
    """
    if getattr(codec, "feature_layout", "flat") == "nchw":
        if erasure:
            raise ValueError("payload erasure is modeled for flat codecs "
                             "and links only (nchw has no packetized "
                             "payload layout)")
        if isinstance(codec, SplitLink):
            # only mirrored links can be nchw (asymmetric is rejected at
            # construction); unwrap to the one shared codec
            params = codec.fwd_params(params)
            codec = codec.fwd.codec
        payload = codec.encode(params, Z)
        Zhat = codec.decode(params, payload)
        if with_snr:
            from repro.core.hrr import retrieval_snr
            return Zhat, retrieval_snr(Z, Zhat)
        return Zhat
    shape = Z.shape
    Zf = Z.reshape(shape[0], -1)
    out = roundtrip(codec, params, Zf, with_snr=with_snr, bwd_probe=bwd_probe,
                    erasure=erasure)
    if with_snr:
        Zhat, snr = out
        return Zhat.reshape(shape), snr
    return out.reshape(shape)


def make_split_loss_fn(front_apply: Callable, back_apply: Callable, codec,
                       loss_fn: Callable, with_metrics: bool = False) -> Callable:
    """Logical split: loss(params, batch) with the codec at the cut layer.

    params = {"front": ..., "back": ..., "codec": ...}
    batch  = {"x": ..., "y": ...}

    ``codec`` may be a static codec or a static ``SplitLink``.  The returned
    fn also accepts an optional third argument, the backward-SNR probe:
    ``loss(params, batch, probe)`` with ``jax.value_and_grad(loss,
    argnums=(0, 2))`` yields the measured gradient-retrieval SNR as the
    probe's "gradient" — zero when the link is mirrored or a bare codec.

    ``with_metrics=True`` makes the returned fn yield (loss, metrics) where
    metrics["cut_snr"] is the cut-layer retrieval SNR in dB — pair it with
    ``jax.value_and_grad(..., has_aux=True)`` to feed the Adaptive-R
    scheduler without a second forward pass.

    The returned fn also accepts ``erasure`` (per-direction keep-mask
    dict, see ``roundtrip``): a runtime argument with static shapes, so
    a chaos loop feeds each step's drawn mask to ONE compiled branch.
    ``erasure=None`` (the default) is structurally the fault-free trace.
    """

    def loss(params, batch, bwd_probe=None, erasure=None):
        Z = front_apply(params["front"], batch["x"])
        if with_metrics:
            Zhat, snr = apply_codec(codec, params["codec"], Z, with_snr=True,
                                    bwd_probe=bwd_probe, erasure=erasure)
            logits = back_apply(params["back"], Zhat)
            return loss_fn(logits, batch["y"]), {"cut_snr": snr}
        Zhat = apply_codec(codec, params["codec"], Z, bwd_probe=bwd_probe,
                           erasure=erasure)
        logits = back_apply(params["back"], Zhat)
        return loss_fn(logits, batch["y"])

    return loss


def split_comm_bytes(codec, B: int, directions: int = 2) -> int:
    """Wire bytes per step (activations up + gradients down).  A SplitLink
    accounts each direction with its own channel's codec/bucket."""
    if isinstance(codec, SplitLink):
        total = codec.wire_bytes_fwd(B)
        if directions >= 2:
            total += codec.wire_bytes_bwd(B)
        return total
    return directions * codec.wire_bytes(B)
