"""2-stage pod pipeline over the transport layer (compressed ppermute wire).

Moved here from ``repro.core.split`` and extended two ways:

* **Per-direction codecs** — the channel accepts a static ``SplitLink``;
  an asymmetric link inserts the gradient seam on the payload, so the
  gradient crossing the pod boundary is degraded/accounted as the backward
  channel's own codec/R.  Like every wire stage in this repo (int8, topk),
  the seam is a straight-through MODEL: the in-graph adjoint tensor keeps
  the forward payload's (mb/R_fwd, D) shape — the measured HLO
  collective-permute bytes do not shrink — while ``wire_bytes_bwd``
  accounts what the re-grouped (mb/(R_fwd*R_bwd), D) payload would ship,
  and the reconstruction noise of that round-trip is applied for real.

* **Asynchronous (double-buffered) channel** — ``async_depth`` sizes a ring
  of in-flight payload buffers in the ``lax.scan`` carry.  ``async_depth=1``
  is the synchronous PR-4 schedule bit-identically (one buffer: the payload
  sent at step t is consumed at t+1, the scan serializes send→consume).
  ``async_depth=2`` consumes the payload sent at step t-2, so the ppermute
  of microbatch t's payload has the whole of step t+1's front-pass compute
  to complete in — the send overlaps the next microbatch's forward work
  instead of sitting on the scan's critical path.

  Staleness semantics (well-defined, pinned in tests/test_pipeline_async.py):
  the payload of microbatch m is consumed by the back stage at scan step
  m + depth and paired with ITS OWN labels y_m — the skew delays
  consumption, it never mis-pairs microbatches — so the loss and gradients
  are identical to the synchronous schedule; the cost is depth-1 extra
  bubble steps (the scan runs M + depth steps) and depth payload buffers
  resident in the carry.

Pipeline schedule (M = num_microbatches, d = async_depth, steps t = 0..M+d-1):
    pod0:  front(mb_t)          for t < M
    pod1:  back(recv_{t-d})     for t >= d
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.codecs import AdaptiveC3SL
from repro.transport.channel import grad_roundtrip, masked_decode
from repro.transport.link import SplitLink


def _shard_map(f, mesh, in_specs, out_specs, manual_axes):
    """Partial-manual shard_map on current jax; full-manual fallback on
    older releases (which lack ``jax.shard_map`` and whose partial-auto
    mode cannot lower ``axis_index``).  The fallback replicates the
    data/model-axis compute per device — correct, just not sharded —
    so tests on simulated host meshes run everywhere."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(manual_axes),
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False, auto=frozenset())


def _require_static(codec):
    chans = (codec.fwd.codec, codec.bwd.codec) if isinstance(codec, SplitLink) \
        else (codec,)
    for c in chans:
        if isinstance(c, AdaptiveC3SL):
            raise ValueError(
                "the pod pipeline compiles ONE program; resolve adaptive "
                "channels to static buckets first (transport.pin_link / "
                "AdaptiveC3SL.current) — see repro.launch.train.run_pipeline")


def make_pod_pipeline_loss_fn(
    embed_fn: Callable,        # (embed_params, x_mb) -> h (mb, S, E)
    stage_fn: Callable,        # (stage_blocks, h) -> h  (one stage's blocks; same fn both stages)
    head_loss_fn: Callable,    # (head_params, h, y_mb) -> scalar mean loss
    codec,                     # flat codec OR static SplitLink
    mesh,
    num_microbatches: int = 1,
    async_depth: int = 1,
    with_erasure: bool = False,
) -> Callable:
    """Returns loss(params, batch) implementing the 2-stage compressed pipeline.

    params = {"embed", "blocks" (leading stage axis 2, sharded P("pod")),
              "head", "codec"}.
    batch  = {"x": (B, S) or (B, S, E_in), "y": (B, S)} — replicated over pod,
             sharded over data on the batch dim by the caller.

    The in-flight payloads are a ring of ``async_depth`` lax.scan carry
    buffers; ``lax.ppermute`` moves the newest one each step (see module
    docstring for the schedule and staleness semantics).

    ``with_erasure=True`` compiles the chaos variant instead:
    ``loss(params, batch, keep)`` where ``keep`` is an
    ``(M + depth, mb // R_fwd, D)`` float32 stack of per-step keep masks
    — ``keep[t]`` masks the payload the back stage CONSUMES at scan step
    t (the one sent at t - depth), decoded through the renormalizing
    ``decode_masked`` path.  An all-ones stack reproduces the clean
    schedule bitwise (the masked decode is exact at full mask); the
    erasure-free builder keeps the exact pre-fault trace.
    """
    M = num_microbatches
    depth = int(async_depth)
    if depth < 1:
        raise ValueError(f"async_depth must be >= 1, got {async_depth}")
    _require_static(codec)
    link = codec if isinstance(codec, SplitLink) else None
    fwd_codec = link.fwd.codec if link is not None else codec

    def loss(params, batch, keep=None):
        if with_erasure and keep is None:
            raise ValueError(
                "with_erasure=True compiles the masked consume path: pass "
                "the (M + depth, rows, D) keep-mask stack (all-ones for a "
                "loss-free step)")
        if not with_erasure and keep is not None:
            raise ValueError("keep masks need the with_erasure=True builder")

        def inner(x, y, embed_p, blocks_local, head_p, codec_p, *rest):
            keep_stack = rest[0] if rest else None
            stage = jax.lax.axis_index("pod")
            # blocks_local: (1, L/2, ...) — this pod's stage blocks
            my_blocks = jax.tree.map(lambda a: a[0], blocks_local)
            fwd_p = link.fwd_params(codec_p) if link is not None else codec_p

            B = x.shape[0]
            assert B % M == 0, (B, M)
            mb = B // M
            x_mbs = x.reshape(M, mb, *x.shape[1:])
            y_mbs = y.reshape(M, mb, *y.shape[1:])

            h_probe = embed_fn(embed_p, x_mbs[0])
            flat_shape = (mb, h_probe.shape[1] * h_probe.shape[2])

            def payload_of(h):
                payload = fwd_codec.encode(fwd_p, h.reshape(flat_shape))
                if link is not None and not link.mirrored:
                    # gradient seam: the cotangent crossing back through
                    # the pod boundary is round-tripped (straight-through,
                    # shape-preserving) by the backward channel's codec —
                    # in SPMD both pods run the same program, so which side
                    # of the reverse ppermute applies it is equivalent
                    payload = grad_roundtrip(link.bwd.codec, payload,
                                             link.bwd_params(codec_p))
                # shard the wire tensor over (data, model) BEFORE the pod
                # hop: the FFT encode otherwise leaves D replicated and every
                # model shard would redundantly send the full payload.
                # (scatter is intra-pod ICI — cheap; the pod link is scarce)
                from repro.sharding.constraints import constrain
                return constrain(payload, ("data", "model"))

            def step(bufs, t):
                # input for my stage at step t; the back stage consumes the
                # OLDEST in-flight buffer (sent depth steps ago = microbatch
                # t - depth) and pairs it with that microbatch's labels
                x_t = jax.lax.dynamic_index_in_dim(
                    x_mbs, jnp.minimum(t, M - 1), axis=0, keepdims=False)
                y_prev = jax.lax.dynamic_index_in_dim(
                    y_mbs, jnp.clip(t - depth, 0, M - 1), axis=0,
                    keepdims=False)
                h_front_in = embed_fn(embed_p, x_t)
                if keep_stack is None:
                    h_back = fwd_codec.decode(fwd_p, bufs[-1])
                else:
                    keep_t = jax.lax.dynamic_index_in_dim(
                        keep_stack, t, axis=0, keepdims=False)
                    h_back = masked_decode(fwd_codec, fwd_p, bufs[-1],
                                           keep_t)
                h_back_in = h_back.reshape(h_front_in.shape)
                h_in = jnp.where(stage == 0, h_front_in, h_back_in)
                h_out = stage_fn(my_blocks, h_in)
                payload = payload_of(h_out)
                # channel: stage0 -> stage1 (stage1's payload goes back to 0
                # and is ignored, closing the permutation ring)
                recv = jax.lax.ppermute(payload, "pod", perm=[(0, 1), (1, 0)])
                mb_loss = head_loss_fn(head_p, h_out, y_prev)
                valid = jnp.logical_and(stage == 1, t >= depth)
                # per-step losses ride the scan ys (not a scalar carry): the
                # masked-out warmup/front-stage entries are exact zeros
                return (recv,) + bufs[:-1], jnp.where(valid, mb_loss, 0.0)

            payload0 = jnp.zeros_like(payload_of(h_probe))
            bufs0 = (payload0,) * depth
            _, step_losses = jax.lax.scan(step, bufs0, jnp.arange(M + depth))
            # only pod1 accumulated loss; sum over pods and average microbatches
            return jax.lax.psum(step_losses.sum(), "pod") / M

        args = (batch["x"], batch["y"], params["embed"], params["blocks"],
                params["head"], params["codec"])
        specs = (P(), P(), P(), P("pod"), P(), P())
        if with_erasure:
            args += (keep,)
            specs += (P(),)
        return _shard_map(inner, mesh, specs, P(), {"pod"})(*args)

    return loss
