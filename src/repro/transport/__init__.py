"""repro.transport — the directional cut-layer transport subsystem.

The split-learning exchange has two directions with different payloads:
client→server activations (``fwd``) and server→client gradients (``bwd``).
This package models each as a :class:`Channel` (codec + adaptive controller
+ exact wire accounting) and composes them into a :class:`SplitLink`,
buildable from a spec string::

    build_link("c3sl:R=16|int8 >> bwd:c3sl:R=8", D=4096)

No ``bwd:`` stage → a MIRRORED link: both directions share one codec, the
gradient payload has the forward's compressed shape, and every call site
behaves bit-identically to the pre-transport shared-codec path.  An explicit
``bwd:`` codec inserts a custom-VJP seam on the payload that re-compresses
the gradient with the backward channel's own codec/R and measures the
gradient-retrieval SNR in the same backward pass (probe cotangent) — the
feedback for an independent backward ``AdaptiveC3SL`` controller.

Loss builders:

* :func:`make_split_loss_fn` — logical split (front/back in one program).
* :func:`make_pod_pipeline_loss_fn` — the 2-stage pod pipeline, now with an
  asynchronous double-buffered channel (``async_depth``): the ppermute of
  microbatch t's payload overlaps the front pass of t+1; depth=1 is the
  synchronous schedule bit-identically.

``repro.core.split`` remains a thin re-export shim for pre-transport
imports (same pattern PR 1 used for ``repro.core.codec``).
"""
from repro.faults import ChannelErasure, FaultPlan, RecoveryPolicy
from repro.transport.channel import Channel, grad_roundtrip, masked_decode
from repro.transport.link import (BWD_PREFIX, DRAFT_PREFIX, LINK_SEP,
                                  SplitLink, as_link, build_link,
                                  build_link_or_codec,
                                  build_link_program_table, is_link_spec,
                                  link_program_key, parse_link_spec, pin_link,
                                  roundtrip, slice_link_params)
from repro.transport.pipeline import make_pod_pipeline_loss_fn
from repro.transport.split import (apply_codec, make_split_loss_fn,
                                   split_comm_bytes)

__all__ = [
    "Channel", "SplitLink", "grad_roundtrip", "roundtrip", "masked_decode",
    "as_link", "build_link", "build_link_or_codec", "is_link_spec",
    "parse_link_spec", "LINK_SEP", "BWD_PREFIX", "DRAFT_PREFIX",
    "build_link_program_table", "link_program_key", "pin_link",
    "slice_link_params",
    "apply_codec", "make_split_loss_fn", "split_comm_bytes",
    "make_pod_pipeline_loss_fn",
    "FaultPlan", "RecoveryPolicy", "ChannelErasure",
]
