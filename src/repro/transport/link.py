"""SplitLink: the bidirectional cut-layer exchange as a pair of Channels.

Spec grammar (extends the codec grammar in ``repro.codecs``)::

    LINK := CODEC_SPEC [" >> bwd:" CODEC_SPEC] [" >> draft:" CODEC_SPEC]

The part before ``>>`` is the forward (client→server activation) codec; the
``bwd:``-prefixed part is the backward (server→client gradient) codec.  With
no ``bwd:`` stage the link is MIRRORED: both directions share ONE codec and
the backward payload simply has the forward's compressed shape — exactly the
shared-codec behavior every pre-transport call site had, bit-identical
(pinned in tests/test_transport.py).

The ``draft:``-prefixed segment is the speculative-decoding DRAFT channel
(``repro.serving.spec``): the server→client cut-feature feedback payload the
client-side draft head reads between verify rounds.  It is a third
:class:`Channel` with its own codec/R and wire accounting; it never touches
the forward/backward numerics (draft-channel loss degrades only the draft
ACCEPTANCE RATE, never output correctness — see
src/repro/transport/README.md), so unlike ``bwd:`` it composes with any
forward codec and may appear with or without a ``bwd:`` stage.

    build_link("c3sl:R=16|int8 >> bwd:c3sl:R=8", D=4096)
    build_link("adaptive:c3sl:R=8,min_R=2|int8 >> "
               "bwd:adaptive:c3sl:R=4,min_R=2|int8", D=256)
    build_link("c3sl:R=16|int8 >> bwd:c3sl:R=8 >> draft:c3sl:R=32|int8",
               D=4096)

An asymmetric link inserts :func:`repro.transport.channel.grad_roundtrip` on
the payload: the forward pass is unchanged (the seam is identity), and the
backward pass round-trips the gradient payload — shape ``(B/R_fwd, D)`` —
through the backward codec, so the wire carries ``(B/(R_fwd·R_bwd), D)``
gradient rows in the backward codec's wire format.  The gradient-retrieval
SNR is measured in the same backward pass and surfaced through a probe
cotangent, feeding a SECOND deadband controller (the backward channel's own
``AdaptiveC3SL``) that schedules R_bwd independently of R_fwd.

jit-safety is the same contract as ``repro.codecs.adaptive``: adaptive
channels are resolved to static bucket pairs via
:func:`build_link_program_table` — one compiled program per (R_fwd, R_bwd)
pair, switched host-side, zero recompiles on schedule changes.
"""
from __future__ import annotations

from repro import codecs
from repro.codecs import AdaptiveC3SL, clamp_R
from repro.core import hrr
from repro.transport.channel import Channel, grad_roundtrip, masked_decode

LINK_SEP = ">>"
BWD_PREFIX = "bwd:"
DRAFT_PREFIX = "draft:"


def is_link_spec(spec: str) -> bool:
    """True for per-direction specs (``... >> bwd:...`` / ``... >> draft:...``)."""
    return isinstance(spec, str) and LINK_SEP in spec


def parse_link_spec(spec: str) -> tuple[str, str | None, str | None]:
    """Split a link spec into (fwd_spec, bwd_spec-or-None, draft_spec-or-None).

    Tagged segments after the forward codec may appear in either order but
    at most once each; every segment after ``>>`` must carry a ``bwd:`` or
    ``draft:`` tag."""
    if not is_link_spec(spec):
        return spec.strip(), None, None
    parts = [p.strip() for p in spec.split(LINK_SEP)]
    if len(parts) > 3:
        raise ValueError(f"more than two '{LINK_SEP}' in link spec {spec!r}")
    fwd_spec = parts[0]
    if not fwd_spec:
        raise ValueError(f"empty forward codec spec in {spec!r}")
    bwd_spec = draft_spec = None
    for part in parts[1:]:
        if part.startswith(BWD_PREFIX):
            if bwd_spec is not None:
                raise ValueError(f"duplicate '{BWD_PREFIX}' stage in {spec!r}")
            bwd_spec = part[len(BWD_PREFIX):].strip()
            if not bwd_spec:
                raise ValueError(f"empty backward codec spec in {spec!r}")
        elif part.startswith(DRAFT_PREFIX):
            if draft_spec is not None:
                raise ValueError(
                    f"duplicate '{DRAFT_PREFIX}' stage in {spec!r}")
            draft_spec = part[len(DRAFT_PREFIX):].strip()
            if not draft_spec:
                raise ValueError(f"empty draft codec spec in {spec!r}")
        else:
            raise ValueError(
                f"stages after '{LINK_SEP}' must be tagged '{BWD_PREFIX}' or "
                f"'{DRAFT_PREFIX}', got {part!r} in {spec!r}")
    return fwd_spec, bwd_spec, draft_spec


def _has_trainable_params(codec) -> bool:
    """True when any stage of ``codec`` declares ``trainable = True``
    (dense/bnpp autoencoders), unwrapping Chain transforms and adaptive
    buckets.  C3-SL's key tables are fixed (stop_gradient), so c3sl chains
    report False."""
    if isinstance(codec, AdaptiveC3SL):
        return any(_has_trainable_params(b) for b in codec.buckets.values())
    inner = getattr(codec, "transform", None)
    if inner is not None:                      # Chain: the transform stage
        return _has_trainable_params(inner)
    return bool(getattr(codec, "trainable", False))


class SplitLink:
    """(fwd: Channel, bwd: Channel[, draft: Channel]) — the cut-layer
    exchange, both ways, plus the optional speculative draft channel.

    ``bwd_codec=None`` builds a MIRRORED link: the backward channel aliases
    the forward codec (one codec object, one params tree, the pre-transport
    behavior).  An explicit backward codec makes the link asymmetric: its
    params tree becomes ``{"fwd": ..., "bwd": ...}`` and the gradient seam
    is inserted at the payload.

    ``draft_codec`` adds the serving-side draft channel (a third
    :class:`Channel`, direction tag ``"draft"``).  It carries the
    server→client cut-feature feedback the speculative draft head reads
    (repro.serving.spec) — it is OUTSIDE the fwd/bwd numeric path, so its
    presence never changes training or non-speculative serving numerics;
    the params tree gains a ``"draft"`` key only when the channel exists.
    """

    def __init__(self, fwd_codec, bwd_codec=None, draft_codec=None):
        if bwd_codec is not None:
            for tag, c in (("fwd", fwd_codec), ("bwd", bwd_codec)):
                if getattr(c, "feature_layout", "flat") != "flat":
                    raise ValueError(
                        f"per-direction links support flat codecs only; the "
                        f"{tag} codec has feature_layout="
                        f"{getattr(c, 'feature_layout', None)!r}")
            if _has_trainable_params(bwd_codec):
                # the gradient seam applies the bwd codec INSIDE a VJP rule
                # and returns zero cotangents for its params — a trainable
                # bwd codec would silently stay at init while corrupting
                # every gradient.  Fail loudly instead.
                raise ValueError(
                    f"the backward channel cannot train codec params "
                    f"({bwd_codec.spec()}): the gradient seam runs in the "
                    f"backward pass, where codec params receive no "
                    f"gradient — use a fixed-key codec (c3sl/identity) or "
                    f"wire stages on the bwd: side")
        if draft_codec is not None:
            if getattr(draft_codec, "feature_layout", "flat") != "flat":
                raise ValueError(
                    f"the draft channel supports flat codecs only, got "
                    f"feature_layout="
                    f"{getattr(draft_codec, 'feature_layout', None)!r}")
            if _has_trainable_params(draft_codec):
                raise ValueError(
                    f"the draft channel cannot train codec params "
                    f"({draft_codec.spec()}): serving never backpropagates "
                    f"through the feedback payload — use a fixed-key codec "
                    f"(c3sl/identity) or wire stages on the draft: side")
        self.fwd = Channel("fwd", fwd_codec)
        self.bwd = Channel("bwd", bwd_codec if bwd_codec is not None
                           else fwd_codec)
        self.mirrored = bwd_codec is None
        self.draft = (Channel("draft", draft_codec)
                      if draft_codec is not None else None)

    # ---- codec-protocol-ish surface (forward channel's view) -------------

    @property
    def feature_layout(self) -> str:
        return getattr(self.fwd.codec, "feature_layout", "flat")

    @property
    def D(self) -> int:
        return self.fwd.codec.D

    @property
    def _nested(self) -> bool:
        """True when the params tree is the tagged ``{"fwd": ...}`` dict
        (any non-mirrored or draft-carrying link); a mirrored draft-free
        link keeps the bare forward tree for checkpoint back-compat."""
        return (not self.mirrored) or (self.draft is not None)

    def init(self, rng=None):
        """Codec params.  Mirrored (no draft): exactly the forward codec's
        params (the pre-transport tree, so existing checkpoints/tests line
        up).  Otherwise ``{"fwd": ...[, "bwd": ...][, "draft": ...]}``, all
        from the SAME rng so equal specs get bit-identical key tables."""
        if not self._nested:
            return self.fwd.codec.init(rng)
        tree = {"fwd": self.fwd.codec.init(rng)}
        if not self.mirrored:
            tree["bwd"] = self.bwd.codec.init(rng)
        if self.draft is not None:
            tree["draft"] = self.draft.codec.init(rng)
        return tree

    def fwd_params(self, params):
        return params["fwd"] if self._nested else params

    def bwd_params(self, params):
        if self.mirrored:
            return self.fwd_params(params)
        return params["bwd"]

    def draft_params(self, params):
        if self.draft is None:
            raise ValueError("link has no draft channel")
        return params["draft"]

    def spec(self) -> str:
        out = self.fwd.spec()
        if not self.mirrored:
            out = f"{out} {LINK_SEP} {BWD_PREFIX}{self.bwd.spec()}"
        if self.draft is not None:
            out = f"{out} {LINK_SEP} {DRAFT_PREFIX}{self.draft.spec()}"
        return out

    def __repr__(self) -> str:
        return f"SplitLink({self.spec()!r}{', mirrored' if self.mirrored else ''})"

    # ---- controllers -----------------------------------------------------

    def observe(self, fwd_snr=None, bwd_snr=None, loss_slack=None):
        """Feed both direction controllers one step's signals; returns the
        (R_fwd, R_bwd) pair serving the NEXT dispatch.  Mirrored links have
        ONE controller — ``fwd_snr`` drives it and ``bwd_snr`` is ignored."""
        rf = self.fwd.observe(fwd_snr, loss_slack)
        if self.mirrored:
            return rf, rf
        return rf, self.bwd.observe(bwd_snr, loss_slack)

    # ---- fault injection -------------------------------------------------

    def install_faults(self, plan, recovery=None) -> "SplitLink":
        """Install one ``repro.faults.FaultPlan`` on both directions (the
        channels draw independently — their rngs key on the direction
        tag).  Returns self."""
        self.fwd.install_faults(plan, recovery)
        self.bwd.install_faults(plan, recovery)
        return self

    def next_erasure(self, B: int):
        """Draw both directions' erasure masks for the next step:
        ``{"fwd": keep, "bwd": keep}`` suitable for ``roundtrip``'s
        ``erasure`` argument (entries None on clean directions; the whole
        dict is None when nothing is installed), plus the merged
        retransmission info ``{"fwd": ..., "bwd": ...}``."""
        kf, inf_f = self.fwd.next_erasure(rows=B)
        kb, inf_b = (None, None)
        if not self.mirrored:
            rows = B // self.fwd.current_R
            kb, inf_b = self.bwd.next_erasure(rows=rows)
        if kf is None and kb is None:
            return None, None
        erasure = {}
        if kf is not None:
            erasure["fwd"] = kf
        if kb is not None:
            erasure["bwd"] = kb
        return erasure, {"fwd": inf_f, "bwd": inf_b}

    # ---- accounting ------------------------------------------------------

    def wire_bytes_fwd(self, B: int) -> int:
        """Bytes the forward payload ships for a B-row cut activation."""
        return self.fwd.wire_bytes(B)

    def wire_bytes_bwd(self, B: int) -> int:
        """Bytes the backward (gradient) payload ships.  Mirrored: the
        gradient has the forward's compressed shape (the adjoint of a linear
        codec), so it equals the forward bytes.  Asymmetric: the gradient
        payload's ``B/R_fwd`` rows re-grouped through the backward codec."""
        if self.mirrored:
            return self.fwd.wire_bytes(B)
        rows = B // self.fwd.current_R
        return self.bwd.wire_bytes(rows)

    def wire_bytes_draft(self, B: int) -> int:
        """Bytes one draft-feedback payload ships (the (B, D) cut feature of
        the last accepted position, through the draft channel's current
        bucket).  0 without a draft channel."""
        if self.draft is None:
            return 0
        return self.draft.wire_bytes(B)

    def total_wire_bytes(self, B: int) -> int:
        return self.wire_bytes_fwd(B) + self.wire_bytes_bwd(B)

    # ---- clamp_R integration --------------------------------------------

    def with_max_R(self, max_R: int) -> "SplitLink":
        """``clamp_R`` entry point: clamp the forward channel to the batch,
        then the backward channel to the SMALLEST gradient-payload row count
        any forward bucket can produce (``max_R / max_R_fwd`` rows per
        forward group) — so no (R_fwd, R_bwd) pair can hit a divisibility
        error mid-schedule.  The draft channel's payload is the full B-row
        feedback feature, so it clamps to the batch like the forward one."""
        f2 = clamp_R(self.fwd.codec, max_R)
        d2 = (clamp_R(self.draft.codec, max_R)
              if self.draft is not None else None)
        if self.mirrored:
            return SplitLink(f2, draft_codec=d2)
        max_R_f = getattr(f2, "max_R", getattr(f2, "R", 1))
        b2 = clamp_R(self.bwd.codec, max(max_R // max(max_R_f, 1), 1))
        return SplitLink(f2, b2, draft_codec=d2)


def as_link(codec_or_link) -> SplitLink:
    """Wrap a bare codec into a mirrored link (links pass through)."""
    if isinstance(codec_or_link, SplitLink):
        return codec_or_link
    return SplitLink(codec_or_link)


def build_link(spec: str, /, **defaults) -> SplitLink:
    """Build a ``SplitLink`` from a link spec (all segments share the
    keyword ``defaults``, e.g. the runtime ``D``)."""
    fwd_spec, bwd_spec, draft_spec = parse_link_spec(spec)
    fwd_codec = codecs.build(fwd_spec, **defaults)
    bwd_codec = (codecs.build(bwd_spec, **defaults)
                 if bwd_spec is not None else None)
    draft_codec = (codecs.build(draft_spec, **defaults)
                   if draft_spec is not None else None)
    return SplitLink(fwd_codec, bwd_codec, draft_codec)


def build_link_or_codec(spec: str, /, *, quant_bits=None, **defaults):
    """The one spec dispatcher the CLIs share: a ``... >> bwd:...`` spec
    builds a ``SplitLink``, anything else a plain codec through the
    registry.  The legacy ``quant_bits=8`` flag appends the int8 wire stage
    to plain specs only — a link spec must name its wire stages per
    direction, so combining the two is rejected with one canonical error.
    """
    if is_link_spec(spec):
        if quant_bits is not None:
            raise ValueError(
                "the quant flag composes only with single-codec specs; put "
                "the wire stage in the link spec itself, e.g. "
                "'c3sl:R=8|int8 >> bwd:c3sl:R=4|int8'")
        return build_link(spec, **defaults)
    return codecs.build(codecs.apply_quant_bits(spec, quant_bits), **defaults)


# --------------------------------------------------------------------------
# the round-trip seam (shared by the loss builders and repro.models.lm)
# --------------------------------------------------------------------------

def roundtrip(codec, params, Zf, *, with_snr: bool = False, bwd_probe=None,
              erasure=None):
    """Round-trip flat (B, D) cut features through a STATIC codec or a
    STATIC ``SplitLink`` (adaptive channels must already be resolved to
    buckets — same contract as every jitted call site).

    Bare codecs and mirrored links take the exact pre-transport path
    (encode → decode); an asymmetric link inserts the gradient seam on the
    payload, so the forward numbers are IDENTICAL to mirrored and only the
    backward pass changes.  ``with_snr`` adds the forward retrieval SNR;
    ``bwd_probe`` is the gradient-SNR tap (see ``grad_roundtrip``).

    ``erasure`` injects payload loss: ``{"fwd": keep}`` (and, for an
    asymmetric link, ``"bwd": keep``) with keep masks shaped like each
    direction's payload (1.0 kept / 0.0 erased) — runtime arguments with
    bucket-static shapes, so masked steps share one compiled branch and
    never retrace.  The decode renormalizes over survivors
    (``decode_masked``) and ``with_snr`` reports the erasure-DEGRADED
    retrieval SNR, which is exactly what the adaptive controller should
    observe: loss on the wire reads as an R step-down, not a crash.
    ``erasure=None`` is structurally the pre-fault trace (bit-identity by
    construction).
    """
    fwd_keep = erasure.get("fwd") if erasure else None
    bwd_keep = erasure.get("bwd") if erasure else None
    if isinstance(codec, SplitLink):
        fwd_c = codec.fwd.codec
        fwd_p = codec.fwd_params(params)
        payload = fwd_c.encode(fwd_p, Zf)
        if not codec.mirrored:
            payload = grad_roundtrip(codec.bwd.codec, payload,
                                     codec.bwd_params(params), bwd_probe,
                                     keep=bwd_keep)
        if fwd_keep is None:
            Zhat = fwd_c.decode(fwd_p, payload)
        else:
            Zhat = masked_decode(fwd_c, fwd_p, payload, fwd_keep)
    else:
        payload = codec.encode(params, Zf)
        if fwd_keep is None:
            Zhat = codec.decode(params, payload)
        else:
            Zhat = masked_decode(codec, params, payload, fwd_keep)
    if with_snr:
        return Zhat, hrr.retrieval_snr(Zf, Zhat)
    return Zhat


# --------------------------------------------------------------------------
# per-direction program tables (zero-recompile schedule switching)
# --------------------------------------------------------------------------

def link_program_key(codec_or_link):
    """Host-side dispatch key for the next compiled program.  Links key by
    the (fwd, bwd) bucket pair — ``(R_fwd, None)`` when mirrored or the
    backward channel is static; bare codecs keep the PR-4 scalar key."""
    if isinstance(codec_or_link, SplitLink):
        link = codec_or_link
        bwd_key = None if link.mirrored else link.bwd.program_key()
        return (link.fwd.program_key(), bwd_key)
    return codecs.program_key(codec_or_link)


def _static_pair(link: SplitLink, params, kf, kb):
    """Resolve one (fwd bucket, bwd bucket) pair to a static link+params.
    The draft channel is NOT part of the fwd/bwd numeric path (it never
    enters ``roundtrip``), so static pairs drop it — the serving engine
    builds its own per-(bucket, k) speculative programs."""
    fwd_c = link.fwd.codec.buckets[kf] if kf is not None else link.fwd.codec
    if link.mirrored:
        static = SplitLink(fwd_c)
        p = (None if params is None
             else link.fwd.params_for(link.fwd_params(params), kf))
        return static, p
    bwd_c = link.bwd.codec.buckets[kb] if kb is not None else link.bwd.codec
    static = SplitLink(fwd_c, bwd_c)
    if params is None:
        return static, None
    return static, {"fwd": link.fwd.params_for(link.fwd_params(params), kf),
                    "bwd": link.bwd.params_for(link.bwd_params(params), kb)}


def build_link_program_table(codec_or_link, params, make):
    """One compiled-program entry per schedulable (R_fwd, R_bwd) pair.

    ``make(static_codec_or_link, static_params)`` builds the caller's
    compiled program for ONE static configuration.  Bare codecs defer to
    ``repro.codecs.build_program_table`` (identical keys/semantics to PR 4);
    links build the cross product of the two channels' ladders — each pair
    its own compiled branch, indexed by :func:`link_program_key` at dispatch
    time, so independent per-direction R switches never retrace.
    """
    if not isinstance(codec_or_link, SplitLink):
        return codecs.build_program_table(codec_or_link, params, make)
    link = codec_or_link
    fwd_keys = (link.fwd.codec.ladder
                if isinstance(link.fwd.codec, AdaptiveC3SL) else (None,))
    bwd_keys = ((None,) if link.mirrored else
                (link.bwd.codec.ladder
                 if isinstance(link.bwd.codec, AdaptiveC3SL) else (None,)))
    table = {}
    for kf in fwd_keys:
        for kb in bwd_keys:
            static, p = _static_pair(link, params, kf, kb)
            table[(kf, kb)] = make(static, p)
    return table


def pin_link(link: SplitLink) -> SplitLink:
    """Freeze both channels at their CURRENT buckets; returns the static
    link (pair with :func:`slice_link_params` for the matching params).
    For single-program callers (the pod pipeline) that cannot switch
    host-side — the per-step schedule needs the program-table path."""
    kf = link.fwd.program_key()
    kb = None if link.mirrored else link.bwd.program_key()
    static, _ = _static_pair(link, None, kf, kb)
    return static


def slice_link_params(link: SplitLink, params):
    """Current-bucket params matching :func:`pin_link`'s static link."""
    if link.mirrored:
        return link.fwd.params_for(link.fwd_params(params))
    return {"fwd": link.fwd.params_for(link.fwd_params(params)),
            "bwd": link.bwd.params_for(link.bwd_params(params))}
