"""Pallas TPU kernels for C3-SL's HRR codec (bind+superpose / unbind).

TPU adaptation (see DESIGN.md): instead of the GPU-friendly FFT route, the
circular convolution is computed as a tiled Toeplitz-block contraction that
runs on the MXU.  For an output tile d in [d0, d0+T) and an input tile
j in [j0, j0+T), the key slice K[(d - j) mod D] is a T x T Toeplitz block
built in-VMEM from a (2T-1)-window of the doubled key Kext = [K || K]:

    bind:    S[g, d]      = sum_i sum_j Z[g, i, j] * K_i[(d - j) mod D]
    unbind:  Zhat[g, i, d] = sum_j S[g, j] * K_i[(j - d) mod D]

Grid: (G/GT, D/T, D/T) with accumulation over the last (j-tile) grid axis.
Each j-step does R small (GT x T) @ (T x T) MXU contractions.  FLOPs match
the paper's Table 2 accounting (D^2 MACs per bound vector).

VMEM budget per step (T=128, R=16, D=4096, GT=8, f32):
    Z tile 8*16*128*4 = 64 KiB, Kext 16*8192*4 = 512 KiB,
    Toeplitz scratch 128*128*4 = 64 KiB, out 8*128*4 = 4 KiB  -> ~0.7 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(D: int, target: int = 128) -> int:
    """Largest divisor of D that is <= target (MXU-aligned when D % 128 == 0)."""
    t = min(D, target)
    while D % t:
        t -= 1
    return t


# The smallest tile the MXU/VPU lanes amortize: below this the Toeplitz
# grid degrades toward (G, D, D) single-element "contractions" — slower
# than the direct backend and liable to blow the grid-size limit for a
# prime D (tile 1 -> D^2 grid steps).
MIN_TILE = 8


def mxu_alignable(D: int, target: int = 128) -> bool:
    """Whether the Toeplitz tiling has a usable tile for this D: the
    largest divisor <= target must itself be lane-aligned (multiple of
    MIN_TILE).  False for prime/odd D like 4097 (largest divisor 17)."""
    return _pick_tile(D, target) % MIN_TILE == 0


def _check_tile(D: int, T: int):
    if T % MIN_TILE:
        raise ValueError(
            f"D={D} is not MXU-alignable: its largest tile <= 128 is {T}, "
            f"so the Toeplitz-tiled pallas backend would degrade to "
            f"{T}x{T} contractions over a (G, {D // T}, {D // T}) grid — "
            f"slower than backend='direct' and liable to blow the grid "
            f"limit.  Use backend='fft' (O(D log D), any D), or pad D to "
            f"a multiple of {MIN_TILE * MIN_TILE}.")


def _window_indices(T: int):
    ia = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)  # tile-local j (rows)
    ib = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)  # tile-local d (cols)
    return ia, ib


def _bind_kernel(z_ref, kext_ref, out_ref, *, T: int, R: int, D: int):
    dt = pl.program_id(1)
    jt = pl.program_id(2)
    d0 = dt * T
    j0 = jt * T

    @pl.when(jt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    z = z_ref[...].astype(jnp.float32)           # (GT, R, T)
    ia, ib = _window_indices(T)
    widx = ib - ia + (T - 1)                      # toep[a, b] <- win[b - a + T - 1]
    # window start so that Kext[w0 + (b - a + T-1)] == K[(d0+b - j0-a) mod D]
    w0 = d0 - j0 + D - (T - 1)
    acc = jnp.zeros(out_ref.shape, jnp.float32)   # (GT, T)
    for i in range(R):
        win = jax.lax.dynamic_slice(kext_ref[i], (w0,), (2 * T - 1,))
        toep = jnp.take(win, widx, axis=0)        # (T_j, T_d)
        acc += jnp.dot(z[:, i, :], toep, preferred_element_type=jnp.float32)
    out_ref[...] += acc.astype(out_ref.dtype)


def _unbind_kernel(s_ref, kext_ref, out_ref, *, T: int, R: int, D: int):
    dt = pl.program_id(1)
    jt = pl.program_id(2)
    d0 = dt * T
    j0 = jt * T

    @pl.when(jt == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    s = s_ref[...].astype(jnp.float32)            # (GT, T)
    ia, ib = _window_indices(T)
    widx = ia - ib + (T - 1)                      # toep[a, b] <- win[a - b + T - 1]
    # Kext[w0 + (a - b + T-1)] == K[(j0+a - d0-b) mod D]
    w0 = j0 - d0 + D - (T - 1)
    outs = []
    for i in range(R):
        win = jax.lax.dynamic_slice(kext_ref[i], (w0,), (2 * T - 1,))
        toep = jnp.take(win, widx, axis=0)        # (T_j, T_d)
        outs.append(jnp.dot(s, toep, preferred_element_type=jnp.float32))
    acc = jnp.stack(outs, axis=1)                 # (GT, R, T)
    out_ref[...] += acc.astype(out_ref.dtype)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def interpret_mode() -> bool:
    """True when pallas_call runs the kernels in INTERPRET mode (any
    non-TPU backend): the math is the kernel's, the speed is not."""
    return _interpret()


def execution_mode() -> str:
    """How a ``backend=pallas`` request actually executes here:
    ``"pallas-compiled"`` (real Mosaic kernels) or ``"pallas-interpret"``
    (CPU emulation — honest benchmarks must tag rows with this; see
    benchmarks/bench_roofline.py)."""
    return "pallas-interpret" if _interpret() else "pallas-compiled"


@functools.partial(jax.jit, static_argnames=("tile",))
def bind_superpose_kernel(Z: jax.Array, Kext: jax.Array, tile: int | None = None) -> jax.Array:
    """Z (G, R, D), Kext (R, 2D) -> S (G, D).  Requires divisible tiles."""
    G, R, D = Z.shape
    assert Kext.shape == (R, 2 * D), (Kext.shape, (R, 2 * D))
    T = tile or _pick_tile(D)
    _check_tile(D, T)
    GT = _pick_tile(G, 8)
    grid = (G // GT, D // T, D // T)
    kernel = functools.partial(_bind_kernel, T=T, R=R, D=D)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((GT, R, T), lambda g, dt, jt: (g, 0, jt)),
            pl.BlockSpec((R, 2 * D), lambda g, dt, jt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((GT, T), lambda g, dt, jt: (g, dt)),
        out_shape=jax.ShapeDtypeStruct((G, D), Z.dtype),
        interpret=_interpret(),
    )(Z, Kext)


@functools.partial(jax.jit, static_argnames=("tile",))
def unbind_kernel(S: jax.Array, Kext: jax.Array, tile: int | None = None) -> jax.Array:
    """S (G, D), Kext (R, 2D) -> Zhat (G, R, D).  Requires divisible tiles."""
    G, D = S.shape
    R = Kext.shape[0]
    assert Kext.shape == (R, 2 * D)
    T = tile or _pick_tile(D)
    _check_tile(D, T)
    GT = _pick_tile(G, 8)
    grid = (G // GT, D // T, D // T)
    kernel = functools.partial(_unbind_kernel, T=T, R=R, D=D)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((GT, T), lambda g, dt, jt: (g, jt)),
            pl.BlockSpec((R, 2 * D), lambda g, dt, jt: (0, 0)),
        ],
        out_specs=pl.BlockSpec((GT, R, T), lambda g, dt, jt: (g, 0, dt)),
        out_shape=jax.ShapeDtypeStruct((G, R, D), S.dtype),
        interpret=_interpret(),
    )(S, Kext)
