"""Pure-jnp oracles for the Pallas HRR kernels.

These are the correctness references the kernel tests assert against:
exact O(D^2) gather-based circular convolution / correlation, plus the
grouped encode/decode used by C3-SL.
"""
from __future__ import annotations

import jax.numpy as jnp


def circ_conv_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(a (*) b)[d] = sum_j a[j] b[(d-j) mod D], last axis, exact."""
    D = b.shape[-1]
    d = jnp.arange(D)
    idx = (d[:, None] - d[None, :]) % D
    mat = jnp.take(a, idx, axis=-1)  # (..., D, D)
    return jnp.einsum("...dj,...j->...d", mat, b)


def circ_corr_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(a (.) b)[d] = sum_j a[j] b[(d+j) mod D], last axis, exact.

    Rewritten as sum_m a[(m-d) mod D] b[m] so the gather runs over `a`.
    """
    D = b.shape[-1]
    d = jnp.arange(D)
    idx = (d[None, :] - d[:, None]) % D  # idx[d, m] = (m - d) mod D
    mat = jnp.take(a, idx, axis=-1)
    return jnp.einsum("...dj,...j->...d", mat, b)


def bind_superpose_ref(Z: jnp.ndarray, K: jnp.ndarray) -> jnp.ndarray:
    """Z (G, R, D), K (R, D) -> S (G, D): S_g = sum_i K_i (*) Z_gi."""
    return circ_conv_ref(K, Z).sum(axis=-2)


def unbind_ref(S: jnp.ndarray, K: jnp.ndarray) -> jnp.ndarray:
    """S (G, D), K (R, D) -> Zhat (G, R, D): Zhat_gi = K_i (.) S_g."""
    return circ_corr_ref(K, S[..., None, :])
