"""Pallas paged-attention decode kernel: page-table walk INSIDE the kernel.

The gather path (``repro.models.paging.gather_pages``) re-materializes a
contiguous ``(B, T, KV, hd)`` view of the page pools on every decode step —
one full pool read plus a same-size write and re-read per cache leaf, the
materialization tax ROADMAP names as the biggest raw-speed lever left in
the repo.  This kernel walks the per-slot page table with
``PrefetchScalarGridSpec`` instead: grid ``(B, P)``, and the block index
map of each pool operand is ``table[b, p]`` — the pages stream
HBM -> VMEM directly in page-table order, and the contiguous view never
exists (vLLM's PagedAttention, expressed in Pallas).

Bit-identical equivalence with the gather path is the design constraint
(the serving suite pins greedy outputs, not tolerances), so the reduction
is NOT a flash-style online softmax: once a slot's pages sit in VMEM
scratch, the kernel runs the literal op sequence of
``repro.models.attention._sdpa`` / ``_sdpa_quant`` — same einsum strings
with B=1/Sq=1 singleton axes, same f32 casts, same ``hd ** -0.5``
placement, same ``NEG_INF`` masking, same ``jax.nn.softmax`` — on the
same values the gathered view would hold.  Decode-step VMEM comfortably
fits the whole per-slot K/V strip (see kernels/README.md for the budget),
so tiling the T axis buys nothing at these shapes and would cost the
bitwise guarantee.

Coverage: GQA/MHA decode (linear caches and ring-buffer SWA) with float
or int8-quantized KV pools.  MLA latent caches and prefill stay on the
gather path — the serving engine falls back LOUDLY (see
``BatchedEngine(kv_read=...)``), never silently.

Like the circconv kernels, this runs in interpret mode off-TPU
(``circconv._interpret``); callers surface the effective execution mode
instead of pretending interpret numbers are kernel numbers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.circconv import _interpret

# Must match repro.models.attention.NEG_INF or masked scores differ bitwise.
NEG_INF = -1e30


def _decode_mask(pos_b, T: int, sliding_window):
    """The (1, 1, 1, T) decode validity mask for one slot — the literal
    mask math of ``apply_gqa_decode`` at B=1 (linear: written positions;
    ring: the last min(pos+1, T) writes)."""
    idx = jnp.arange(T)[None, :]
    if sliding_window is not None:
        slots = pos_b % T
        age = (slots[:, None] - idx) % T
        valid = age < jnp.minimum(pos_b + 1, T)[:, None]
    else:
        valid = idx <= pos_b[:, None]
    return valid[:, None, None, :]


def _attn_kernel(table_ref, pos_ref, q_ref, k_pool_ref, v_pool_ref, out_ref,
                 k_acc, v_acc, *, T: int, ps: int, P: int, H: int, KV: int,
                 hd: int, sliding_window):
    """Float-KV body.  Grid (B, P): step (b, p) lands page table[b, p] in
    VMEM via the block index map and appends it to the slot's scratch
    strip; the last page step runs the full ``_sdpa`` op sequence."""
    b = pl.program_id(0)
    p = pl.program_id(1)
    k_acc[pl.ds(p * ps, ps)] = k_pool_ref[0]
    v_acc[pl.ds(p * ps, ps)] = v_pool_ref[0]

    @pl.when(p == P - 1)
    def _compute():
        q = q_ref[...].reshape(1, 1, H, hd)
        k = k_acc[...][:T][None]                       # (1, T, KV, hd)
        v = v_acc[...][:T][None]
        pos_b = pos_ref[b][None]
        mask = _decode_mask(pos_b, T, sliding_window)
        groups = H // KV
        qg = q.reshape(1, 1, KV, groups, hd)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
        scores = scores * (hd ** -0.5)
        scores = jnp.where(mask[:, :, None, :, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
        out_ref[0] = out.reshape(H * hd)


def _attn_kernel_quant(table_ref, pos_ref, q_ref, k_pool_ref, ks_pool_ref,
                       v_pool_ref, vs_pool_ref, out_ref, k_acc, ks_acc,
                       v_acc, vs_acc, *, T: int, ps: int, P: int, H: int,
                       KV: int, hd: int, sliding_window, compute_dtype):
    """int8-KV body: pages stream as int8 + per-(pos, kv-head) scales, and
    the compute step is the literal ``_sdpa_quant`` sequence (scales folded
    into scores/probs; the dequantized cache is never materialized)."""
    b = pl.program_id(0)
    p = pl.program_id(1)
    k_acc[pl.ds(p * ps, ps)] = k_pool_ref[0]
    v_acc[pl.ds(p * ps, ps)] = v_pool_ref[0]
    ks_acc[pl.ds(p * ps, ps)] = ks_pool_ref[0]
    vs_acc[pl.ds(p * ps, ps)] = vs_pool_ref[0]

    @pl.when(p == P - 1)
    def _compute():
        q = q_ref[...].reshape(1, 1, H, hd)
        k_q = k_acc[...][:T][None]
        v_q = v_acc[...][:T][None]
        k_scale = ks_acc[...][:T][None]                # (1, T, KV, 1)
        v_scale = vs_acc[...][:T][None]
        pos_b = pos_ref[b][None]
        mask = _decode_mask(pos_b, T, sliding_window)
        groups = H // KV
        qg = q.reshape(1, 1, KV, groups, hd)
        scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                            k_q.astype(jnp.float32))
        scores = scores * k_scale[:, :, :, 0].transpose(0, 2, 1)[:, :, None, None, :]
        scores = scores * (hd ** -0.5)
        scores = jnp.where(mask[:, :, None, :, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        probs = probs * v_scale[:, :, :, 0].transpose(0, 2, 1)[:, :, None, None, :]
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v_q.astype(jnp.float32))
        out_ref[0] = out.reshape(H * hd).astype(compute_dtype)


def _check_geometry(q, pool, table, length):
    B, Sq, H, hd = q.shape
    if Sq != 1:
        raise ValueError(f"decode kernel takes one query token, got Sq={Sq}")
    P = table.shape[1]
    ps, KV = pool.shape[1], pool.shape[2]
    if table.shape[0] != B:
        raise ValueError(f"page table batch {table.shape[0]} != query batch {B}")
    if length > P * ps:
        raise ValueError(f"length {length} exceeds table capacity {P}x{ps}")
    if H % KV:
        raise ValueError(f"H={H} not a multiple of KV={KV}")
    return B, H, hd, P, ps, KV


def paged_attention(q, k_pool, v_pool, table, pos, *, length: int,
                    sliding_window=None, interpret=None):
    """q (B, 1, H, hd) post-rope; k/v pools (num_pages, ps, KV, hd); table
    (B, P) int32; pos (B,) int32.  Returns the (B, 1, H*hd) attention
    output — bit-identical to ``_sdpa(q, *gather_pages(...), mask)``."""
    B, H, hd, P, ps, KV = _check_geometry(q, k_pool, table, length)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, H * hd), lambda b, p, tab, pos: (b, 0)),
            pl.BlockSpec((1, ps, KV, hd),
                         lambda b, p, tab, pos: (tab[b, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, KV, hd),
                         lambda b, p, tab, pos: (tab[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H * hd), lambda b, p, tab, pos: (b, 0)),
        scratch_shapes=[pltpu.VMEM((P * ps, KV, hd), k_pool.dtype),
                        pltpu.VMEM((P * ps, KV, hd), v_pool.dtype)],
    )
    kernel = functools.partial(_attn_kernel, T=length, ps=ps, P=P, H=H,
                               KV=KV, hd=hd, sliding_window=sliding_window)
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H * hd), q.dtype),
        interpret=_interpret() if interpret is None else interpret,
    )(table.astype(jnp.int32), pos.astype(jnp.int32),
      q.reshape(B, H * hd), k_pool, v_pool)
    return out.reshape(B, 1, H * hd)


def paged_attention_quant(q, k_pool, k_scale_pool, v_pool, v_scale_pool,
                          table, pos, *, length: int, sliding_window=None,
                          compute_dtype=None, interpret=None):
    """int8-KV variant: scale pools (num_pages, ps, KV, 1) ride the same
    page table.  Bit-identical to ``_sdpa_quant`` over the gathered view."""
    B, H, hd, P, ps, KV = _check_geometry(q, k_pool, table, length)
    compute_dtype = compute_dtype or q.dtype
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, H * hd), lambda b, p, tab, pos: (b, 0)),
            pl.BlockSpec((1, ps, KV, hd),
                         lambda b, p, tab, pos: (tab[b, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, KV, 1),
                         lambda b, p, tab, pos: (tab[b, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, KV, hd),
                         lambda b, p, tab, pos: (tab[b, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, KV, 1),
                         lambda b, p, tab, pos: (tab[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, H * hd), lambda b, p, tab, pos: (b, 0)),
        scratch_shapes=[pltpu.VMEM((P * ps, KV, hd), k_pool.dtype),
                        pltpu.VMEM((P * ps, KV, 1), k_scale_pool.dtype),
                        pltpu.VMEM((P * ps, KV, hd), v_pool.dtype),
                        pltpu.VMEM((P * ps, KV, 1), v_scale_pool.dtype)],
    )
    kernel = functools.partial(_attn_kernel_quant, T=length, ps=ps, P=P,
                               H=H, KV=KV, hd=hd,
                               sliding_window=sliding_window,
                               compute_dtype=compute_dtype)
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H * hd), compute_dtype),
        interpret=_interpret() if interpret is None else interpret,
    )(table.astype(jnp.int32), pos.astype(jnp.int32),
      q.reshape(B, H * hd), k_pool, k_scale_pool, v_pool, v_scale_pool)
    return out.reshape(B, 1, H * hd)
