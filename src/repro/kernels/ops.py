"""Jit'd public wrappers over the Pallas HRR kernels.

Adds shape checks, the doubled-key layout, and custom VJPs.  The codec is
linear in Z, and its adjoints are again HRR ops with the SAME keys:

    d/dZ of bind_superpose  == unbind        (correlate the upstream grad)
    d/dS of unbind          == bind_superpose (bind+superpose the upstream grad)

which is exactly how C3-SL compresses the backward-path gradients with zero
extra machinery.  Keys are constants (stop_gradient; no key cotangent).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import circconv


def _kext(K: jax.Array) -> jax.Array:
    K = jax.lax.stop_gradient(K)
    return jnp.concatenate([K, K], axis=-1)


@jax.custom_vjp
def bind_superpose_pallas(Z: jax.Array, K: jax.Array) -> jax.Array:
    """Z (G, R, D), K (R, D) -> S (G, D) via the Pallas Toeplitz kernel."""
    return circconv.bind_superpose_kernel(Z, _kext(K))


def _bind_fwd(Z, K):
    return bind_superpose_pallas(Z, K), K


def _bind_bwd(K, dS):
    dZ = circconv.unbind_kernel(dS, _kext(K))
    return dZ, None


bind_superpose_pallas.defvjp(_bind_fwd, _bind_bwd)


@jax.custom_vjp
def unbind_pallas(S: jax.Array, K: jax.Array) -> jax.Array:
    """S (G, D), K (R, D) -> Zhat (G, R, D) via the Pallas Toeplitz kernel."""
    return circconv.unbind_kernel(S, _kext(K))


def _unbind_fwd(S, K):
    return unbind_pallas(S, K), K


def _unbind_bwd(K, dZhat):
    dS = circconv.bind_superpose_kernel(dZhat, _kext(K))
    return dS, None


unbind_pallas.defvjp(_unbind_fwd, _unbind_bwd)


# ---------------------------------------------------------------------------
# Paged-attention decode (repro.kernels.paged_attention)
# ---------------------------------------------------------------------------

def paged_attention_decode(q, cache, table, pos, *, length: int,
                           sliding_window=None, compute_dtype=None,
                           interpret=None):
    """Decode-step attention over paged KV pools, page-table walk in-kernel.

    ``q`` (B, 1, H, hd) post-rope; ``cache`` the attn sublayer's pool dict
    ({"k", "v"} float pools, plus {"k_scale", "v_scale"} when int8-
    quantized); ``table`` (B, P) int32 page table; ``pos`` (B,) int32
    per-slot positions.  Returns (B, 1, H*hd), bit-identical to
    ``_sdpa[_quant]`` over ``gather_pages`` of the same pools.

    Inference-only (no custom VJP): decode never differentiates through
    the cache read.  Quantized vs float dispatch mirrors
    ``apply_gqa_decode``'s ``"k_scale" in cache`` seam.
    """
    from repro.kernels import paged_attention as pa
    if "k_scale" in cache:
        return pa.paged_attention_quant(
            q, cache["k"], cache["k_scale"], cache["v"], cache["v_scale"],
            table, pos, length=length, sliding_window=sliding_window,
            compute_dtype=compute_dtype, interpret=interpret)
    return pa.paged_attention(q, cache["k"], cache["v"], table, pos,
                              length=length, sliding_window=sliding_window,
                              interpret=interpret)
