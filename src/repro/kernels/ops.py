"""Jit'd public wrappers over the Pallas HRR kernels.

Adds shape checks, the doubled-key layout, and custom VJPs.  The codec is
linear in Z, and its adjoints are again HRR ops with the SAME keys:

    d/dZ of bind_superpose  == unbind        (correlate the upstream grad)
    d/dS of unbind          == bind_superpose (bind+superpose the upstream grad)

which is exactly how C3-SL compresses the backward-path gradients with zero
extra machinery.  Keys are constants (stop_gradient; no key cotangent).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import circconv


def _kext(K: jax.Array) -> jax.Array:
    K = jax.lax.stop_gradient(K)
    return jnp.concatenate([K, K], axis=-1)


@jax.custom_vjp
def bind_superpose_pallas(Z: jax.Array, K: jax.Array) -> jax.Array:
    """Z (G, R, D), K (R, D) -> S (G, D) via the Pallas Toeplitz kernel."""
    return circconv.bind_superpose_kernel(Z, _kext(K))


def _bind_fwd(Z, K):
    return bind_superpose_pallas(Z, K), K


def _bind_bwd(K, dS):
    dZ = circconv.unbind_kernel(dS, _kext(K))
    return dZ, None


bind_superpose_pallas.defvjp(_bind_fwd, _bind_bwd)


@jax.custom_vjp
def unbind_pallas(S: jax.Array, K: jax.Array) -> jax.Array:
    """S (G, D), K (R, D) -> Zhat (G, R, D) via the Pallas Toeplitz kernel."""
    return circconv.unbind_kernel(S, _kext(K))


def _unbind_fwd(S, K):
    return unbind_pallas(S, K), K


def _unbind_bwd(K, dZhat):
    dS = circconv.bind_superpose_kernel(dZhat, _kext(K))
    return dS, None


unbind_pallas.defvjp(_unbind_fwd, _unbind_bwd)
