"""Opt-in runtime sanitizers (the ``--sanitize`` tier).

Static analysis (:mod:`repro.analysis.rules`) catches what an AST can
see; this module checks what only a running system can — behind an
explicit flag, because every check here costs host syncs or extra
dispatches that the production paths refuse to pay:

* :func:`enable_debug_nans` / :func:`checkify_jit` — jax-level float
  sanitizers for the train step.

* :class:`EngineSanitizer` — per-tick :class:`~repro.serving.engine
  .BatchedEngine` invariant checks, attached via
  ``engine.attach_sanitizer``:

  - **pool accounting**: every page is on the free list or owned by
    exactly one slot (``free + in_use == total``);
  - **slot-state hygiene**: a slot with no resident request must be
    inert device-side (``active``/``done`` False, ``pos``/``out_len``
    zero) — the state analogue of the cache-zeroing reset;
  - **live-slot zeroing pre-encode**: the PR 7 C3-SL fix pinned as a
    CHECKED invariant.  A probe program re-runs the real
    ``lm.decode_step`` front half (``return_cut=True``, non-donating)
    and asserts dead rows contribute EXACTLY zero to the cut-layer
    tensor entering ``codec.encode`` — any nonzero means stale
    allocation-history garbage is back in the batch-wise superposition,
    perturbing live rows through HRR cross-talk.

* :class:`SlowCallbackDetector` — event-loop stall diagnostics for the
  front door (jit warmup legitimately blocks the loop, so stalls are
  recorded and reported, not fatal).

* :class:`TrainSanitizer` — per-step finite checks for the train loops
  (loss/grad-norm NaN/Inf trips immediately, with the step index).
"""
from __future__ import annotations

import asyncio
import time

import jax
import jax.numpy as jnp
from jax.experimental import checkify


class SanitizerError(AssertionError):
    """A checked runtime invariant was violated."""


# ---------------------------------------------------------------------------
# jax-level float sanitizers
# ---------------------------------------------------------------------------

def enable_debug_nans(on: bool = True) -> None:
    """Global NaN trap: any jitted computation producing a NaN re-runs
    un-jitted and raises at the producing primitive."""
    jax.config.update("jax_debug_nans", on)


def checkify_jit(fn, *, errors=None):
    """jit ``fn`` under checkify float checks; the wrapper re-raises any
    accumulated error host-side (``err.throw()``) and returns ``fn``'s
    plain outputs, so it drops into existing call sites."""
    errors = checkify.float_checks if errors is None else errors
    checked = jax.jit(checkify.checkify(fn, errors=errors))

    def wrapper(*args, **kwargs):
        err, out = checked(*args, **kwargs)
        err.throw()
        return out

    return wrapper


class TrainSanitizer:
    """Per-step host-side finite checks for the train loops.  Syncs on
    every step by design — sanitize mode trades throughput for checks."""

    def __init__(self):
        self.steps_checked = 0

    def check_step(self, step: int, **scalars) -> None:
        import math
        for name, value in scalars.items():
            if value is None:
                continue
            v = float(value)  # lint-ok: R3 sanitize mode trades throughput for per-step checks
            if not math.isfinite(v):
                raise SanitizerError(
                    f"[sanitize] step {step}: {name} is {v!r} — "
                    f"non-finite training signal")
        self.steps_checked += 1


# ---------------------------------------------------------------------------
# engine invariants
# ---------------------------------------------------------------------------

class EngineSanitizer:
    """Per-tick invariant checks for a :class:`BatchedEngine`.

    Attach with ``engine.attach_sanitizer(EngineSanitizer(engine))``;
    the engine then calls :meth:`on_tick` after every tick/run
    iteration.  ``every`` thins the expensive cut-probe (the cheap
    host-side checks always run).  ``counts`` records how often each
    check actually fired, so tests can assert the invariant was
    EXERCISED, not just never tripped.
    """

    def __init__(self, engine, *, every: int = 1):
        from repro import codecs as codecs_lib
        from repro.models import lm as lm_lib
        self.every = max(1, int(every))
        self.ticks = 0
        self.counts = {"pool": 0, "slot_state": 0, "cut_zeroing": 0}
        self._probes = None
        if engine.codec is not None:
            cfg, paged = engine.cfg, engine.paged

            def make_probe(codec, codec_params):
                def probe(params, cache, state):
                    live = state["active"] & ~state["done"]
                    _, _, cut = lm_lib.decode_step(
                        params, cache, state["last_tok"][:, None],
                        state["pos"], cfg, codec=codec,
                        codec_params=codec_params, paged=paged, live=live,
                        return_cut=True)
                    dead = (~live).astype(cut.dtype)[:, None]
                    return jnp.sum(jnp.abs(cut) * dead), live.sum()
                # non-donating on purpose: the probe reads the same
                # cache/state the next real dispatch will consume
                return jax.jit(probe)

            self._probes = codecs_lib.build_program_table(
                engine.codec, engine.codec_params, make_probe)

    # -- individual checks -------------------------------------------------

    def check_pool(self, engine) -> None:
        acct = engine.pool_accounting()
        if acct["total"] and acct["free"] + acct["in_use"] != acct["total"]:
            raise SanitizerError(
                f"[sanitize] page-pool accounting broken: free "
                f"{acct['free']} + in_use {acct['in_use']} != total "
                f"{acct['total']} — a page leaked or is double-owned")
        self.counts["pool"] += 1

    def check_slot_state(self, engine) -> None:
        empty = [i for i, s in enumerate(engine.slots) if s.req is None]
        if not empty:
            return
        st = jax.device_get({k: engine.state[k]
                             for k in ("active", "done", "pos", "out_len")})
        for i in empty:
            if bool(st["active"][i]) or bool(st["done"][i]) \
                    or int(st["pos"][i]) or int(st["out_len"][i]):
                raise SanitizerError(
                    f"[sanitize] empty slot {i} is not inert: "
                    f"active={bool(st['active'][i])} "
                    f"done={bool(st['done'][i])} pos={int(st['pos'][i])} "
                    f"out_len={int(st['out_len'][i])} — stale device "
                    f"state survived a retire/evict")
        self.counts["slot_state"] += 1

    def check_cut_zeroing(self, engine) -> None:
        """The PR 7 invariant: rows that are not live contribute EXACTLY
        zero to the cut-layer tensor entering the batch-wise codec.
        ``jnp.where`` writes exact zeros, so any tolerance would only
        mask a regression — the threshold is 0.0."""
        if self._probes is None:
            return
        live = engine.state["active"] & ~engine.state["done"]
        n_live = int(jnp.sum(live))
        if n_live == 0 or n_live == engine.num_slots:
            return          # no dead/live mix: the invariant is vacuous
        from repro import codecs as codecs_lib
        probe = self._probes[codecs_lib.program_key(engine.codec)]
        dead_mag, _ = probe(engine.params, engine.cache, engine.state)
        dead_mag = float(dead_mag)
        if dead_mag != 0.0:
            raise SanitizerError(
                f"[sanitize] live-slot zeroing violated: dead rows "
                f"contribute |cut| sum = {dead_mag!r} (expected exactly "
                f"0.0) to the C3-SL superposition — stale slot state is "
                f"leaking into live rows through HRR cross-talk")
        self.counts["cut_zeroing"] += 1

    # -- engine hook -------------------------------------------------------

    def on_tick(self, engine) -> None:
        self.ticks += 1
        self.check_pool(engine)
        self.check_slot_state(engine)
        if self.ticks % self.every == 0:
            self.check_cut_zeroing(engine)


# ---------------------------------------------------------------------------
# event-loop stall diagnostics
# ---------------------------------------------------------------------------

class SlowCallbackDetector:
    """Record event-loop stalls: a probe task sleeps ``interval_s`` and
    measures how late it wakes; anything beyond ``threshold_s`` of lag
    is one stall.  Diagnostic, not fatal — jit compilation legitimately
    blocks the loop at warmup.  Also turns on asyncio debug slow-
    callback logging at the same threshold."""

    def __init__(self, *, threshold_s: float = 0.25,
                 interval_s: float = 0.05):
        self.threshold_s = threshold_s
        self.interval_s = interval_s
        self.max_lag_s = 0.0
        self.stalls: list[float] = []
        self._task: asyncio.Task | None = None

    def install(self) -> "SlowCallbackDetector":
        loop = asyncio.get_running_loop()
        loop.slow_callback_duration = self.threshold_s
        self._task = asyncio.create_task(self._probe())
        return self

    async def _probe(self):
        while True:
            t0 = time.perf_counter()
            await asyncio.sleep(self.interval_s)
            lag = time.perf_counter() - t0 - self.interval_s
            self.max_lag_s = max(self.max_lag_s, lag)
            if lag > self.threshold_s:
                self.stalls.append(lag)

    async def stop(self) -> None:
        if self._task is not None:
            self._task.cancel()
            try:
                await self._task
            except asyncio.CancelledError:  # lint-ok: R5 reaping the probe task WE just cancelled
                pass
            self._task = None

    def report(self) -> str:
        return (f"event-loop lag: max {self.max_lag_s * 1e3:.1f}ms, "
                f"{len(self.stalls)} stall(s) over {self.threshold_s}s")
