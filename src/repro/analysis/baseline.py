"""Committed-baseline workflow for the lint gate.

The baseline file (``analysis-baseline.json`` at the repo root) is the
contract between the linter and CI: the gate fails on findings that are
NOT in the baseline, so new code is held to the rules while any
grandfathered findings stay visible (and shrink over time) instead of
blocking unrelated work.  The shipped baseline has an EMPTY ``findings``
list — ``src/`` lints clean — and a populated ``suppressed`` section
documenting every inline ``# lint-ok`` rationale for the record.

Matching is by :meth:`Finding.fingerprint` — ``(rule, path, stripped
source line)`` — counted with multiplicity, so a finding survives edits
that only move its line, but duplicating a flagged construct is a new
finding.
"""
from __future__ import annotations

import json
from collections import Counter
from pathlib import Path

from repro.analysis.lint import Finding, LintReport

BASELINE_NAME = "analysis-baseline.json"


def _entry(f: Finding) -> dict:
    d = {"rule": f.rule, "path": f.path, "line": f.line,
         "code": f.code, "message": f.message}
    if f.reason:
        d["reason"] = f.reason
    return d


def write_baseline(report: LintReport, path: str | Path) -> None:
    payload = {
        "comment": (
            "Lint baseline for `python -m repro.analysis --check`. "
            "`findings` are grandfathered violations the gate tolerates "
            "(kept empty on purpose: src/ lints clean); `suppressed` is "
            "an informational record of every inline `# lint-ok` "
            "suppression and its rationale. Regenerate with "
            "`python -m repro.analysis --write-baseline src/`."),
        "findings": [_entry(f) for f in report.findings],
        "suppressed": [_entry(f) for f in report.suppressed],
    }
    Path(path).write_text(json.dumps(payload, indent=2) + "\n",
                          encoding="utf-8")


def load_baseline(path: str | Path) -> Counter:
    """Fingerprint multiset of the baselined (tolerated) findings."""
    p = Path(path)
    if not p.exists():
        return Counter()
    data = json.loads(p.read_text(encoding="utf-8"))
    return Counter((e["rule"], e["path"], e["code"])
                   for e in data.get("findings", ()))


def diff_against_baseline(report: LintReport,
                          baseline: Counter) -> tuple[list[Finding],
                                                      Counter]:
    """Split the current findings into (new, fixed).

    ``new``   — findings whose fingerprint exceeds the baselined count
                (these fail the gate);
    ``fixed`` — baselined fingerprints no longer present (informational;
                the baseline should be regenerated to shrink).
    """
    remaining = Counter(baseline)
    new: list[Finding] = []
    for f in report.findings:
        fp = f.fingerprint()
        if remaining[fp] > 0:
            remaining[fp] -= 1
        else:
            new.append(f)
    fixed = Counter({fp: n for fp, n in remaining.items() if n > 0})
    return new, fixed
