"""The lint engine: file walking, suppression parsing, rule dispatch.

The rules themselves live in :mod:`repro.analysis.rules`; this module
owns everything rule-agnostic:

* :class:`Finding` — one diagnostic, carrying a rule id, location, the
  stripped source line it fired on, and a stable :meth:`fingerprint`
  (rule + path + code text, NOT line numbers — findings survive
  unrelated edits above them).

* **Suppression** — a finding is suppressed by an inline comment on the
  SAME physical line::

      losses.append(float(loss))   # lint-ok: R3 log-gated periodic sync

  One comment can clear several rules (``# lint-ok: R3,R5 reason``).
  The rationale text after the rule ids is mandatory in spirit — the
  baseline writer records it — but not enforced syntactically.
  Suppressed findings are kept (``LintReport.suppressed``) so the
  baseline file can document every accepted deviation.

* :func:`lint_source` / :func:`lint_paths` — run every registered rule
  over a source string / a tree of ``.py`` files.

The engine is stdlib-only (``ast`` + ``re``): it must run in the CI
gate before any heavyweight dependency imports, and must never import
jax itself.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path

#: ``# lint-ok: R3`` / ``# lint-ok: R1, R5 free-form rationale``
_SUPPRESS_RE = re.compile(
    r"#\s*lint-ok:\s*(?P<rules>R\d+(?:\s*,\s*R\d+)*)\s*(?P<reason>.*)$")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str            # "R1".."R5"
    path: str            # as given to the linter (repo-relative in CI)
    line: int            # 1-based
    col: int             # 0-based
    message: str
    code: str            # stripped source of the flagged line
    reason: str = ""     # suppression rationale (suppressed findings only)

    def fingerprint(self) -> tuple[str, str, str]:
        """Line-number-free identity used for baseline matching: two
        findings with the same rule, file, and flagged source text are
        the same finding wherever the line moved to."""
        return (self.rule, self.path, self.code)

    def __str__(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"{self.message}\n    {self.code}")


@dataclasses.dataclass
class LintReport:
    findings: list[Finding] = dataclasses.field(default_factory=list)
    suppressed: list[Finding] = dataclasses.field(default_factory=list)
    errors: list[str] = dataclasses.field(default_factory=list)  # parse fails

    def extend(self, other: "LintReport"):
        self.findings.extend(other.findings)
        self.suppressed.extend(other.suppressed)
        self.errors.extend(other.errors)

    def by_rule(self) -> dict[str, int]:
        counts: dict[str, int] = {}
        for f in self.findings:
            counts[f.rule] = counts.get(f.rule, 0) + 1
        return counts


def parse_suppressions(source: str) -> tuple[dict[int, set[str]],
                                             dict[int, str]]:
    """Per-line rule-id suppressions: {lineno: {"R3", ...}} plus the
    free-form rationale text per line (for the baseline record)."""
    rules_at: dict[int, set[str]] = {}
    reason_at: dict[int, str] = {}
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if m is None:
            continue
        rules_at[lineno] = {r.strip() for r in m.group("rules").split(",")}
        reason_at[lineno] = m.group("reason").strip()
    return rules_at, reason_at


def lint_source(source: str, path: str = "<string>",
                rules: set[str] | None = None) -> LintReport:
    """Run the registered rules over one source string."""
    from repro.analysis.rules import CHECKERS
    report = LintReport()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        report.errors.append(f"{path}: syntax error: {e}")
        return report
    lines = source.splitlines()
    suppress_at, reason_at = parse_suppressions(source)

    def line_text(lineno: int) -> str:
        if 1 <= lineno <= len(lines):
            return lines[lineno - 1].strip()
        return ""

    for rule_id, checker in sorted(CHECKERS.items()):
        if rules is not None and rule_id not in rules:
            continue
        for raw in checker(tree, source, path):
            lineno, col, message = raw
            finding = Finding(rule=rule_id, path=path, line=lineno, col=col,
                              message=message, code=line_text(lineno))
            if rule_id in suppress_at.get(lineno, ()):
                finding = dataclasses.replace(
                    finding, reason=reason_at.get(lineno, ""))
                report.suppressed.append(finding)
            else:
                report.findings.append(finding)
    report.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    report.suppressed.sort(key=lambda f: (f.path, f.line, f.rule))
    return report


def iter_py_files(paths: list[str | Path]) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            files.append(p)
    return files


def lint_paths(paths: list[str | Path], rules: set[str] | None = None,
               root: Path | None = None) -> LintReport:
    """Lint every ``.py`` file under ``paths``.  Finding paths are made
    relative to ``root`` (default: cwd) when possible, so fingerprints
    are stable between local runs and CI."""
    report = LintReport()
    root = Path.cwd() if root is None else Path(root)
    for f in iter_py_files(paths):
        try:
            rel = f.resolve().relative_to(root.resolve())
        except ValueError:
            rel = f
        report.extend(lint_source(f.read_text(encoding="utf-8"),
                                  path=str(rel), rules=rules))
    return report
