"""Repo-specific static analysis + runtime sanitizers.

Two halves, one goal — turn the repo's correctness conventions into
machine-checked invariants:

* **Static** (:mod:`repro.analysis.lint`, :mod:`repro.analysis.rules`,
  :mod:`repro.analysis.baseline`): an AST lint engine with five
  repo-specific rules (R1 recompile hazards, R2 use-after-donate,
  R3 hidden host syncs, R4 codec accounting completeness, R5 asyncio
  race/hygiene), inline ``# lint-ok: R<n> rationale`` suppression, and
  a committed-baseline gate.  Stdlib-only — never imports jax.  CLI:
  ``python -m repro.analysis --check src/``.

* **Runtime** (:mod:`repro.analysis.sanitize`): opt-in sanitizers
  behind ``--sanitize`` on the launchers — ``jax_debug_nans`` /
  checkify wiring, per-tick engine invariant checks (pool accounting,
  live-slot zeroing pre-encode: the PR 7 C3-SL superposition-hygiene
  fix pinned as a checked invariant), and an event-loop slow-callback
  detector for the front door.

See ``src/repro/analysis/README.md`` for the rule catalog and the
baseline workflow.
"""
from repro.analysis.lint import (Finding, LintReport, lint_paths,
                                 lint_source)
from repro.analysis.baseline import (BASELINE_NAME, diff_against_baseline,
                                     load_baseline, write_baseline)

__all__ = [
    "Finding", "LintReport", "lint_source", "lint_paths",
    "BASELINE_NAME", "load_baseline", "write_baseline",
    "diff_against_baseline",
]
