"""CLI for the lint tier.

Usage::

    python -m repro.analysis --check src/            # gate vs baseline
    python -m repro.analysis src/ tests/             # plain report
    python -m repro.analysis --write-baseline src/   # regenerate baseline
    python -m repro.analysis --rules R3,R5 src/      # subset of rules

``--check`` exits nonzero on (a) any finding not covered by the
committed baseline, or (b) a syntax error in a linted file.  Baselined-
but-fixed findings are reported as a nudge to regenerate the baseline
but do not fail the gate.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis.baseline import (BASELINE_NAME, diff_against_baseline,
                                     load_baseline, write_baseline)
from repro.analysis.lint import lint_paths


def _find_root(start: Path) -> Path:
    """Nearest ancestor holding the baseline or a .git dir, else cwd —
    finding paths are made root-relative so fingerprints match CI."""
    cur = start.resolve()
    for cand in [cur, *cur.parents]:
        if (cand / BASELINE_NAME).exists() or (cand / ".git").exists():
            return cand
    return start


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-specific static analysis (rules R1-R5).")
    parser.add_argument("paths", nargs="+",
                        help="files or directories to lint")
    parser.add_argument("--check", action="store_true",
                        help="fail on findings not in the baseline")
    parser.add_argument("--write-baseline", action="store_true",
                        help="regenerate the baseline from this run")
    parser.add_argument("--baseline", default=None,
                        help=f"baseline path (default: <root>/{BASELINE_NAME})")
    parser.add_argument("--rules", default=None,
                        help="comma-separated subset, e.g. R3,R5")
    parser.add_argument("--show-suppressed", action="store_true",
                        help="also print suppressed findings")
    args = parser.parse_args(argv)

    root = _find_root(Path(args.paths[0]))
    baseline_path = Path(args.baseline) if args.baseline \
        else root / BASELINE_NAME
    rules = ({r.strip() for r in args.rules.split(",")}
             if args.rules else None)

    report = lint_paths(args.paths, rules=rules, root=root)

    for err in report.errors:
        print(f"ERROR {err}", file=sys.stderr)

    if args.write_baseline:
        write_baseline(report, baseline_path)
        print(f"wrote {baseline_path} ({len(report.findings)} findings, "
              f"{len(report.suppressed)} suppressions recorded)")
        return 1 if report.errors else 0

    if args.check:
        new, fixed = diff_against_baseline(report,
                                           load_baseline(baseline_path))
        for f in new:
            print(f)
        if fixed:
            print(f"note: {sum(fixed.values())} baselined finding(s) no "
                  f"longer present — regenerate the baseline to lock in "
                  f"the fix (--write-baseline)")
        counts = ", ".join(f"{k}={v}" for k, v in
                           sorted(report.by_rule().items())) or "none"
        print(f"{len(new)} new finding(s) vs baseline "
              f"[{counts} total; {len(report.suppressed)} suppressed]")
        return 1 if (new or report.errors) else 0

    for f in report.findings:
        print(f)
    if args.show_suppressed:
        for f in report.suppressed:
            print(f"SUPPRESSED ({f.reason or 'no rationale'}): {f}")
    print(f"{len(report.findings)} finding(s), "
          f"{len(report.suppressed)} suppressed")
    return 1 if (report.findings or report.errors) else 0


if __name__ == "__main__":
    raise SystemExit(main())
