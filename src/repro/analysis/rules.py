"""Repo-specific lint rules R1–R5.

Every rule is a function ``(tree, source, path) -> iterable[(line, col,
message)]`` registered in :data:`CHECKERS`.  The rules are HEURISTIC —
they encode this repo's conventions (program tables, donated engine
buffers, single-threaded asyncio front door), not general Python
semantics — and every one has an escape hatch: an inline ``# lint-ok:
R<n> rationale`` comment on the flagged line (see
:mod:`repro.analysis.lint`).

Rule catalog (the authoritative copy lives in
``src/repro/analysis/README.md``):

* **R1 — recompile hazards.**  ``jax.jit``/``pjit`` wrappers created
  inside a ``for``/``while`` body (a fresh wrapper per iteration means a
  fresh trace cache), and immediate ``jax.jit(<lambda>)(...)``
  invocations (the lambda object is new every execution, so the jit
  cache can never hit).  The sanctioned pattern is a program table built
  once (``codecs.build_program_table``) and dispatched host-side — the
  PR 4/5 zero-recompile contract.

* **R2 — use-after-donate.**  A name bound to ``jax.jit(...,
  donate_argnums=...)`` marks its donated call arguments as DEAD: XLA
  may have reused the buffer in place.  Reading such a variable later in
  the same function without rebinding it is flagged.

* **R3 — hidden host syncs.**  ``.item()`` / ``float()`` / ``int()`` /
  ``bool()`` / ``np.asarray`` / ``jax.device_get`` /
  ``jax.block_until_ready`` (a) inside a function that is jit-traced
  (these raise or silently constant-fold at trace time), or (b) inside a
  loop that dispatches a compiled program (a per-iteration host sync
  serializes dispatch with compute — the classic
  ``losses.append(float(loss))`` throughput bug).  Also flags truthiness
  (``if``/``while``) directly on a traced function's parameter.

* **R4 — codec accounting completeness.**  Every class registered in
  the codec registry (``@register(...)``) must implement the wire
  accounting surface in its own body: transforms need ``payload_shape``
  + ``wire_bytes`` + ``flops``, wire stages need ``wire_bytes`` +
  ``flops`` + ``apply``.  Exact byte accounting is what
  ``BENCH_comm``/engine stats and the HLO cross-checks pin — a codec
  without it silently under-reports the split link.

* **R5 — asyncio race / hygiene** (the front door is ONE event loop;
  everything here either stalls it or races it):

  - R5a: blocking calls (``time.sleep``, ``subprocess.*``,
    ``os.system``, sync socket constructors) inside ``async def``.
  - R5b: ``asyncio.create_task`` / ``ensure_future`` whose result is
    dropped (bare expression statement) — the event loop keeps only a
    weak reference, so the task can be garbage-collected mid-flight
    (the PR 7 orphan-task class).
  - R5c: ``except asyncio.CancelledError`` that neither re-raises nor
    raises the caught name — swallowing cancellation breaks
    ``task.cancel()``-based shutdown.
  - R5d: ``for`` over a shared container (name / ``self.x``, incl.
    ``.items()``/``.keys()``/``.values()``) whose body both awaits AND
    mutates that container — the await yields to handlers that may also
    mutate it (RuntimeError at best, the PR 7 ghost-request class at
    worst).  Iterating a snapshot (``list(...)``) is the sanctioned
    pattern.
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator

Raw = tuple[int, int, str]     # (line, col, message)

_JIT_NAMES = {"jax.jit", "jax.pjit", "pjit.pjit",
              "jax.experimental.pjit.pjit"}
_PROGRAM_TABLE_BUILDERS = ("build_program_table", "build_link_program_table")
_SYNC_CALLS = {"jax.device_get", "jax.block_until_ready",
               "numpy.asarray", "numpy.array", "numpy.copy"}
_SYNC_BUILTINS = {"float", "int", "bool"}
_BLOCKING_IN_ASYNC = {"time.sleep", "os.system", "subprocess.run",
                      "subprocess.call", "subprocess.check_output",
                      "subprocess.check_call", "socket.create_connection"}
_TASK_SPAWNERS = {"create_task", "ensure_future"}
_MUTATORS = {"pop", "append", "remove", "clear", "update", "extend",
             "insert", "popitem", "setdefault", "add", "discard"}


# ---------------------------------------------------------------------------
# shared AST plumbing
# ---------------------------------------------------------------------------

def _attach_parents(tree: ast.AST) -> None:
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child._repro_parent = node  # type: ignore[attr-defined]


def _parent(node: ast.AST) -> ast.AST | None:
    return getattr(node, "_repro_parent", None)


def _ancestors(node: ast.AST) -> Iterator[ast.AST]:
    cur = _parent(node)
    while cur is not None:
        yield cur
        cur = _parent(cur)


def _enclosing_function(node: ast.AST):
    for anc in _ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return anc
    return None


def _in_loop_same_function(node: ast.AST) -> bool:
    """True when a loop encloses ``node`` WITHIN its own function scope
    (a loop outside the enclosing ``def`` does not re-execute it)."""
    for anc in _ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.Lambda)):
            return False
        if isinstance(anc, (ast.For, ast.AsyncFor, ast.While)):
            return True
    return False


class _Imports(ast.NodeVisitor):
    """Alias map so ``jnp.asarray`` resolves to ``jax.numpy.asarray``,
    ``from jax import jit`` resolves bare ``jit``, etc."""

    def __init__(self):
        self.aliases: dict[str, str] = {}

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self.aliases[a.asname or a.name.split(".")[0]] = \
                a.name if a.asname else a.name.split(".")[0]

    def visit_ImportFrom(self, node: ast.ImportFrom):
        if node.module is None:
            return
        for a in node.names:
            self.aliases[a.asname or a.name] = f"{node.module}.{a.name}"


def _dotted(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Best-effort dotted name of an expression, aliases resolved:
    ``jnp.asarray`` -> ``jax.numpy.asarray``, ``self._reset`` ->
    ``self._reset``.  None for anything not a Name/Attribute chain."""
    parts: list[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if not isinstance(cur, ast.Name):
        return None
    head = aliases.get(cur.id, cur.id)
    return ".".join([head, *reversed(parts)])


def _call_base(func: ast.AST, aliases: dict[str, str]) -> str | None:
    """The dispatchable base of a call target, subscripts peeled:
    ``step_fns[key](...)`` -> ``step_fns``;
    ``self._programs[b]["window"](...)`` -> ``self._programs``."""
    while isinstance(func, ast.Subscript):
        func = func.value
    return _dotted(func, aliases)


def _is_jit_call(node: ast.AST, aliases: dict[str, str]) -> bool:
    return (isinstance(node, ast.Call)
            and _dotted(node.func, aliases) in _JIT_NAMES)


def _target_names(target: ast.AST, aliases: dict[str, str]) -> list[str]:
    """Dotted names bound by an assignment target (tuples flattened)."""
    if isinstance(target, (ast.Tuple, ast.List)):
        out: list[str] = []
        for elt in target.elts:
            out.extend(_target_names(elt, aliases))
        return out
    if isinstance(target, ast.Starred):
        return _target_names(target.value, aliases)
    name = _dotted(target, aliases)
    return [name] if name is not None else []


def _prep(tree: ast.AST) -> dict[str, str]:
    _attach_parents(tree)
    imp = _Imports()
    imp.visit(tree)
    return imp.aliases


# ---------------------------------------------------------------------------
# R1 — recompile hazards
# ---------------------------------------------------------------------------

def check_r1(tree: ast.AST, source: str, path: str) -> Iterable[Raw]:
    aliases = _prep(tree)
    for node in ast.walk(tree):
        if not _is_jit_call(node, aliases):
            continue
        parent = _parent(node)
        immediate = isinstance(parent, ast.Call) and parent.func is node
        if immediate and node.args and isinstance(node.args[0], ast.Lambda):
            yield (node.lineno, node.col_offset,
                   "jax.jit(<lambda>)(...) can never hit the jit cache "
                   "(a fresh lambda object per execution retraces every "
                   "call); name the function and jit it once, or build a "
                   "program table")
            continue
        if _in_loop_same_function(node):
            yield (node.lineno, node.col_offset,
                   "jit wrapper created inside a loop — a fresh wrapper "
                   "(and trace cache) per iteration; hoist it out of the "
                   "loop or pre-build a program table "
                   "(codecs.build_program_table) and dispatch host-side")


# ---------------------------------------------------------------------------
# R2 — use-after-donate
# ---------------------------------------------------------------------------

def _donated_positions(call: ast.Call) -> tuple[list[int], list[str]]:
    """Literal donate_argnums positions / donate_argnames names."""
    positions: list[int] = []
    names: list[str] = []
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
            for e in elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, int):
                    positions.append(e.value)
        elif kw.arg == "donate_argnames":
            v = kw.value
            elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) else [v]
            for e in elts:
                if isinstance(e, ast.Constant) and isinstance(e.value, str):
                    names.append(e.value)
    return positions, names


def check_r2(tree: ast.AST, source: str, path: str) -> Iterable[Raw]:
    aliases = _prep(tree)
    # pass 1: names bound (anywhere) to a donating jit wrapper
    donors: dict[str, tuple[list[int], list[str]]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, (ast.Assign, ast.AnnAssign)):
            continue
        value = node.value
        if value is None or not _is_jit_call(value, aliases):
            continue
        pos, names = _donated_positions(value)
        if not pos and not names:
            continue
        targets = (node.targets if isinstance(node, ast.Assign)
                   else [node.target])
        for t in targets:
            for name in _target_names(t, aliases):
                donors[name] = (pos, names)
    if not donors:
        return
    # pass 2: per function, order donate/store/load events by line
    funcs = [n for n in ast.walk(tree)
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
    for fn in funcs:
        events: dict[str, list[tuple[int, int, str, ast.AST]]] = {}

        def note(var: str, line: int, col: int, kind: str, node: ast.AST):
            events.setdefault(var, []).append((line, col, kind, node))

        for node in ast.walk(fn):
            if node is not fn and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue                     # nested scopes stand alone
            if _enclosing_function(node) is not fn:
                continue
            if isinstance(node, ast.Call):
                callee = _dotted(node.func, aliases)
                if callee in donors:
                    pos, kwnames = donors[callee]
                    donated_args = [node.args[p] for p in pos
                                    if p < len(node.args)]
                    donated_args += [kw.value for kw in node.keywords
                                     if kw.arg in kwnames]
                    for arg in donated_args:
                        var = _dotted(arg, aliases)
                        if var is not None:
                            note(var, node.lineno, node.col_offset,
                                 "donate", node)
            if isinstance(node, (ast.Name, ast.Attribute)):
                var = _dotted(node, aliases)
                if var is None:
                    continue
                ctx = getattr(node, "ctx", None)
                if isinstance(ctx, ast.Store):
                    note(var, node.lineno, node.col_offset, "store", node)
                elif isinstance(ctx, ast.Load):
                    note(var, node.lineno, node.col_offset, "load", node)

        for var, evs in events.items():
            evs.sort(key=lambda e: (e[0], e[1]))
            for line, col, kind, node in evs:
                if kind != "donate":
                    continue
                # the first later load with no intervening store is a read
                # of a possibly-reused buffer.  A store on the DONATING
                # line is the wrapping assignment (`cache = step(cache)`,
                # the engine idiom) and forgives; same-line loads are the
                # call's own arguments.
                if any(l2 == line and k2 == "store"
                       for l2, _c2, k2, _n2 in evs):
                    continue
                for l2, c2, k2, _n2 in evs:
                    if l2 <= line:
                        continue
                    if k2 == "store":
                        break
                    if k2 == "load":
                        yield (l2, c2,
                               f"{var!r} was donated to a jitted call on "
                               f"line {line} (donate_argnums) and read "
                               f"again without rebinding — the buffer may "
                               f"have been reused in place; rebind it "
                               f"from the call's result")
                        break


# ---------------------------------------------------------------------------
# R3 — hidden host syncs
# ---------------------------------------------------------------------------

def _traced_functions(tree: ast.AST, aliases: dict[str, str]) -> set[ast.AST]:
    """Function defs that jit traces: decorated with jax.jit (bare or
    called), or passed by name to a jax.jit(...) call in this module —
    plus every def nested inside one."""
    traced: set[ast.AST] = set()
    by_name: dict[str, list[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(node.name, []).append(node)
            for dec in node.decorator_list:
                if _dotted(dec, aliases) in _JIT_NAMES:
                    traced.add(node)
                elif isinstance(dec, ast.Call) \
                        and _dotted(dec.func, aliases) in _JIT_NAMES:
                    traced.add(node)
                elif (isinstance(dec, ast.Call)
                      and _dotted(dec.func, aliases)
                      in ("functools.partial", "partial")
                      and dec.args
                      and _dotted(dec.args[0], aliases) in _JIT_NAMES):
                    traced.add(node)
    for node in ast.walk(tree):
        if _is_jit_call(node, aliases) and node.args:
            arg = node.args[0]
            name = _dotted(arg, aliases)
            if name in by_name:
                traced.update(by_name[name])
    # nested defs trace with their parent
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(a in traced for a in _ancestors(node)):
                traced.add(node)
    return traced


def _program_names(tree: ast.AST, aliases: dict[str, str]) -> set[str]:
    """Names bound to compiled programs: ``x = jax.jit(f)``, ``x =
    <...>.build_program_table(...)``, or a def decorated @jax.jit."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            value = node.value
            if value is None or not isinstance(value, ast.Call):
                continue
            callee = _dotted(value.func, aliases)
            is_builder = callee is not None and callee.rsplit(".", 1)[-1] \
                in _PROGRAM_TABLE_BUILDERS
            if not (_is_jit_call(value, aliases) or is_builder):
                continue
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                names.update(_target_names(t, aliases))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _dotted(dec, aliases) in _JIT_NAMES or (
                        isinstance(dec, ast.Call)
                        and _dotted(dec.func, aliases) in _JIT_NAMES):
                    names.add(node.name)
    return names


def _sync_construct(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Describe ``node`` if it is a host-sync construct, else None."""
    if not isinstance(node, ast.Call):
        return None
    if isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
            and not node.args:
        return ".item() forces a device->host transfer"
    callee = _dotted(node.func, aliases)
    if callee in _SYNC_CALLS:
        return f"{callee}() blocks on device results"
    if callee in _SYNC_BUILTINS and len(node.args) == 1 \
            and not isinstance(node.args[0], ast.Constant):
        return f"{callee}() on a device value forces a host sync"
    return None


def check_r3(tree: ast.AST, source: str, path: str) -> Iterable[Raw]:
    aliases = _prep(tree)
    traced = _traced_functions(tree, aliases)
    programs = _program_names(tree, aliases)

    # (a) host syncs / truthiness inside traced functions
    for fn in traced:
        params = {a.arg for a in [*fn.args.args, *fn.args.posonlyargs,
                                  *fn.args.kwonlyargs]}
        for node in ast.walk(fn):
            desc = _sync_construct(node, aliases)
            if desc is not None:
                yield (node.lineno, node.col_offset,
                       f"{desc} inside jit-traced function {fn.name!r} "
                       f"(raises or constant-folds at trace time)")
            if isinstance(node, (ast.If, ast.While)):
                test = node.test
                if isinstance(test, ast.Name) and test.id in params:
                    yield (test.lineno, test.col_offset,
                           f"truthiness on traced argument {test.id!r} "
                           f"inside jit-traced function {fn.name!r} — use "
                           f"jnp.where / lax.cond (or make it static)")

    # (b) per-iteration host syncs in loops that dispatch compiled programs
    if not programs:
        return
    for loop in ast.walk(tree):
        if not isinstance(loop, (ast.For, ast.AsyncFor, ast.While)):
            continue
        body_nodes = [n for stmt in loop.body for n in ast.walk(stmt)]
        dispatches = any(
            isinstance(n, ast.Call)
            and _call_base(n.func, aliases) in programs
            for n in body_nodes)
        if not dispatches:
            continue
        for n in body_nodes:
            desc = _sync_construct(n, aliases)
            if desc is not None:
                yield (n.lineno, n.col_offset,
                       f"{desc} every iteration of a loop that dispatches "
                       f"a compiled program — the sync serializes dispatch "
                       f"with compute; accumulate device values and "
                       f"convert after the loop (or gate it on the "
                       f"logging cadence)")


# ---------------------------------------------------------------------------
# R4 — codec accounting completeness
# ---------------------------------------------------------------------------

_R4_REQUIRED = {"transform": ("payload_shape", "wire_bytes", "flops"),
                "wire": ("wire_bytes", "flops", "apply")}


def check_r4(tree: ast.AST, source: str, path: str) -> Iterable[Raw]:
    aliases = _prep(tree)
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for dec in node.decorator_list:
            if not isinstance(dec, ast.Call):
                continue
            callee = _dotted(dec.func, aliases)
            if callee is None or callee.rsplit(".", 1)[-1] != "register":
                continue
            kind = "transform"
            for kw in dec.keywords:
                if kw.arg == "kind" and isinstance(kw.value, ast.Constant):
                    kind = str(kw.value.value)
            required = _R4_REQUIRED.get(kind, _R4_REQUIRED["transform"])
            defined = {n.name for n in node.body
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))}
            missing = [m for m in required if m not in defined]
            if missing:
                yield (node.lineno, node.col_offset,
                       f"registered {kind} codec {node.name!r} does not "
                       f"implement {', '.join(missing)} in its own body — "
                       f"every wire stage must carry the exact byte/FLOP "
                       f"accounting surface (BENCH_comm and the HLO "
                       f"cross-checks depend on it)")


# ---------------------------------------------------------------------------
# R5 — asyncio race / hygiene
# ---------------------------------------------------------------------------

def _handles_cancelled(handler: ast.ExceptHandler,
                       aliases: dict[str, str]) -> bool:
    t = handler.type
    types = t.elts if isinstance(t, ast.Tuple) else ([t] if t else [])
    for typ in types:
        name = _dotted(typ, aliases)
        if name is not None and name.rsplit(".", 1)[-1] == "CancelledError":
            return True
    return False


def _reraises(handler: ast.ExceptHandler) -> bool:
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            if node.exc is None:
                return True
            if isinstance(node.exc, ast.Name) and node.exc.id == handler.name:
                return True
            # raising ANYTHING keeps the cancellation path loud enough
            return True
    return False


def _container_key(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """The shared-container identity an async loop iterates: a bare
    name / attribute, or the same with .items()/.keys()/.values()."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
            and node.func.attr in ("items", "keys", "values") \
            and not node.args:
        node = node.func.value
    return _dotted(node, aliases)


def check_r5(tree: ast.AST, source: str, path: str) -> Iterable[Raw]:
    aliases = _prep(tree)
    async_fns = [n for n in ast.walk(tree)
                 if isinstance(n, ast.AsyncFunctionDef)]

    for fn in async_fns:
        for node in ast.walk(fn):
            if _enclosing_function(node) is not fn:
                continue
            # R5a: blocking call on the event loop thread
            if isinstance(node, ast.Call):
                callee = _dotted(node.func, aliases)
                if callee in _BLOCKING_IN_ASYNC:
                    yield (node.lineno, node.col_offset,
                           f"blocking {callee}() inside async def "
                           f"{fn.name!r} stalls the single-threaded event "
                           f"loop (every tenant pays); use the asyncio "
                           f"equivalent or run_in_executor")
            # R5c: swallowed cancellation
            if isinstance(node, ast.ExceptHandler) \
                    and _handles_cancelled(node, aliases) \
                    and not _reraises(node):
                yield (node.lineno, node.col_offset,
                       f"except CancelledError without re-raise in async "
                       f"def {fn.name!r} — swallowing cancellation breaks "
                       f"task.cancel()-based shutdown (the PR 7 orphan-"
                       f"task cleanup relies on it propagating)")
            # R5d: mutate-while-iterating across an await
            if isinstance(node, (ast.For, ast.AsyncFor)):
                key = _container_key(node.iter, aliases)
                if key is None:
                    continue
                body = [n for stmt in node.body for n in ast.walk(stmt)]
                if not any(isinstance(n, ast.Await) for n in body):
                    continue
                mutated = False
                for n in body:
                    if isinstance(n, (ast.Delete, ast.Assign)):
                        targets = (n.targets if isinstance(n, (ast.Delete,
                                                               ast.Assign))
                                   else [])
                        for t in targets:
                            if isinstance(t, ast.Subscript) \
                                    and _dotted(t.value, aliases) == key:
                                mutated = True
                    if isinstance(n, ast.Call) \
                            and isinstance(n.func, ast.Attribute) \
                            and n.func.attr in _MUTATORS \
                            and _dotted(n.func.value, aliases) == key:
                        mutated = True
                if mutated:
                    yield (node.lineno, node.col_offset,
                           f"iterating {key!r} with an await in the body "
                           f"while also mutating it — the await yields to "
                           f"handlers that may touch the same container; "
                           f"iterate a snapshot (list({key})) instead")

    # R5b: dropped task reference (any scope, not just async defs —
    # a sync helper can spawn tasks too)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Expr) or not isinstance(node.value,
                                                            ast.Call):
            continue
        call = node.value
        callee = _dotted(call.func, aliases)
        tail = callee.rsplit(".", 1)[-1] if callee else (
            call.func.attr if isinstance(call.func, ast.Attribute) else None)
        if tail in _TASK_SPAWNERS:
            yield (node.lineno, node.col_offset,
                   "task spawned and its reference dropped — the event "
                   "loop holds only a weak ref, so the task can be "
                   "garbage-collected mid-flight and its exceptions are "
                   "never observed; retain it (self._tasks.add / await)")


CHECKERS = {"R1": check_r1, "R2": check_r2, "R3": check_r3,
            "R4": check_r4, "R5": check_r5}
