"""Composable codec pipelines: a transform codec + wire-format stages."""
from __future__ import annotations

import dataclasses
import math


def payload_wire_bytes(codec, shape: tuple[int, ...]) -> int:
    """Wire bytes for an ALREADY-SHAPED payload.

    ``codec.wire_bytes(B)`` covers the decode path's (B, D) features; the
    chunked-prefill path ships the 3-D sequence-grouped payload
    (C, B/R, D) from ``sequence_group_encode``, whose per-row scale/mask
    counts depend on the true leading shape — this entry point feeds that
    shape straight to the codec's last wire stage (stages are rank-generic:
    a "row" is everything but the trailing axis).  Bare transforms ship f32.
    """
    stages = getattr(codec, "stages", ())
    if stages:
        return stages[-1].wire_bytes(tuple(shape))
    return math.prod(shape) * 4


@dataclasses.dataclass(frozen=True)
class Chain:
    """Pipeline a transform codec with one or more wire stages.

    encode: transform.encode, then each wire stage's straight-through
    ``apply`` (fake-quant style round-trip, so the payload keeps the
    transform's shape/dtype in-graph).  decode: the transform's decode.

    Accounting composes: params/FLOPs add across stages; wire bytes are
    whatever the LAST wire stage puts on the wire for the transform's
    payload shape (earlier stages are in-graph conditioning).  With a single
    ``int8`` stage behind C3-SL this reproduces the old inlined
    ``quant_bits=8`` numbers exactly.
    """
    transform: object
    stages: tuple = ()

    def __post_init__(self):
        for s in self.stages:
            if not hasattr(s, "apply"):
                raise TypeError(f"{s!r} is not a wire stage (no .apply)")

    # ---- protocol passthroughs -------------------------------------------

    @property
    def feature_layout(self) -> str:
        return self.transform.feature_layout

    @property
    def R(self) -> int:
        return getattr(self.transform, "R", 1)

    @property
    def D(self) -> int:
        return self.transform.D

    def init(self, rng=None):
        return self.transform.init(rng)

    def encode(self, params, Z):
        payload = self.transform.encode(params, Z)
        for stage in self.stages:
            payload = stage.apply(payload)
        return payload

    def decode(self, params, payload):
        return self.transform.decode(params, payload)

    def decode_masked(self, params, payload, keep):
        """Erasure-aware decode: wire stages are straight-through (the
        in-graph payload keeps the transform's shape), so the mask
        applies at the transform's decode."""
        fn = getattr(self.transform, "decode_masked", None)
        if fn is None:
            return self.transform.decode(params, payload * keep)
        return fn(params, payload, keep)

    # ---- accounting ------------------------------------------------------

    def param_count(self) -> int:
        return self.transform.param_count() + sum(
            s.param_count() for s in self.stages)

    def flops(self, B: int) -> int:
        shape = self.payload_shape(B)
        return self.transform.flops(B) + sum(
            s.flops(shape) for s in self.stages)

    def payload_shape(self, B: int) -> tuple[int, ...]:
        return self.transform.payload_shape(B)

    def wire_bytes(self, B: int) -> int:
        if not self.stages:
            return self.transform.wire_bytes(B)
        return self.stages[-1].wire_bytes(self.payload_shape(B))

    def spec(self) -> str:
        return "|".join([self.transform.spec()]
                        + [s.spec() for s in self.stages])
