"""C3-SL: the paper's batch-wise HRR codec (bind + superpose / unbind).

Pure transform stage — the beyond-paper int8 wire format that used to be a
``quant_bits`` option here now lives in ``repro.codecs.wire`` and composes
via specs, e.g. ``build("c3sl:R=8|int8", D=4096)``.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.codecs.base import SpecMixin, register
from repro.core import hrr


@register("c3sl", "hrr")
@dataclasses.dataclass(frozen=True)
class C3SLCodec(SpecMixin):
    """Fixed random keys, bind+superpose R features into one D-vector.

    Z (B, D) is grouped into B/R groups; each group becomes one D-vector.
    Keys are constants (stop_gradient inside the HRR ops) — param_count is
    the paper's R*D and flops(B) the paper's 2*B*D^2.  The HRR execution
    backend (fft | direct | pallas) is part of the spec.
    """
    R: int
    D: int
    backend: str = "fft"
    unitary: bool = False          # beyond-paper: exact-rotation keys
    key_seed: int = 0

    feature_layout = "flat"

    def __post_init__(self):
        if self.R < 1:
            raise ValueError(f"R must be >= 1, got {self.R}")
        if self.backend not in ("fft", "direct", "pallas"):
            raise ValueError(f"unknown HRR backend {self.backend!r} "
                             "(expected fft | direct | pallas)")

    def init(self, rng=None):
        rng = rng if rng is not None else jax.random.PRNGKey(self.key_seed)
        keys = hrr.generate_keys(rng, self.R, self.D, unitary=self.unitary)
        params = {"keys": keys}
        if self.backend == "fft":
            # cache F(K) so every encode/decode (and the custom-VJP backward,
            # which is again an HRR op with the same keys) transforms only
            # the activations — the keys are fixed, their spectrum is too
            params["keys_fft"] = hrr.key_spectrum(keys)
        return params

    def _group(self, Z):
        """(..., B, D) -> (G, R, D) groups of R consecutive rows.  Rank-3
        inputs (S, B, D) group WITHIN each leading slice (B % R == 0), so a
        group never straddles two positions of a sequence-grouped payload."""
        *lead, B, D = Z.shape
        if D != self.D:
            raise ValueError(f"feature dim {D} != codec D={self.D}")
        if B % self.R:
            raise ValueError(f"batch {B} not divisible by R={self.R}")
        return Z.reshape(-1, self.R, D)

    def encode(self, params, Z):
        """Z (B, D) -> payload (B/R, D); Z (S, B, D) -> payload (S, B/R, D)
        (the sequence-grouped 3-D wire layout — same math, the leading axis
        is kept so per-row wire accounting sees the true row count)."""
        payload = hrr.bind_superpose(self._group(Z), params["keys"],
                                     backend=self.backend,
                                     K_fft=params.get("keys_fft"))
        return payload.reshape(*Z.shape[:-2], Z.shape[-2] // self.R, self.D)

    def decode(self, params, payload):
        Zhat = hrr.unbind(payload.reshape(-1, self.D), params["keys"],
                          backend=self.backend, K_fft=params.get("keys_fft"))
        G, R, D = Zhat.shape
        return Zhat.reshape(*payload.shape[:-2], payload.shape[-2] * R, D)

    def decode_masked(self, params, payload, keep):
        """Erasure-aware decode: ``keep`` (payload-shaped, 1.0 kept /
        0.0 erased) marks the elements that survived the wire; the
        superposition is renormalized over the survivors
        (``repro.core.hrr.masked_unbind``).  Bitwise identical to
        :meth:`decode` at an all-ones mask."""
        Zhat = hrr.masked_unbind(payload.reshape(-1, self.D),
                                 params["keys"], keep.reshape(-1, self.D),
                                 backend=self.backend,
                                 K_fft=params.get("keys_fft"))
        G, R, D = Zhat.shape
        return Zhat.reshape(*payload.shape[:-2], payload.shape[-2] * R, D)

    def execution_mode(self) -> str:
        """How this codec's HRR ops ACTUALLY execute on this host — unlike
        ``spec()`` (the canonical registry string, which must round-trip
        through ``build`` and so never changes per-host): ``"fft"`` /
        ``"direct"`` for the jnp backends, ``"pallas-compiled"`` on a real
        TPU, ``"pallas-interpret"`` when the kernel is CPU-emulated, and
        ``"fft-fallback"`` when a non-MXU-alignable D reroutes the pallas
        request (repro.core.hrr).  Benchmarks must record this tag —
        bench_roofline refuses interpret-mode rows labeled as kernels."""
        if self.backend != "pallas":
            return self.backend
        from repro.kernels import circconv
        if not circconv.mxu_alignable(self.D):
            return "fft-fallback"
        return circconv.execution_mode()

    def param_count(self) -> int:
        return self.R * self.D  # paper Table 2

    def flops(self, B: int) -> int:
        return 2 * B * self.D ** 2  # paper Table 2 (direct form; FFT is B*D*log D)

    def payload_shape(self, B: int) -> tuple[int, ...]:
        return (B // self.R, self.D)

    def wire_bytes(self, B: int) -> int:
        return (B // self.R) * self.D * 4


def sequence_group_encode(codec, params, Z_bsd: jax.Array) -> jax.Array:
    """Beyond-paper: group along sequence blocks when batch==1 (long_500k),
    or per position across slots (chunked prefill feeds (C, B, d)).

    Z (B, S, D) with B*S divisible by R -> payload.  When S % R == 0 the
    payload keeps the 3-D sequence-grouped layout (B, S/R, D) — groups
    never straddle the leading axis, and wire stages see/account the true
    per-row structure.  Otherwise groups wrap across the leading axis and
    the payload is the flat (B*S/R, D).  Both are bit-identical row-wise
    (the 3-D form is a reshape of the flat one).
    """
    B, S, D = Z_bsd.shape
    R = getattr(codec, "R", 1)
    if (B * S) % R:
        raise ValueError(
            f"batch {B * S} (B={B} x S={S} sequence groups) not divisible "
            f"by R={R}")
    if S % R == 0:
        return codec.encode(params, Z_bsd)               # 3-D (B, S/R, D)
    return codec.encode(params, Z_bsd.reshape(B * S, D))


def sequence_group_decode(codec, params, payload: jax.Array,
                          B: int, S: int) -> jax.Array:
    return codec.decode(params, payload).reshape(B, S, -1)
