"""Codec protocol, spec grammar, and the string-keyed codec registry.

This module is the single source of truth for what a boundary codec *is*:

* ``Codec`` — a runtime-checkable protocol.  A codec owns the cut-layer
  transform (init/encode/decode over pytree params) plus the analytic
  accounting the paper-repro benchmarks consume (``param_count`` /
  ``flops`` / ``wire_bytes`` / ``payload_shape``) and a ``feature_layout``
  attribute ("flat" for (B, D) codecs, "nchw" for conv codecs) that the
  split-step machinery dispatches on instead of ``isinstance``.

* ``CodecSpec`` — one parsed stage of a spec string (serializable:
  ``str(spec)`` round-trips through ``CodecSpec.parse``).

* the registry — ``@register("name")`` for transform codecs,
  ``@register("name", kind="wire")`` for wire-format stages, and
  ``build("c3sl:R=8,backend=fft|int8", D=4096)`` to construct a codec
  (optionally chained with wire stages) from a spec string.  Keyword
  ``defaults`` passed to ``build`` fill fields the spec string leaves out
  (typically runtime dims like ``D``); explicit spec args always win, and
  defaults that a stage's dataclass doesn't declare are ignored.

The full spec grammar is documented in ``repro.codecs.__init__``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable


@runtime_checkable
class Codec(Protocol):
    """What every boundary codec implements (structural — no base class)."""

    #: "flat" — encode/decode consume (B, D); "nchw" — (B, C, H, W).
    feature_layout: str

    def init(self, rng) -> Any: ...                      # params pytree
    def encode(self, params, Z) -> Any: ...              # wire payload
    def decode(self, params, payload) -> Any: ...        # reconstruction
    def param_count(self) -> int: ...                    # codec parameters
    def flops(self, B: int) -> int: ...                  # FLOPs per batch
    def wire_bytes(self, B: int) -> int: ...             # bytes/direction/step
    def payload_shape(self, B: int) -> tuple[int, ...]: ...
    def spec(self) -> str: ...                           # canonical spec string


@runtime_checkable
class WireStage(Protocol):
    """A wire-format stage: reshapes the *bytes* of a payload, not its math.

    ``apply`` runs in-graph as a straight-through round-trip (fake-quant
    style), so encode-side chaining needs no decode-side counterpart; the
    byte accounting lives in ``wire_bytes(shape)`` over the transform
    codec's payload shape.
    """

    def apply(self, payload): ...
    def param_count(self) -> int: ...
    def flops(self, shape: tuple[int, ...]) -> int: ...
    def wire_bytes(self, shape: tuple[int, ...]) -> int: ...
    def spec(self) -> str: ...


# --------------------------------------------------------------------------
# Spec strings
# --------------------------------------------------------------------------

def _parse_value(text: str):
    if text.lower() in ("true", "false"):
        return text.lower() == "true"
    for conv in (int, float):
        try:
            return conv(text)
        except ValueError:
            pass
    return text


def _format_value(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    return str(v)


@dataclasses.dataclass(frozen=True)
class CodecSpec:
    """One parsed stage: ``name[:k=v[,k=v...]]``.  Serializable both ways."""
    name: str
    args: dict

    @classmethod
    def parse(cls, text: str) -> "CodecSpec":
        stage = text.strip()
        name, _, argtext = stage.partition(":")
        name = name.strip()
        if not name:
            raise ValueError(f"empty stage name in codec spec {text!r}")
        args = {}
        if argtext.strip():
            for kv in argtext.split(","):
                k, sep, v = kv.partition("=")
                if not sep or not k.strip():
                    raise ValueError(
                        f"malformed arg {kv!r} in codec stage {stage!r} "
                        "(expected key=value)")
                args[k.strip()] = _parse_value(v.strip())
        return cls(name, args)

    def __str__(self) -> str:
        if not self.args:
            return self.name
        body = ",".join(f"{k}={_format_value(v)}" for k, v in self.args.items())
        return f"{self.name}:{body}"


def parse_spec(text: str) -> list[CodecSpec]:
    """Parse a full spec string into its ``|``-separated stages."""
    if not text or not text.strip():
        raise ValueError("empty codec spec")
    return [CodecSpec.parse(stage) for stage in text.split("|")]


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_TRANSFORMS: dict[str, type] = {}
_WIRES: dict[str, type] = {}


def register(name: str, *aliases: str, kind: str = "transform"):
    """Class decorator: register a codec (or wire stage) under spec name(s).

    The first name is canonical — it is what ``spec()`` emits.
    """
    if kind not in ("transform", "wire"):
        raise ValueError(f"kind must be 'transform' or 'wire', got {kind!r}")
    table = _WIRES if kind == "wire" else _TRANSFORMS

    def deco(cls):
        for n in (name, *aliases):
            if n in _TRANSFORMS or n in _WIRES:
                raise ValueError(f"codec name {n!r} already registered")
            table[n] = cls
        cls.spec_name = name
        return cls

    return deco


def available() -> dict[str, list[str]]:
    """Registered spec names, for error messages and docs."""
    return {"transform": sorted(_TRANSFORMS), "wire": sorted(_WIRES)}


def _spec_fields(cls) -> dict:
    return {f.name: f for f in dataclasses.fields(cls)
            if f.metadata.get("spec", True)}


def _construct(table: dict, stage: CodecSpec, defaults: dict, what: str):
    if stage.name not in table:
        raise ValueError(
            f"unknown {what} {stage.name!r}; registered transforms: "
            f"{sorted(_TRANSFORMS)}, wire stages: {sorted(_WIRES)}")
    cls = table[stage.name]
    fields = _spec_fields(cls)
    unknown = sorted(set(stage.args) - set(fields))
    if unknown:
        raise ValueError(
            f"{stage.name}: unknown spec arg(s) {unknown}; "
            f"valid args: {sorted(fields)}")
    kwargs = dict(stage.args)
    for k, v in defaults.items():
        if k in fields and k not in kwargs and v is not None:
            kwargs[k] = v
    missing = sorted(k for k, f in fields.items()
                     if f.default is dataclasses.MISSING
                     and f.default_factory is dataclasses.MISSING
                     and k not in kwargs)
    if missing:
        raise ValueError(
            f"{stage.name}: missing required arg(s) {missing} — supply them "
            f"in the spec string or as build(..., {missing[0]}=...) defaults")
    return cls(**kwargs)


def build(spec: str, /, **defaults):
    """Build a codec from a spec string; later ``|`` stages are wire formats.

    ``defaults`` fill spec-omitted dataclass fields (runtime dims like ``D``);
    explicit spec args win, and defaults unknown to a stage are ignored.

    An ``adaptive:`` prefix wraps the rest of the spec in the Adaptive-R
    scheduler (see ``repro.codecs.adaptive``): the inner codec's spec grammar
    is unchanged, and adaptive args (``min_R``/``target_snr``/...) ride in
    the first stage's arg list.
    """
    stripped = spec.strip()
    if stripped == "adaptive" or stripped.startswith("adaptive:"):
        from repro.codecs.adaptive import build_adaptive
        return build_adaptive(stripped, **defaults)
    head, *rest = parse_spec(spec)
    codec = _construct(_TRANSFORMS, head, defaults, "transform codec")
    if rest:
        from repro.codecs.compose import Chain
        wires = tuple(_construct(_WIRES, s, defaults, "wire stage")
                      for s in rest)
        codec = Chain(codec, wires)
    return codec


# --------------------------------------------------------------------------
# Spec emission + generic helpers shared by implementations
# --------------------------------------------------------------------------

def format_stage(obj) -> str:
    """Canonical stage string: registered name + non-default fields in
    declaration order.  ``build(format_stage(c)) == c`` for registered
    dataclass codecs."""
    parts = []
    for f in dataclasses.fields(obj):
        if not f.metadata.get("spec", True):
            continue
        v = getattr(obj, f.name)
        if f.default is not dataclasses.MISSING and v == f.default:
            continue
        parts.append(f"{f.name}={_format_value(v)}")
    name = obj.spec_name
    return f"{name}:{','.join(parts)}" if parts else name


def apply_quant_bits(spec: str, quant_bits) -> str:
    """Legacy ``--quant`` flag: 8 appends the int8 wire stage (unless the
    spec already names one); any other non-None value is an error."""
    if quant_bits is None:
        return spec
    if quant_bits != 8:
        raise ValueError(
            f"only int8 wire quantization supported, got quant_bits={quant_bits}")
    if any(s.name == "int8" for s in parse_spec(spec)):
        return spec
    return spec + "|int8"


class SpecMixin:
    """Default ``spec()`` for registered dataclass codecs/wire stages."""

    def spec(self) -> str:
        return format_stage(self)


def clamp_R(codec, max_R: int):
    """Return ``codec`` with its grouping factor R clamped to ``max_R``.

    Works through ``Chain`` wrappers (re-building the inner transform), lets
    codecs with their own clamping logic handle it (``with_max_R``, e.g. the
    Adaptive-R wrapper trims its bucket ladder), and is a no-op for codecs
    without an R field.  The returned codec's ``spec()`` always round-trips
    through ``build`` (pinned in tests/test_codec_registry.py).  NOTE: the
    caller must re-``init`` params if the codec changed — C3-SL keys have
    shape (R, D).
    """
    with_max = getattr(codec, "with_max_R", None)
    if with_max is not None:
        return with_max(max_R)
    R = getattr(codec, "R", 1)
    if R <= max_R:
        return codec
    inner = getattr(codec, "transform", None)
    if inner is not None:  # composed codec: clamp the transform stage
        return dataclasses.replace(codec, transform=clamp_R(inner, max_R))
    if "R" not in {f.name for f in dataclasses.fields(codec)}:
        return codec
    return dataclasses.replace(codec, R=max_R)
