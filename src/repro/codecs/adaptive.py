"""Adaptive-R wrapper codec: SNR-driven batch-wise compression scheduling.

C3-SL's cross-talk grows ~sqrt(R-1) (repro.core.hrr), so a fixed grouping
factor R either wastes bandwidth (R too small) or bleeds accuracy (R too
large) depending on where training is.  ``AdaptiveC3SL`` wraps any R-bearing
transform codec (or a ``Chain`` ending in wire stages) and picks R each step
from a bucketed ladder {min_R, 2*min_R, ..., max_R}, driven by an EMA of the
measured retrieval SNR at the cut layer — in the spirit of adaptive
feature-wise compression (Oh et al., 2023) and frequency-aware rate
adaptation (SL-FAC).

Spec grammar (handled by ``repro.codecs.build``)::

    adaptive:<inner stage>[,<adaptive args>][|<wire stages>]

    build("adaptive:c3sl:R=16,min_R=2,target_snr=12", D=4096)
    build("adaptive:c3sl:R=8,min_R=2|int8", D=256)

The adaptive args (``min_R``, ``target_snr``, ``ema``, ``hysteresis``) are
spliced into the FIRST stage's arg list and extracted before the inner codec
is built; everything else (including later ``|`` wire stages) is the inner
spec.  ``spec()`` round-trips through ``build``.

jit-safety: the wrapper pre-builds ONE inner codec per bucket at init
(rebuilding chained specs via ``clamp_R``), so callers compile one branch
per bucket and switch HOST-SIDE — an R change never retraces anything
(pinned by the compile-counter test in tests/test_adaptive_codec.py).  The
wrapper itself must never be closed over by a jitted function: its
encode/decode delegate to whatever bucket is current *at trace time*.  Use
``buckets`` / ``params_for`` to build per-bucket programs instead (see
``repro.launch.train`` and ``repro.serving.engine``).

The controller is deliberately host-side and dumb-simple: a deadband ladder
walk.  SNR is monotonically non-increasing in R (in expectation — a
hypothesis-pinned invariant), so "EMA above target + hysteresis" means
head-room for one more doubling of R, "below target - hysteresis" means back
off.  An optional ``loss_slack`` signal (positive = loss better than
budget) vetoes ramp-ups and forces ramp-downs when negative, for callers
that track a task-loss budget alongside SNR.
"""
from __future__ import annotations

from repro.codecs.base import CodecSpec, _format_value, build, clamp_R, parse_spec

#: adaptive args recognized in the first spec stage (everything else is the
#: inner codec's), with their defaults.  Order is the canonical emission order.
_ADAPTIVE_DEFAULTS = {
    "min_R": 1,           # smallest bucket (ladder doubles up to inner R)
    "target_snr": 0.0,    # retrieval-SNR setpoint, dB
    "ema": 0.9,           # EMA coefficient on the observed SNR
    "hysteresis": 1.0,    # deadband around the setpoint, dB
}


def bucket_key(R: int) -> str:
    """Params-pytree key of one bucket's codec params."""
    return f"R{R}"


class AdaptiveC3SL:
    """Codec-protocol wrapper that schedules R over a bucketed ladder.

    ``inner`` is the max-R codec (a bare transform or a ``Chain``); every
    smaller bucket is pre-built at construction with ``clamp_R`` so chained
    specs (e.g. ``c3sl:R=16|int8``) rebuild correctly.  The protocol
    accounting surface (``flops``/``wire_bytes``/``payload_shape``) reports
    the CURRENT bucket; ``param_count`` reports every resident bucket's
    params (all key tables live in memory at once — that is the price of
    zero-recompile switching).
    """

    def __init__(self, inner, min_R: int = 1, target_snr: float = 0.0,
                 ema: float = 0.9, hysteresis: float = 1.0):
        max_R = getattr(inner, "R", None)
        if not isinstance(max_R, int) or max_R < 1:
            raise ValueError(
                f"adaptive needs an inner codec with an integer R >= 1, got "
                f"{inner!r}")
        if not 1 <= min_R <= max_R:
            raise ValueError(f"min_R={min_R} must be in [1, max_R={max_R}]")
        ratio = max_R // min_R
        if min_R * ratio != max_R or ratio & (ratio - 1):
            raise ValueError(
                f"bucket ladder doubles from min_R to max_R: max_R/min_R "
                f"must be a power of two, got {max_R}/{min_R}")
        if not 0.0 <= ema < 1.0:
            raise ValueError(f"ema must be in [0, 1), got {ema}")
        if hysteresis < 0.0:
            raise ValueError(f"hysteresis must be >= 0, got {hysteresis}")
        self.inner = inner
        self.min_R = min_R
        self.max_R = max_R
        self.target_snr = float(target_snr)
        self.ema = float(ema)
        self.hysteresis = float(hysteresis)
        self.ladder: tuple[int, ...] = tuple(
            min_R * 2 ** i for i in range((ratio).bit_length()))
        # one pre-built codec per bucket — ONE compiled branch each, switched
        # host-side; clamp_R rebuilds chained specs, max bucket is `inner`
        self.buckets = {R: (inner if R == max_R else clamp_R(inner, R))
                        for R in self.ladder}
        self._R = min_R               # start conservative, ramp up on headroom
        self._pinned: int | None = None
        self._ema_snr: float | None = None

    # ---- controller ------------------------------------------------------

    @property
    def current_R(self) -> int:
        return self._R

    @property
    def current(self):
        """The currently selected bucket codec."""
        return self.buckets[self._R]

    @property
    def ema_snr(self) -> float | None:
        return self._ema_snr

    def pin(self, R: int) -> "AdaptiveC3SL":
        """Fix the schedule to a constant R (e.g. for equivalence tests or an
        externally driven controller).  Returns self for chaining."""
        if R not in self.buckets:
            raise ValueError(f"R={R} not in bucket ladder {self.ladder}")
        self._pinned = self._R = R
        return self

    def unpin(self) -> "AdaptiveC3SL":
        self._pinned = None
        return self

    def observe(self, snr_db=None, loss_slack=None) -> int:
        """Feed the controller one step's signals; returns the R to use NEXT.

        ``snr_db`` — measured retrieval SNR at the cut layer (see
        ``repro.core.hrr.retrieval_snr``); folded into the EMA.
        ``loss_slack`` — optional task-loss budget signal: negative (loss
        over budget) forces a ramp-down and positive is required for a
        ramp-up when provided.
        """
        if snr_db is not None:
            snr = float(snr_db)
            self._ema_snr = (snr if self._ema_snr is None
                             else self.ema * self._ema_snr
                             + (1.0 - self.ema) * snr)
        if self._pinned is not None:
            return self._R
        i = self.ladder.index(self._R)
        if loss_slack is not None and loss_slack < 0.0:
            self._R = self.ladder[max(i - 1, 0)]
        elif self._ema_snr is not None:
            if (self._ema_snr > self.target_snr + self.hysteresis
                    and i + 1 < len(self.ladder)
                    and (loss_slack is None or loss_slack > 0.0)):
                self._R = self.ladder[i + 1]
            elif (self._ema_snr < self.target_snr - self.hysteresis
                    and i > 0):
                self._R = self.ladder[i - 1]
        return self._R

    # ---- codec protocol (delegates to the CURRENT bucket) ----------------

    @property
    def feature_layout(self) -> str:
        return self.inner.feature_layout

    @property
    def R(self) -> int:
        return self._R

    @property
    def D(self) -> int:
        return self.inner.D

    @property
    def stages(self):
        """Wire stages of the current bucket (so shape-based accounting like
        ``payload_wire_bytes`` sees the chain through the wrapper)."""
        return getattr(self.current, "stages", ())

    def init(self, rng=None):
        """Params for EVERY bucket, keyed ``R<k>``.  Each bucket inits from
        the SAME rng, so bucket k's params are bit-identical to the static
        ``c3sl:R=k`` codec initialized with that rng (the equivalence the
        test suite pins)."""
        return {bucket_key(R): c.init(rng) for R, c in self.buckets.items()}

    def params_for(self, params, R: int | None = None):
        """Slice one bucket's params out of the ``init`` pytree."""
        return params[bucket_key(self._R if R is None else R)]

    def encode(self, params, Z):
        return self.current.encode(self.params_for(params), Z)

    def decode(self, params, payload):
        return self.current.decode(self.params_for(params), payload)

    def decode_masked(self, params, payload, keep):
        return self.current.decode_masked(self.params_for(params),
                                          payload, keep)

    def param_count(self) -> int:
        return sum(c.param_count() for c in self.buckets.values())

    def flops(self, B: int) -> int:
        return self.current.flops(B)

    def wire_bytes(self, B: int) -> int:
        return self.current.wire_bytes(B)

    def payload_shape(self, B: int) -> tuple[int, ...]:
        return self.current.payload_shape(B)

    def spec(self) -> str:
        inner_stages = self.inner.spec().split("|")
        extra = ",".join(
            f"{k}={_format_value(getattr(self, k))}"
            for k, default in _ADAPTIVE_DEFAULTS.items()
            if getattr(self, k) != default)
        head = inner_stages[0]
        if extra:
            head = head + ("," if ":" in head else ":") + extra
        return "adaptive:" + "|".join([head] + inner_stages[1:])

    def __repr__(self) -> str:
        return (f"AdaptiveC3SL({self.spec()!r}, ladder={self.ladder}, "
                f"current_R={self._R}"
                f"{', pinned' if self._pinned is not None else ''})")

    # ---- clamp_R integration --------------------------------------------

    def with_max_R(self, max_R: int) -> "AdaptiveC3SL":
        """``clamp_R`` entry point: shrink the ladder to buckets that FIT
        ``max_R``.

        Callers pass the runtime batch / slot count as ``max_R``, and
        batch-wise grouping needs ``max_R % R == 0`` — so a bucket fits only
        if it DIVIDES max_R, not merely stays below it (batch 12 must drop
        the R=8 bucket, or the controller would ramp into a mid-training
        shape error).  The surviving buckets keep the power-of-two ladder
        valid; if none fit, the ladder collapses to the single clamped
        bucket (max_R itself, which trivially divides)."""
        if self.max_R <= max_R and all(max_R % r == 0 for r in self.ladder):
            return self
        cands = [r for r in self.ladder if r <= max_R and max_R % r == 0]
        # any surviving cand is a power-of-two multiple of min_R that divides
        # max_R, so min_R itself survives too and the ladder stays valid; an
        # empty cands collapses to the single bucket max_R (min == max)
        new_max = max(cands) if cands else max(max_R, 1)
        new_min = self.min_R if cands else new_max
        return AdaptiveC3SL(clamp_R(self.inner, new_max), min_R=new_min,
                            target_snr=self.target_snr, ema=self.ema,
                            hysteresis=self.hysteresis)


def build_adaptive(spec: str, /, **defaults) -> AdaptiveC3SL:
    """Build an ``AdaptiveC3SL`` from an ``adaptive:...`` spec string.

    The text after ``adaptive:`` is parsed as a normal spec; adaptive args
    (``min_R``/``target_snr``/``ema``/``hysteresis``) are extracted from the
    first stage and the remainder builds the inner (max-R) codec through the
    registry — so defaults like ``D=...`` flow through, and later ``|``
    stages become the inner ``Chain``'s wire formats.  ``defaults`` may also
    carry adaptive args a spec omits (explicit spec args win).
    """
    name, sep, body = spec.strip().partition(":")
    if name != "adaptive":
        raise ValueError(f"not an adaptive spec: {spec!r}")
    if not sep or not body.strip():
        raise ValueError(
            "adaptive needs an inner codec spec, e.g. "
            "'adaptive:c3sl:R=16,min_R=2,target_snr=12'")
    stages = parse_spec(body)
    head_args = dict(stages[0].args)
    kwargs = {k: head_args.pop(k) for k in list(head_args)
              if k in _ADAPTIVE_DEFAULTS}
    for k in _ADAPTIVE_DEFAULTS:
        if k not in kwargs and defaults.get(k) is not None and k in defaults:
            kwargs[k] = defaults[k]
    inner_spec = "|".join(
        str(s) for s in [CodecSpec(stages[0].name, head_args)] + stages[1:])
    inner_defaults = {k: v for k, v in defaults.items()
                      if k not in _ADAPTIVE_DEFAULTS}
    return AdaptiveC3SL(build(inner_spec, **inner_defaults), **kwargs)


def program_key(codec):
    """The host-side dispatch key for the NEXT compiled dispatch: the
    adaptive codec's current R bucket, or None for a static (or absent)
    codec.  Pair with :func:`build_program_table`."""
    return codec.current_R if isinstance(codec, AdaptiveC3SL) else None


def build_program_table(codec, codec_params, make):
    """One compiled-program entry per schedulable bucket.

    ``make(codec, codec_params)`` builds whatever the caller compiles for a
    SINGLE static codec (a jitted step, a dict of programs, ...).  For an
    ``AdaptiveC3SL`` the table maps every ladder bucket's R to
    ``make(bucket, bucket_params)`` — each its own compiled branch, so
    host-side R switches never retrace; for a static codec (or None) the
    table is the single entry ``{None: make(codec, codec_params)}``.  Index
    the result with :func:`program_key` at dispatch time.  This is the ONLY
    supported way to put an adaptive codec behind jit: closing the wrapper
    itself over a traced function silently bakes in whatever bucket was
    current at trace time.
    """
    if isinstance(codec, AdaptiveC3SL):
        return {R: make(codec.buckets[R],
                        codec.params_for(codec_params, R)
                        if codec_params is not None else None)
                for R in codec.ladder}
    return {None: make(codec, codec_params)}


def chunk_payload_shape(codec, num_rows: int, chunk: int) -> tuple[int, ...]:
    """Payload shape ``sequence_group_encode`` ships for a prefill chunk of
    ``chunk`` positions across ``num_rows`` slots — 3-D sequence-grouped
    ``(chunk, rows/R, D)`` when rows divide by R, else the flat wrap-around
    form.  Mirrors ``repro.codecs.c3sl.sequence_group_encode`` so byte
    accounting can run host-side without materializing a payload."""
    R = getattr(codec, "R", 1)
    D = codec.D
    if num_rows % R == 0:
        return (chunk, num_rows // R, D)
    return ((chunk * num_rows) // R, D)
