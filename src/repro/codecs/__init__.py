"""repro.codecs — the unified boundary-codec API for split learning.

Every codec is a drop-in module at the cut layer implementing the
``Codec`` protocol (see ``repro.codecs.base``):

    params  = codec.init(rng)                 # pytree ({} for stateless)
    payload = codec.encode(params, Z)         # what crosses the wire
    Zhat    = codec.decode(params, payload)   # reconstruction

    codec.param_count()                       # codec parameters
    codec.flops(B)                            # codec FLOPs per batch
    codec.wire_bytes(B)                       # bytes/direction/step
    codec.payload_shape(B)                    # wire tensor shape
    codec.feature_layout                      # "flat" (B, D) | "nchw"
    codec.spec()                              # canonical spec string

Spec grammar
============

Codecs are buildable from strings through the registry::

    SPEC  := STAGE ("|" STAGE)*
    STAGE := NAME [":" KEY "=" VALUE ("," KEY "=" VALUE)*]

The first stage names a registered *transform* codec; every later stage
names a registered *wire format* applied to the transform's payload
(straight-through, fake-quant style).  Values parse as int, float, bool
("true"/"false"), or string.  ``build(spec, **defaults)`` fills fields the
spec omits from keyword defaults (runtime dims such as ``D``); explicit
spec args always win.

Registered transforms:
    identity                  — vanilla SL.              args: D
    c3sl     (alias: hrr)     — the paper's HRR codec.   args: R, D,
                                backend=fft|direct|pallas, unitary, key_seed
    dense    (alias: dense-bottleneck)
                              — linear autoencoder.      args: R, D
    bnpp     (alias: bottlenetpp)
                              — BottleNet++ conv codec.  args: R, C, H, W, k

Registered wire stages:
    int8  — per-row absmax int8 STE quant.
    topk  — magnitude top-k, mask-encoded indices.  args: k | ratio
    noop  — f32 passthrough.

An ``adaptive:`` prefix wraps the rest of the spec in the Adaptive-R
scheduler (``repro.codecs.adaptive``): one pre-built inner codec per
bucket of a {min_R, ..., R} ladder, switched host-side from an EMA of the
measured retrieval SNR.  Adaptive args (``min_R``, ``target_snr``,
``ema``, ``hysteresis``) ride in the first stage's arg list.

Examples::

    build("c3sl:R=8,backend=fft|int8", D=4096)   # paper codec + int8 wire
    build("c3sl:R=4,D=256").spec()               # -> "c3sl:R=4,D=256"
    build("bnpp:R=4,C=64,H=8,W=8")               # BottleNet++ baseline
    build("c3sl:R=4|topk:ratio=0.1", D=4096)     # HRR + sparsified wire
    build("adaptive:c3sl:R=16,min_R=2,target_snr=12|int8", D=4096)

``repro.core.codec`` and ``repro.core.bottlenet`` remain as thin
re-export shims for pre-registry imports.
"""
from repro.codecs.adaptive import (AdaptiveC3SL, build_adaptive,
                                   build_program_table, chunk_payload_shape,
                                   program_key)
from repro.codecs.base import (Codec, CodecSpec, WireStage, apply_quant_bits,
                               available, build, clamp_R, parse_spec, register)
from repro.codecs.bottleneck import BottleNetPPCodec, DenseBottleneckCodec
from repro.codecs.c3sl import (C3SLCodec, sequence_group_decode,
                               sequence_group_encode)
from repro.codecs.compose import Chain, payload_wire_bytes
from repro.codecs.identity import IdentityCodec
from repro.codecs.wire import Int8STEQuant, NoOpWire, TopKSparsify

__all__ = [
    "Codec", "CodecSpec", "WireStage", "apply_quant_bits", "available",
    "build", "clamp_R", "parse_spec", "register",
    "IdentityCodec", "C3SLCodec", "DenseBottleneckCodec", "BottleNetPPCodec",
    "AdaptiveC3SL", "build_adaptive", "build_program_table",
    "chunk_payload_shape", "program_key",
    "Chain", "Int8STEQuant", "TopKSparsify", "NoOpWire", "payload_wire_bytes",
    "sequence_group_encode", "sequence_group_decode",
]
