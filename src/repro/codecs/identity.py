"""The uncompressed baseline codec (vanilla split learning)."""
from __future__ import annotations

import dataclasses

from repro.codecs.base import SpecMixin, register


@register("identity")
@dataclasses.dataclass(frozen=True)
class IdentityCodec(SpecMixin):
    """Vanilla SL — features cross the wire untouched, f32."""
    D: int

    feature_layout = "flat"
    R = 1

    def init(self, rng=None):
        return {}

    def encode(self, params, Z):
        return Z

    def decode(self, params, payload):
        return payload

    def param_count(self) -> int:
        return 0

    def flops(self, B: int) -> int:
        return 0

    def payload_shape(self, B: int) -> tuple[int, ...]:
        return (B, self.D)

    def wire_bytes(self, B: int) -> int:
        return B * self.D * 4
