"""Trainable bottleneck codecs (the paper's dimension-wise baselines).

* ``BottleNetPPCodec`` — BottleNet++ (Shao & Zhang 2020), paper-faithful
  conv autoencoder on (B, C, H, W) cut-layer feature maps
  (``feature_layout = "nchw"``).
* ``DenseBottleneckCodec`` — the same idea for flattened (B, D) features,
  used for iso-interface comparisons on transformer cut layers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.codecs.base import SpecMixin, register


@register("dense", "dense-bottleneck")
@dataclasses.dataclass(frozen=True)
class DenseBottleneckCodec(SpecMixin):
    """BottleNet++-style trainable autoencoder on flattened features.

    encoder: Linear(D -> D/R) + sigmoid;  decoder: Linear(D/R -> D) + ReLU.
    """
    R: int
    D: int

    feature_layout = "flat"
    #: params take gradients in normal training (vs C3-SL's fixed keys) —
    #: surfaces like the transport layer's gradient seam, which cannot
    #: backprop into codec params, check this to fail loudly
    trainable = True

    def __post_init__(self):
        if self.D % self.R:
            raise ValueError("D must be divisible by R")

    @property
    def d_code(self) -> int:
        return self.D // self.R

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        s_in = self.D ** -0.5
        s_code = self.d_code ** -0.5
        return {
            "w_enc": jax.random.normal(k1, (self.D, self.d_code)) * s_in,
            "b_enc": jnp.zeros((self.d_code,)),
            "w_dec": jax.random.normal(k2, (self.d_code, self.D)) * s_code,
            "b_dec": jnp.zeros((self.D,)),
        }

    def encode(self, params, Z):
        return jax.nn.sigmoid(Z @ params["w_enc"] + params["b_enc"])

    def decode(self, params, payload):
        return jax.nn.relu(payload @ params["w_dec"] + params["b_dec"])

    def param_count(self) -> int:
        return (self.D + 1) * self.d_code + (self.d_code + 1) * self.D

    def flops(self, B: int) -> int:
        return 2 * B * 2 * self.D * self.d_code  # enc + dec matmuls (MAC*2)

    def payload_shape(self, B: int) -> tuple[int, ...]:
        return (B, self.d_code)

    def wire_bytes(self, B: int) -> int:
        return B * self.d_code * 4


def _batchnorm(x: jax.Array, scale, bias, axis=(0, 2, 3), eps=1e-5):
    mean = x.mean(axis=axis, keepdims=True)
    var = x.var(axis=axis, keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    return xn * scale[None, :, None, None] + bias[None, :, None, None]


@register("bnpp", "bottlenetpp")
@dataclasses.dataclass(frozen=True)
class BottleNetPPCodec(SpecMixin):
    """Paper-faithful conv codec on (B, C, H, W) cut-layer feature maps.

    encoder: Conv(k=2, stride=2, C -> C' = 4C/R) + BatchNorm + sigmoid
    decoder: ConvTranspose(k=2, stride=2, C' -> C) + BatchNorm + ReLU
    (channel-condition layers removed, as in C3-SL Sec. 4.1).

    Total compression R = (C*H*W) / (C'*(H/2)*(W/2)) = 4C/C'  =>  C' = 4C/R.
    param_count() and flops(B) implement C3-SL Table 2's formulas verbatim.
    """
    R: int
    C: int
    H: int
    W: int
    k: int = 2  # kernel size and stride, per C3-SL Sec. 4.1

    feature_layout = "nchw"
    trainable = True  # see DenseBottleneckCodec

    def __post_init__(self):
        if (4 * self.C) % self.R:
            raise ValueError("4C must be divisible by R")

    @property
    def c_code(self) -> int:
        return 4 * self.C // self.R

    @property
    def D(self) -> int:
        return self.C * self.H * self.W

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        Cp, k = self.c_code, self.k
        fan_in_e = self.C * k * k
        fan_in_d = Cp * k * k
        return {
            "w_enc": jax.random.normal(k1, (Cp, self.C, k, k)) * fan_in_e ** -0.5,
            "b_enc": jnp.zeros((Cp,)),
            "bn_enc_scale": jnp.ones((Cp,)),
            "bn_enc_bias": jnp.zeros((Cp,)),
            "w_dec": jax.random.normal(k2, (Cp, self.C, k, k)) * fan_in_d ** -0.5,
            "b_dec": jnp.zeros((self.C,)),
            "bn_dec_scale": jnp.ones((self.C,)),
            "bn_dec_bias": jnp.zeros((self.C,)),
        }

    def encode(self, params, Z):
        """Z (B, C, H, W) -> payload (B, C', H/2, W/2)."""
        y = jax.lax.conv_general_dilated(
            Z, params["w_enc"], window_strides=(self.k, self.k), padding="VALID",
            dimension_numbers=("NCHW", "OIHW", "NCHW"))
        y = y + params["b_enc"][None, :, None, None]
        y = _batchnorm(y, params["bn_enc_scale"], params["bn_enc_bias"])
        return jax.nn.sigmoid(y)

    def decode(self, params, payload):
        """payload (B, C', H/2, W/2) -> (B, C, H, W)."""
        y = jax.lax.conv_transpose(
            payload, params["w_dec"], strides=(self.k, self.k), padding="VALID",
            dimension_numbers=("NCHW", "IOHW", "NCHW"))
        y = y + params["b_dec"][None, :, None, None]
        y = _batchnorm(y, params["bn_dec_scale"], params["bn_dec_bias"])
        return jax.nn.relu(y)

    # ---- paper Table 2 accounting (BN params excluded, as in the paper) ----

    def param_count(self) -> int:
        C, k, R = self.C, self.k, self.R
        return (C * k * k + 1) * (4 * C // R) + ((4 * C // R) * k * k + 1) * C

    def flops(self, B: int) -> int:
        C, k, R, H, W = self.C, self.k, self.R, self.H, self.W
        Hp, Wp = H // self.k, W // self.k
        enc = B * (2 * C * k * k + 1) * (4 * C // R) * Hp * Wp
        dec = B * ((8 * C // R) * k * k + 1) * C * H * W
        return enc + dec

    def payload_shape(self, B: int) -> tuple[int, ...]:
        return (B, self.c_code, self.H // self.k, self.W // self.k)

    def wire_bytes(self, B: int) -> int:
        return B * self.c_code * (self.H // self.k) * (self.W // self.k) * 4
