"""Standalone wire-format stages for the SL boundary.

A wire stage changes how a payload is *represented on the wire* — not its
shape or the codec math.  Each stage's ``apply`` runs in-graph as a
straight-through round-trip (fake-quant style: forward applies the lossy
representation, backward passes the gradient unchanged), so stages chain
behind any transform codec via ``repro.codecs.compose.Chain`` / build specs
like ``"c3sl:R=8|int8"``.

Byte accounting takes the transform's ``payload_shape(B)``; FLOP accounting
follows the paper's convention of counting only MAC-dominated work, so the
elementwise stages here report 0 (matching the old inlined ``quant_bits=8``
numbers exactly).

Implemented stages:
  * Int8STEQuant  — per-row absmax int8 fake-quant (f32 scale per row).
  * TopKSparsify  — magnitude top-k per row, mask-encoded indices on the
                    wire (1 bit/position + k f32 values), as in
                    mask-encoded sparsification (Zhou et al., 2024).
  * NoOpWire      — f32 passthrough (accounting baseline).
"""
from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.codecs.base import SpecMixin, register


def _rows(shape: tuple[int, ...]) -> int:
    """Quantization rows of a payload: everything but the trailing axis.

    Must agree with how the stages APPLY — scale/mask granularity is
    axis=-1 — for payloads of ANY rank: the decode path ships 2-D
    (B/R, D), chunked prefill ships the 3-D sequence-grouped layout
    (C, B/R, D) whose row count is C * B/R, not B/R.  Pinned against the
    runtime representation in tests/test_wire_accounting.py.
    """
    return math.prod(shape[:-1]) if len(shape) > 1 else 1


# --------------------------------------------------------------------------
# straight-through int8 fake-quant
# --------------------------------------------------------------------------

@jax.custom_vjp
def ste_quant_int8(x: jax.Array) -> jax.Array:
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.round(x / scale).astype(jnp.int8)
    return q.astype(x.dtype) * scale


def _steq_fwd(x):
    return ste_quant_int8(x), None


def _steq_bwd(_, g):
    return (g,)


ste_quant_int8.defvjp(_steq_fwd, _steq_bwd)


@register("int8", kind="wire")
@dataclasses.dataclass(frozen=True)
class Int8STEQuant(SpecMixin):
    """Per-row absmax int8 wire format with a straight-through estimator."""

    def apply(self, payload):
        return ste_quant_int8(payload)

    def param_count(self) -> int:
        return 0

    def flops(self, shape: tuple[int, ...]) -> int:
        return 0  # elementwise; excluded by the paper's MAC accounting

    def wire_bytes(self, shape: tuple[int, ...]) -> int:
        # 1 byte per value + one f32 scale per row
        return math.prod(shape) + 4 * _rows(shape)


# --------------------------------------------------------------------------
# straight-through top-k sparsification (mask-encoded indices)
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_topk(x: jax.Array, k: int) -> jax.Array:
    # exact-k scatter mask (a >= kth-magnitude threshold would keep every
    # tied value and break the k-values-per-row wire accounting)
    D = x.shape[-1]
    flat = x.reshape(-1, D)
    idx = jax.lax.top_k(jnp.abs(flat), k)[1]
    rows = jnp.arange(flat.shape[0])[:, None]
    mask = jnp.zeros(flat.shape, bool).at[rows, idx].set(True)
    return jnp.where(mask, flat, 0).reshape(x.shape)


def _topk_fwd(x, k):
    return ste_topk(x, k), None


def _topk_bwd(k, _, g):
    return (g,)


ste_topk.defvjp(_topk_fwd, _topk_bwd)


@register("topk", kind="wire")
@dataclasses.dataclass(frozen=True)
class TopKSparsify(SpecMixin):
    """Keep the top-k magnitudes per row; gradient is straight-through.

    On the wire the kept positions are mask-encoded — a D-bit mask per row
    plus the k surviving f32 values — instead of 32-bit indices, so the
    format wins whenever k < D * (31/32) / 8.  Give either an absolute
    ``k`` or a ``ratio`` of the row dim (k wins when both are set).
    """
    k: int = 0
    ratio: float = 0.25

    def __post_init__(self):
        if self.k < 0:
            raise ValueError(f"k must be >= 0, got {self.k}")
        if self.k == 0 and not 0.0 < self.ratio <= 1.0:
            raise ValueError(f"ratio must be in (0, 1], got {self.ratio}")

    def _k_for(self, D: int) -> int:
        k = self.k if self.k else max(1, int(round(self.ratio * D)))
        return min(k, D)

    def apply(self, payload):
        return ste_topk(payload, self._k_for(payload.shape[-1]))

    def param_count(self) -> int:
        return 0

    def flops(self, shape: tuple[int, ...]) -> int:
        return 0  # comparison-dominated; excluded by the MAC accounting

    def wire_bytes(self, shape: tuple[int, ...]) -> int:
        D = shape[-1]
        k = self._k_for(D)
        mask_bytes = (D + 7) // 8
        return _rows(shape) * (mask_bytes + 4 * k)


@register("noop", kind="wire")
@dataclasses.dataclass(frozen=True)
class NoOpWire(SpecMixin):
    """f32 passthrough — the accounting baseline for wire formats."""

    def apply(self, payload):
        return payload

    def param_count(self) -> int:
        return 0

    def flops(self, shape: tuple[int, ...]) -> int:
        return 0

    def wire_bytes(self, shape: tuple[int, ...]) -> int:
        return math.prod(shape) * 4
