from repro.sharding.rules import (batch_spec, cache_shardings, param_shardings,
                                  opt_state_shardings, spec_for_param)
