"""Divisibility-safe partition rules for every param/cache/batch tensor.

Name-based rules produce a PartitionSpec for the *trailing* dims of each
leaf; leading stack axes (superblocks, pipeline stages) are padded with
None.  Every axis assignment is guarded: if the dim is not divisible by the
mesh axis size, it falls back to replication — so every (arch x shape x
mesh) combination lowers instead of erroring (the rule engine's contract
with the dry-run).

Modes:
  train  — params: tensor-parallel over "model"; optimizer state
           additionally ZeRO-1-sharded over "data" on the largest
           still-replicated dim.
  decode — params fully sharded (model rules + "data" on another dim,
           FSDP-style); caches: batch over "data", long axes over "model".
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _dims(mesh, axis) -> int:
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _guard(spec: P, shape, mesh) -> P:
    """Replicate any spec entry whose dim is not divisible by its axes."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, entries):
        if ax is None:
            out.append(None)
        elif dim % _dims(mesh, ax) == 0 and dim >= _dims(mesh, ax):
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


# rule: (path regex, trailing spec) — first match wins.  The spec applies to
# the LAST len(spec) dims of the leaf.
_PARAM_RULES: list[tuple[str, tuple]] = [
    # --- embeddings / head -------------------------------------------------
    (r"/embed$",               ("model", None)),
    (r"/head$",                (None, "model")),
    (r"frontend_proj$",        (None, "model")),
    # --- MoE (expert parallelism over the E axis) ---------------------------
    (r"/router$",              (None, None)),
    (r"moe/w_(gate|up|down)$", ("model", None, None)),
    (r"moe/shared/w_(gate|up)$", (None, "model")),
    (r"moe/shared/w_down$",    ("model", None)),
    # --- MLA ----------------------------------------------------------------
    (r"mla/w_q$",              (None, "model")),
    (r"mla/w_dkv$",            (None, None)),
    (r"mla/w_uk$",             (None, "model")),
    (r"mla/w_uv$",             (None, "model")),
    (r"mla/w_kpe$",            (None, None)),
    (r"mla/w_o$",              ("model", None)),
    # --- RWKV ----------------------------------------------------------------
    (r"rwkv_tm/w_(r|k|v|g)$",  (None, "model")),
    (r"rwkv_tm/w_o$",          ("model", None)),
    (r"rwkv_tm/w_dec_a$",      (None, None)),
    (r"rwkv_tm/w_dec_b$",      (None, "model")),
    (r"rwkv_tm/(w0|ln_scale)$", ("model",)),
    (r"rwkv_tm/u$",            ("model", None)),
    (r"rwkv_cm/w_k$",          (None, "model")),
    (r"rwkv_cm/w_v$",          ("model", None)),
    (r"rwkv_cm/w_r$",          (None, "model")),
    # --- Mamba ----------------------------------------------------------------
    (r"mamba/w_in$",           (None, "model")),
    (r"mamba/conv_w$",         (None, "model")),
    (r"mamba/conv_b$",         ("model",)),
    (r"mamba/w_x$",            ("model", None)),
    (r"mamba/w_dt$",           (None, "model")),
    (r"mamba/dt_bias$",        ("model",)),
    (r"mamba/A_log$",          ("model", None)),
    (r"mamba/D$",              ("model",)),
    (r"mamba/w_out$",          ("model", None)),
    # --- attention (GQA + cross) ----------------------------------------------
    (r"/w_q$",                 (None, "model")),
    (r"/w_k$",                 (None, "model")),
    (r"/w_v$",                 (None, "model")),
    (r"/w_o$",                 ("model", None)),
    (r"/b_(q|k|v)$",           ("model",)),
    # --- MLPs -------------------------------------------------------------------
    (r"/w_(gate|up)$",         (None, "model")),
    (r"/w_down$",              ("model", None)),
    # --- norms, biases, scalars, codec keys, convnets: replicate ---------------
    (r".*",                    ()),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/" + "/".join(parts)


def spec_for_param(path_str: str, shape, mesh) -> P:
    for pat, trailing in _PARAM_RULES:
        if re.search(pat, path_str):
            pad = (None,) * (len(shape) - len(trailing))
            return _guard(P(*(pad + tuple(trailing))), shape, mesh)
    return P()


def _extend_over(spec: P, shape, mesh, axis: str, min_size: int = 1) -> P:
    """Shard the largest still-replicated dim over `axis` (ZeRO/FSDP)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    ax_size = _dims(mesh, axis)
    best, best_dim = -1, -1
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % ax_size == 0 and dim >= max(ax_size, min_size) \
                and dim > best_dim:
            best, best_dim = i, dim
    if best >= 0:
        entries[best] = axis
    return P(*entries)


def param_shardings(params, mesh, mode: str = "train"):
    """NamedShardings for a param pytree."""
    data_axis = "data"

    def one(path, leaf):
        ps = _path_str(path)
        spec = spec_for_param(ps, leaf.shape, mesh)
        # fully shard big tensors over data too (FSDP/ZeRO-3-style: XLA
        # all-gathers per layer inside the scan).  Without this, a 123B
        # model's bf16 params alone (246GB/16 model shards) overflow HBM.
        spec = _extend_over(spec, leaf.shape, mesh, data_axis, min_size=1024)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_shardings(opt_state, mesh):
    """m/v mirror the param specs + ZeRO-1 over data; scalars replicated."""

    def one(path, leaf):
        ps = _path_str(path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = spec_for_param(ps, leaf.shape, mesh)
        spec = _extend_over(spec, leaf.shape, mesh, "data", min_size=1024)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, opt_state)


def batch_spec(mesh, multi_pod_data: bool = True) -> P:
    """Batch-dim sharding: over (pod, data) when the mesh has a pod axis."""
    axes = tuple(mesh.axis_names)
    if "pod" in axes and multi_pod_data:
        return P(("pod", "data"))
    return P("data")


def batch_shardings(batch, mesh, multi_pod_data: bool = True):
    bspec = batch_spec(mesh, multi_pod_data)

    def one(leaf):
        spec = _guard(P(*(tuple(bspec) + (None,) * (len(leaf.shape) - 1))),
                      leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(one, batch)


# --- decode caches -----------------------------------------------------------

_CACHE_RULES: list[tuple[str, tuple]] = [
    # attn KV cache (N, B, T, KV, hd): batch over data, time over model
    (r"/(k|v)$",       ("data", "model", None, None)),
    (r"/(k|v)_scale$", ("data", "model", None, None)),
    # MLA compressed cache (N, B, T, L)
    (r"/c_kv$",        ("data", "model", None)),
    (r"/k_pe$",        ("data", "model", None)),
    # mamba state (N, B, di, ds) / conv (N, B, K-1, di)
    (r"/h$",           ("data", "model", None)),
    (r"/conv$",        ("data", None, "model")),
    # rwkv (N, B, H, hd, hd) / (N, B, d)
    (r"/wkv$",         ("data", "model", None, None)),
    (r"/x_prev$",      ("data", "model")),
    # encoder memory (B, S, d)
    (r"/memory$",      ("data", None, "model")),
    (r".*",            ()),
]


def cache_shardings(cache, mesh):
    def one(path, leaf):
        ps = _path_str(path)
        for pat, trailing in _CACHE_RULES:
            if re.search(pat, ps):
                pad = (None,) * (len(leaf.shape) - len(trailing))
                spec = _guard(P(*(pad + tuple(trailing))), leaf.shape, mesh)
                return NamedSharding(mesh, spec)
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache)
