"""Guarded sharding-constraint helper usable inside model code.

`constrain(x, template)` applies jax.lax.with_sharding_constraint with the
given axis-name template (tuple entries may be None / "data" / "model" /
("pod","data")), but only when a mesh with those axes is active, each axis
is Auto, and the dim is divisible — so model code stays runnable on bare
CPU and inside partial-manual shard_map without special-casing.
"""
from __future__ import annotations

import jax


def constrain(x: jax.Array, template) -> jax.Array:
    try:
        am = jax.sharding.get_abstract_mesh()
    except Exception:
        return x
    if am is None or am.empty:
        return x
    from jax.sharding import AxisType, PartitionSpec as P
    auto = {n for n, t in zip(am.axis_names, am.axis_types)
            if t == AxisType.Auto}
    entries = []
    for dim, ax in zip(x.shape, tuple(template) + (None,) * (x.ndim - len(template))):
        if ax is None:
            entries.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        if not all(a in auto for a in axes):
            entries.append(None)
            continue
        size = 1
        for a in axes:
            size *= am.shape[a]
        entries.append(ax if (dim % size == 0 and dim >= size) else None)
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))
