"""Boundary codecs for split learning.

All codecs share one interface over flattened cut-layer features Z (B, D):

    params  = codec.init(rng)                      # pytree ("" for stateless)
    payload = codec.encode(params, Z)              # what crosses the wire
    Zhat    = codec.decode(params, payload)        # (B, D) again

plus analytic accounting used by the paper-repro benchmarks:

    codec.param_count()          trainable+fixed codec parameters
    codec.flops(B)               codec FLOPs per training batch (paper Table 2)
    codec.wire_bytes(B)          bytes on the wire per direction per step

Implemented codecs:
  * IdentityCodec       — vanilla SL (no compression).
  * C3SLCodec           — the paper: HRR bind+superpose / unbind, fixed keys.
                          Options: backend (fft | direct | pallas),
                          unitary keys (beyond-paper), int8 wire (beyond-paper).
  * DenseBottleneckCodec — BottleNet++-style trainable autoencoder for
                          flattened features (linear enc + sigmoid / dec + relu).
  (BottleNetPPCodec, the paper-faithful conv version for (B,C,H,W) feature
   maps, lives in repro/core/bottlenet.py.)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import hrr


# --------------------------------------------------------------------------
# straight-through int8 fake-quant (beyond-paper wire format)
# --------------------------------------------------------------------------

@jax.custom_vjp
def _ste_quant_int8(x: jax.Array) -> jax.Array:
    scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.round(x / scale).astype(jnp.int8)
    return q.astype(x.dtype) * scale


def _steq_fwd(x):
    return _ste_quant_int8(x), None


def _steq_bwd(_, g):
    return (g,)


_ste_quant_int8.defvjp(_steq_fwd, _steq_bwd)


@dataclasses.dataclass(frozen=True)
class IdentityCodec:
    """Vanilla SL — the uncompressed baseline."""
    D: int
    wire_dtype: Any = jnp.float32

    R = 1

    def init(self, rng):
        return {}

    def encode(self, params, Z):
        return Z

    def decode(self, params, payload):
        return payload

    def param_count(self) -> int:
        return 0

    def flops(self, B: int) -> int:
        return 0

    def wire_bytes(self, B: int) -> int:
        return B * self.D * jnp.dtype(self.wire_dtype).itemsize


@dataclasses.dataclass(frozen=True)
class C3SLCodec:
    """The paper's codec: fixed random keys, bind+superpose R features into one.

    Z (B, D) is grouped into B/R groups; each group becomes one D-vector.
    Keys are constants (stop_gradient inside the HRR ops) — param_count is
    the paper's R*D and flops(B) the paper's 2*B*D^2.
    """
    R: int
    D: int
    backend: str = "fft"
    unitary: bool = False          # beyond-paper: exact-rotation keys
    quant_bits: int | None = None  # beyond-paper: int8 wire format
    key_seed: int = 0

    def __post_init__(self):
        if self.quant_bits not in (None, 8):
            raise ValueError("only int8 wire quantization supported")

    def init(self, rng=None):
        rng = rng if rng is not None else jax.random.PRNGKey(self.key_seed)
        return {"keys": hrr.generate_keys(rng, self.R, self.D, unitary=self.unitary)}

    def _group(self, Z):
        B, D = Z.shape
        if D != self.D:
            raise ValueError(f"feature dim {D} != codec D={self.D}")
        if B % self.R:
            raise ValueError(f"batch {B} not divisible by R={self.R}")
        return Z.reshape(B // self.R, self.R, D)

    def encode(self, params, Z):
        S = hrr.bind_superpose(self._group(Z), params["keys"], backend=self.backend)
        if self.quant_bits == 8:
            S = _ste_quant_int8(S)
        return S

    def decode(self, params, payload):
        Zhat = hrr.unbind(payload, params["keys"], backend=self.backend)
        G, R, D = Zhat.shape
        return Zhat.reshape(G * R, D)

    def param_count(self) -> int:
        return self.R * self.D  # paper Table 2

    def flops(self, B: int) -> int:
        return 2 * B * self.D ** 2  # paper Table 2 (direct form; FFT path is B*D*log D)

    def wire_bytes(self, B: int) -> int:
        per_val = 1 if self.quant_bits == 8 else 4
        scales = 4 * (B // self.R) if self.quant_bits == 8 else 0
        return (B // self.R) * self.D * per_val + scales


@dataclasses.dataclass(frozen=True)
class DenseBottleneckCodec:
    """BottleNet++-style trainable autoencoder on flattened features.

    encoder: Linear(D -> D/R) + sigmoid;  decoder: Linear(D/R -> D) + ReLU.
    Used for iso-interface comparisons on transformer cut layers where the
    conv codec's (C, H, W) layout does not exist.
    """
    R: int
    D: int

    def __post_init__(self):
        if self.D % self.R:
            raise ValueError("D must be divisible by R")

    @property
    def d_code(self) -> int:
        return self.D // self.R

    def init(self, rng):
        k1, k2 = jax.random.split(rng)
        s_in = self.D ** -0.5
        s_code = self.d_code ** -0.5
        return {
            "w_enc": jax.random.normal(k1, (self.D, self.d_code)) * s_in,
            "b_enc": jnp.zeros((self.d_code,)),
            "w_dec": jax.random.normal(k2, (self.d_code, self.D)) * s_code,
            "b_dec": jnp.zeros((self.D,)),
        }

    def encode(self, params, Z):
        return jax.nn.sigmoid(Z @ params["w_enc"] + params["b_enc"])

    def decode(self, params, payload):
        return jax.nn.relu(payload @ params["w_dec"] + params["b_dec"])

    def param_count(self) -> int:
        return (self.D + 1) * self.d_code + (self.d_code + 1) * self.D

    def flops(self, B: int) -> int:
        return 2 * B * 2 * self.D * self.d_code  # enc + dec matmuls (MAC*2)

    def wire_bytes(self, B: int) -> int:
        return B * self.d_code * 4


def sequence_group_encode(codec: C3SLCodec, params, Z_bsd: jax.Array) -> jax.Array:
    """Beyond-paper: group along sequence blocks when batch==1 (long_500k).

    Z (B, S, D) with B*S divisible by R -> payload (B*S/R, D).
    """
    B, S, D = Z_bsd.shape
    return codec.encode(params, Z_bsd.reshape(B * S, D))


def sequence_group_decode(codec: C3SLCodec, params, payload: jax.Array,
                          B: int, S: int) -> jax.Array:
    return codec.decode(params, payload).reshape(B, S, -1)
