"""Thin re-export shim — the codec layer lives in ``repro.codecs`` now.

Old imports keep working:

    from repro.core.codec import C3SLCodec, IdentityCodec, ...

``C3SLCodec`` here is a compatibility factory: the historical
``quant_bits=8`` option is expressed in the new API as a composed wire
stage (``repro.codecs.build("c3sl:R=...|int8")``), so passing it returns a
``Chain`` with identical encode/decode behavior and accounting.  New code
should use ``repro.codecs`` directly.

Imports are lazy (module ``__getattr__``) because ``repro.core.__init__``
loads this shim while ``repro.codecs`` may itself be mid-import (its c3sl
module pulls in ``repro.core.hrr``).
"""
from __future__ import annotations

_EXPORTS = {
    "IdentityCodec": ("repro.codecs.identity", "IdentityCodec"),
    "DenseBottleneckCodec": ("repro.codecs.bottleneck", "DenseBottleneckCodec"),
    "Chain": ("repro.codecs.compose", "Chain"),
    "Int8STEQuant": ("repro.codecs.wire", "Int8STEQuant"),
    "_ste_quant_int8": ("repro.codecs.wire", "ste_quant_int8"),
    "sequence_group_encode": ("repro.codecs.c3sl", "sequence_group_encode"),
    "sequence_group_decode": ("repro.codecs.c3sl", "sequence_group_decode"),
}


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib
        mod, attr = _EXPORTS[name]
        return getattr(importlib.import_module(mod), attr)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def C3SLCodec(*, R: int, D: int, backend: str = "fft", unitary: bool = False,
              quant_bits: int | None = None, key_seed: int = 0):
    """Build the paper codec; ``quant_bits=8`` composes the int8 wire stage."""
    from repro.codecs.c3sl import C3SLCodec as _C3SLCodec
    from repro.codecs.compose import Chain
    from repro.codecs.wire import Int8STEQuant

    codec = _C3SLCodec(R=R, D=D, backend=backend, unitary=unitary,
                       key_seed=key_seed)
    if quant_bits is None:
        return codec
    if quant_bits != 8:
        raise ValueError("only int8 wire quantization supported")
    return Chain(codec, (Int8STEQuant(),))
