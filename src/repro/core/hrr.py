"""Holographic Reduced Representation (HRR) primitives for C3-SL.

Conventions (Plate 1995):
    circular convolution  (a (*) b)[d] = sum_j a[j] * b[(d - j) mod D]
    circular correlation  (a (.) b)[d] = sum_j a[j] * b[(d + j) mod D]

In the Fourier domain:  F(a (*) b) = F(a) . F(b),   F(a (.) b) = conj(F(a)) . F(b)

C3-SL encoder:  S^g = sum_i  K_i (*) Z_i^g          (bind + superpose)
C3-SL decoder:  Zhat_i^g = K_i (.) S^g              (unbind)

Keys K_i ~ N(0, 1/D), unit-normalized, FIXED (never trained) — the paper's
memory claim (R*D codec parameters) rests on this, so every op here wraps keys
in stop_gradient.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp


def _pallas_usable(D: int, op: str) -> bool:
    """Gate for ``backend="pallas"``: the Toeplitz-tiled kernel needs an
    MXU-alignable D (see repro.kernels.circconv.mxu_alignable).  For a
    prime/odd D the tile degrades to 1 and the kernel would be slower than
    the direct path — route to the fft backend instead, LOUDLY (a silent
    reroute would let benchmark rows masquerade as kernel numbers)."""
    from repro.kernels import circconv
    if circconv.mxu_alignable(D):
        return True
    warnings.warn(
        f"backend='pallas' {op}: D={D} is not MXU-alignable "
        f"(largest tile <= 128 is {circconv._pick_tile(D)}); falling back "
        f"to the fft backend.  Codec.execution_mode() reports "
        f"'fft-fallback' for this configuration.", stacklevel=3)
    return False


def generate_keys(rng: jax.Array, R: int, D: int, dtype=jnp.float32,
                  unitary: bool = False) -> jax.Array:
    """R fixed random keys, each D-dim, ~N(0, 1/D) then unit-normalized.

    unitary=False is the paper-faithful sampler.  Its retrieval noise has two
    parts (Eq. 4): self-noise from |F(K)|^2 ~ Exp(1) spectral jitter (~1.0
    relative) plus cross-talk (~sqrt(R-1) relative); training through the
    codec absorbs it.

    unitary=True is a beyond-paper improvement: project each key to unit
    spectral magnitude (|F(K)_f| = 1 for all f).  Binding becomes an exact
    rotation — self-retrieval is EXACT and only the sqrt(R-1) cross-talk
    remains, at identical memory/FLOP cost.
    """
    k = jax.random.normal(rng, (R, D), jnp.float32) * (D ** -0.5)
    if unitary:
        F = jnp.fft.fft(k, axis=-1)
        F = F / jnp.maximum(jnp.abs(F), 1e-12)
        k = jnp.fft.ifft(F, axis=-1).real
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    return k.astype(dtype)


# --------------------------------------------------------------------------
# FFT backend (beyond-paper O(D log D); XLA lowers FFT natively on TPU).
# --------------------------------------------------------------------------

def _fft_safe(x: jax.Array) -> jax.Array:
    """XLA:CPU's FFT thunk requires a row-major operand; a barrier stops
    layout assignment from propagating a transposed layout into the FFT
    (hit in the pod-pipeline path where the operand comes via ppermute)."""
    return jax.lax.optimization_barrier(x.astype(jnp.float32))


def circ_conv_fft(a: jax.Array, b: jax.Array) -> jax.Array:
    """Circular convolution along the last axis (leading dims broadcast)."""
    D = b.shape[-1]
    out_dtype = jnp.result_type(a.dtype, b.dtype)
    fa = jnp.fft.rfft(_fft_safe(a), axis=-1)
    fb = jnp.fft.rfft(_fft_safe(b), axis=-1)
    return jnp.fft.irfft(fa * fb, n=D, axis=-1).astype(out_dtype)


def circ_corr_fft(a: jax.Array, b: jax.Array) -> jax.Array:
    """Circular correlation along the last axis (leading dims broadcast)."""
    D = b.shape[-1]
    out_dtype = jnp.result_type(a.dtype, b.dtype)
    fa = jnp.fft.rfft(_fft_safe(a), axis=-1)
    fb = jnp.fft.rfft(_fft_safe(b), axis=-1)
    return jnp.fft.irfft(jnp.conj(fa) * fb, n=D, axis=-1).astype(out_dtype)


# --------------------------------------------------------------------------
# Direct backend (paper-faithful O(D^2) contraction; what the Pallas kernel
# implements with Toeplitz tiling on the MXU).
# --------------------------------------------------------------------------

def _conv_index(D: int) -> jax.Array:
    d = jnp.arange(D)
    return (d[:, None] - d[None, :]) % D  # idx[d, j] = (d - j) mod D


def _corr_index(D: int) -> jax.Array:
    d = jnp.arange(D)
    return (d[None, :] - d[:, None]) % D  # idx[d, m] = (m - d) mod D


def circ_conv_direct(a: jax.Array, b: jax.Array) -> jax.Array:
    D = b.shape[-1]
    mat = jnp.take(a, _conv_index(D), axis=-1)  # (..., D, D): a[(d-j) mod D]
    return jnp.einsum("...dj,...j->...d", mat, b)


def circ_corr_direct(a: jax.Array, b: jax.Array) -> jax.Array:
    D = b.shape[-1]
    mat = jnp.take(a, _corr_index(D), axis=-1)  # (..., D, D): a[(d+j) mod D]
    return jnp.einsum("...dj,...j->...d", mat, b)


# --------------------------------------------------------------------------
# Grouped encode / decode (the paper's Algorithm 1 inner loop, vectorized)
# --------------------------------------------------------------------------

def key_spectrum(K: jax.Array) -> jax.Array:
    """rfft(K) along the last axis — precompute once at codec init and pass
    as ``K_fft`` so the fft backend never re-transforms the fixed keys
    (forward OR custom-VJP backward; the keys' spectrum is half each op's
    FFT work otherwise)."""
    return jnp.fft.rfft(_fft_safe(K), axis=-1)


def _bind_impl(Z, K, KF, backend):
    if backend == "fft":
        # superpose in the Fourier domain: S = irfft(sum_i F(K_i) . F(Z_i)).
        # One irfft of (..., D) instead of R of them — fewer FFTs than the
        # naive form, and every FFT operand is a freshly materialized
        # contiguous tensor (XLA:CPU's FFT thunk requires row-major input).
        D = Z.shape[-1]
        dt = Z.dtype
        fk = KF if KF is not None else key_spectrum(K)
        fz = jnp.fft.rfft(_fft_safe(Z), axis=-1)
        return jnp.fft.irfft((fk * fz).sum(axis=-2), n=D, axis=-1).astype(dt)
    if backend == "direct":
        return circ_conv_direct(K, Z).sum(axis=-2)
    raise ValueError(f"unknown backend {backend!r}")


def _unbind_impl(S, K, KF, backend):
    if backend == "fft":
        D = S.shape[-1]
        dt = S.dtype
        fk = KF if KF is not None else key_spectrum(K)
        fs = jnp.fft.rfft(_fft_safe(S), axis=-1)
        prod = jnp.conj(fk) * fs[..., None, :]
        return jnp.fft.irfft(prod, n=D, axis=-1).astype(dt)
    if backend == "direct":
        return circ_corr_direct(K, S[..., None, :])
    raise ValueError(f"unknown backend {backend!r}")


# Custom VJPs: the codec is linear and its adjoints are again HRR ops with
# the same keys (adjoint of bind = unbind, and vice versa).  This (a) makes
# the compressed-gradient property explicit, and (b) routes the backward
# pass through the same layout-safe FFT wrappers as the forward (XLA:CPU's
# FFT thunk rejects non-row-major operands that autodiff-generated FFTs can
# otherwise receive).

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _bind_vjp(Z, K, KF, backend):
    return _bind_impl(Z, K, KF, backend)


def _bind_fwd(Z, K, KF, backend):
    return _bind_impl(Z, K, KF, backend), (K, KF)


def _bind_bwd(backend, res, dS):
    K, KF = res
    return _unbind_impl(dS, K, KF, backend), None, None


_bind_vjp.defvjp(_bind_fwd, _bind_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _unbind_vjp(S, K, KF, backend):
    return _unbind_impl(S, K, KF, backend)


def _unbind_fwd(S, K, KF, backend):
    return _unbind_impl(S, K, KF, backend), (K, KF)


def _unbind_bwd(backend, res, dZhat):
    K, KF = res
    return _bind_impl(dZhat, K, KF, backend), None, None


_unbind_vjp.defvjp(_unbind_fwd, _unbind_bwd)


def bind_superpose(Z: jax.Array, K: jax.Array, backend: str = "fft",
                   K_fft: jax.Array | None = None) -> jax.Array:
    """Encode a group: Z (..., R, D) + keys K (R, D) -> S (..., D).

    S = sum_i K_i (*) Z_i.  Keys take no gradient (paper Sec. 3.1).
    ``K_fft`` (from :func:`key_spectrum`) skips the keys' rfft in the fft
    backend — forward and backward both transform only activations.
    """
    K = jax.lax.stop_gradient(K)
    if backend == "pallas":
        if _pallas_usable(Z.shape[-1], "bind_superpose"):
            from repro.kernels import ops as kops
            return kops.bind_superpose_pallas(Z, K)
        backend = "fft"
    if K_fft is not None and backend == "fft":
        K_fft = jax.lax.stop_gradient(K_fft)
    else:
        K_fft = None
    return _bind_vjp(Z, K, K_fft, backend)


def unbind(S: jax.Array, K: jax.Array, backend: str = "fft",
           K_fft: jax.Array | None = None) -> jax.Array:
    """Decode a group: S (..., D) + keys K (R, D) -> Zhat (..., R, D).

    Zhat_i = K_i (.) S.  ``K_fft`` as in :func:`bind_superpose`.
    """
    K = jax.lax.stop_gradient(K)
    if backend == "pallas":
        if _pallas_usable(S.shape[-1], "unbind"):
            from repro.kernels import ops as kops
            return kops.unbind_pallas(S, K)
        backend = "fft"
    if K_fft is not None and backend == "fft":
        K_fft = jax.lax.stop_gradient(K_fft)
    else:
        K_fft = None
    return _unbind_vjp(S, K, K_fft, backend)


def masked_unbind(S: jax.Array, K: jax.Array, keep: jax.Array,
                  backend: str = "fft",
                  K_fft: jax.Array | None = None) -> jax.Array:
    """Erasure-aware decode: unbind ``S`` with elements marked 0 in
    ``keep`` treated as LOST, renormalizing each superposition row over
    its surviving elements.

    ``keep`` (same shape as S, 1.0 kept / 0.0 erased) zeroes the lost
    elements before correlation; the per-row scale ``D / #kept`` makes
    the retrieval unbiased under random erasure — each correlation lag
    sums over the kept elements only, so its expectation shrinks by
    ``#kept / D`` and the rescale restores it (the mask-encoded decode
    argument of sparse-payload codecs, applied to erasures).  Exact at
    an all-ones mask: ``S * 1.0`` and the scale ``D / D == 1.0`` are
    IEEE-exact, so the result is bitwise ``unbind(S, K)`` — the property
    the zero-fault bit-identity tests pin.
    """
    keep = keep.astype(S.dtype)
    D = S.shape[-1]
    kept = keep.sum(axis=-1, keepdims=True)            # (..., 1)
    scale = (jnp.float32(D) / jnp.maximum(kept, 1.0)).astype(S.dtype)
    Zhat = unbind(S * keep, K, backend=backend, K_fft=K_fft)
    # unbind adds the R axis before D: broadcast the per-row scale over it
    return Zhat * scale[..., None, :]


def retrieval_snr(Z: jax.Array, Zhat: jax.Array) -> jax.Array:
    """Signal-to-noise ratio (dB) of HRR retrieval — diagnostics for Eq. 4."""
    sig = jnp.sum(Z.astype(jnp.float32) ** 2)
    err = jnp.sum((Z.astype(jnp.float32) - Zhat.astype(jnp.float32)) ** 2)
    return 10.0 * jnp.log10(sig / jnp.maximum(err, 1e-12))
