from repro.core import codec, hrr, metrics, split  # noqa: F401
