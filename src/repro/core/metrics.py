"""Communication accounting + retrieval diagnostics for the SL boundary."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CommReport:
    """Per-training-step boundary traffic, both directions."""
    method: str
    R: int
    bytes_fwd: int
    bytes_bwd: int
    baseline_bytes: int

    @property
    def total(self) -> int:
        return self.bytes_fwd + self.bytes_bwd

    @property
    def compression(self) -> float:
        return self.baseline_bytes / max(self.total, 1)

    def row(self) -> str:
        return (f"{self.method:>14s} R={self.R:<3d} fwd={self.bytes_fwd:>12,d} B "
                f"bwd={self.bytes_bwd:>12,d} B  total={self.total:>13,d} B "
                f"({self.compression:5.2f}x vs vanilla)")


def comm_report(codec, B: int, D: int, method: str | None = None) -> CommReport:
    baseline = 2 * B * D * 4
    wire = codec.wire_bytes(B)
    return CommReport(
        method=method or type(codec).__name__,
        R=getattr(codec, "R", 1),
        bytes_fwd=wire,
        bytes_bwd=wire,
        baseline_bytes=baseline,
    )
