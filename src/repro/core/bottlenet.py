"""Thin re-export shim — BottleNet++ lives in ``repro.codecs.bottleneck``."""
from __future__ import annotations

from repro.codecs.bottleneck import BottleNetPPCodec, _batchnorm  # noqa: F401
