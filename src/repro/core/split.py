"""Split-learning step machinery.

Two execution modes, one codec interface:

1. logical split (`make_split_loss_fn`) — front/back halves live in the same
   program (single device or any mesh); the codec sits between them exactly
   like the paper's Algorithm 1.  Used for the paper reproduction, CPU tests
   and as the baseline single-program integration.

2. pod pipeline (`make_pod_pipeline_loss_fn`) — the production mapping: the
   mesh has a "pod" axis of size 2; stage 0 (the paper's *edge*) owns the
   front blocks, stage 1 (*cloud*) owns the back blocks.  Both pods run the
   same SPMD program (partial-manual `jax.shard_map` over the pod axis; data/
   model axes stay auto-partitioned).  The cut-layer payload crosses pods via
   `lax.ppermute`; because the whole step is differentiated, the backward
   `ppermute` carries the codec-compressed *gradient* — the paper's
   bidirectional communication saving falls out of the adjoint for free.
   Microbatching gives the classic GPipe M/(M+1) utilization: at step t,
   pod0 runs the front half on microbatch t while pod1 runs the back half on
   microbatch t-1.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

def apply_codec(codec, params, Z, *, with_snr=False):
    """Round-trip Z through a codec, preserving Z's shape.

    Dispatch is protocol-level via ``codec.feature_layout``: "nchw" codecs
    (BottleNet++) consume (B, C, H, W) natively; "flat" codecs work on
    flattened (B, D).  Wrapper codecs (e.g. the Adaptive-R scheduler) expose
    the same attribute, so they dispatch identically.

    ``with_snr=True`` additionally returns the retrieval SNR (dB) of the
    round-trip — the Adaptive-R controller's feedback signal.
    """
    if getattr(codec, "feature_layout", "flat") == "nchw":
        payload = codec.encode(params, Z)
        Zhat = codec.decode(params, payload)
    else:
        shape = Z.shape
        Zf = Z.reshape(shape[0], -1)
        payload = codec.encode(params, Zf)
        Zhat = codec.decode(params, payload).reshape(shape)
    if with_snr:
        from repro.core.hrr import retrieval_snr
        return Zhat, retrieval_snr(Z, Zhat)
    return Zhat


def make_split_loss_fn(front_apply: Callable, back_apply: Callable, codec,
                       loss_fn: Callable, with_metrics: bool = False) -> Callable:
    """Logical split: loss(params, batch) with the codec at the cut layer.

    params = {"front": ..., "back": ..., "codec": ...}
    batch  = {"x": ..., "y": ...}

    ``with_metrics=True`` makes the returned fn yield (loss, metrics) where
    metrics["cut_snr"] is the cut-layer retrieval SNR in dB — pair it with
    ``jax.value_and_grad(..., has_aux=True)`` to feed the Adaptive-R
    scheduler without a second forward pass.
    """

    def loss(params, batch):
        Z = front_apply(params["front"], batch["x"])
        if with_metrics:
            Zhat, snr = apply_codec(codec, params["codec"], Z, with_snr=True)
            logits = back_apply(params["back"], Zhat)
            return loss_fn(logits, batch["y"]), {"cut_snr": snr}
        Zhat = apply_codec(codec, params["codec"], Z)
        logits = back_apply(params["back"], Zhat)
        return loss_fn(logits, batch["y"])

    return loss


def split_comm_bytes(codec, B: int, directions: int = 2) -> int:
    """Wire bytes per step (activations up + gradients down)."""
    return directions * codec.wire_bytes(B)


# --------------------------------------------------------------------------
# Pod pipeline (2-stage GPipe over the "pod" mesh axis, compressed channel)
# --------------------------------------------------------------------------

def make_pod_pipeline_loss_fn(
    embed_fn: Callable,        # (embed_params, x_mb) -> h (mb, S, E)
    stage_fn: Callable,        # (stage_blocks, h) -> h  (one stage's blocks; same fn both stages)
    head_loss_fn: Callable,    # (head_params, h, y_mb) -> scalar mean loss
    codec,                     # flattened-feature codec (C3SL / Identity / Dense)
    mesh,
    num_microbatches: int = 1,
) -> Callable:
    """Returns loss(params, batch) implementing the 2-stage compressed pipeline.

    params = {"embed", "blocks" (leading stage axis 2, sharded P("pod")),
              "head", "codec"}.
    batch  = {"x": (B, S) or (B, S, E_in), "y": (B, S)} — replicated over pod,
             sharded over data on the batch dim by the caller.

    Schedule (M = num_microbatches, steps t = 0..M):
        pod0:  front(mb_t)        for t < M
        pod1:  back(recv_{t-1})   for t >= 1
    The in-flight payload is the lax.scan carry; ppermute(0->1) moves it.
    """
    M = num_microbatches

    def loss(params, batch):
        def inner(x, y, embed_p, blocks_local, head_p, codec_p):
            stage = jax.lax.axis_index("pod")
            # blocks_local: (1, L/2, ...) — this pod's stage blocks
            my_blocks = jax.tree.map(lambda a: a[0], blocks_local)

            B = x.shape[0]
            assert B % M == 0, (B, M)
            mb = B // M
            x_mbs = x.reshape(M, mb, *x.shape[1:])
            y_mbs = y.reshape(M, mb, *y.shape[1:])

            h_probe = embed_fn(embed_p, x_mbs[0])
            flat_shape = (mb, h_probe.shape[1] * h_probe.shape[2])

            def payload_of(h):
                payload = codec.encode(codec_p, h.reshape(flat_shape))
                # shard the wire tensor over (data, model) BEFORE the pod
                # hop: the FFT encode otherwise leaves D replicated and every
                # model shard would redundantly send the full payload.
                # (scatter is intra-pod ICI — cheap; the pod link is scarce)
                from repro.sharding.constraints import constrain
                return constrain(payload, ("data", "model"))

            def step(carry, t):
                payload_prev, loss_acc = carry
                # input for my stage at step t
                x_t = jax.lax.dynamic_index_in_dim(
                    x_mbs, jnp.minimum(t, M - 1), axis=0, keepdims=False)
                y_prev = jax.lax.dynamic_index_in_dim(
                    y_mbs, jnp.clip(t - 1, 0, M - 1), axis=0, keepdims=False)
                h_front_in = embed_fn(embed_p, x_t)
                h_back_in = codec.decode(codec_p, payload_prev).reshape(h_front_in.shape)
                h_in = jnp.where(stage == 0, h_front_in, h_back_in)
                h_out = stage_fn(my_blocks, h_in)
                payload = payload_of(h_out)
                # channel: stage0 -> stage1 (stage1's payload goes back to 0
                # and is ignored, closing the permutation ring)
                recv = jax.lax.ppermute(payload, "pod", perm=[(0, 1), (1, 0)])
                mb_loss = head_loss_fn(head_p, h_out, y_prev)
                valid = jnp.logical_and(stage == 1, t >= 1)
                loss_acc = loss_acc + jnp.where(valid, mb_loss, 0.0)
                return (recv, loss_acc), None

            payload0 = jnp.zeros_like(payload_of(h_probe))
            (_, loss_sum), _ = jax.lax.scan(
                step, (payload0, jnp.array(0.0, jnp.float32)), jnp.arange(M + 1))
            # only pod1 accumulated loss; sum over pods and average microbatches
            return jax.lax.psum(loss_sum, "pod") / M

        return jax.shard_map(
            inner,
            mesh=mesh,
            in_specs=(P(), P(), P(), P("pod"), P(), P()),
            out_specs=P(),
            axis_names={"pod"},
            check_vma=False,
        )(batch["x"], batch["y"], params["embed"], params["blocks"],
          params["head"], params["codec"])

    return loss
