"""Thin re-export shim — the split-step machinery moved to ``repro.transport``.

The cut-layer exchange is now a first-class subsystem: per-direction codecs
(``SplitLink``), the gradient-compression custom-VJP seam, and the
double-buffered asynchronous pod-pipeline channel all live in
``repro.transport`` (same shim pattern PR 1 used for ``repro.core.codec``).
Pre-transport imports keep working::

    from repro.core import split as split_lib
    split_lib.make_split_loss_fn(...)          # -> repro.transport.split
    split_lib.make_pod_pipeline_loss_fn(...)   # -> repro.transport.pipeline

Imports are lazy (module ``__getattr__``) because ``repro.core.__init__``
loads this shim while ``repro.codecs`` — which the transport layer builds
on — may itself be mid-import.
"""
from __future__ import annotations

_EXPORTS = {
    "apply_codec": ("repro.transport.split", "apply_codec"),
    "make_split_loss_fn": ("repro.transport.split", "make_split_loss_fn"),
    "split_comm_bytes": ("repro.transport.split", "split_comm_bytes"),
    "make_pod_pipeline_loss_fn": ("repro.transport.pipeline",
                                  "make_pod_pipeline_loss_fn"),
}

__all__ = list(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib
        mod, attr = _EXPORTS[name]
        return getattr(importlib.import_module(mod), attr)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
