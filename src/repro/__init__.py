"""repro: C3-SL (circular-convolution batch-wise compression for split
learning) as a production-grade multi-pod JAX framework.

Public entry points:
    repro.codecs           — Codec protocol, spec registry (build("c3sl:R=8|int8")),
                             C3SL/BottleNet++/Identity codecs + wire stages
    repro.core.hrr         — HRR bind/unbind primitives (fft/direct/pallas)
    repro.core.split       — logical + pod-pipeline split-learning steps
    repro.models.lm        — CausalLM/EncDec init/loss/decode
    repro.configs.base     — get_config/list_configs/reduced
    repro.launch           — train / serve / dryrun drivers
"""
__version__ = "1.0.0"
