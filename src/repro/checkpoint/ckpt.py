"""Pytree checkpointing: npz payload + json manifest per step.

Layout:  <dir>/step_<n>/arrays.npz  +  <dir>/step_<n>/manifest.json
Arrays are keyed by their flattened tree path; restore validates structure
against a template pytree and casts to the template's dtypes.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(ckpt_dir: str, step: int, tree, metadata: dict | None = None):
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(out, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    for path, leaf in flat:
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz has no native bf16; upcast losslessly
            arr = arr.astype(np.float32)
        arrays[_path_str(path)] = arr
    np.savez(os.path.join(out, "arrays.npz"), **arrays)
    manifest = {"step": step, "keys": sorted(arrays),
                "metadata": metadata or {}}
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return out


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template):
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat:
        key = _path_str(p)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
