from repro.optim.optimizers import (adam, adamw, sgd_momentum, apply_updates,
                                    clip_by_global_norm, global_norm,
                                    warmup_cosine)
