"""Pure-pytree optimizers (no optax in this environment).

API mirrors optax: opt = adamw(lr); state = opt.init(params);
updates, state = opt.update(grads, state, params); params = apply_updates(...).

Optimizer states have the same tree structure (and per-leaf shapes) as the
params, so the sharding rules that place params also place m/v — with the
ZeRO-1 extension over the data axis applied by repro.sharding.rules.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def _f32(x):
    """Lossless f32 view for optimizer math.  Complex leaves only occur as
    FROZEN constants (the C3-SL codec's cached key spectrum rides in the
    params tree); their gradients are exactly zero, so the real part is the
    whole story — and apply_updates leaves complex params untouched."""
    if jnp.iscomplexobj(x):
        x = jnp.real(x)
    return x.astype(jnp.float32)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(jnp.abs(x).astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda x: x * scale, tree), gn


def _adam_core(lr, b1, b2, eps, weight_decay):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        count = state["count"] + 1
        lr_t = lr(count) if callable(lr) else lr
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * _f32(g),
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(_f32(g)),
                         state["v"], grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr_t * (m / c1) / (jnp.sqrt(v / c2) + eps)
            if weight_decay and p is not None:
                u = u - lr_t * weight_decay * _f32(p)
            return u

        if weight_decay:
            updates = jax.tree.map(upd, m, v, params)
        else:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), m, v)
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init, update)


def adam(lr, b1=0.9, b2=0.999, eps=1e-8) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay=0.0)


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay)


def sgd_momentum(lr, momentum=0.9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        count = state["count"] + 1
        lr_t = lr(count) if callable(lr) else lr
        mu = jax.tree.map(lambda mu, g: momentum * mu + _f32(g),
                          state["mu"], grads)
        updates = jax.tree.map(lambda mu: -lr_t * mu, mu)
        return updates, {"mu": mu, "count": count}

    return Optimizer(init, update)


def apply_updates(params, updates):
    def one(p, u):
        if jnp.iscomplexobj(p):
            return p   # frozen constants (cached key spectra) take no updates
        return (p.astype(jnp.float32) + u).astype(p.dtype)
    return jax.tree.map(one, params, updates)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1):
    def schedule(count):
        count = count.astype(jnp.float32)
        warm = count / max(warmup_steps, 1)
        frac = jnp.clip((count - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return peak_lr * jnp.where(count < warmup_steps, warm, cos)
    return schedule
