"""int8 KV cache tests (beyond-paper, §Perf-4)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import attention as attn_lib
from repro.models import lm as lm_lib


def test_quantize_roundtrip_error_small():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 1, 4, 64))
    q, s = attn_lib._quantize_kv(x)
    deq = q.astype(jnp.float32) * s
    rel = float(jnp.max(jnp.abs(deq - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.01
    assert q.dtype == jnp.int8 and s.shape == (2, 1, 4, 1)


def test_int8_cache_decode_close_to_bf16():
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    cfgq = dataclasses.replace(cfg, kv_cache_quant=True)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)

    def decode_all(c):
        cache = lm_lib.init_decode_cache(params, c, 2, 16)
        outs = []
        for t in range(8):
            lg, cache = lm_lib.decode_step(params, cache, toks[:, t:t + 1],
                                           jnp.int32(t), c)
            outs.append(lg[:, 0])
        return jnp.stack(outs, 1)

    a, b = decode_all(cfg), decode_all(cfgq)
    rel = float(jnp.max(jnp.abs(a - b)) / jnp.max(jnp.abs(a)))
    assert rel < 0.05, rel


def test_int8_cache_halves_bytes():
    c16 = attn_lib.init_gqa_cache(4, 128, 2, 64, jnp.bfloat16)
    c8 = attn_lib.init_gqa_cache(4, 128, 2, 64, jnp.bfloat16, quant=True)
    b16 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c16))
    b8 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c8))
    assert b8 < 0.6 * b16  # int8 + small scales vs bf16
