"""2-stage pod pipeline tests: correctness vs the logical split, compressed
channel shape, and training convergence.  Runs on 2+ host devices via a
subprocess (XLA device count is locked at first jax init, so the 8-device
tests must not pollute the main pytest process)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8) -> dict:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


PIPELINE_PROG = textwrap.dedent("""
    import json, dataclasses
    import jax, jax.numpy as jnp
    from repro.configs.base import get_config, reduced
    from repro.core import codec as codec_lib
    from repro.core import split as split_lib
    from repro.launch import mesh as mesh_lib
    from repro.models import lm as lm_lib

    cfg = reduced(get_config("deepseek-7b"), num_layers=4, d_model=128,
                  d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    mesh = mesh_lib.make_host_mesh(data=2, model=2, pod=2)
    B, S, M = 8, 16, {M}
    rng = jax.random.PRNGKey(0)
    full = lm_lib.init_lm_params(rng, cfg)
    D_flat = (B // M) * 0 + S * cfg.d_model  # per-sample cut feature
    codec = {codec_expr}
    codec_params = codec.init(jax.random.PRNGKey(7)) if hasattr(codec, "init") else {{}}

    params = {{
        "embed": {{"embed": full["embed"]}},
        "blocks": lm_lib.split_stack_for_pipeline(full["stack"]),
        "head": {{"final_norm": full["final_norm"], "head": full["head"]}},
        "codec": codec_params,
    }}
    embed_fn, stage_fn, head_loss_fn = lm_lib.make_pipeline_fns(cfg)
    loss_fn = split_lib.make_pod_pipeline_loss_fn(
        embed_fn, stage_fn, head_loss_fn, codec, mesh, num_microbatches=M)

    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {{"x": tokens, "y": tokens}}
    with mesh_lib.set_mesh(mesh):
        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
        gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))

    # logical-split reference: identical math when codec is identity
    def ref_loss(full_params):
        out, _ = lm_lib.lm_forward(full_params, {{"tokens": tokens}}, cfg, remat=False)
        from repro.models.layers import softmax_cross_entropy
        return softmax_cross_entropy(out, tokens)
    ref = float(ref_loss(full))
    print(json.dumps({{"loss": float(loss), "ref": ref, "gnorm": gnorm}}))
""")


def test_pipeline_identity_codec_matches_logical():
    r = run_py(PIPELINE_PROG.format(
        M=1, codec_expr="codec_lib.IdentityCodec(D=D_flat)"))
    assert abs(r["loss"] - r["ref"]) < 2e-2, r
    assert r["gnorm"] > 0


def test_pipeline_microbatched_identity_matches():
    r = run_py(PIPELINE_PROG.format(
        M=4, codec_expr="codec_lib.IdentityCodec(D=D_flat)"))
    assert abs(r["loss"] - r["ref"]) < 2e-2, r


def test_pipeline_c3sl_codec_runs_and_differs():
    r = run_py(PIPELINE_PROG.format(
        M=2, codec_expr="codec_lib.C3SLCodec(R=2, D=D_flat)"))
    # lossy codec: finite loss, not identical to the uncompressed reference
    assert r["loss"] == r["loss"]  # not NaN
    assert r["gnorm"] > 0


TRAIN_PROG = textwrap.dedent("""
    import json, subprocess, sys
    import jax
    # run the actual launcher end-to-end in pipeline mode
    from repro.launch import train as train_mod
    import argparse
    args = argparse.Namespace(arch="deepseek-7b", reduced=True, steps=8,
        batch=8, seq=16, lr=1e-3, seed=0, codec="c3sl", R=2, quant=None,
        unitary=False, pipeline=True, microbatches=2, async_depth=2,
        log_every=100, ckpt_dir=None)
    from repro.configs.base import get_config, reduced
    cfg = reduced(get_config(args.arch), num_layers=2, d_model=128, d_ff=256,
                  vocab_size=128, num_heads=4, num_kv_heads=2, head_dim=32)
    losses = train_mod.run_pipeline(args, cfg)
    print(json.dumps({"first": losses[0], "last": losses[-1]}))
""")


def test_pipeline_training_loss_decreases():
    r = run_py(TRAIN_PROG)
    assert r["last"] < r["first"], r
