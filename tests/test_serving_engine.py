"""Continuous-batching engine tests: per-slot positions, slot recycling,
and equivalence with lockstep decode."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.models import lm as lm_lib
from repro.serving.engine import BatchedEngine, Request


def _setup(num_slots=4, max_len=32):
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    eng = BatchedEngine(params, cfg, num_slots=num_slots, max_len=max_len,
                        greedy=True)
    return cfg, params, eng


def test_engine_completes_all_requests_with_recycling():
    cfg, params, eng = _setup(num_slots=2)
    reqs = [Request(uid=i, prompt=[1 + i, 2 + i, 3 + i], max_new_tokens=4)
            for i in range(5)]  # 5 requests through 2 slots -> recycling
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 5
    assert all(len(r.out) == 4 for r in done)
    assert all(all(0 <= t < cfg.vocab_size for t in r.out) for r in done)


def test_engine_matches_lockstep_decode():
    """A single request through the engine must equal greedy lockstep
    decoding with the plain decode_step."""
    cfg, params, eng = _setup(num_slots=3)
    prompt = [5, 17, 23, 2]
    eng.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=5))
    done = eng.run()
    got = done[0].out

    # reference: scalar-pos decode with batch 1
    cache = lm_lib.init_decode_cache(params, cfg, 1, 32)
    step = jax.jit(lambda p, c, t, pos: lm_lib.decode_step(p, c, t, pos, cfg))
    toks = list(prompt)
    out = []
    pos = 0
    cur = prompt
    logits = None
    for t in prompt:
        logits, cache = step(params, cache,
                             jnp.asarray([[t]], jnp.int32), jnp.int32(pos))
        pos += 1
    for _ in range(5):
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        logits, cache = step(params, cache,
                             jnp.asarray([[nxt]], jnp.int32), jnp.int32(pos))
        pos += 1
    assert got == out, (got, out)


def test_vector_pos_equals_scalar_pos():
    """decode_step with pos (B,) of equal values == scalar pos."""
    cfg, params, _ = _setup()
    B = 3
    cache_a = lm_lib.init_decode_cache(params, cfg, B, 16)
    cache_b = lm_lib.init_decode_cache(params, cfg, B, 16)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab_size)
    la, _ = lm_lib.decode_step(params, cache_a, toks, jnp.int32(0), cfg)
    lb, _ = lm_lib.decode_step(params, cache_b, toks,
                               jnp.zeros((B,), jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-5,
                               atol=1e-5)


def test_engine_with_c3sl_codec_and_int8_cache():
    """Full serving stack: continuous batching + C3-SL boundary codec +
    int8 KV cache, all at once."""
    import dataclasses
    from repro.core.codec import C3SLCodec
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    codec = C3SLCodec(R=2, D=cfg.d_model)
    eng = BatchedEngine(params, cfg, num_slots=2, max_len=32,
                        codec=codec, codec_params=codec.init(jax.random.PRNGKey(7)))
    for i in range(3):
        eng.submit(Request(uid=i, prompt=[1 + i, 2 + i], max_new_tokens=3))
    done = eng.run()
    assert len(done) == 3
    assert all(len(r.out) == 3 for r in done)


def test_submit_rejects_overlong_and_empty_prompts():
    """Prompts that leave no decode position are rejected AT SUBMIT with a
    clear error instead of coming back short.  Regression: a prompt of
    exactly max_len used to be admitted, prefilled, and cut off after one
    token regardless of max_new_tokens (finish_check fires at
    pos >= max_len) — it must be rejected, not silently truncated."""
    import pytest
    cfg, params, eng = _setup(num_slots=2, max_len=8)
    with pytest.raises(ValueError, match="max_len=8"):
        eng.submit(Request(uid=0, prompt=list(range(1, 10)), max_new_tokens=2))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(uid=1, prompt=[], max_new_tokens=2))
    # the old silent-truncation case: len(prompt) == max_len
    with pytest.raises(ValueError, match="no decode positions"):
        eng.submit(Request(uid=2, prompt=[1, 2, 3, 4, 5, 6, 7, 2],
                           max_new_tokens=4))
    # boundary case max_len - 1 is admitted; generation is still capped by
    # the cache (1 prefill-predicted token + 1 decoded position), never 0
    eng.submit(Request(uid=3, prompt=[1, 2, 3, 4, 5, 6, 7], max_new_tokens=4))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out) == 2


def test_reset_slot_cache_is_layout_aware():
    """Regression for the old shape heuristic: with max_len == num_slots an
    UNSTACKED first-dense cache leaf (B, T, ...) has shape[1] == num_slots,
    and `leaf.at[:, idx].set(0)` would zero cache POSITION idx across every
    slot (corrupting all in-flight rows) instead of slot idx's row."""
    cfg = reduced(get_config("deepseek-v2-lite-16b"))   # has first_dense_layers
    assert cfg.first_dense_layers
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    n = 8
    eng = BatchedEngine(params, cfg, num_slots=n, max_len=n)
    eng.cache = jax.tree.map(jnp.ones_like, eng.cache)
    eng._reset_slot_cache(0)
    first = eng.cache["first"]["l0_0_mla"]["c_kv"]      # (B, T, L), T == B
    assert np.asarray(first[0]).max() == 0.0            # slot 0 cleared
    assert np.asarray(first[1:]).min() == 1.0           # other slots intact
    stacked = eng.cache["stack"]["l0_0_mla"]["c_kv"]    # (N, B, T, L)
    assert np.asarray(stacked[:, 0]).max() == 0.0
    assert np.asarray(stacked[:, 1:]).min() == 1.0


def test_drained_batch_exits_decode_window_early():
    """Regression: the run loop used to dispatch the full `sync_every`
    donated steps before checking EOS flags, so a batch that drained on
    step 1 paid sync_every - 1 wasted dispatches per boundary.  Decode now
    runs as ONE jitted window whose device-side while_loop stops the moment
    no slot is live: dispatch and step counts must reflect that."""
    cfg, params, eng = _setup(num_slots=2, max_len=32)
    assert eng.sync_every == 8
    eng.submit(Request(uid=0, prompt=[3, 5, 7], max_new_tokens=2))
    done = eng.run()
    assert len(done) == 1 and len(done[0].out) == 2
    # 1 generated in prefill + 1 decode step; the old loop would have run 8
    assert eng.stats["decode_steps"] == 1
    # one prefill chunk + one decode window (not 8 step dispatches)
    assert eng.stats["prefill_chunks"] == 1
    assert eng.stats["dispatches"] == 2


def test_interleave_scheduler_outputs_invariant():
    """interleave > 0 alternates prefill chunks with bounded decode windows
    (TTFT/throughput knob); without a codec rows are independent, so every
    request's GREEDY tokens must be IDENTICAL at any interleave setting
    (sampling would consume a different key schedule per setting)."""
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(31)
    lens = [2, 17, 5, 21, 3]                 # long prompts admitted mid-decode
    reqs = [list(map(int, rng.randint(2, cfg.vocab_size, n))) for n in lens]
    outs = []
    for il in (0, 1, 3):
        eng = BatchedEngine(params, cfg, num_slots=2, max_len=48, eos_id=1,
                            chunk_size=4, sync_every=4, interleave=il)
        for u, p in enumerate(reqs):
            eng.submit(Request(uid=u, prompt=list(p), max_new_tokens=6))
        outs.append({r.uid: r.out for r in eng.run()})
        assert len(outs[-1]) == len(reqs)
    assert outs[0] == outs[1] == outs[2]


def test_requests_report_time_to_first_token():
    cfg, params, eng = _setup(num_slots=2)
    for u in range(3):
        eng.submit(Request(uid=u, prompt=[2 + u, 3, 4], max_new_tokens=3))
    done = eng.run()
    for r in done:
        assert r.t_first is not None and r.t_first >= r.t_submit > 0


def test_staggered_positions_are_independent():
    """Slots at different positions don't contaminate each other: decoding
    row 0 at pos 3 while row 1 sits at pos 0 gives the same logits for row 0
    as a batch where all rows are at pos 3 with the same history."""
    cfg, params, _ = _setup()
    B, T = 2, 16
    history = [7, 11, 13]
    step = jax.jit(lambda p, c, t, pos: lm_lib.decode_step(p, c, t, pos, cfg))

    # batch where both rows see the history
    cache = lm_lib.init_decode_cache(params, cfg, B, T)
    logits = None
    for i, t in enumerate(history):
        logits, cache = step(params, cache,
                             jnp.asarray([[t], [t]], jnp.int32),
                             jnp.full((B,), i, jnp.int32))
    ref = np.asarray(logits[0, -1])

    # batch where row 1 lags (its token differs and its pos stays 0)
    cache2 = lm_lib.init_decode_cache(params, cfg, B, T)
    logits2 = None
    for i, t in enumerate(history):
        logits2, cache2 = step(params, cache2,
                               jnp.asarray([[t], [99]], jnp.int32),
                               jnp.asarray([i, 0], jnp.int32))
    got = np.asarray(logits2[0, -1])
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_adaptive_pinned_engine_matches_static_greedy_decode():
    """AdaptiveC3SL pinned to a constant schedule through a BatchedEngine
    greedy decode is bit-identical to the static codec — including the
    |int8 chain: the engine's per-bucket programs close over the same
    bucket codec + params the static engine compiles."""
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    reqs = [([5, 17, 23, 2], 5), ([7, 7, 9], 4), ([3, 11], 3)]
    for adaptive_spec, static_spec in [
        ("adaptive:c3sl:R=4,min_R=2", "c3sl:R=2"),
        ("adaptive:c3sl:R=4,min_R=2|int8", "c3sl:R=2|int8"),
    ]:
        outs = {}
        for name, spec in (("static", static_spec), ("adaptive", adaptive_spec)):
            eng = BatchedEngine(params, cfg, num_slots=2, max_len=32,
                                codec=spec, greedy=True)
            if name == "adaptive":
                eng.codec.pin(2)
            for u, (p, mn) in enumerate(reqs):
                eng.submit(Request(uid=u, prompt=list(p), max_new_tokens=mn))
            outs[name] = {r.uid: r.out for r in eng.run(max_steps=128)}
            assert len(outs[name]) == len(reqs)
        assert outs["adaptive"] == outs["static"], adaptive_spec


def test_adaptive_engine_legacy_mode_matches_static_too():
    """Same pinned-schedule equivalence on the prefill-as-decode baseline
    path (the per-bucket legacy program)."""
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    outs = {}
    for name, spec in (("static", "c3sl:R=2"),
                       ("adaptive", "adaptive:c3sl:R=4,min_R=2")):
        eng = BatchedEngine(params, cfg, num_slots=2, max_len=32,
                            codec=spec, greedy=True, prefill_mode="decode")
        if name == "adaptive":
            eng.codec.pin(2)
        for u in range(3):
            eng.submit(Request(uid=u, prompt=[1 + u, 2 + u, 3], max_new_tokens=3))
        outs[name] = {r.uid: r.out for r in eng.run(max_steps=128)}
    assert outs["adaptive"] == outs["static"]
