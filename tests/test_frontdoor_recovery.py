"""Front-door failure recovery: every way a connection can die must
leave the books correct.

The load-bearing invariant (pinned across every abnormal path below) is
the admission counter: ``inflight_total`` drops back the moment a
connection ends — abrupt close, silent peer, handshake stall, shutdown —
never leaking a unit that would eventually wedge admission shut.  On top
of that: detach-with-resume replays withdrawn work bit-identically (the
engine re-prefills prompt + emitted tokens, so greedy decode cannot tell
it was interrupted), repeated SUBMITs after a reconnect are idempotent,
``generate`` honors its wall-clock deadline with a typed error, and
``stop()`` leaves no orphaned asyncio task behind.

No pytest-asyncio in the image: every scenario runs under a plain
``asyncio.run``.
"""
import asyncio
import time

import numpy as np
import pytest

import jax

from repro.configs.base import get_config, reduced
from repro.frontdoor import (AdmissionController, DeadlineExceeded,
                             FrameStream, FrontDoorClient, FrontDoorServer,
                             MsgType, TenantPolicy, pack_array)
from repro.models import lm as lm_lib
from repro.serving.engine import BatchedEngine, Request


def _cfg():
    return reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                   d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                   head_dim=32)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, codec=None, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("chunk_size", 8)
    kw.setdefault("sync_every", 4)
    return BatchedEngine(params, cfg, codec=codec, greedy=True, seed=0, **kw)


def _prompts(n, rng):
    return [[int(t) for t in rng.randint(1, 128, 5 + i)] for i in range(n)]


def _reference(cfg, params, prompts, max_new):
    eng = _engine(cfg, params)
    for u, p in enumerate(prompts):
        eng.submit(Request(uid=u, prompt=list(p), max_new_tokens=max_new))
    return {r.uid: list(r.out) for r in eng.run()}


async def _until(cond, timeout=5.0, what="condition"):
    t0 = time.monotonic()
    while not cond():
        if time.monotonic() - t0 > timeout:
            raise AssertionError(f"timed out waiting for {what}")
        await asyncio.sleep(0.01)


# ---------------------------------------------------------------------------
# the admission counter-invariant, failure path by failure path
# ---------------------------------------------------------------------------

def test_abrupt_disconnect_releases_admission_and_withdraws(setup):
    cfg, params = setup

    async def go():
        eng = _engine(cfg, params)
        server = FrontDoorServer(eng, auto_tick=False, heartbeat_s=0.2)
        host, port = await server.start()
        client = await FrontDoorClient.open(host, port, tenant="drop",
                                            reconnect=False)
        rids = [await client.submit(p, max_new=4)
                for p in _prompts(2, np.random.RandomState(0))]
        assert server.stats()["admission"]["inflight_total"] == 2
        assert len(eng.queue) == 2           # staged, auto_tick off
        client._stream.close()               # die without BYE
        await _until(
            lambda: server.stats()["admission"]["inflight_total"] == 0,
            what="admission release on disconnect")
        s = server.stats()
        assert s["sessions"] == {"open": 0, "detached": 1}
        assert s["tenants"]["drop"]["disconnects"] == 1
        # the work left the engine with the connection...
        assert not eng.queue and eng.active == 0
        # ...and is parked on the session, keyed by the original rids
        sess = next(iter(server._sessions.values()))
        assert sorted(rid for rid, _ in sess.withdrawn) == sorted(rids)
        await client.close()
        await server.stop(drain=False)

    asyncio.run(go())


def test_silent_peer_is_detached_by_heartbeats(setup):
    cfg, params = setup

    async def go():
        eng = _engine(cfg, params)
        server = FrontDoorServer(eng, auto_tick=False, heartbeat_s=0.05,
                                 max_misses=2)
        host, port = await server.start()
        reader, writer = await asyncio.open_connection(host, port)
        stream = FrameStream(reader, writer, direction="c2s")
        await stream.send(MsgType.HELLO, {"tenant": "mute", "codec": "none"})
        got = await stream.recv(timeout=2.0)
        assert got is not None and got[0] == MsgType.HELLO_OK
        hdr, payload = pack_array(np.asarray([1, 2, 3], dtype=np.int32))
        await stream.send(MsgType.SUBMIT, {"rid": 0, "max_new": 2, **hdr},
                          payload)
        await _until(
            lambda: server.stats()["admission"]["inflight_total"] == 1,
            what="the SUBMIT to be admitted")
        # now go silent: recv() is never called again, so the server's
        # PINGs are never answered — max_misses intervals later the peer
        # is declared dead and its admission unit comes back
        await _until(
            lambda: server.stats()["admission"]["inflight_total"] == 0,
            what="heartbeat death detection")
        assert server.stats()["sessions"]["detached"] == 1
        stream.close()
        await stream.wait_closed()
        await server.stop(drain=False)

    asyncio.run(go())


def test_handshake_stall_frees_the_connection_slot(setup):
    cfg, params = setup

    async def go():
        eng = _engine(cfg, params)
        server = FrontDoorServer(eng, auto_tick=False,
                                 handshake_timeout_s=0.15, heartbeat_s=0.05)
        host, port = await server.start()
        reader, writer = await asyncio.open_connection(host, port)
        await _until(lambda: len(server._conn_tasks) == 1,
                     what="the handler to pick the connection up")
        # say nothing: the server must hang up on its own (the bytes we
        # do receive are its handshake PINGs probing for a lost HELLO)
        await asyncio.wait_for(reader.read(-1), timeout=5.0)
        assert reader.at_eof()
        await _until(lambda: not server._conn_tasks,
                     what="the handler to finish")
        s = server.stats()
        assert s["sessions"] == {"open": 0, "detached": 0}
        assert s["admission"]["inflight_total"] == 0
        writer.close()
        await server.stop(drain=False)

    asyncio.run(go())


def test_stop_cancels_inflight_and_leaves_no_orphan_tasks(setup):
    cfg, params = setup

    async def go():
        eng = _engine(cfg, params)
        server = FrontDoorServer(eng, auto_tick=True)
        host, port = await server.start()
        rng = np.random.RandomState(1)
        clients = [await FrontDoorClient.open(host, port, tenant=f"t{i}",
                                              reconnect=False)
                   for i in range(2)]
        rids = [await c.submit(p, max_new=3)
                for c, p in zip(clients, _prompts(2, rng))]
        # stop() drains first: the admitted work completes and is
        # delivered before the connections are torn down
        await server.stop()
        outs = [await c.result(r) for c, r in zip(clients, rids)]
        assert all(len(o["tokens"]) == 3 for o in outs)
        assert server._conn_tasks == set() and server._tick_task is None
        assert server._routes == {} and server._sessions == {}
        assert server.admission.inflight_total == 0
        for c in clients:
            await c.close()
        # nothing survives on the loop but this coroutine itself
        leftover = [t for t in asyncio.all_tasks()
                    if t is not asyncio.current_task()]
        assert not leftover, leftover

    asyncio.run(go())


# ---------------------------------------------------------------------------
# detach -> resume: bit-identical continuation
# ---------------------------------------------------------------------------

def test_resume_after_disconnect_is_bit_identical(setup):
    cfg, params = setup
    prompts = _prompts(2, np.random.RandomState(2))
    ref = _reference(cfg, params, prompts, max_new=12)

    async def go():
        eng = _engine(cfg, params)
        server = FrontDoorServer(eng, auto_tick=False, resume_ttl_s=10.0)
        host, port = await server.start()
        a = await FrontDoorClient.open(host, port, tenant="ph",
                                       reconnect=False)
        rids = [await a.submit(p, max_new=12) for p in prompts]
        eng.tick()                           # decode PART of the answer...
        assert eng.active == 2               # ...both genuinely mid-flight
        a._stream.close()                    # ...then die mid-decode
        await _until(
            lambda: server.stats()["admission"]["inflight_total"] == 0,
            what="detach after the mid-decode disconnect")
        token = a.session
        await a.close()

        # a new connection presenting the session token gets the
        # withdrawn work re-admitted; the engine re-prefills prompt +
        # emitted tokens, so the continuation is bit-identical
        b = FrontDoorClient(host, port, tenant="ph", reconnect=False)
        b.session = token
        await b._connect()
        assert b.server_info["resumed"] is True
        loop = asyncio.get_running_loop()
        for rid in rids:                     # adopt the orphaned rids
            b._results[rid] = loop.create_future()
        await _until(lambda: len(server._routes) == 2,
                     what="resume re-submission")
        await server.drain()
        outs = [await b.result(rid) for rid in rids]
        s = server.stats()
        assert s["tenants"]["ph"]["resumes"] == 1
        assert s["admission"]["inflight_total"] == 0
        await b.close()
        await server.stop(drain=False)
        return outs

    outs = asyncio.run(go())
    for uid, out in enumerate(outs):
        assert out["tokens"] == ref[uid], uid


def test_client_auto_reconnect_resumes_transparently(setup):
    cfg, params = setup
    prompts = _prompts(2, np.random.RandomState(7))
    ref = _reference(cfg, params, prompts, max_new=12)

    async def go():
        eng = _engine(cfg, params)
        server = FrontDoorServer(eng, auto_tick=False, resume_ttl_s=10.0)
        host, port = await server.start()
        client = await FrontDoorClient.open(host, port, tenant="auto")
        rids = [await client.submit(p, max_new=12) for p in prompts]
        eng.tick()
        assert eng.active == 2               # disconnect lands mid-decode
        # the network dies under the client (RST, not a clean FIN); its
        # read loop reconnects with the session token on its own
        sess = next(iter(server._sessions.values()))
        sess.conn.stream.writer.transport.abort()
        await _until(lambda: client.server_info.get("resumed") is True,
                     what="the client's automatic resume")
        await _until(lambda: len(server._routes) == 2,
                     what="the resumed work to be back in flight")
        await server.drain()
        outs = [await client.result(rid) for rid in rids]
        s = server.stats()
        assert s["tenants"]["auto"]["resumes"] == 1
        assert s["admission"]["inflight_total"] == 0
        await client.close()
        await server.stop(drain=False)
        return outs

    outs = asyncio.run(go())
    for uid, out in enumerate(outs):
        assert out["tokens"] == ref[uid], uid


# ---------------------------------------------------------------------------
# protocol-level recovery details
# ---------------------------------------------------------------------------

def test_repeated_submit_is_idempotent(setup):
    cfg, params = setup

    async def go():
        eng = _engine(cfg, params)
        server = FrontDoorServer(eng, auto_tick=False)
        host, port = await server.start()
        client = await FrontDoorClient.open(host, port, tenant="dup")
        prompt = [1, 2, 3, 4]
        rid = await client.submit(prompt, max_new=3)
        # replay the SUBMIT verbatim — the lost-ACK half of the reconnect
        # race: the request must be re-ACKed, never doubled
        hdr, payload = pack_array(np.asarray(prompt, dtype=np.int32))
        await client._stream.send(MsgType.SUBMIT,
                                  {"rid": rid, "max_new": 3, **hdr}, payload)
        # frames are ordered: once STATS_OK returns, the dup was handled
        stats = await client.stats()
        assert stats["admission"]["inflight_total"] == 1
        assert len(eng.queue) == 1
        await server.drain()
        out = await client.result(rid)
        assert len(out["tokens"]) == 3
        await client.close()
        await server.stop(drain=False)

    asyncio.run(go())


def test_generate_deadline_raises_typed_error(setup):
    cfg, params = setup

    async def go():
        eng = _engine(cfg, params)
        # auto_tick=False and max_inflight=1: the first submit is admitted
        # but never completes, so generate() can only ever see BUSY
        server = FrontDoorServer(
            eng, auto_tick=False,
            admission=AdmissionController(
                default_policy=TenantPolicy(max_inflight=1)))
        host, port = await server.start()
        client = await FrontDoorClient.open(host, port, tenant="late")
        await client.submit([1, 2, 3], max_new=4)
        t0 = time.monotonic()
        with pytest.raises(DeadlineExceeded, match="deadline"):
            await client.generate([4, 5], max_new=4, retries=10_000,
                                  backoff_s=0.005, deadline_s=0.15)
        assert time.monotonic() - t0 < 2.0   # the deadline actually bounded it
        await server.drain()                 # let the admitted one finish
        await client.close()
        await server.stop(drain=False)

    asyncio.run(go())
