"""Chunked prefill: token-level equivalence vs prefill-as-decode, ragged
chunk tails, masked rows, ring buffers, stateful layers, and engine-level
equivalence (greedy, with and without the C3-SL codec)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs as codecs_lib
from repro.configs.base import get_config, reduced
from repro.models import lm as lm_lib
from repro.serving.engine import BatchedEngine, Request


def _cfg(**over):
    base = dict(num_layers=2, d_model=128, d_ff=256, vocab_size=128,
                num_heads=4, num_kv_heads=2, head_dim=32)
    base.update(over)
    return reduced(get_config("deepseek-7b"), **base)


def _decode_reference(params, cfg, prompts, T, codec=None, codec_params=None):
    """Token-by-token ingest with per-row positions; returns (logits at each
    row's last prompt token, final cache)."""
    B = len(prompts)
    cache = lm_lib.init_decode_cache(params, cfg, B, T)
    pos = np.zeros((B,), np.int64)
    ref = [None] * B
    for t in range(max(len(p) for p in prompts)):
        toks = np.array([[p[t] if t < len(p) else 0] for p in prompts], np.int32)
        lg, cache = lm_lib.decode_step(params, cache, jnp.asarray(toks),
                                       jnp.asarray(pos.astype(np.int32)), cfg,
                                       codec=codec, codec_params=codec_params)
        for b, p in enumerate(prompts):
            if t < len(p):
                pos[b] += 1
                if t == len(p) - 1:
                    ref[b] = np.asarray(lg[b, -1])
    return np.stack(ref), cache


def _chunked(params, cfg, prompts, T, C, codec=None, codec_params=None):
    """prefill_chunk over ceil(maxlen/C) chunks with ragged-tail masks;
    returns (per-row last-valid logits of the chunk each row completed in,
    final cache)."""
    B = len(prompts)
    cache = lm_lib.init_decode_cache(params, cfg, B, T)
    pos = jnp.zeros((B,), jnp.int32)
    out = np.zeros((B, cfg.vocab_size), np.float32)
    for k in range(math.ceil(max(len(p) for p in prompts) / C)):
        toks = np.zeros((B, C), np.int32)
        val = np.zeros((B, C), bool)
        for b, p in enumerate(prompts):
            seg = p[k * C:(k + 1) * C]
            if seg:
                toks[b, :len(seg)] = seg
                val[b, :len(seg)] = True
        lg, cache = lm_lib.prefill_chunk(params, cache, jnp.asarray(toks), pos,
                                         cfg, codec=codec,
                                         codec_params=codec_params,
                                         valid=jnp.asarray(val))
        pos = pos + jnp.asarray(val.sum(1), jnp.int32)
        for b, p in enumerate(prompts):
            if k * C < len(p) <= (k + 1) * C:
                out[b] = np.asarray(lg[b])
    return out, cache


def test_prefill_matches_decode_ragged_tails():
    """Ragged prompts (rows complete in different chunks): same last-token
    logits, same greedy token, and identical cache contents at every
    written position; positions past a row's prompt stay untouched."""
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    prompts = [[5, 17, 23, 2, 9, 11, 40], [7, 3, 1, 19, 25]]
    ref, cache_ref = _decode_reference(params, cfg, prompts, 32)
    got, cache_new = _chunked(params, cfg, prompts, 32, C=4)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
    assert (got.argmax(-1) == ref.argmax(-1)).all()
    k_ref = np.asarray(cache_ref["stack"]["l0_0_attn"]["k"])
    k_new = np.asarray(cache_new["stack"]["l0_0_attn"]["k"])
    for b, p in enumerate(prompts):
        np.testing.assert_allclose(k_new[:, b, :len(p)], k_ref[:, b, :len(p)],
                                   rtol=1e-4, atol=1e-5)
        # padded tail positions were dropped, not written
        assert np.abs(k_new[:, b, len(p):]).max() == 0.0


def test_prefill_matches_decode_with_c3sl_codec():
    """Per-position sequence grouping reproduces the decode path's batch-wise
    codec groups: same greedy tokens with c3sl:R=4|int8 at the cut layer."""
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    codec = codecs_lib.build("c3sl:R=4|int8", D=cfg.d_model)
    cp = codec.init(jax.random.PRNGKey(7))
    rng = np.random.RandomState(3)
    prompts = [list(map(int, rng.randint(1, cfg.vocab_size, 6)))
               for _ in range(4)]  # equal lengths: group contents match
    ref, _ = _decode_reference(params, cfg, prompts, 32, codec, cp)
    got, _ = _chunked(params, cfg, prompts, 32, C=4, codec=codec,
                      codec_params=cp)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
    assert (got.argmax(-1) == ref.argmax(-1)).all()


def test_prefill_sliding_window_ring_buffer():
    """Prompt longer than the attention window: chunked prefill must match
    the decode loop through the ring-buffer cache."""
    import dataclasses
    cfg = dataclasses.replace(_cfg(), sliding_window=8)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(5)
    prompts = [list(map(int, rng.randint(1, cfg.vocab_size, 12)))
               for _ in range(2)]
    ref, _ = _decode_reference(params, cfg, prompts, 32)
    got, _ = _chunked(params, cfg, prompts, 32, C=4)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
    assert (got.argmax(-1) == ref.argmax(-1)).all()


def test_prefill_mla_moe_first_dense():
    """MLA absorbed-matrices prefill + MoE + first-dense superblock."""
    cfg = reduced(get_config("deepseek-v2-lite-16b"))
    assert cfg.first_dense_layers
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(11)
    prompts = [list(map(int, rng.randint(1, cfg.vocab_size, 7))),
               list(map(int, rng.randint(1, cfg.vocab_size, 4)))]
    ref, _ = _decode_reference(params, cfg, prompts, 16)
    got, _ = _chunked(params, cfg, prompts, 16, C=4)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
    assert (got.argmax(-1) == ref.argmax(-1)).all()


def test_prefill_stateful_rwkv():
    """Recurrent sublayers (token-shift + wkv state) advance inside the
    chunked program with masked commits."""
    cfg = reduced(get_config("rwkv6-1.6b"), d_model=128, d_ff=256,
                  vocab_size=128, num_heads=4)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(13)
    prompts = [list(map(int, rng.randint(1, cfg.vocab_size, 7))),
               list(map(int, rng.randint(1, cfg.vocab_size, 5)))]
    ref, _ = _decode_reference(params, cfg, prompts, 16)
    got, _ = _chunked(params, cfg, prompts, 16, C=4)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
    assert (got.argmax(-1) == ref.argmax(-1)).all()


def test_prefill_masked_rows_are_untouched():
    """A row with valid=False everywhere (mid-decode while another slot
    prefills) keeps its cache bit-identical."""
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    B, T = 2, 16
    cache = lm_lib.init_decode_cache(params, cfg, B, T)
    # give row 1 some history first
    for t in range(3):
        _, cache = lm_lib.decode_step(params, cache,
                                      jnp.asarray([[0], [7 + t]], jnp.int32),
                                      jnp.asarray([0, t], jnp.int32), cfg)
    before = jax.tree.map(np.asarray, cache)
    toks = np.zeros((B, 4), np.int32)
    toks[0] = [5, 6, 7, 8]
    val = np.zeros((B, 4), bool)
    val[0] = True
    _, cache = lm_lib.prefill_chunk(params, cache, jnp.asarray(toks),
                                    jnp.asarray([0, 3], jnp.int32), cfg,
                                    valid=jnp.asarray(val))
    after = jax.tree.map(np.asarray, cache)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        if a.ndim >= 2 and a.shape[1] == B:       # stacked (N, B, ...) leaves
            assert (a[:, 1] == b[:, 1]).all()


# ---------------------------------------------------------------------------
# engine-level equivalence (chunked + device-resident stepping vs legacy)
# ---------------------------------------------------------------------------

def _engine_pair(cfg, params, **kw):
    a = BatchedEngine(params, cfg, prefill_mode="chunked", **kw)
    b = BatchedEngine(params, cfg, prefill_mode="decode", **kw)
    return a, b


def test_engine_chunked_equals_decode_mode_ragged_recycling():
    """6 ragged requests through 3 slots (mid-flight recycling, chunk tails
    of every length): the fast path emits bit-identical greedy outputs."""
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    fast, slow = _engine_pair(cfg, params, num_slots=3, max_len=32,
                              eos_id=1, chunk_size=4, sync_every=3)
    lens = [3, 5, 9, 2, 7, 4]
    rng = np.random.RandomState(17)
    reqs = [list(map(int, rng.randint(2, cfg.vocab_size, n))) for n in lens]
    for eng in (fast, slow):
        for u, p in enumerate(reqs):
            eng.submit(Request(uid=u, prompt=list(p), max_new_tokens=4 + u % 3))
    out_fast = {r.uid: r.out for r in fast.run()}
    out_slow = {r.uid: r.out for r in slow.run()}
    assert len(out_fast) == len(out_slow) == len(reqs)
    assert out_fast == out_slow


def test_engine_chunked_equals_decode_mode_with_codec():
    """Full batch of equal-length prompts through the C3-SL codec: the
    per-position sequence groups coincide with the decode path's batch
    groups, so outputs match exactly."""
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    codec = codecs_lib.build("c3sl:R=4|int8", D=cfg.d_model)
    cp = codec.init(jax.random.PRNGKey(7))
    fast, slow = _engine_pair(cfg, params, num_slots=4, max_len=32,
                              codec=codec, codec_params=cp,
                              chunk_size=4, sync_every=2)
    rng = np.random.RandomState(19)
    reqs = [list(map(int, rng.randint(1, cfg.vocab_size, 8))) for _ in range(4)]
    for eng in (fast, slow):
        for u, p in enumerate(reqs):
            eng.submit(Request(uid=u, prompt=list(p), max_new_tokens=4))
    out_fast = {r.uid: r.out for r in fast.run()}
    out_slow = {r.uid: r.out for r in slow.run()}
    assert out_fast == out_slow


def test_engine_prompt_longer_than_chunk_and_sync_window():
    """Prompt spanning many chunks + generation spanning many sync windows."""
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    fast, slow = _engine_pair(cfg, params, num_slots=2, max_len=64,
                              chunk_size=4, sync_every=5)
    prompt = list(range(2, 25))                    # 23 tokens -> 6 chunks
    for eng in (fast, slow):
        eng.submit(Request(uid=0, prompt=list(prompt), max_new_tokens=12))
    assert [r.out for r in fast.run()] == [r.out for r in slow.run()]
