"""Pallas kernel vs pure-jnp oracle: shape/dtype sweeps + gradient checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hrr
from repro.kernels import ops as kops
from repro.kernels import ref as kref

jax.config.update("jax_enable_x64", False)


def _data(G, R, D, dtype, seed=0):
    kz, kk = jax.random.split(jax.random.PRNGKey(seed))
    Z = jax.random.normal(kz, (G, R, D), jnp.float32).astype(dtype)
    K = hrr.generate_keys(kk, R, D, dtype)
    return Z, K


SHAPES = [
    (1, 1, 64),
    (2, 2, 128),
    (4, 4, 128),
    (8, 2, 256),
    (3, 5, 96),     # non-power-of-two D, G not multiple of GT tile target
    (16, 16, 128),
    (2, 8, 512),
]


@pytest.mark.parametrize("G,R,D", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bind_kernel_matches_ref(G, R, D, dtype):
    Z, K = _data(G, R, D, dtype)
    got = kops.bind_superpose_pallas(Z, K)
    want = kref.bind_superpose_ref(Z.astype(jnp.float32), K.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol)
    assert got.dtype == dtype and got.shape == (G, D)


@pytest.mark.parametrize("G,R,D", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_unbind_kernel_matches_ref(G, R, D, dtype):
    Z, K = _data(G, R, D, dtype)
    S = kref.bind_superpose_ref(Z.astype(jnp.float32), K.astype(jnp.float32)).astype(dtype)
    got = kops.unbind_pallas(S, K)
    want = kref.unbind_ref(S.astype(jnp.float32), K.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want), rtol=tol, atol=tol)
    assert got.shape == (G, R, D)


@pytest.mark.parametrize("backend", ["fft", "direct"])
def test_jnp_backends_match_ref(backend):
    Z, K = _data(4, 4, 128, jnp.float32)
    S = hrr.bind_superpose(Z, K, backend=backend)
    np.testing.assert_allclose(np.asarray(S), np.asarray(kref.bind_superpose_ref(Z, K)),
                               rtol=2e-4, atol=2e-4)
    Zh = hrr.unbind(S, K, backend=backend)
    np.testing.assert_allclose(np.asarray(Zh), np.asarray(kref.unbind_ref(S, K)),
                               rtol=2e-4, atol=2e-4)


def test_bind_custom_vjp_matches_autodiff_of_ref():
    Z, K = _data(2, 4, 128, jnp.float32)
    dS = jax.random.normal(jax.random.PRNGKey(7), (2, 128))

    def f_pallas(z):
        return jnp.vdot(kops.bind_superpose_pallas(z, K), dS)

    def f_ref(z):
        return jnp.vdot(kref.bind_superpose_ref(z, K), dS)

    gp = jax.grad(f_pallas)(Z)
    gr = jax.grad(f_ref)(Z)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), rtol=1e-4, atol=1e-4)


def test_unbind_custom_vjp_matches_autodiff_of_ref():
    Z, K = _data(2, 4, 128, jnp.float32)
    S = kref.bind_superpose_ref(Z, K)
    dZ = jax.random.normal(jax.random.PRNGKey(8), (2, 4, 128))

    def f_pallas(s):
        return jnp.vdot(kops.unbind_pallas(s, K), dZ)

    def f_ref(s):
        return jnp.vdot(kref.unbind_ref(s, K), dZ)

    gp = jax.grad(f_pallas)(S)
    gr = jax.grad(f_ref)(S)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(gr), rtol=1e-4, atol=1e-4)


def test_keys_get_no_gradient():
    Z, K = _data(2, 2, 128, jnp.float32)
    g = jax.grad(lambda k: kops.bind_superpose_pallas(Z, k).sum())(K)
    np.testing.assert_array_equal(np.asarray(g), 0.0)


# ---------------------------------------------------------------------------
# degenerate tile shapes: non-MXU-alignable D must fail loudly, not run a
# T=1 Toeplitz grid (the _pick_tile degradation bug)
# ---------------------------------------------------------------------------

def test_mxu_alignable_classifier():
    from repro.kernels import circconv
    for D in (64, 96, 128, 256, 512, 1024, 4096):
        assert circconv.mxu_alignable(D), D
    # 4097 = 17 x 241: largest divisor <= 128 is 17, not 8-aligned
    for D in (4097, 127, 241):
        assert not circconv.mxu_alignable(D), D


@pytest.mark.parametrize("op", ["bind", "unbind"])
def test_kernel_raises_on_degenerate_tile_D4097(op):
    """Direct kernel calls with D=4097 (prime-ish: tile degrades to 17)
    must raise a clear error instead of silently running a 17x17-tile
    grid slower than backend='direct'."""
    from repro.kernels import circconv
    D = 4097
    Z = jnp.zeros((1, 2, D), jnp.float32)
    K = jnp.zeros((2, 2 * D), jnp.float32)
    with pytest.raises(ValueError, match="not MXU-alignable"):
        if op == "bind":
            circconv.bind_superpose_kernel(Z, K)
        else:
            circconv.unbind_kernel(jnp.zeros((1, D)), K)


def test_hrr_pallas_falls_back_to_fft_for_degenerate_D():
    """The high-level hrr entry points reroute pallas -> fft for
    non-alignable D — with a warning (loud), and values equal to the fft
    backend (the reroute really is the fft path, not a broken kernel)."""
    Z, K = _data(2, 2, 127, jnp.float32)
    with pytest.warns(UserWarning, match="falling back to the fft backend"):
        S = hrr.bind_superpose(Z, K, backend="pallas")
    np.testing.assert_array_equal(
        np.asarray(S), np.asarray(hrr.bind_superpose(Z, K, backend="fft")))
    with pytest.warns(UserWarning, match="falling back to the fft backend"):
        Zh = hrr.unbind(S, K, backend="pallas")
    np.testing.assert_array_equal(
        np.asarray(Zh), np.asarray(hrr.unbind(S, K, backend="fft")))


def test_alignable_pallas_does_not_warn():
    Z, K = _data(2, 2, 128, jnp.float32)
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        kops.bind_superpose_pallas(Z, K)
        hrr.bind_superpose(Z, K, backend="pallas")


# ---------------------------------------------------------------------------
# effective execution mode: spec() stays canonical, execution_mode() tells
# the truth (the silent interpret-mode bug)
# ---------------------------------------------------------------------------

def test_circconv_execution_mode_matches_host():
    from repro.kernels import circconv
    mode = circconv.execution_mode()
    if jax.default_backend() == "tpu":
        assert mode == "pallas-compiled"
    else:
        assert mode == "pallas-interpret"
    assert circconv.interpret_mode() == (mode == "pallas-interpret")


def test_codec_execution_mode_vs_spec():
    from repro.codecs import build
    c = build("c3sl:R=2,backend=pallas", D=256)
    # spec stays the canonical registry string regardless of host
    assert "backend=pallas" in c.spec()
    assert c.execution_mode() in ("pallas-compiled", "pallas-interpret")
    assert build("c3sl:R=2,backend=fft", D=256).execution_mode() == "fft"
    assert build("c3sl:R=2,backend=direct", D=256).execution_mode() == "direct"
    # degenerate D: the pallas spec executes as fft — and says so
    assert build("c3sl:R=2,backend=pallas",
                 D=4097).execution_mode() == "fft-fallback"
