"""Paged KV cache: reads must be bit-identical to the contiguous layout.

Covers the property the engine's correctness rests on — gather-addressed
paged attention (GQA linear + SWA ring + int8 KV + MLA latents) equals the
contiguous cache for the same token stream — across ragged prefill tails,
staggered per-row positions, shuffled/non-contiguous page tables, and
mid-flight slot recycling (pages freed and reallocated to other requests).
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import lm as lm_lib
from repro.models.paging import PagedLayout, gather_pages, scatter_chunk, \
    scatter_rows
from repro.serving.engine import BatchedEngine, Request


def _cfg(**over):
    base = dict(num_layers=2, d_model=128, d_ff=256, vocab_size=128,
                num_heads=4, num_kv_heads=2, head_dim=32)
    base.update(over)
    return reduced(get_config("deepseek-7b"), **base)


def _variant_cfg(variant):
    cfg = _cfg()
    if variant == "swa":
        cfg = dataclasses.replace(cfg, sliding_window=8)
    elif variant == "int8":
        cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    return cfg


def _paged_cache(params, cfg, B, T, ps, rng):
    """Fully-provisioned paged cache with SHUFFLED page tables, so every
    slot owns scattered, out-of-order physical pages — the layout can only
    agree with the contiguous cache if the table indirection is right."""
    pps = -(-T // ps)
    len_swa = min(T, cfg.sliding_window) if cfg.sliding_window else 0
    pps_swa = -(-len_swa // ps) if len_swa else 0
    layout = PagedLayout(ps, T, B * pps, len_swa, max(B * pps_swa, 1)
                         if len_swa else 0)
    cache = lm_lib.init_decode_cache(params, cfg, B, T, paged=layout)
    cache["pages"] = jnp.asarray(
        rng.permutation(B * pps).astype(np.int32).reshape(B, pps))
    if len_swa:
        cache["pages_swa"] = jnp.asarray(
            rng.permutation(B * pps_swa).astype(np.int32).reshape(B, pps_swa))
    return layout, cache


# ---------------------------------------------------------------------------
# paging primitives
# ---------------------------------------------------------------------------

def test_gather_scatter_roundtrip():
    """gather_pages(view) of scattered writes reconstructs the contiguous
    layout exactly, including a view length that is NOT a page multiple."""
    rng = np.random.RandomState(0)
    B, T, ps = 3, 14, 4                      # 4 pages/slot, view sliced to 14
    pps = -(-T // ps)
    table = jnp.asarray(rng.permutation(B * pps).astype(np.int32)
                        .reshape(B, pps))
    pool = jnp.zeros((B * pps, ps, 2), jnp.float32)
    ref = np.zeros((B, pps * ps, 2), np.float32)
    # row-wise decode writes at staggered positions, some rows masked dead
    for t in range(T):
        vals = rng.randn(B, 1, 2).astype(np.float32)
        live = rng.rand(B) < 0.8
        slots = jnp.full((B,), t, jnp.int32)
        pool = scatter_rows(pool, table, slots, jnp.asarray(vals),
                            live=jnp.asarray(live))
        ref[live, t] = vals[live, 0]
    got = np.asarray(gather_pages(pool, table, T))
    np.testing.assert_array_equal(got, ref[:, :T])
    # chunked writes with ragged-tail masking
    slots = jnp.asarray(np.stack([np.arange(4) + o for o in (0, 5, 9)])
                        .astype(np.int32))
    valid = jnp.asarray(np.array([[1, 1, 1, 0], [1, 1, 0, 0], [1, 1, 1, 1]],
                                 bool))
    vals = rng.randn(B, 4, 2).astype(np.float32)
    pool = scatter_chunk(pool, table, slots, valid, jnp.asarray(vals))
    got = np.asarray(gather_pages(pool, table, T))
    for b, o in enumerate((0, 5, 9)):
        for c in range(4):
            if bool(valid[b, c]):
                ref[b, o + c] = vals[b, c]
    np.testing.assert_array_equal(got, ref[:, :T])


# ---------------------------------------------------------------------------
# step-level parity: decode + chunked prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["plain", "swa", "int8"])
def test_paged_decode_matches_contiguous(variant):
    """Staggered live-masked decode: identical logits on both layouts,
    page size NOT dividing max_len (exercises the sliced view)."""
    cfg = _variant_cfg(variant)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    B, T, ps = 3, 16, 5
    rng = np.random.RandomState(1)
    layout, pcache = _paged_cache(params, cfg, B, T, ps, rng)
    ccache = lm_lib.init_decode_cache(params, cfg, B, T)
    pos = np.zeros((B,), np.int32)
    for t in range(12):
        toks = rng.randint(1, cfg.vocab_size, (B, 1)).astype(np.int32)
        live = rng.rand(B) < 0.75
        live[t % B] = True                   # at least one row advances
        lc, ccache = lm_lib.decode_step(params, ccache, jnp.asarray(toks),
                                        jnp.asarray(pos), cfg,
                                        live=jnp.asarray(live))
        lp, pcache = lm_lib.decode_step(params, pcache, jnp.asarray(toks),
                                        jnp.asarray(pos), cfg, paged=layout,
                                        live=jnp.asarray(live))
        lc, lp = np.asarray(lc), np.asarray(lp)
        np.testing.assert_allclose(lp[live], lc[live], rtol=1e-5, atol=1e-5)
        assert (lp[live].argmax(-1) == lc[live].argmax(-1)).all()
        pos += live


@pytest.mark.parametrize("variant", ["plain", "swa", "int8"])
def test_paged_prefill_matches_contiguous(variant):
    """Ragged chunked prefill (rows complete in different chunks): identical
    last-valid logits AND the gathered paged view equals the contiguous
    cache bit-for-bit at every written position."""
    cfg = _variant_cfg(variant)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    B, T, ps, C = 2, 16, 3, 4
    rng = np.random.RandomState(2)
    layout, pcache = _paged_cache(params, cfg, B, T, ps, rng)
    ccache = lm_lib.init_decode_cache(params, cfg, B, T)
    prompts = [list(map(int, rng.randint(1, cfg.vocab_size, 11))),
               list(map(int, rng.randint(1, cfg.vocab_size, 6)))]
    pos = np.zeros((B,), np.int32)
    for k in range(math.ceil(max(len(p) for p in prompts) / C)):
        toks = np.zeros((B, C), np.int32)
        val = np.zeros((B, C), bool)
        for b, p in enumerate(prompts):
            seg = p[k * C:(k + 1) * C]
            toks[b, :len(seg)] = seg
            val[b, :len(seg)] = True
        lc, ccache = lm_lib.prefill_chunk(params, ccache, jnp.asarray(toks),
                                          jnp.asarray(pos), cfg,
                                          valid=jnp.asarray(val))
        lp, pcache = lm_lib.prefill_chunk(params, pcache, jnp.asarray(toks),
                                          jnp.asarray(pos), cfg,
                                          valid=jnp.asarray(val), paged=layout)
        rows = val.any(1)
        np.testing.assert_allclose(np.asarray(lp)[rows], np.asarray(lc)[rows],
                                   rtol=1e-5, atol=1e-5)
        pos += val.sum(1).astype(np.int32)
    # gathered paged pools == contiguous strips at every written position
    T_swa = min(T, cfg.sliding_window) if cfg.sliding_window else T
    table = pcache["pages_swa"] if cfg.sliding_window else pcache["pages"]
    for name in ccache["stack"]["l0_0_attn"]:
        c_leaf = np.asarray(ccache["stack"]["l0_0_attn"][name])   # (N,B,T,..)
        p_pool = pcache["stack"]["l0_0_attn"][name]
        for n in range(c_leaf.shape[0]):
            view = np.asarray(gather_pages(p_pool[n], table, T_swa))
            for b, p in enumerate(prompts):
                w = min(len(p), T_swa)       # ring holds the last w writes
                np.testing.assert_array_equal(view[b, :w], c_leaf[n, b, :w])


def test_paged_prefill_mla_first_dense():
    """MLA latent caches + the unstacked first-dense superblock page their
    pools through the same tables."""
    cfg = reduced(get_config("deepseek-v2-lite-16b"))
    assert cfg.first_dense_layers
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    B, T, ps, C = 2, 16, 4, 4
    rng = np.random.RandomState(3)
    layout, pcache = _paged_cache(params, cfg, B, T, ps, rng)
    ccache = lm_lib.init_decode_cache(params, cfg, B, T)
    prompts = [list(map(int, rng.randint(1, cfg.vocab_size, 7))),
               list(map(int, rng.randint(1, cfg.vocab_size, 4)))]
    pos = np.zeros((B,), np.int32)
    lc = lp = None
    for k in range(2):
        toks = np.zeros((B, C), np.int32)
        val = np.zeros((B, C), bool)
        for b, p in enumerate(prompts):
            seg = p[k * C:(k + 1) * C]
            toks[b, :len(seg)] = seg
            val[b, :len(seg)] = True
        lc, ccache = lm_lib.prefill_chunk(params, ccache, jnp.asarray(toks),
                                          jnp.asarray(pos), cfg,
                                          valid=jnp.asarray(val))
        lp, pcache = lm_lib.prefill_chunk(params, pcache, jnp.asarray(toks),
                                          jnp.asarray(pos), cfg,
                                          valid=jnp.asarray(val), paged=layout)
        rows = val.any(1)
        np.testing.assert_allclose(np.asarray(lp)[rows], np.asarray(lc)[rows],
                                   rtol=1e-4, atol=1e-5)
        pos += val.sum(1).astype(np.int32)
    # decode a few tokens on top of the prefilled caches
    for t in range(3):
        toks = rng.randint(1, cfg.vocab_size, (B, 1)).astype(np.int32)
        live = jnp.ones((B,), bool)
        lc, ccache = lm_lib.decode_step(params, ccache, jnp.asarray(toks),
                                        jnp.asarray(pos), cfg, live=live)
        lp, pcache = lm_lib.decode_step(params, pcache, jnp.asarray(toks),
                                        jnp.asarray(pos), cfg, paged=layout,
                                        live=live)
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lc),
                                   rtol=1e-4, atol=1e-5)
        pos += 1


# ---------------------------------------------------------------------------
# engine-level parity: recycling (page free + realloc), codec, scheduler
# ---------------------------------------------------------------------------

def _engine_pair(cfg, params, *, num_pages, page_size=4, **kw):
    paged = BatchedEngine(params, cfg, kv_layout="paged", page_size=page_size,
                          num_pages=num_pages, **kw)
    contig = BatchedEngine(params, cfg, kv_layout="contiguous", **kw)
    return paged, contig


def test_paged_engine_recycling_matches_contiguous():
    """7 ragged requests through 2 slots with an OVERSUBSCRIBED pool: slots
    recycle mid-flight, pages free and realloc in shuffled order, admission
    occasionally waits for pages — greedy outputs must match the contiguous
    engine token-for-token."""
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    # contiguous equivalent would hold 2 slots * 32 positions = 16 pages;
    # 10 pages oversubscribes while still fitting any single request
    paged, contig = _engine_pair(cfg, params, num_pages=10, num_slots=2,
                                 max_len=32, eos_id=1, chunk_size=4,
                                 sync_every=3)
    lens = [3, 9, 5, 2, 12, 7, 4]
    rng = np.random.RandomState(11)
    reqs = [list(map(int, rng.randint(2, cfg.vocab_size, n))) for n in lens]
    for eng in (paged, contig):
        for u, p in enumerate(reqs):
            eng.submit(Request(uid=u, prompt=list(p), max_new_tokens=3 + u % 4))
    out_p = {r.uid: r.out for r in paged.run()}
    out_c = {r.uid: r.out for r in contig.run()}
    assert len(out_p) == len(out_c) == len(reqs)
    assert out_p == out_c
    assert paged.allocator.free_pages == paged.paged.num_pages  # all freed
    # the paged cache really is smaller than the contiguous strips
    assert paged.cache_bytes < contig.cache_bytes


def test_paged_engine_swa_int8_matches_contiguous():
    """Ring-buffer SWA + int8 KV through the paged engine."""
    cfg = dataclasses.replace(_cfg(), sliding_window=8, kv_cache_quant=True)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    paged, contig = _engine_pair(cfg, params, num_pages=12, num_slots=2,
                                 max_len=32, chunk_size=4, sync_every=2)
    rng = np.random.RandomState(13)
    reqs = [list(map(int, rng.randint(2, cfg.vocab_size, n)))
            for n in (11, 4, 6)]             # 11 > window: ring wraps
    for eng in (paged, contig):
        for u, p in enumerate(reqs):
            eng.submit(Request(uid=u, prompt=list(p), max_new_tokens=4))
    assert {r.uid: r.out for r in paged.run()} \
        == {r.uid: r.out for r in contig.run()}


def test_paged_engine_codec_matches_contiguous():
    """The PR2 codec equivalence setting (full batch, equal-length prompts,
    lockstep admission) with c3sl:R=4|int8: paged == contiguous exactly."""
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    paged, contig = _engine_pair(cfg, params, num_pages=16, num_slots=4,
                                 max_len=32, codec="c3sl:R=4|int8",
                                 chunk_size=4, sync_every=2)
    rng = np.random.RandomState(19)
    reqs = [list(map(int, rng.randint(1, cfg.vocab_size, 8))) for _ in range(4)]
    for eng in (paged, contig):
        for u, p in enumerate(reqs):
            eng.submit(Request(uid=u, prompt=list(p), max_new_tokens=4))
    assert {r.uid: r.out for r in paged.run()} \
        == {r.uid: r.out for r in contig.run()}


def test_paged_engine_serializes_when_pool_is_tight():
    """A pool that fits only ONE request at a time still completes everything
    (admission waits FIFO for pages instead of deadlocking or overtaking)."""
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    eng = BatchedEngine(params, cfg, kv_layout="paged", page_size=4,
                        num_pages=5, num_slots=3, max_len=32, chunk_size=4)
    rng = np.random.RandomState(23)
    reqs = [list(map(int, rng.randint(1, cfg.vocab_size, 12)))
            for _ in range(3)]               # each needs 4 of the 5 pages
    for u, p in enumerate(reqs):
        eng.submit(Request(uid=u, prompt=list(p), max_new_tokens=4))
    done = eng.run()
    assert sorted(r.uid for r in done) == [0, 1, 2]
    assert all(len(r.out) == 4 for r in done)
    assert eng.allocator.free_pages == 5


def test_paged_swa_only_model_skips_linear_reservation():
    """Regression: with a sliding window every attn leaf lives in the
    statically-owned ring pools, so admission must not gate (or submit
    reject) on the full-length pool no leaf is allocated from — a tiny
    num_pages must neither reject nor serialize a pure-SWA model."""
    cfg = dataclasses.replace(_cfg(), sliding_window=8)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    paged, contig = _engine_pair(cfg, params, num_pages=1, num_slots=2,
                                 max_len=32, chunk_size=4, sync_every=2)
    rng = np.random.RandomState(37)
    reqs = [list(map(int, rng.randint(2, cfg.vocab_size, n)))
            for n in (14, 9, 5)]             # far beyond 1 page * 4 positions
    for eng in (paged, contig):
        for u, p in enumerate(reqs):
            eng.submit(Request(uid=u, prompt=list(p), max_new_tokens=4))
    assert {r.uid: r.out for r in paged.run()} \
        == {r.uid: r.out for r in contig.run()}
    assert all(not s.pages for s in paged.slots)   # nothing ever reserved


def test_eos_frees_pages_early_under_pool_starvation():
    """PR-3 preemption follow-up: a slot that finishes mid-window must not
    hold its page reservation for the rest of the window while the queue is
    starved.  With the pool starved, the decode window exits the moment a
    slot finishes (stats["eos_early_exits"]), the boundary frees its pages
    immediately, and the queued request admits — and every page is always
    either free or owned by exactly one slot (pool_accounting)."""
    cfg = _cfg(num_layers=2, d_model=64, d_ff=128, vocab_size=64,
               num_heads=2, num_kv_heads=1, head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)

    def run(num_pages):
        eng = BatchedEngine(params, cfg, num_slots=2, max_len=64,
                            kv_layout="paged", page_size=4,
                            num_pages=num_pages, sync_every=32, chunk_size=8)
        # A: big reservation (3 pages), finishes after 2 tokens
        eng.submit(Request(uid=0, prompt=[1] * 8, max_new_tokens=2))
        # C: keeps decoding for the whole window (7 pages)
        eng.submit(Request(uid=1, prompt=[2] * 4, max_new_tokens=24))
        # B: queued; needs 12 pages -> starved until A frees
        eng.submit(Request(uid=2, prompt=[3] * 8, max_new_tokens=40))
        done = eng.run(max_steps=200)
        acct = eng.pool_accounting()
        assert acct["free"] + acct["in_use"] == acct["total"]
        assert acct["in_use"] == 0               # everything retired
        assert sorted(r.uid for r in done) == [0, 1, 2]
        return eng

    # pool 14: A(3) + C(7) resident, B needs 12 > 4 free -> starved, so A's
    # EOS must cut the window short to free its 3 pages for B
    starved = run(14)
    assert starved.stats["eos_early_exits"] >= 1
    # fully provisioned pool: B admits straight away, no window is ever cut
    roomy = run(2 * 16)
    assert roomy.stats["eos_early_exits"] == 0
    # identical token streams either way (scheduling must not change math)
    assert {r.uid: r.out for r in starved.finished} \
        == {r.uid: r.out for r in roomy.finished}


def test_paged_submit_rejects_requests_larger_than_pool():
    import pytest as _pytest
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    eng = BatchedEngine(params, cfg, kv_layout="paged", page_size=4,
                        num_pages=3, num_slots=2, max_len=32)
    with _pytest.raises(ValueError, match="cache pages"):
        eng.submit(Request(uid=0, prompt=list(range(1, 14)),
                           max_new_tokens=8))  # needs ceil(21/4)=6 > 3 pages


def test_paged_property_sweep():
    """Randomized sweep: random prompt mixes, chunk sizes, page sizes, and
    cache variants — paged and contiguous engines agree token-for-token."""
    rng = np.random.RandomState(29)
    for trial, variant in enumerate(["plain", "swa", "int8"]):
        cfg = _variant_cfg(variant)
        params = lm_lib.init_lm_params(jax.random.PRNGKey(trial), cfg)
        C = int(rng.randint(2, 6))
        ps = int(rng.randint(3, 7))
        paged, contig = _engine_pair(cfg, params, num_pages=14, page_size=ps,
                                     num_slots=2, max_len=24, chunk_size=C,
                                     sync_every=int(rng.randint(1, 5)),
                                     eos_id=1)
        lens = rng.randint(1, 16, size=5)
        reqs = [list(map(int, rng.randint(2, cfg.vocab_size, n)))
                for n in lens]
        for eng in (paged, contig):
            for u, p in enumerate(reqs):
                eng.submit(Request(uid=u, prompt=list(p),
                                   max_new_tokens=int(2 + u % 4)))
        assert {r.uid: r.out for r in paged.run()} \
            == {r.uid: r.out for r in contig.run()}, (trial, variant)
