"""Beyond-paper feature tests: unitary keys, int8 wire, sequence-group
binding for B=1 long-context, and the Fourier-domain superposition."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as codec_lib
from repro.core import hrr


def _roundtrip_err(codec, B=16, D=256, seed=0):
    p = codec.init(jax.random.PRNGKey(seed))
    Z = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, D))
    Zhat = codec.decode(p, codec.encode(p, Z))
    return float(jnp.linalg.norm(Zhat - Z) / jnp.linalg.norm(Z))


def test_unitary_codec_lower_error_every_R():
    for R in (2, 4, 8):
        e_g = _roundtrip_err(codec_lib.C3SLCodec(R=R, D=2048), D=2048)
        e_u = _roundtrip_err(codec_lib.C3SLCodec(R=R, D=2048, unitary=True),
                             D=2048)
        assert e_u < e_g, (R, e_u, e_g)


def test_sequence_group_binding_long_context():
    """B=1 long-context: group along sequence blocks instead of batch."""
    B, S, d = 1, 64, 32
    codec = codec_lib.C3SLCodec(R=4, D=d)
    p = codec.init(jax.random.PRNGKey(0))
    Z = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    payload = codec_lib.sequence_group_encode(codec, p, Z)
    # 4x fewer vectors on the wire, leading group axis kept (3-D layout)
    assert payload.shape == (B, S // 4, d)
    Zhat = codec_lib.sequence_group_decode(codec, p, payload, B, S)
    assert Zhat.shape == Z.shape
    # information flows (lossy but correlated)
    cos = float(jnp.vdot(Z, Zhat) / (jnp.linalg.norm(Z) * jnp.linalg.norm(Zhat)))
    assert cos > 0.2


def test_fourier_domain_superpose_matches_naive():
    """The optimized encode (superpose in Fourier domain, 1 irfft) equals
    the naive R-convolutions-then-sum definition."""
    rng = jax.random.PRNGKey(0)
    kz, kk = jax.random.split(rng)
    Z = jax.random.normal(kz, (3, 4, 128))
    K = hrr.generate_keys(kk, 4, 128)
    fast = hrr.bind_superpose(Z, K, backend="fft")
    naive = hrr.circ_conv_fft(K, Z).sum(axis=-2)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(naive),
                               rtol=1e-4, atol=1e-4)


def test_int8_wire_bytes_and_fidelity():
    c8 = codec_lib.C3SLCodec(R=4, D=512, quant_bits=8)
    c32 = codec_lib.C3SLCodec(R=4, D=512)
    assert c8.wire_bytes(16) < c32.wire_bytes(16) / 3.9
    # int8 adds little error on top of the HRR crosstalk
    e8 = _roundtrip_err(c8, D=512)
    e32 = _roundtrip_err(c32, D=512)
    assert e8 < e32 * 1.1


def test_unitary_key_spectrum_is_flat():
    K = hrr.generate_keys(jax.random.PRNGKey(0), 4, 1024, unitary=True)
    mag = jnp.abs(jnp.fft.fft(K, axis=-1))
    np.testing.assert_allclose(np.asarray(mag), 1.0, rtol=2e-3, atol=2e-3)
