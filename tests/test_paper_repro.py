"""Paper reproduction tests: Table 1/2 analytics exact, split conv models
train, and the accuracy-trend claim at reduced scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks import bench_table1
from repro.configs.paper import RESNET50_CIFAR100, VGG16_CIFAR10
from repro.core import codec as codec_lib
from repro.core.split import apply_codec
from repro.data.pipeline import SyntheticImageDataset
from repro.models import convnets


def test_table1_c3sl_columns_match_paper_exactly():
    rows = bench_table1.check_rows()
    c3 = [r for r in rows if r["method"] == "c3sl"]
    assert len(c3) == 8
    assert all(r["params_match"] and r["flops_match"] for r in c3)


def test_table1_bottlenet_columns_match_except_known_R2():
    rows = [r for r in bench_table1.check_rows() if r["method"] == "bottlenet++"]
    for r in rows:
        if r["R"] == 2:
            # the paper's own R=2 rows contradict its Table 2 formula; we
            # implement the formula (see EXPERIMENTS.md §Repro)
            assert not r["params_match"]
        else:
            assert r["params_match"] and r["flops_match"], r


def test_vgg16_split_shapes():
    p = convnets.init_vgg16(jax.random.PRNGKey(0))
    x = jnp.zeros((2, 3, 32, 32))
    z = convnets.vgg16_front(p, x)
    assert z.shape == (2, *convnets.VGG_CUT_SHAPE)  # D = 2048 (paper)
    assert int(np.prod(convnets.VGG_CUT_SHAPE)) == 2048
    logits = convnets.vgg16_back(p, z)
    assert logits.shape == (2, 10)


def test_resnet50_split_shapes():
    p = convnets.init_resnet50(jax.random.PRNGKey(0))
    x = jnp.zeros((2, 3, 32, 32))
    z = convnets.resnet50_front(p, x)
    assert z.shape == (2, *convnets.RESNET_CUT_SHAPE)  # D = 4096 (paper)
    assert int(np.prod(convnets.RESNET_CUT_SHAPE)) == 4096
    logits = convnets.resnet50_back(p, z)
    assert logits.shape == (2, 100)


def test_resnet50_param_count_plausible():
    p = convnets.init_resnet50(jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(p))
    assert 23e6 < n < 27e6  # ~25.6M for ResNet-50


@pytest.mark.slow
def test_accuracy_trend_c3sl_close_to_vanilla():
    """Short version of benchmarks/bench_accuracy.py: C3-SL R=4 within a few
    points of vanilla on the synthetic task."""
    from benchmarks import bench_accuracy
    van = bench_accuracy.run_one(None, {}, steps=120)
    c = codec_lib.C3SLCodec(R=4, D=bench_accuracy.D)
    c3 = bench_accuracy.run_one(c, c.init(jax.random.PRNGKey(0)), steps=120)
    assert van > 0.6, van  # task is learnable
    assert c3 > van - 0.15, (van, c3)  # negligible-drop trend


def test_vgg_split_trains_one_step_through_codec():
    rng = jax.random.PRNGKey(0)
    p = {"net": convnets.init_vgg16(rng), "codec":
         codec_lib.C3SLCodec(R=4, D=2048).init(rng)}
    codec = codec_lib.C3SLCodec(R=4, D=2048)
    ds = SyntheticImageDataset(n_classes=10)
    batch = ds.batch(8, 0)

    def loss_fn(p):
        z = convnets.vgg16_front(p["net"], batch["x"])
        zhat = apply_codec(codec, p["codec"], z)
        logits = convnets.vgg16_back(p["net"], zhat)
        return -jax.nn.log_softmax(logits)[jnp.arange(8), batch["y"]].mean()

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(p)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads["net"]))
    assert gn > 0
