"""Unit tests for the trip-count-aware HLO analyzer (roofline source)."""
import textwrap

from repro.launch import hloparse

SAMPLE = textwrap.dedent("""\
    HloModule test

    %add (x: f32[], y: f32[]) -> f32[] {
      %x = f32[] parameter(0)
      %y = f32[] parameter(1)
      ROOT %s = f32[] add(%x, %y)
    }

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %a = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant(0)
      %d = f32[8,16]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%d), to_apply=%add
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]) tuple(%i2, %ar)
    }

    %cond (p: (s32[], f32[8,16])) -> pred[] {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(10)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
      %arg = f32[8,16]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,16]) tuple(%zero, %arg)
      %w = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
    }
""")


def test_trip_count_and_flops():
    t = hloparse.analyze(SAMPLE)
    # dot: 2 * 8*16 out * 16 contract = 4096 flops, x10 trips
    assert t["dot_flops"] == 2 * 8 * 16 * 16 * 10
    # all-reduce: 8*16*4 bytes x10
    assert t["coll_bytes"] == 8 * 16 * 4 * 10
    assert t["coll_by_op"]["all-reduce"] == 8 * 16 * 4 * 10


def test_collective_bytes_counts_tuple_shapes():
    txt = "%x = (f32[4,4]{1,0}, f32[2]{0}) all-gather(%a, %b), dims={0}\n"
    from repro.launch.dryrun import collective_bytes
    out = collective_bytes(txt)
    assert out["all-gather"] == (16 + 2) * 4


def test_header_param_order_handles_tuples():
    hdr = "%c (a: (s32[], f32[2,2]), b: f32[4]) -> pred[] {"
    assert hloparse._header_param_order(hdr) == ["a", "b"]
