"""Unit tests for the trip-count-aware HLO analyzer (roofline source)."""
import textwrap

from repro.launch import hloparse

SAMPLE = textwrap.dedent("""\
    HloModule test

    %add (x: f32[], y: f32[]) -> f32[] {
      %x = f32[] parameter(0)
      %y = f32[] parameter(1)
      ROOT %s = f32[] add(%x, %y)
    }

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %a = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant(0)
      %d = f32[8,16]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%d), to_apply=%add
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]) tuple(%i2, %ar)
    }

    %cond (p: (s32[], f32[8,16])) -> pred[] {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(10)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
      %arg = f32[8,16]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,16]) tuple(%zero, %arg)
      %w = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
    }
""")


def test_trip_count_and_flops():
    t = hloparse.analyze(SAMPLE)
    # dot: 2 * 8*16 out * 16 contract = 4096 flops, x10 trips
    assert t["dot_flops"] == 2 * 8 * 16 * 16 * 10
    # all-reduce: 8*16*4 bytes x10
    assert t["coll_bytes"] == 8 * 16 * 4 * 10
    assert t["coll_by_op"]["all-reduce"] == 8 * 16 * 4 * 10


def test_collective_bytes_counts_tuple_shapes():
    txt = "%x = (f32[4,4]{1,0}, f32[2]{0}) all-gather(%a, %b), dims={0}\n"
    from repro.launch.dryrun import collective_bytes
    out = collective_bytes(txt)
    assert out["all-gather"] == (16 + 2) * 4


def test_header_param_order_handles_tuples():
    hdr = "%c (a: (s32[], f32[2,2]), b: f32[4]) -> pred[] {"
    assert hloparse._header_param_order(hdr) == ["a", "b"]


# ---------------------------------------------------------------------------
# mask-aware (measured) top-k wire accounting
# ---------------------------------------------------------------------------

def test_topk_wire_bytes_from_custom_call_line():
    ln = ('%custom-call = (f32[20,8]{1,0}, s32[20,8]{1,0}) '
          'custom-call(f32[20,64]{1,0} %abs.40), custom_call_target="TopK"')
    defs = {"abs.40": "%abs.40 = f32[20,64]{1,0} abs(f32[20,64]{1,0} %x)"}
    # 20 rows x (64-bit mask -> 8 bytes + 8 f32 survivors -> 32 bytes)
    assert hloparse._topk_wire_bytes_for_line(ln, defs) == 20 * (64 // 8 + 4 * 8)
    # bare-name operand dialect: shape resolved through the defs map
    bare = ('%custom-call = (f32[20,8]{1,0}, s32[20,8]{1,0}) '
            'custom-call(%abs.40), custom_call_target="TopK"')
    assert hloparse._topk_wire_bytes_for_line(bare, defs) \
        == 20 * (64 // 8 + 4 * 8)
    # non-topk custom calls measure nothing
    assert hloparse._topk_wire_bytes_for_line(
        '%cc = f32[4]{0} custom-call(f32[4]{0} %x), '
        'custom_call_target="Other"', defs) == 0.0


def test_topk_wire_bytes_excludes_router_topk():
    """Only MAGNITUDE top-ks (the wire stage ranks |payload|) count as
    sparsified payload — a MoE router's top-k over raw logits is program
    control flow and must not pollute the measured codec bytes."""
    import jax
    import jax.numpy as jnp

    txt = jax.jit(lambda z: jax.lax.top_k(z, 2)).lower(
        jnp.zeros((64, 16))).compile().as_text()
    assert "TopK" in txt or "topk(" in txt          # the op IS there
    assert hloparse.analyze(txt)["topk_wire_bytes"] == 0.0


def test_topk_wire_bytes_measured_from_compiled_hlo():
    """Cross-check the ROADMAP item end-to-end: wire bytes of a sparsified
    payload MEASURED from the lowered program equal the analytic
    ``payload_wire_bytes`` — rows/k/D all read off the real top-k op."""
    import jax
    import jax.numpy as jnp
    from repro import codecs
    from repro.codecs import build

    codec = build("c3sl:R=4,D=64|topk:k=8")
    p = codec.init(jax.random.PRNGKey(0))
    z = jnp.zeros((80, 64))
    txt = jax.jit(lambda z: codec.encode(p, z)).lower(z).compile().as_text()
    measured = hloparse.analyze(txt)["topk_wire_bytes"]
    analytic = codecs.payload_wire_bytes(codec, codec.payload_shape(80))
    assert measured == analytic == (80 // 4) * (64 // 8 + 4 * 8)


def test_topk_wire_bytes_trip_count_aware():
    """A top-k inside a scan body multiplies by the loop trip count, like
    every other per-computation stat (the encode must be loop-variant or
    XLA hoists it — which the measurement would faithfully report as 1x)."""
    import jax
    import jax.numpy as jnp
    from repro import codecs
    from repro.codecs import build

    codec = build("c3sl:R=4,D=64|topk:k=8")
    p = codec.init(jax.random.PRNGKey(0))
    z = jnp.zeros((80, 64))

    def scanned(z):
        def body(c, i):
            return c + 1.0, codec.encode(p, z + i)
        _, ys = jax.lax.scan(body, 0.0, jnp.arange(5.0))
        return ys

    txt = jax.jit(scanned).lower(z).compile().as_text()
    analytic = codecs.payload_wire_bytes(codec, codec.payload_shape(80))
    assert hloparse.analyze(txt)["topk_wire_bytes"] == 5 * analytic
