"""Adaptive-R scheduler tests: spec round-trip, controller behavior, bucket
equivalence, and the zero-recompile guarantee across R switches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs
from repro.codecs import AdaptiveC3SL, build, clamp_R
from repro.core import split as split_lib


# --------------------------------------------------------------------------
# spec / ladder construction
# --------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    "adaptive:c3sl:R=8,D=64,min_R=2",
    "adaptive:c3sl:R=16,D=64,min_R=2,target_snr=12.0",
    "adaptive:c3sl:R=4,D=64,min_R=2,ema=0.8,hysteresis=2.0",
    "adaptive:c3sl:R=8,D=64,backend=direct,min_R=2|int8",
    "adaptive:c3sl:R=8,D=256,min_R=2|topk:k=16|int8",
    "adaptive:c3sl:R=4,D=64",                      # min_R defaults to 1
])
def test_adaptive_spec_roundtrip(spec):
    c = build(spec)
    assert c.spec() == spec
    assert build(c.spec()).spec() == spec


def test_adaptive_builds_bucket_ladder():
    c = build("adaptive:c3sl:R=16,min_R=2,target_snr=12", D=128)
    assert isinstance(c, AdaptiveC3SL)
    assert c.ladder == (2, 4, 8, 16)
    assert c.current_R == 2                        # starts conservative
    assert c.target_snr == 12.0
    # one pre-built inner codec per bucket, chained specs rebuilt via clamp_R
    assert {R: b.spec() for R, b in c.buckets.items()} == {
        R: f"c3sl:R={R},D=128" for R in (2, 4, 8, 16)}
    chained = build("adaptive:c3sl:R=8,min_R=2|int8", D=64)
    assert chained.buckets[4].spec() == "c3sl:R=4,D=64|int8"


def test_adaptive_defaults_flow_to_inner_and_adaptive_args():
    # runtime defaults fill the inner spec; adaptive args may come from
    # defaults too, but explicit spec args always win
    c = build("adaptive:c3sl:R=8", D=64, min_R=4, target_snr=-3.0)
    assert c.min_R == 4 and c.target_snr == -3.0 and c.D == 64
    c = build("adaptive:c3sl:R=8,min_R=2", D=64, min_R=4)
    assert c.min_R == 2


def test_adaptive_validation_errors():
    with pytest.raises(ValueError, match="power of two"):
        build("adaptive:c3sl:R=6,D=64,min_R=2")    # 6/2 = 3 buckets?? no
    with pytest.raises(ValueError, match="min_R"):
        build("adaptive:c3sl:R=4,D=64,min_R=8")
    with pytest.raises(ValueError, match="inner codec spec"):
        build("adaptive", D=64)
    with pytest.raises(ValueError, match="ema"):
        build("adaptive:c3sl:R=4,D=64,ema=1.0")
    # R=1 transforms build the degenerate single-bucket wrapper (nothing to
    # schedule, but clamp_R may legitimately collapse a ladder to this)
    assert build("adaptive:identity:D=64").ladder == (1,)


def test_clamp_R_trims_adaptive_ladder_and_roundtrips():
    c = build("adaptive:c3sl:R=16,min_R=2,target_snr=5|int8", D=64)
    t = clamp_R(c, 8)
    assert t.ladder == (2, 4, 8) and t.max_R == 8
    assert t.target_snr == 5.0                     # controller knobs survive
    assert build(t.spec()).spec() == t.spec()
    assert clamp_R(c, 16) is c                     # no-op keeps identity
    # degenerate: clamp below min_R collapses to a single bucket
    one = clamp_R(c, 1)
    assert one.ladder == (1,)


def test_clamp_R_drops_buckets_that_do_not_divide_the_batch():
    """clamp_R's max_R is the runtime batch/slot count, and batch-wise
    grouping needs batch % R == 0 — a bucket that merely FITS the batch but
    does not divide it would let the controller ramp into a mid-training
    shape error (batch 12 must drop R=8, keeping {2, 4})."""
    c = build("adaptive:c3sl:R=8,min_R=2", D=64)
    t = clamp_R(c, 12)
    assert t.ladder == (2, 4)
    assert build(t.spec()).spec() == t.spec()
    # batch 6: only R=2 divides; batch 7: nothing does -> single R=7 bucket
    assert clamp_R(c, 6).ladder == (2,)
    assert clamp_R(c, 7).ladder == (7,)
    # every surviving bucket's encode really fits the clamp target
    import jax as _jax
    t6 = clamp_R(c, 6)
    p = t6.init(_jax.random.PRNGKey(0))
    Z = _jax.random.normal(_jax.random.PRNGKey(1), (6, 64))
    for R in t6.ladder:
        t6.pin(R)
        assert t6.encode(p, Z).shape == (6 // R, 64)


# --------------------------------------------------------------------------
# controller
# --------------------------------------------------------------------------

def test_controller_ladder_walk_with_hysteresis():
    c = build("adaptive:c3sl:R=8,D=64,min_R=2,target_snr=0,ema=0.0,"
              "hysteresis=1.0")
    assert c.current_R == 2
    assert c.observe(5.0) == 4                     # headroom -> ramp up
    assert c.observe(5.0) == 8
    assert c.observe(5.0) == 8                     # top of the ladder holds
    # deadband: |snr - target| <= hysteresis changes nothing
    assert c.observe(0.5) == 8
    assert c.observe(-0.5) == 8
    assert c.observe(-3.0) == 4                    # below target -> back off
    assert c.observe(-3.0) == 2
    assert c.observe(-3.0) == 2                    # floor holds


def test_controller_ema_smooths_the_signal():
    c = build("adaptive:c3sl:R=8,D=64,min_R=2,target_snr=0,ema=0.9")
    c.observe(-10.0)                               # ema seeds at -10
    assert c.ema_snr == -10.0
    # one high outlier must not flip the decision through the EMA
    assert c.observe(30.0) == 2
    assert c.ema_snr == pytest.approx(-6.0)


def test_controller_loss_slack_vetoes_and_forces():
    c = build("adaptive:c3sl:R=8,D=64,min_R=2,target_snr=0,ema=0.0")
    # SNR headroom but negative slack: forced DOWN (here: held at floor)
    assert c.observe(10.0, loss_slack=-1.0) == 2
    c.observe(10.0)
    assert c.current_R == 4
    assert c.observe(10.0, loss_slack=-1.0) == 2   # ramp-down beats SNR
    # zero slack vetoes the ramp-up without forcing down
    assert c.observe(10.0, loss_slack=0.0) == 2
    assert c.observe(10.0, loss_slack=1.0) == 4    # positive slack allows it


def test_pin_freezes_the_schedule():
    c = build("adaptive:c3sl:R=8,D=64,min_R=2,target_snr=0,ema=0.0")
    c.pin(4)
    for snr in (30.0, 30.0, -30.0, -30.0):
        assert c.observe(snr) == 4
    assert c.ema_snr is not None                   # EMA still tracks
    c.unpin()
    assert c.observe(-30.0) == 2
    with pytest.raises(ValueError, match="not in bucket ladder"):
        c.pin(3)


# --------------------------------------------------------------------------
# protocol surface + bucket equivalence
# --------------------------------------------------------------------------

def test_adaptive_protocol_accounting_follows_current_bucket():
    c = build("adaptive:c3sl:R=8,min_R=2|int8", D=64)
    B = 16
    for R in (2, 4, 8):
        c.pin(R)
        assert c.R == R
        assert c.payload_shape(B) == (B // R, 64)
        assert c.wire_bytes(B) == c.buckets[R].wire_bytes(B)
        assert c.flops(B) == c.buckets[R].flops(B)
    # resident params: every bucket's key table lives in memory at once
    assert c.param_count() == sum(R * 64 for R in (2, 4, 8))
    assert c.feature_layout == "flat"
    # the stages surface exposes the chain through the wrapper, so
    # payload_wire_bytes sees the int8 wire stage
    assert codecs.payload_wire_bytes(c, (4, 64)) == 4 * 64 + 4 * 4


def test_adaptive_pinned_is_bit_identical_to_static_bucket():
    rng = jax.random.PRNGKey(7)
    Z = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
    for spec, static_spec in [
        ("adaptive:c3sl:R=8,min_R=2", "c3sl:R=4,D=64"),
        ("adaptive:c3sl:R=8,min_R=2|int8", "c3sl:R=4,D=64|int8"),
    ]:
        a = build(spec, D=64).pin(4)
        s = build(static_spec)
        pa, ps = a.init(rng), s.init(rng)
        np.testing.assert_array_equal(np.asarray(a.encode(pa, Z)),
                                      np.asarray(s.encode(ps, Z)))
        np.testing.assert_array_equal(
            np.asarray(a.decode(pa, a.encode(pa, Z))),
            np.asarray(s.decode(ps, s.encode(ps, Z))))


# --------------------------------------------------------------------------
# zero recompiles across R switches
# --------------------------------------------------------------------------

def test_zero_recompiles_across_R_switches():
    """The jit-safety contract the whole design hangs on: one compiled
    branch per bucket, switched host-side — an R schedule that bounces
    across the ladder must trace each bucket EXACTLY once (the trace
    counter increments only while tracing)."""
    D_in, D_cut, n_cls, B = 8, 64, 4, 16
    rng = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(rng, 3)
    net = {"front": {"w": jax.random.normal(k1, (D_in, D_cut)) * D_in ** -0.5},
           "back": {"w": jax.random.normal(k2, (D_cut, n_cls)) * D_cut ** -0.5}}
    codec = build("adaptive:c3sl:R=8,D=64,min_R=2,target_snr=0")
    codec_params = codec.init(jax.random.PRNGKey(7))
    traces = [0]

    def make_step(bucket, bucket_params):
        loss_fn = split_lib.make_split_loss_fn(
            lambda p, x: jax.nn.relu(x @ p["w"]), lambda p, z: z @ p["w"],
            bucket, lambda logits, y: jnp.mean((logits - y) ** 2),
            with_metrics=True)

        @jax.jit
        def step(net, batch):
            traces[0] += 1            # runs only while tracing
            params = {**net, "codec": bucket_params}
            (loss, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                     batch)
            net2 = jax.tree.map(lambda a, b: a - 0.1 * b, net,
                                {"front": g["front"], "back": g["back"]})
            return net2, loss, m["cut_snr"]

        return step

    step_fns = {R: make_step(codec.buckets[R],
                             codec.params_for(codec_params, R))
                for R in codec.ladder}
    batch = {"x": jax.random.normal(k3, (B, D_in)),
             "y": jnp.zeros((B, n_cls))}
    # warm every bucket, then drive a schedule that switches every step
    for R in codec.ladder:
        step_fns[R](net, batch)
    assert traces[0] == len(codec.ladder)
    for R in (2, 4, 8, 4, 2, 8, 2, 4, 8, 8, 2):
        codec.pin(R)
        net, loss, snr = step_fns[codec.current_R](net, batch)
    assert traces[0] == len(codec.ladder), "R switch triggered a retrace"


def test_engine_zero_recompiles_and_r_served_across_switches():
    """Same contract at the serving layer: the engine pre-compiles one
    program set per bucket; pinning a different R between run() calls
    reuses the existing programs (jit cache misses would show up as new
    traces of lm.decode_step — instead we assert the engine keeps exactly
    one compiled window/prefill per bucket and the served schedule lands
    in r_served)."""
    from repro.configs.base import get_config, reduced
    from repro.models import lm as lm_lib
    from repro.serving.engine import BatchedEngine, Request
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=64,
                  d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=1,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    eng = BatchedEngine(params, cfg, num_slots=4, max_len=16,
                        codec="adaptive:c3sl:R=4,min_R=2|int8")
    assert set(eng._programs) == {2, 4}
    progs = {R: eng._programs[R] for R in (2, 4)}
    for pin in (2, 4, 2):
        eng.codec.pin(pin)
        for u in range(2):
            eng.submit(Request(uid=10 * pin + u, prompt=[1 + u, 2, 3],
                               max_new_tokens=2))
        eng.run(max_steps=64)
    assert eng._programs is not None and all(
        eng._programs[R] is progs[R] for R in (2, 4))  # never rebuilt
    assert set(eng.r_served) == {2, 4}                 # both buckets served
    assert eng.stats["payload_wire_bytes"] > 0
    assert len(eng.finished) == 6
