"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned arch family runs one forward + one train step + one decode step on
CPU, asserting output shapes and finite values."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ALL_ARCHS
from repro.configs.base import get_config, reduced
from repro.models import lm as lm_lib
from repro.optim import adam, apply_updates

B, S = 2, 16


def _setup(name):
    cfg = reduced(get_config(name))
    rng = jax.random.PRNGKey(0)
    params = lm_lib.init_lm_params(rng, cfg)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(
            rng, (B, cfg.frontend_seq, cfg.frontend_dim))
    return cfg, params, batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_forward_shapes_finite(name):
    cfg, params, batch = _setup(name)
    logits, aux = jax.jit(
        lambda p, b: lm_lib.lm_forward(p, b, cfg, remat=False))(params, batch)
    S_total = S + (cfg.frontend_seq if cfg.frontend and not cfg.is_encdec else 0)
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_train_step_reduces_nothing_nan(name):
    cfg, params, batch = _setup(name)
    opt = adam(1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(p, o, b):
        loss, grads = jax.value_and_grad(
            lambda q: lm_lib.lm_loss(q, b, cfg))(p)
        updates, o = opt.update(grads, o, p)
        return apply_updates(p, updates), o, loss

    p1, o1, l1 = step(params, opt_state, batch)
    p2, o2, l2 = step(p1, o1, batch)
    assert np.isfinite(float(l1)) and np.isfinite(float(l2))
    assert float(l2) < float(l1) + 0.5  # no blow-up on identical batch
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_decode_step(name):
    cfg, params, batch = _setup(name)
    cache = lm_lib.init_decode_cache(params, cfg, B, 32,
                                     frontend_emb=batch.get("frontend"))
    logits, new_cache = jax.jit(
        lambda p, c, t: lm_lib.decode_step(p, c, t, jnp.int32(0), cfg))(
        params, cache, batch["tokens"][:, :1])
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("name", ["deepseek-7b", "rwkv6-1.6b",
                                  "deepseek-v2-lite-16b", "jamba-1.5-large-398b"])
def test_parallel_vs_sequential_decode_consistency(name):
    """The recurrent/cached decode forms must match the parallel train form."""
    cfg = reduced(get_config(name))
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    rng = jax.random.PRNGKey(1)
    params = lm_lib.init_lm_params(rng, cfg)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(
            rng, (B, cfg.frontend_seq, cfg.frontend_dim))
    logits_par, _ = lm_lib.lm_forward(params, batch, cfg, remat=False)
    cache = lm_lib.init_decode_cache(params, cfg, B, S,
                                     frontend_emb=batch.get("frontend"))
    step = jax.jit(lambda p, c, t, pos: lm_lib.decode_step(p, c, t, pos, cfg))
    outs = []
    for t in range(S):
        lg, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(logits_par - jnp.stack(outs, axis=1))))
    assert err < 2e-3, err


def test_sliding_window_variant_matches_full_when_window_covers():
    cfg = reduced(get_config("deepseek-7b"))
    cfg_win = dataclasses.replace(cfg, sliding_window=S + 4)  # covers all
    rng = jax.random.PRNGKey(2)
    params = lm_lib.init_lm_params(rng, cfg)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    full, _ = lm_lib.lm_forward(params, batch, cfg, remat=False)
    win, _ = lm_lib.lm_forward(params, batch, cfg_win, remat=False)
    np.testing.assert_allclose(np.asarray(win), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_sliding_window_actually_windows():
    cfg = reduced(get_config("deepseek-7b"))
    cfg_win = dataclasses.replace(cfg, sliding_window=4)
    rng = jax.random.PRNGKey(2)
    params = lm_lib.init_lm_params(rng, cfg)
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    full, _ = lm_lib.lm_forward(params, batch, cfg, remat=False)
    win, _ = lm_lib.lm_forward(params, batch, cfg_win, remat=False)
    assert float(jnp.max(jnp.abs(full - win))) > 1e-3


def test_param_count_matches_init():
    """Analytic param_count must equal the actual initialized tree size."""
    for name in ALL_ARCHS:
        cfg = reduced(get_config(name))
        params = lm_lib.abstract_params(cfg)
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.05, \
            (name, actual, analytic)
