"""Sharding-rule engine tests: divisibility guards, rule hits, ZeRO extension."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import rules


class FakeMesh:
    """Minimal stand-in exposing .shape / .axis_names (no devices needed)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


def test_attention_weight_rules():
    assert rules.spec_for_param("/stack/l0_0_attn/w_q", (4096, 4096), MESH) \
        == P(None, "model")
    assert rules.spec_for_param("/stack/l0_0_attn/w_o", (4096, 4096), MESH) \
        == P("model", None)
    # stacked leading superblock dim gets padded with None
    assert rules.spec_for_param("/stack/l0_0_attn/w_q", (30, 4096, 4096), MESH) \
        == P(None, None, "model")


def test_moe_expert_parallel():
    assert rules.spec_for_param("/stack/l0_1_moe/w_gate", (16, 4096, 6400), MESH) \
        == P("model", None, None)
    assert rules.spec_for_param("/stack/l0_1_moe/router", (4096, 16), MESH) \
        == P(None, None)  # replicated (router output feeds top_k)


def test_divisibility_guard_replicates():
    # 10 heads not divisible by 16 -> replicate that dim
    assert rules.spec_for_param("/x/w_q", (4096, 10), MESH) == P(None, None)
    # kv_heads*hd = 2*128 = 256 divisible -> sharded
    assert rules.spec_for_param("/x/w_k", (4096, 256), MESH) == P(None, "model")


def test_rwkv_name_disambiguation():
    # rwkv channel-mix w_v is an OUTPUT projection (ff, d): row-sharded
    assert rules.spec_for_param("/stack/l0_1_rwkv_cm/w_v", (7168, 2048), MESH) \
        == P("model", None)
    # attention w_v is column-sharded
    assert rules.spec_for_param("/stack/l0_0_attn/w_v", (2048, 2048), MESH) \
        == P(None, "model")


def test_zero_extension_picks_largest_free_dim():
    spec = rules._extend_over(P(None, "model"), (4096, 4096), MESH, "data")
    assert spec == P("data", "model")
    # already fully sharded -> unchanged
    spec = rules._extend_over(P("data", "model"), (4096, 4096), MESH, "data")
    assert spec == P("data", "model")
    # nothing divisible -> unchanged
    spec = rules._extend_over(P(), (5, 3), MESH, "data")
    assert spec == P(None, None)


def test_norms_replicated():
    assert rules.spec_for_param("/stack/l0_0_attn/norm/scale", (4096,), MESH) \
        == P(None)
    assert rules.spec_for_param("/final_norm/scale", (4096,), MESH) == P(None)


def test_cache_rules():
    import jax.numpy as jnp
    from repro.launch import mesh as mesh_lib
    mesh = mesh_lib.make_host_mesh(data=1, model=1)
    cache = {"stack": {"l0_0_attn": {"k": jnp.zeros((2, 4, 8, 2, 16)),
                                     "v": jnp.zeros((2, 4, 8, 2, 16))}}}
    sh = rules.cache_shardings(cache, mesh)
    spec = sh["stack"]["l0_0_attn"]["k"].spec
    # (N, B, T, KV, hd): B->data, T->model (guarded: size-1 axes always ok)
    assert spec == P(None, "data", "model", None, None)
