"""In-kernel paged attention vs the gather read: BIT-identical, loudly gated.

The kernel tier's contract (repro.kernels.paged_attention) is the same one
tests/test_paged_cache.py pins for paged-vs-contiguous: not "close", but
bit-for-bit equal greedy outputs — the page-table walk moved into the
kernel must be invisible to every downstream consumer.  Covered here:

- step-level decode equivalence across plain GQA, ring-buffer SWA, and
  int8-quantized KV, over SHUFFLED page tables with dead slots and
  staggered per-slot positions;
- engine-level greedy identity (``kv_read="kernel"`` vs ``"gather"``),
  including mid-stream eviction/resume under slot preemption;
- the ``gather_pages`` trailing-page parities (length exactly on a page
  boundary vs one-past — the edge audited in repro.models.paging);
- a hypothesis property for the in-kernel page-table addressing math;
- the LOUD gating: kernel-without-paged raises, uncovered layouts warn,
  and the effective execution mode is surfaced in engine stats.
"""
import dataclasses
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.models import attention as attn_lib
from repro.models import lm as lm_lib
from repro.models.paging import PagedLayout, gather_pages
from repro.serving.engine import BatchedEngine, Request


def _cfg(**over):
    base = dict(num_layers=2, d_model=128, d_ff=256, vocab_size=128,
                num_heads=4, num_kv_heads=2, head_dim=32)
    base.update(over)
    return reduced(get_config("deepseek-7b"), **base)


def _variant_cfg(variant):
    cfg = _cfg()
    if variant == "swa":
        cfg = dataclasses.replace(cfg, sliding_window=8)
    elif variant == "int8":
        cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    return cfg


def _paged_cache(params, cfg, B, T, ps, rng):
    """Fully-provisioned paged cache with SHUFFLED page tables (same
    construction as tests/test_paged_cache.py): the kernel's in-table walk
    can only agree with gather if the indirection is right."""
    pps = -(-T // ps)
    len_swa = min(T, cfg.sliding_window) if cfg.sliding_window else 0
    pps_swa = -(-len_swa // ps) if len_swa else 0
    layout = PagedLayout(ps, T, B * pps, len_swa, max(B * pps_swa, 1)
                         if len_swa else 0)
    cache = lm_lib.init_decode_cache(params, cfg, B, T, paged=layout)
    cache["pages"] = jnp.asarray(
        rng.permutation(B * pps).astype(np.int32).reshape(B, pps))
    if len_swa:
        cache["pages_swa"] = jnp.asarray(
            rng.permutation(B * pps_swa).astype(np.int32).reshape(B, pps_swa))
    return layout, cache


@pytest.fixture(scope="module", params=["plain", "swa", "int8"])
def variant_setup(request):
    cfg = _variant_cfg(request.param)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    return request.param, cfg, params


# ---------------------------------------------------------------------------
# step-level: decode_step(kv_read="kernel") == decode_step(kv_read="gather")
# ---------------------------------------------------------------------------

def test_decode_step_kernel_bitwise_equals_gather(variant_setup):
    _, cfg, params = variant_setup
    B, T, ps = 4, 32, 8
    rng = np.random.RandomState(1)
    layout, cache = _paged_cache(params, cfg, B, T, ps, rng)
    cache_g = dict(cache)
    cache_k = dict(cache)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, 1)), jnp.int32)
    # staggered per-slot positions + a dead slot: the kernel must honor
    # the same per-row masks, not just a uniform clock
    pos = np.array([0, 3, 1, 5], np.int32)
    live = jnp.array([True, True, False, True])
    for _ in range(6):
        lg, cache_g = lm_lib.decode_step(params, cache_g, toks,
                                         jnp.asarray(pos), cfg, paged=layout,
                                         live=live, kv_read="gather")
        lk, cache_k = lm_lib.decode_step(params, cache_k, toks,
                                         jnp.asarray(pos), cfg, paged=layout,
                                         live=live, kv_read="kernel")
        np.testing.assert_array_equal(np.asarray(lg), np.asarray(lk))
        for g, k in zip(jax.tree.leaves(cache_g), jax.tree.leaves(cache_k)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(k))
        toks = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
        pos = pos + np.asarray(live)


# ---------------------------------------------------------------------------
# kernel-level: trailing-page parity (the audited gather_pages edge)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("length", [16, 17, 23])
def test_trailing_page_parity_matches_sdpa_over_gather(length):
    """length = 16 sits EXACTLY on the page boundary (2 full pages of 8);
    17 is one-past (3rd page holds one row); 23 is a ragged tail.  The
    kernel fetches whole pages and slices scratch, gather slices the
    reshaped view — both must agree bitwise, with the causal mask (not
    the slice) hiding unwritten positions either way."""
    from repro.kernels import paged_attention as pa
    B, ps, H, KV, hd = 3, 8, 4, 2, 16
    P = -(-length // ps)
    rng = np.random.RandomState(0)
    npages = B * P + 2                     # spare pages: tables don't cover pool
    k_pool = jnp.asarray(rng.randn(npages, ps, KV, hd).astype(np.float32))
    v_pool = jnp.asarray(rng.randn(npages, ps, KV, hd).astype(np.float32))
    table = jnp.asarray(rng.permutation(npages)[:B * P].astype(np.int32)
                        .reshape(B, P))
    q = jnp.asarray(rng.randn(B, 1, H, hd).astype(np.float32))
    # pos on both sides of the last boundary, incl. the final position
    pos = jnp.asarray(np.array([length - 1, length - 2,
                                max(length - ps - 1, 0)], np.int32))
    got = pa.paged_attention(q, k_pool, v_pool, table, pos, length=length)

    k = gather_pages(k_pool, table, length)[None]      # (1, B, T, KV, hd)
    v = gather_pages(v_pool, table, length)[None]
    idx = jnp.arange(length)[None, :]
    mask = (idx <= pos[:, None])[:, None, None, :]
    want = attn_lib._sdpa(q, k[0], v[0], mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# hypothesis property: in-kernel page-table addressing math
# ---------------------------------------------------------------------------

@pytest.mark.property
def test_page_walk_addressing_property():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, strategies as st
    from repro.kernels import paged_attention as pa

    @given(st.data())
    def run(data):
        ps = data.draw(st.integers(1, 8), label="page_size")
        P = data.draw(st.integers(1, 4), label="pages_per_slot")
        B = data.draw(st.integers(1, 3), label="batch")
        length = data.draw(st.integers(1, P * ps), label="length")
        seed = data.draw(st.integers(0, 2 ** 16), label="seed")
        H, KV, hd = 2, 2, 4
        rng = np.random.RandomState(seed)
        npages = B * P
        k_pool = jnp.asarray(rng.randn(npages, ps, KV, hd).astype(np.float32))
        v_pool = jnp.asarray(rng.randn(npages, ps, KV, hd).astype(np.float32))
        table = jnp.asarray(rng.permutation(npages).astype(np.int32)
                            .reshape(B, P))
        q = jnp.asarray(rng.randn(B, 1, H, hd).astype(np.float32))
        pos = jnp.asarray(rng.randint(0, length, (B,)).astype(np.int32))
        got = pa.paged_attention(q, k_pool, v_pool, table, pos, length=length)
        k = gather_pages(k_pool, table, length)
        v = gather_pages(v_pool, table, length)
        idx = jnp.arange(length)[None, :]
        mask = (idx <= pos[:, None])[:, None, None, :]
        want = attn_lib._sdpa(q, k, v, mask)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    run()


# ---------------------------------------------------------------------------
# engine-level: greedy outputs identical across kv_read, incl. preemption
# ---------------------------------------------------------------------------

def _engine(cfg, params, **kw):
    kw.setdefault("num_slots", 4)
    kw.setdefault("max_len", 32)
    kw.setdefault("chunk_size", 8)
    kw.setdefault("sync_every", 4)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("page_size", 8)
    with warnings.catch_warnings():
        # kv_read="kernel" warns about its gather fallbacks by design
        # (tested explicitly below); keep equivalence runs quiet
        warnings.simplefilter("ignore")
        return BatchedEngine(params, cfg, greedy=True, seed=0, **kw)


def _prompts(rng, lens, vocab=128):
    return [[int(t) for t in rng.randint(1, vocab, n)] for n in lens]


def test_engine_greedy_identity_kernel_vs_gather(variant_setup):
    _, cfg, params = variant_setup
    rng = np.random.RandomState(7)
    # prompt lengths straddle the page boundary (8): 7 / 8 / 9 cover both
    # trailing-page parities through prefill-then-decode
    prompts = _prompts(rng, [7, 8, 9, 3], vocab=cfg.vocab_size)
    outs = {}
    for kv_read in ("gather", "kernel"):
        eng = _engine(cfg, params, kv_read=kv_read)
        for uid, p in enumerate(prompts):
            eng.submit(Request(uid=uid, prompt=list(p), max_new_tokens=8))
        outs[kv_read] = {r.uid: r.out for r in eng.run()}
    assert outs["kernel"] == outs["gather"]
    assert len(outs["kernel"]) == len(prompts)


def test_engine_kernel_survives_eviction_and_resume():
    """Mid-stream eviction/resume (slot preemption) under the kernel read:
    the re-admitted request re-prefills and resumes to the same greedy
    output as an uncontended gather-read run."""
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(3)
    shorts = [Request(uid=i, prompt=_prompts(rng, [4])[0], max_new_tokens=8)
              for i in range(2)]
    premium = Request(uid=9, prompt=_prompts(rng, [20])[0], max_new_tokens=4,
                      priority=1)
    # solo (uncontended) references on the GATHER path
    ref = {}
    for r in shorts + [premium]:
        eng = _engine(cfg, params, num_slots=2, num_pages=6, kv_read="gather",
                      preemption=True)
        eng.submit(Request(uid=r.uid, prompt=list(r.prompt),
                           max_new_tokens=r.max_new_tokens))
        ref[r.uid] = eng.run()[0].out
    # oversubscribed KERNEL-read engine: premium preempts the shorts
    eng = _engine(cfg, params, num_slots=2, num_pages=6, kv_read="kernel",
                  preemption=True)
    for r in shorts:
        eng.submit(r)
    eng.tick()
    eng.submit(premium)
    done = {r.uid: r for r in eng.run()}
    assert set(done) == {0, 1, 9}
    assert eng.stats["evictions"] >= 1
    for uid, r in done.items():
        assert r.out == ref[uid], (uid, r.evictions)


# ---------------------------------------------------------------------------
# loud gating + execution-mode surfacing
# ---------------------------------------------------------------------------

def test_kernel_requires_paged_layout():
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="requires kv_layout='paged'"):
        BatchedEngine(params, cfg, kv_layout="contiguous", kv_read="kernel")


def test_kernel_requires_attn_layers():
    cfg = _cfg()
    cfg = dataclasses.replace(cfg, block_pattern=(("mamba", "mlp"),))
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="no attn sublayer"):
        BatchedEngine(params, cfg, kv_layout="paged", kv_read="kernel")


def test_apply_gqa_decode_rejects_kernel_without_pages():
    with pytest.raises(ValueError, match="requires the paged cache layout"):
        attn_lib.apply_gqa_decode(
            {}, jnp.zeros((2, 1, 128)), {}, jnp.zeros((2,), jnp.int32),
            num_heads=4, num_kv_heads=2, head_dim=32, rotary_dim=32,
            kv_read="kernel")


def test_fallback_warning_is_loud():
    """Uncovered reads (here: chunked prefill) warn at construction —
    the engine never silently serves gather while claiming the kernel."""
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    with pytest.warns(UserWarning, match="stay on the gather read path"):
        BatchedEngine(params, cfg, kv_layout="paged", kv_read="kernel",
                      prefill_mode="chunked")


def test_execution_mode_in_engine_stats():
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    eng = _engine(cfg, params, kv_read="kernel")
    assert eng.stats["kv_read"] == "kernel"
    expected = ("pallas-compiled" if jax.default_backend() == "tpu"
                else "pallas-interpret")
    assert eng.stats["kv_read_execution_mode"] == expected
    assert eng.stats["codec_execution_mode"] == "none"

    eng = _engine(cfg, params, kv_read="gather", codec="c3sl:R=2")
    assert eng.stats["kv_read"] == "gather"
    assert eng.stats["kv_read_execution_mode"] == "gather"
    assert eng.stats["codec_execution_mode"] == "fft"
