"""Slot preemption/eviction and early page release.

Pins the engine-side half of the front door's QoS story:

- an evicted request re-prefills prompt + emitted tokens and resumes to a
  greedy output BIT-IDENTICAL to an uninterrupted run;
- the page-pool accounting invariant (free + in_use == total) holds
  across evict/realloc cycles;
- equal-priority work is never preempted (``preemption=False`` and the
  default priority keep the seed's strict FIFO);
- a slot retiring at the decode window's EOS early exit frees its WHOLE
  reservation at that host sync — before any admit/retire boundary —
  with outputs captured at their actual emitted length.
"""
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import lm as lm_lib
from repro.serving.engine import BatchedEngine, Request

import jax


def _cfg():
    return reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                   d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                   head_dim=32)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("chunk_size", 8)
    kw.setdefault("sync_every", 4)
    return BatchedEngine(params, cfg, greedy=True, seed=0,
                         prefill_mode="chunked", **kw)


def _prompt(rng, n, vocab=128):
    return [int(t) for t in rng.randint(1, vocab, n)]


def _solo_outputs(cfg, params, reqs, **kw):
    """Reference greedy outputs, one uncontended engine run per request
    (greedy + no codec: outputs depend only on the prompt)."""
    outs = {}
    for r in reqs:
        eng = _engine(cfg, params, **kw)
        eng.submit(Request(uid=r.uid, prompt=list(r.prompt),
                           max_new_tokens=r.max_new_tokens))
        done = list(eng.run())
        assert len(done) == 1
        outs[r.uid] = done[0].out
    return outs


def _oversubscribed(cfg, params, *, preemption):
    """2 slots, 6-page pool (page_size=8): two low-priority shorts hold
    2 pages each, the premium request needs 3 — admissible only if the
    pool gives up pages the shorts hold."""
    eng = _engine(cfg, params, kv_layout="paged", page_size=8, num_pages=6,
                  preemption=preemption)
    rng = np.random.RandomState(3)
    shorts = [Request(uid=i, prompt=_prompt(rng, 4), max_new_tokens=8)
              for i in range(2)]
    premium = Request(uid=9, prompt=_prompt(rng, 20), max_new_tokens=4,
                      priority=1)
    return eng, shorts, premium


def test_evicted_request_resumes_identical_greedy_output(setup):
    cfg, params = setup
    eng, shorts, premium = _oversubscribed(cfg, params, preemption=True)
    ref = _solo_outputs(cfg, params, shorts + [premium],
                        kv_layout="paged", page_size=8, num_pages=6)
    for r in shorts:
        eng.submit(r)
    # run the shorts into mid-decode before the premium request arrives
    eng.tick()
    assert eng.active == 2 and eng.stats["evictions"] == 0
    eng.submit(premium)
    done = {r.uid: r for r in eng.run()}
    assert set(done) == {0, 1, 9}
    assert eng.stats["evictions"] >= 1
    evicted = [r for r in done.values() if r.evictions]
    assert evicted and all(r.priority == 0 for r in evicted)
    assert done[9].evictions == 0          # the preemptor is never a victim
    for uid, r in done.items():
        assert r.out == ref[uid], (uid, r.evictions)
        assert len(r.out) == r.max_new_tokens


def test_premium_overtakes_fifo_only_with_preemption(setup):
    cfg, params = setup
    order = {}
    for preemption in (False, True):
        eng, shorts, premium = _oversubscribed(cfg, params,
                                               preemption=preemption)
        for r in shorts:
            eng.submit(r)
        eng.tick()
        eng.submit(premium)
        done = list(eng.run())
        assert len(done) == 3
        order[preemption] = [r.uid for r in done]
        if not preemption:
            assert eng.stats["evictions"] == 0
            # FIFO: the premium request finishes last, after the shorts
            # drain enough pages
            assert order[False][-1] == 9
    # with preemption the premium request finishes FIRST: it displaced the
    # running shorts instead of waiting out their reservations
    assert order[True][0] == 9


def test_pool_accounting_invariant_across_evictions(setup):
    cfg, params = setup
    eng, shorts, premium = _oversubscribed(cfg, params, preemption=True)
    for r in shorts:
        eng.submit(r)
    eng.tick()
    eng.submit(premium)
    ticks = 0
    while eng.tick():
        acct = eng.pool_accounting()
        assert acct["free"] + acct["in_use"] == acct["total"], acct
        per_slot = [len(s.pages) for s in eng.slots]
        assert sum(per_slot) == acct["in_use"]
        ticks += 1
        assert ticks < 500, "engine failed to drain"
    assert eng.stats["evictions"] >= 1
    acct = eng.pool_accounting()
    assert acct["free"] == acct["total"], acct


def test_equal_priority_never_preempted(setup):
    cfg, params = setup
    eng = _engine(cfg, params, kv_layout="paged", page_size=8, num_pages=6,
                  preemption=True)
    rng = np.random.RandomState(5)
    for i in range(2):
        eng.submit(Request(uid=i, prompt=_prompt(rng, 4), max_new_tokens=8))
    eng.tick()
    # same default priority as the running shorts: must NOT evict them
    eng.submit(Request(uid=9, prompt=_prompt(rng, 20), max_new_tokens=4))
    done = list(eng.run())
    assert len(done) == 3
    assert eng.stats["evictions"] == 0
    assert [r.uid for r in done][-1] == 9


def test_slots_only_preemption_contiguous(setup):
    cfg, params = setup
    eng = _engine(cfg, params, num_slots=1, preemption=True)
    rng = np.random.RandomState(7)
    low = Request(uid=0, prompt=_prompt(rng, 4), max_new_tokens=12)
    high = Request(uid=1, prompt=_prompt(rng, 4), max_new_tokens=4,
                   priority=2)
    ref = _solo_outputs(cfg, params, [low, high], num_slots=1)
    eng.submit(low)
    eng.tick()                     # low occupies the only slot, mid-decode
    eng.submit(high)
    done = {r.uid: r for r in eng.run()}
    assert eng.stats["evictions"] == 1
    assert done[0].evictions == 1
    assert done[0].out == ref[0]   # resumed run == uninterrupted run
    assert done[1].out == ref[1]


def test_eviction_is_feasibility_checked(setup):
    cfg, params = setup
    # 5-page pool: an equal-priority request holds 3 pages (NOT a victim)
    # and the only lower-priority victim holds 2 -- evicting it cannot
    # cover the head's 3-page need, so nothing may be evicted pointlessly
    eng = _engine(cfg, params, kv_layout="paged", page_size=8, num_pages=5,
                  preemption=True)
    rng = np.random.RandomState(11)
    peer = Request(uid=0, prompt=_prompt(rng, 16), max_new_tokens=8,
                   priority=1)                     # 3 pages, same rank as head
    victim = Request(uid=1, prompt=_prompt(rng, 4), max_new_tokens=8)
    eng.submit(peer)
    eng.submit(victim)
    eng.tick()
    assert eng.active == 2         # 5 pages in use, 0 free, both mid-decode
    eng.submit(Request(uid=9, prompt=_prompt(rng, 16), max_new_tokens=8,
                       priority=1))
    done = list(eng.run())
    assert len(done) == 3
    assert eng.stats["evictions"] == 0
    assert all(r.evictions == 0 for r in done)


def test_eos_early_exit_frees_pages_before_boundary(setup):
    cfg, params = setup
    # page_size=4, pool=7: A (prompt 6 + 2 new -> 2 pages) and B (prompt 4
    # + 16 new -> 5 pages) fill the pool; C (5 pages) starves behind them
    eng = _engine(cfg, params, kv_layout="paged", page_size=4, num_pages=7,
                  sync_every=8)
    rng = np.random.RandomState(13)
    a = Request(uid=0, prompt=_prompt(rng, 6), max_new_tokens=2)
    b = Request(uid=1, prompt=_prompt(rng, 4), max_new_tokens=16)
    c = Request(uid=2, prompt=_prompt(rng, 10), max_new_tokens=10)
    eng.submit(a)
    eng.submit(b)
    eng._boundary()
    while eng._pending_prefill():
        eng._prefill_one_chunk()
    assert eng.pool_accounting() == {"free": 0, "in_use": 7, "total": 7}
    eng.submit(c)                  # starved head: needs 5 pages, 0 free
    executed = eng._decode_window(8)
    # A finished mid-window (1 decode step after its prefill-committed
    # token) and the window exited early instead of running all 8 steps
    assert executed < 8
    assert eng.stats["eos_early_exits"] == 1
    # satellite fix: A retired AT THE WINDOW'S HOST SYNC -- outputs at
    # their actual emitted length, whole reservation back on the free
    # list, no _boundary() in between
    assert [r.uid for r in eng.finished] == [0]
    assert len(eng.finished[0].out) == 2
    assert eng.allocator.free_pages == 2
    assert eng.pool_accounting() == {"free": 2, "in_use": 5, "total": 7}
    # and the drain completes normally from there
    done = {r.uid: r for r in eng.run()}
    assert set(done) == {0, 1, 2}
    assert len(done[1].out) == 16 and len(done[2].out) == 10
    acct = eng.pool_accounting()
    assert acct["free"] == acct["total"]


def test_preemption_requires_chunked_prefill(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="preemption"):
        BatchedEngine(params, cfg, num_slots=2, max_len=32,
                      prefill_mode="decode", preemption=True)
