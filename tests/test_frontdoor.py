"""Front door: wire protocol, admission, QoS, and the loopback server.

The core acceptance test is loopback equivalence: a request stream
through HELLO/SUBMIT/RESULT frames over real TCP must produce greedy
outputs BIT-IDENTICAL to direct ``engine.submit()`` + ``engine.run()`` —
with and without a C3-SL codec.  Under batch-wise superposition the
outputs depend on slot occupancy, so the server is run with
``auto_tick=False`` and drained after all submissions land: identical
admission order -> identical dispatch schedule -> identical cross-talk.

No pytest-asyncio in the image: every async scenario runs under a plain
``asyncio.run``.
"""
import asyncio
import struct
import zlib

import numpy as np
import pytest

import jax

from repro.configs.base import get_config, reduced
from repro.frontdoor import (AdmissionController, BusyError, FrameCorruption,
                             FrontDoorClient, FrontDoorError, FrontDoorServer,
                             LogHistogram, MsgType, ProtocolError,
                             TenantPolicy, decode_frame, encode_frame,
                             pack_array, read_frame, unpack_array)
from repro.frontdoor.admission import ADMIT, BUSY_QUEUE, BUSY_TENANT
from repro.models import lm as lm_lib
from repro.serving.engine import BatchedEngine, Request


# ---------------------------------------------------------------------------
# protocol (no engine, no sockets)
# ---------------------------------------------------------------------------

def test_frame_roundtrip():
    arr = np.arange(7, dtype=np.int32)
    hdr, payload = pack_array(arr)
    frame = encode_frame(MsgType.SUBMIT, {"rid": 3, **hdr}, payload, seq=5)
    mtype, header, body, seq = decode_frame(frame[4:])
    assert mtype == MsgType.SUBMIT and header["rid"] == 3 and seq == 5
    np.testing.assert_array_equal(unpack_array(header, body), arr)


def test_frame_roundtrip_through_stream_reader():
    async def go():
        reader = asyncio.StreamReader()
        hdr, payload = pack_array(np.array([[1, 2], [3, 4]], dtype=np.int8))
        reader.feed_data(encode_frame(MsgType.RESULT, {"rid": 0, **hdr},
                                      payload))
        reader.feed_data(encode_frame(MsgType.BYE, {}))
        reader.feed_eof()
        mtype, header, body, nbytes, _ = await read_frame(reader)
        assert mtype == MsgType.RESULT and nbytes > len(payload)
        assert unpack_array(header, body).tolist() == [[1, 2], [3, 4]]
        mtype, _, _, _, _ = await read_frame(reader)
        assert mtype == MsgType.BYE
        assert await read_frame(reader) is None      # clean EOF

    asyncio.run(go())


def test_truncated_frame_fails_loudly():
    async def go():
        reader = asyncio.StreamReader()
        frame = encode_frame(MsgType.STATS, {"x": 1})
        reader.feed_data(frame[:-2])                 # die mid-body
        reader.feed_eof()
        with pytest.raises(ProtocolError, match="bytes into"):
            await read_frame(reader)

    asyncio.run(go())


def test_oversized_frame_refused():
    async def go():
        reader = asyncio.StreamReader()
        reader.feed_data(b"\xff\xff\xff\xff")        # 4 GiB declared length
        reader.feed_eof()
        with pytest.raises(ProtocolError, match="frame limit"):
            await read_frame(reader)

    asyncio.run(go())


def _crafted(t, hdr=b"{}", payload=b"", hlen=None, seq=0):
    """A CRC-VALID body with arbitrary (possibly malformed) content — the
    peer verifiably sent this, so decode must raise plain ProtocolError,
    not the NACKable FrameCorruption."""
    hlen = len(hdr) if hlen is None else hlen
    zero = struct.pack("!BIII", t, seq, 0, hlen)
    crc = zlib.crc32(payload, zlib.crc32(hdr, zlib.crc32(zero))) & 0xFFFFFFFF
    return struct.pack("!BIII", t, seq, crc, hlen) + hdr + payload


def test_decode_frame_rejects_garbage():
    with pytest.raises(ProtocolError, match="unknown message type"):
        decode_frame(_crafted(0x99))
    with pytest.raises(ProtocolError, match="overruns"):
        decode_frame(_crafted(1, hlen=0xFFFF))
    with pytest.raises(ProtocolError, match="non-JSON"):
        decode_frame(_crafted(1, hdr=b"[["))
    with pytest.raises(ProtocolError, match="JSON object"):
        decode_frame(_crafted(1, hdr=b"[]"))


def test_wire_damage_is_corruption_not_protocol_death():
    frame = encode_frame(MsgType.SUBMIT, {"rid": 1}, b"xy", seq=9)
    body = bytearray(frame[4:])
    body[-1] ^= 0x40                              # damage the payload
    with pytest.raises(FrameCorruption) as ei:
        decode_frame(bytes(body))
    assert ei.value.seq == 9                      # NACKable: seq recovered
    with pytest.raises(FrameCorruption, match="shorter"):
        decode_frame(bytes(frame[4:10]))          # shorter than the header


def test_array_codec_guards():
    with pytest.raises(ProtocolError, match="wire dtype"):
        pack_array(np.zeros(3, dtype=np.float64))
    hdr, payload = pack_array(np.zeros(4, dtype=np.int32))
    with pytest.raises(ProtocolError, match="size mismatch"):
        unpack_array(hdr, payload[:-4])              # short payload
    with pytest.raises(ProtocolError, match="size mismatch"):
        unpack_array({**hdr, "dtype": "int8"}, payload)   # dtype drift
    with pytest.raises(ProtocolError, match="wire dtype"):
        unpack_array({**hdr, "dtype": "float64"}, payload)


# ---------------------------------------------------------------------------
# admission + QoS units
# ---------------------------------------------------------------------------

def test_admission_caps_and_shedding():
    adm = AdmissionController(max_queue_depth=3,
                              default_policy=TenantPolicy(max_inflight=2))
    assert adm.try_admit("a") == ADMIT
    assert adm.try_admit("a") == ADMIT
    assert adm.try_admit("a") == BUSY_TENANT          # per-tenant cap
    assert adm.try_admit("b") == ADMIT
    assert adm.try_admit("b") == BUSY_QUEUE           # global backlog
    adm.release("a")
    assert adm.try_admit("b") == ADMIT
    adm.release("a")                                  # drain: a has 1 left
    adm.release("b")
    adm.release("b")                                  # ... and b has 2
    with pytest.raises(RuntimeError):
        adm.release("a")                              # underflow is a bug


def test_log_histogram_percentiles():
    h = LogHistogram()
    for v in (0.001, 0.01, 0.01, 0.1, 1.0):
        h.record(v)
    snap = h.snapshot()
    assert snap["count"] == 5
    assert snap["min"] == pytest.approx(0.001)
    assert snap["max"] == pytest.approx(1.0)
    assert 0.005 <= snap["p50"] <= 0.05               # bucket upper bound
    assert snap["p99"] == pytest.approx(1.0)
    assert LogHistogram().snapshot() == {"count": 0}


# ---------------------------------------------------------------------------
# loopback server (real engine, real TCP)
# ---------------------------------------------------------------------------

def _cfg():
    return reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                   d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                   head_dim=32)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, codec=None, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("chunk_size", 8)
    kw.setdefault("sync_every", 4)
    return BatchedEngine(params, cfg, codec=codec, greedy=True, seed=0, **kw)


def _prompts(n, rng):
    return [[int(t) for t in rng.randint(1, 128, 5 + i)] for i in range(n)]


@pytest.mark.parametrize("spec", ["none", "c3sl:R=4|int8"])
def test_loopback_bit_identical_to_direct_submit(setup, spec):
    cfg, params = setup
    codec = None if spec == "none" else spec
    prompts = _prompts(3, np.random.RandomState(2))

    # direct: 3 requests through 2 slots (recycling changes occupancy,
    # which changes C3-SL cross-talk -- exactly what must still match)
    direct = _engine(cfg, params, codec=codec)
    for u, p in enumerate(prompts):
        direct.submit(Request(uid=u, prompt=list(p), max_new_tokens=6))
    ref = {r.uid: list(r.out) for r in direct.run()}
    assert len(ref) == 3

    async def go():
        eng = _engine(cfg, params, codec=codec)
        server = FrontDoorServer(eng, auto_tick=False)
        host, port = await server.start()
        client = await FrontDoorClient.open(host, port, tenant="t0",
                                            codec=spec)
        # stage EVERY submission before any engine work, so the dispatch
        # schedule is identical to the direct run
        rids = [await client.submit(p, max_new=6) for p in prompts]
        await server.drain()
        outs = [await client.result(rid) for rid in rids]
        await client.close()
        await server.stop(drain=False)
        return outs

    outs = asyncio.run(go())
    for uid, out in enumerate(outs):
        assert out["tokens"] == ref[uid], (spec, uid)
        assert out["ttft_s"] is not None and out["ttft_s"] >= 0


def test_codec_mismatch_is_a_handshake_failure(setup):
    cfg, params = setup

    async def go():
        # 4 slots so the engine serves R=4 unclamped: R=2 really mismatches
        eng = _engine(cfg, params, codec="c3sl:R=4|int8", num_slots=4)
        server = FrontDoorServer(eng, auto_tick=False)
        host, port = await server.start()
        try:
            for bad in ("none", "c3sl:R=2|int8", "c3sl:R=4"):
                with pytest.raises(FrontDoorError, match="codec mismatch"):
                    await FrontDoorClient.open(host, port, tenant="t0",
                                               codec=bad)
            with pytest.raises(FrontDoorError, match="unbuildable"):
                await FrontDoorClient.open(host, port, tenant="t0",
                                           codec="no-such-codec:R=1")
            # the matching spec (canonicalized: D filled in) still connects
            ok = await FrontDoorClient.open(host, port, tenant="t0",
                                            codec="c3sl:R=4|int8")
            await ok.close()
        finally:
            await server.stop(drain=False)

    asyncio.run(go())


def test_busy_shedding_then_retry_completes(setup):
    cfg, params = setup

    async def go():
        eng = _engine(cfg, params)
        server = FrontDoorServer(
            eng, auto_tick=True,
            admission=AdmissionController(
                max_queue_depth=8,
                default_policy=TenantPolicy(max_inflight=1)))
        host, port = await server.start()
        client = await FrontDoorClient.open(host, port, tenant="shed")
        rng = np.random.RandomState(3)
        prompts = _prompts(3, rng)
        # concurrent generates with max_inflight=1: the extras are shed
        # with BUSY and complete through the client's retry loop
        outs = await asyncio.gather(*(
            client.generate(p, max_new=4) for p in prompts))
        stats = await client.stats()
        await client.close()
        await server.stop()
        return outs, stats

    outs, stats = asyncio.run(go())
    assert len(outs) == 3 and all(len(o["tokens"]) == 4 for o in outs)
    t = stats["tenants"]["shed"]
    assert t["requests"] == 3
    assert t["busy_rejections"] >= 1          # shedding actually happened
    assert stats["admission"]["inflight_total"] == 0


def test_hard_busy_raises_after_retries(setup):
    cfg, params = setup

    async def go():
        eng = _engine(cfg, params)
        # auto_tick=False and max_inflight=1: the first submit is admitted
        # but never completes, so the second can only ever see BUSY
        server = FrontDoorServer(
            eng, auto_tick=False,
            admission=AdmissionController(
                default_policy=TenantPolicy(max_inflight=1)))
        host, port = await server.start()
        client = await FrontDoorClient.open(host, port, tenant="stuck")
        await client.submit([1, 2, 3], max_new=4)
        with pytest.raises(BusyError):
            await client.submit([4, 5, 6], max_new=4)
        with pytest.raises(FrontDoorError, match="still busy"):
            await client.generate([4, 5, 6], max_new=4, retries=2,
                                  backoff_s=0.001)
        await server.drain()                   # let the admitted one finish
        await client.close()
        await server.stop(drain=False)

    asyncio.run(go())


def test_engine_refusal_is_error_not_busy(setup):
    cfg, params = setup

    async def go():
        eng = _engine(cfg, params)
        server = FrontDoorServer(eng, auto_tick=False)
        host, port = await server.start()
        client = await FrontDoorClient.open(host, port, tenant="bad")
        with pytest.raises(FrontDoorError, match="prompt length"):
            await client.submit(list(range(1, 40)), max_new=4)  # > max_len
        # the refusal released its admission slot: a good submit still works
        rid = await client.submit([1, 2, 3], max_new=2)
        await server.drain()
        out = await client.result(rid)
        assert len(out["tokens"]) == 2
        await client.close()
        await server.stop(drain=False)
        return server.stats()

    stats = asyncio.run(go())
    assert stats["tenants"]["bad"]["errors"] == 1
    assert stats["admission"]["inflight_total"] == 0


def test_multi_tenant_concurrent_clients(setup):
    cfg, params = setup

    async def tenant(host, port, name, prompts):
        client = await FrontDoorClient.open(host, port, tenant=name)
        outs = await asyncio.gather(*(
            client.generate(p, max_new=3) for p in prompts))
        await client.close()
        return outs

    async def go():
        eng = _engine(cfg, params)
        server = FrontDoorServer(eng, auto_tick=True)
        host, port = await server.start()
        rng = np.random.RandomState(4)
        names = ["edge-a", "edge-b", "edge-c"]
        outs = await asyncio.gather(*(
            tenant(host, port, n, _prompts(2, rng)) for n in names))
        stats = server.stats()
        await server.stop()
        return outs, stats, eng

    outs, stats, eng = asyncio.run(go())
    assert all(len(o) == 2 for o in outs)
    for name in ("edge-a", "edge-b", "edge-c"):
        t = stats["tenants"][name]
        assert t["requests"] == 2 and t["tokens_out"] == 6
        assert t["ttft_s"]["count"] == 2 and t["bytes_in"] > 0
    assert stats["engine"]["decode_steps"] > 0
    assert stats["engine"]["pool"] == eng.pool_accounting()
    assert not eng.queue and eng.active == 0           # clean shutdown
