"""Optimizer, checkpoint, data-pipeline, and config-registry tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.archs import ALL_ARCHS
from repro.configs.base import get_config, list_configs
from repro.data.pipeline import (SHAPES, SyntheticImageDataset,
                                 SyntheticTokenDataset, input_specs)
from repro.optim import (adam, adamw, apply_updates, clip_by_global_norm,
                         sgd_momentum, warmup_cosine)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def test_adam_converges_on_quadratic():
    opt = adam(0.1)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adam_matches_reference_formula():
    """One Adam step against the textbook update."""
    lr, b1, b2, eps = 1e-2, 0.9, 0.999, 1e-8
    opt = adam(lr, b1, b2, eps)
    w = jnp.array([1.0])
    g = jnp.array([0.5])
    state = opt.init({"w": w})
    updates, state = opt.update({"w": g}, state, {"w": w})
    m = (1 - b1) * g
    v = (1 - b2) * g ** 2
    want = -lr * (m / (1 - b1)) / (jnp.sqrt(v / (1 - b2)) + eps)
    np.testing.assert_allclose(np.asarray(updates["w"]), np.asarray(want),
                               rtol=1e-4)


def test_adamw_decays_weights():
    opt = adamw(1e-2, weight_decay=0.1)
    params = {"w": jnp.array([10.0])}
    state = opt.init(params)
    updates, _ = opt.update({"w": jnp.array([0.0])}, state, params)
    assert float(updates["w"][0]) < 0  # pure decay pulls toward zero


def test_sgd_momentum_and_clip():
    opt = sgd_momentum(0.1, momentum=0.9)
    params = {"w": jnp.array([1.0])}
    state = opt.init(params)
    g = {"w": jnp.array([100.0])}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["w"])) - 1.0) < 1e-5
    assert float(gn) == pytest.approx(100.0)
    updates, state = opt.update(clipped, state, params)
    assert float(updates["w"][0]) == pytest.approx(-0.1, rel=1e-5)


def test_warmup_cosine_schedule():
    sched = warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(sched(jnp.array(0))) == pytest.approx(0.0)
    assert float(sched(jnp.array(10))) == pytest.approx(1.0, abs=0.01)
    assert float(sched(jnp.array(100))) == pytest.approx(0.1, abs=0.02)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)},
            "d": [jnp.zeros((2,)), jnp.full((1,), 7.0)]}
    save_checkpoint(str(tmp_path), 5, tree, {"note": "test"})
    assert latest_step(str(tmp_path)) == 5
    got = restore_checkpoint(str(tmp_path), 5, jax.eval_shape(lambda: tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.ones((2,))})
    bad = jax.eval_shape(lambda: {"a": jnp.ones((3,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, bad)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_token_dataset_deterministic_and_learnable():
    ds = SyntheticTokenDataset(vocab_size=64, seq_len=32, seed=3)
    b1 = ds.batch(8, step=0)
    b2 = ds.batch(8, step=0)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # labels are next-tokens
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))
    # successor structure present: P(label == successor[token]) >> 1/V
    succ = ds.successor[np.asarray(b1["tokens"]).reshape(-1)]
    frac = (succ == np.asarray(b1["labels"]).reshape(-1)).mean()
    assert frac > 0.5


def test_image_dataset_class_conditional():
    ds = SyntheticImageDataset(n_classes=4, seed=0)
    b = ds.batch(64, 0)
    assert b["x"].shape == (64, 3, 32, 32)
    # same-class images correlate more than cross-class
    x = np.asarray(b["x"]).reshape(64, -1)
    y = np.asarray(b["y"])
    same = cross = 0.0
    n_same = n_cross = 0
    for i in range(0, 32):
        for j in range(i + 1, 32):
            c = np.dot(x[i], x[j]) / (np.linalg.norm(x[i]) * np.linalg.norm(x[j]))
            if y[i] == y[j]:
                same += c; n_same += 1
            else:
                cross += c; n_cross += 1
    if n_same and n_cross:
        assert same / n_same > cross / n_cross


# ---------------------------------------------------------------------------
# configs / input specs
# ---------------------------------------------------------------------------

def test_all_archs_registered():
    names = list_configs()
    for a in ALL_ARCHS:
        assert a in names


def test_input_specs_cover_all_combos():
    for a in ALL_ARCHS:
        cfg = get_config(a)
        for s in SHAPES:
            spec = input_specs(cfg, s)
            assert "tokens" in spec
            B = SHAPES[s]["global_batch"]
            assert spec["tokens"].shape[0] == B
            if SHAPES[s]["kind"] == "train":
                assert "labels" in spec
            if cfg.frontend and SHAPES[s]["kind"] != "decode":
                assert "frontend" in spec


def test_exact_assigned_dimensions():
    """The full configs carry the exact assignment numbers."""
    want = {
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102400),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    }
    for name, (L, d, H, KV, ff, V) in want.items():
        cfg = get_config(name)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, H, KV, ff, V), name
    assert get_config("deepseek-v2-lite-16b").num_experts == 64
    assert get_config("deepseek-v2-lite-16b").experts_per_token == 6
    assert get_config("deepseek-v2-lite-16b").kv_lora_rank == 512
    assert get_config("phi3.5-moe-42b-a6.6b").num_experts == 16
    assert get_config("jamba-1.5-large-398b").num_experts == 16
    assert get_config("chatglm3-6b").partial_rotary == 0.5
