"""Deterministic fault injection + erasure-tolerant HRR transport.

Pins the three contracts the fault subsystem is built on:

1. **Replayability** — every FaultPlan draw is keyed on
   (seed, direction, step, attempt), so the same plan replays the same
   failures bit-for-bit, and an all-zero plan is structurally inert
   (install sites take the exact pre-fault code path).

2. **Erasure-exactness** — the mask-aware decode is BITWISE identical to
   the plain decode at zero erasures (multiplying by an all-ones mask and
   renormalizing by D/D changes nothing), and retrieval SNR degrades
   monotonically (within noise) as the erased fraction grows.

3. **Recovery semantics** — "retransmit" converges to a complete payload
   (all-ones keep, wire_mult > 1) under the attempt-keyed redraw;
   "erasure" accepts loss up to the policy threshold; an exhausted retry
   budget surfaces as a typed ChannelErasure, never as garbage.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import transport
from repro.codecs import build
from repro.core import hrr
from repro.faults import (ChannelErasure, FaultPlan, RecoveryPolicy,
                          negotiate_payload)

D, B, R = 256, 8, 4


# ---------------------------------------------------------------------------
# FaultPlan determinism
# ---------------------------------------------------------------------------

def _events(plan, direction, steps=40, epoch=0):
    return [[(e.kind, e.arg) for e in plan.frame_events(direction, s, epoch)]
            for s in range(steps)]


def test_fault_plan_replays_bit_identically():
    mk = lambda seed: FaultPlan(seed=seed, rates={"drop": 0.3,
                                                  "corrupt": 0.15})
    assert _events(mk(3), "c2s") == _events(mk(3), "c2s")
    assert any(_events(mk(3), "c2s"))            # ...and actually fires
    # the rng keys on the direction, the seed, and the connection epoch
    assert _events(mk(3), "c2s") != _events(mk(3), "s2c")
    assert _events(mk(3), "c2s") != _events(mk(4), "c2s")
    assert _events(mk(3), "c2s") != _events(mk(3), "c2s", epoch=1)


def test_schedule_fires_once_at_epoch_zero():
    plan = FaultPlan(seed=0, schedule={"c2s": {3: "disconnect"}})
    assert not plan.is_zero()
    assert [e.kind for e in plan.frame_events("c2s", 3)] == ["disconnect"]
    assert plan.frame_events("c2s", 2) == ()
    assert plan.frame_events("s2c", 3) == ()     # direction-scoped
    # epoch 1 = the connection AFTER the resume the event was testing
    assert plan.frame_events("c2s", 3, epoch=1) == ()


def test_zero_plan_is_structurally_inert():
    assert FaultPlan().is_zero()
    assert FaultPlan(seed=9, rates={"drop": 0.0, "corrupt": 0.0}).is_zero()
    assert not FaultPlan(rates={"drop": 0.01}).is_zero()
    ch = transport.Channel("fwd", build(f"c3sl:R={R}", D=D))
    ch.install_faults(FaultPlan(seed=9, rates={"drop": 0.0}))
    assert ch.next_erasure(rows=B) == (None, None)
    link = transport.as_link(build(f"c3sl:R={R}", D=D))
    link.install_faults(FaultPlan())
    assert link.next_erasure(B) == (None, None)


def test_plan_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan(rates={"gremlins": 0.5})
    with pytest.raises(ValueError, match="outside"):
        FaultPlan(rates={"drop": 1.5})
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultPlan(schedule={0: "gremlins"})
    with pytest.raises(ValueError, match="packets"):
        FaultPlan(packets=0)
    with pytest.raises(ValueError, match="unknown recovery mode"):
        RecoveryPolicy(mode="hope")


def test_packet_masks_cover_the_payload_exactly():
    plan = FaultPlan(seed=1, rates={"drop": 0.4}, packets=16)
    shape = (B // R, D)
    lost = plan.packet_faults("fwd", 0, shape)
    assert lost.shape == (B // R, 16) and lost.dtype == bool
    np.testing.assert_array_equal(
        lost, plan.packet_faults("fwd", 0, shape))      # deterministic
    keep = plan.expand_packets(shape, ~lost)
    assert keep.shape == shape and keep.dtype == np.float32
    # each packet expands to a contiguous span; spans tile D exactly
    assert int(plan.packet_edges(D).sum()) == D
    frac_pkts = float((~lost).mean())
    assert float(keep.mean()) == pytest.approx(frac_pkts, abs=1e-6)


# ---------------------------------------------------------------------------
# recovery policy
# ---------------------------------------------------------------------------

def test_retransmit_converges_to_complete_payload():
    plan = FaultPlan(seed=5, rates={"drop": 0.3})
    keep, info = negotiate_payload(plan, "fwd", 0, (B // R, D),
                                   RecoveryPolicy(mode="retransmit",
                                                  retry_budget=16))
    np.testing.assert_array_equal(keep, np.ones((B // R, D), np.float32))
    assert info["erased_frac"] == 0.0
    assert info["wire_mult"] > 1.0               # the NACK rounds cost bytes
    assert info["attempts"] >= 2


def test_erasure_mode_accepts_bounded_loss():
    plan = FaultPlan(seed=5, rates={"drop": 0.3})
    keep, info = negotiate_payload(plan, "fwd", 0, (B // R, D),
                                   RecoveryPolicy(mode="erasure",
                                                  max_erasure_frac=0.5))
    assert 0.0 < info["erased_frac"] <= 0.5
    assert info["wire_mult"] == 1.0              # loss absorbed, not resent
    assert float(keep.mean()) == pytest.approx(1.0 - info["erased_frac"],
                                               abs=1e-6)


def test_exhausted_budget_raises_typed_erasure():
    plan = FaultPlan(seed=5, rates={"drop": 1.0})     # every packet, always
    with pytest.raises(ChannelErasure) as ei:
        negotiate_payload(plan, "bwd", 7, (B // R, D),
                          RecoveryPolicy(mode="retransmit", retry_budget=3))
    assert ei.value.direction == "bwd" and ei.value.step == 7
    assert ei.value.erased_frac == 1.0


# ---------------------------------------------------------------------------
# mask-aware decode: exact at zero erasures, graceful under loss
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [f"c3sl:R={R}", f"c3sl:R={R}|int8"])
def test_masked_decode_bitwise_exact_at_all_ones(spec):
    codec = build(spec, D=D)
    params = codec.init(jax.random.PRNGKey(1))
    Z = jax.random.normal(jax.random.PRNGKey(2), (B, D))
    payload = codec.encode(params, Z)
    ones = jnp.ones(payload.shape, jnp.float32)
    plain = codec.decode(params, payload)
    masked = codec.decode_masked(params, payload, ones)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(masked))


def test_masked_unbind_full_erasure_zeroes_output():
    codec = build(f"c3sl:R={R}", D=D)
    params = codec.init(jax.random.PRNGKey(1))
    Z = jax.random.normal(jax.random.PRNGKey(2), (B, D))
    payload = codec.encode(params, Z)
    out = codec.decode_masked(params, payload,
                              jnp.zeros(payload.shape, jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), np.zeros((B, D)))


# The hypothesis property variant (random seeds, random erasure orders)
# lives in tests/test_frame_codec.py with the other property suites; this
# is the deterministic pin of the same monotonicity contract.
def test_erasure_snr_monotone_nonincreasing():
    codec = build(f"c3sl:R={R}", D=D)
    params = codec.init(jax.random.PRNGKey(1))
    rng = np.random.RandomState(0)
    Z = jnp.asarray(rng.randn(B, D).astype(np.float32))
    payload = codec.encode(params, Z)
    plan = FaultPlan(seed=0, packets=16)
    order = rng.permutation(16)
    snrs = []
    for n_erased in (0, 4, 8, 12):
        keep_p = np.ones((payload.shape[0], 16), dtype=bool)
        keep_p[:, order[:n_erased]] = False
        keep = jnp.asarray(plan.expand_packets(payload.shape, keep_p))
        Zhat = codec.decode_masked(params, payload, keep)
        snrs.append(float(hrr.retrieval_snr(Z, Zhat)))
    base = float(hrr.retrieval_snr(Z, codec.decode(params, payload)))
    assert snrs[0] == pytest.approx(base, abs=1e-5)
    for lo, hi in zip(snrs[1:], snrs):
        assert lo <= hi + 0.75, snrs


# ---------------------------------------------------------------------------
# the installed link: masks flow into the split loss, clean runs untouched
# ---------------------------------------------------------------------------

def _front(p, x):
    return x @ p["w"]


def _back(p, z):
    return z @ p["w"]


def _loss(logits, y):
    return jnp.mean((logits - y) ** 2)


def _split_setup(spec):
    codec = build(spec, D=D)
    params = {
        "front": {"w": jax.random.normal(jax.random.PRNGKey(3), (16, D))
                  * 16 ** -0.5},
        "back": {"w": jax.random.normal(jax.random.PRNGKey(4), (D, 4))
                 * D ** -0.5},
        "codec": codec.init(jax.random.PRNGKey(7)),
    }
    batch = {"x": jax.random.normal(jax.random.PRNGKey(5), (B, 16)),
             "y": jax.random.normal(jax.random.PRNGKey(6), (B, 4))}
    loss_fn = transport.make_split_loss_fn(_front, _back, codec, _loss)
    return codec, params, batch, loss_fn


def test_link_erasure_masks_match_payload_and_replay():
    spec = f"c3sl:R={R}|int8"
    plan = FaultPlan(seed=11, rates={"drop": 0.25})
    links = []
    for _ in range(2):
        link = transport.as_link(build(spec, D=D))
        link.install_faults(plan, RecoveryPolicy(mode="erasure"))
        links.append(link)
    e1, i1 = links[0].next_erasure(B)
    e2, i2 = links[1].next_erasure(B)
    assert e1["fwd"].shape == tuple(links[0].fwd.current.payload_shape(B))
    np.testing.assert_array_equal(e1["fwd"], e2["fwd"])   # replayable
    assert i1["fwd"] == i2["fwd"]
    # the per-direction step counters advance: the next draw differs
    e3, _ = links[0].next_erasure(B)
    assert not np.array_equal(e1["fwd"], e3["fwd"])


def test_split_loss_under_erasure_finite_and_exact_at_all_ones():
    codec, params, batch, loss_fn = _split_setup(f"c3sl:R={R}")
    clean = float(loss_fn(params, batch))
    shape = tuple(codec.payload_shape(B))
    ones = {"fwd": jnp.ones(shape, jnp.float32)}
    assert float(loss_fn(params, batch, erasure=ones)) == \
        pytest.approx(clean, rel=1e-6)
    plan = FaultPlan(seed=2, rates={"drop": 0.3})
    keep = {"fwd": jnp.asarray(plan.payload_keep("fwd", 0, shape))}
    lossy = float(loss_fn(params, batch, erasure=keep))
    assert np.isfinite(lossy) and lossy != clean
    # gradients stay finite through the masked unbind
    g = jax.grad(lambda p: loss_fn(p, batch, erasure=keep))(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert all(bool(jnp.isfinite(x).all()) for x in leaves)


def test_erasure_rejected_for_nchw_codecs():
    codec = build("bnpp:R=4", D=D, C=4, H=8, W=8)
    params = codec.init(jax.random.PRNGKey(0))
    Z = jax.random.normal(jax.random.PRNGKey(1), (B, 4, 8, 8))
    with pytest.raises(ValueError, match="flat codecs"):
        transport.apply_codec(codec, params, Z,
                              erasure={"fwd": jnp.ones((1,))})
