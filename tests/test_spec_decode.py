"""Speculative decoding over the split link (repro.serving.spec).

The contract everything here pins: GREEDY verification makes the
speculative engine's emitted token streams BIT-IDENTICAL to vanilla
decode for every (codec, k, draft head, KV layout) — the draft channel
can only change the acceptance rate (and with it the wire/latency
profile), never an output token.  Around that core:

- rollback is pure position truncation: after rejected rounds the
  committed cache matches a never-speculated engine's position-for-
  position — exactly on integer leaves, and within float-accumulation
  noise (~1e-6; asserted < 1e-4) on KV values, orders of magnitude below
  the O(1) delta a leaked rejected-draft token would leave (hypothesis
  property, contiguous/no-codec layout);
- batch-wise codecs force GROUP-LOCKSTEP acceptance (unit-tested on
  ``accept_lengths`` directly, plus engine equivalence under lockstep
  occupancy — C3-SL outputs are schedule-dependent repo-wide, so the
  codec comparison pins identical dispatch schedules);
- eviction between speculative windows resumes bit-identically and the
  per-request accepted/rejected/rollback counters survive preemption;
- one pre-built program per (R bucket, draft bucket, k): a schedule
  bouncing across all of them never recompiles post-warmup;
- the front-door loopback serves the same tokens as a direct vanilla
  engine, streams TOKENS bursts that prefix the RESULT, and pins the
  draft spec at the handshake;
- wire accounting: verify rounds ship ZERO forward bytes; the draft
  channel's bytes reconcile exactly against the served round schedule.
"""
import asyncio
import dataclasses

import numpy as np
import pytest

import jax

from repro.configs.base import get_config, reduced
from repro.models import lm as lm_lib
from repro.serving.engine import BatchedEngine, Request
from repro.serving.spec import (AdaptiveK, SpecConfig, accept_lengths,
                                token_wire_bytes)

import jax.numpy as jnp


def _cfg(**kw):
    return reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                   d_ff=256, vocab_size=128, num_heads=4, num_kv_heads=2,
                   head_dim=32, **kw)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _engine(cfg, params, **kw):
    kw.setdefault("num_slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("chunk_size", 8)
    kw.setdefault("sync_every", 4)
    return BatchedEngine(params, cfg, greedy=True, seed=0,
                         prefill_mode="chunked", **kw)


def _prompt(rng, n, vocab=128):
    return [int(t) for t in rng.randint(1, vocab, n)]


def _run(eng, prompts, max_new=8):
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=list(p),
                           max_new_tokens=max_new[i]
                           if isinstance(max_new, (list, tuple)) else max_new))
    done = {r.uid: r for r in eng.run()}
    eng.finished.clear()
    return done


# ---------------------------------------------------------------------------
# bit-identity: speculative == vanilla greedy decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,head", [(2, "copy"), (8, "copy"), (4, "tied")])
def test_bit_identity_no_codec_ragged(setup, k, head):
    """Ragged prompts + staggered finishes (no codec, so occupancy cannot
    leak between rows): every k and both draft heads reproduce vanilla."""
    cfg, params = setup
    rng = np.random.RandomState(1)
    prompts = [_prompt(rng, n) for n in (3, 9, 5)]
    ref = {u: r.out for u, r in _run(_engine(cfg, params), prompts,
                                     max_new=(7, 4, 8)).items()}
    eng = _engine(cfg, params,
                  spec_decode=SpecConfig(k=k, draft_head=head))
    done = _run(eng, prompts, max_new=(7, 4, 8))
    assert {u: r.out for u, r in done.items()} == ref
    assert eng.stats["spec_rounds"] > 0
    # per-request speculative counters folded at retire
    folded = sum(r.accepted + r.rejected for r in done.values())
    assert folded == eng.stats["spec_accepted"] + eng.stats["spec_rejected"]


@pytest.mark.parametrize("k", [2, 4])
def test_bit_identity_codec_lockstep(setup, k):
    """Batch-wise codec: identical-shape requests submitted together run
    in lockstep (same dispatch schedule vanilla and speculative), so the
    group-min acceptance rule must keep superposition contents — and with
    them the outputs — bit-identical."""
    cfg, params = setup
    rng = np.random.RandomState(2)
    prompts = [_prompt(rng, 6), _prompt(rng, 6)]
    ref = {u: r.out
           for u, r in _run(_engine(cfg, params, codec="c3sl:R=2|int8"),
                            prompts, max_new=6).items()}
    eng = _engine(cfg, params, codec="c3sl:R=2|int8",
                  spec_decode=SpecConfig(k=k, draft="c3sl:R=2|int8",
                                         draft_head="tied"))
    done = _run(eng, prompts, max_new=6)
    assert {u: r.out for u, r in done.items()} == ref
    assert eng.stats["spec_rounds"] > 0
    assert eng.stats["wire_bytes_draft"] > 0


@pytest.mark.parametrize("layout", ["ring_swa", "int8_kv", "paged_gather",
                                    "paged_kernel"])
def test_bit_identity_kv_layouts(layout):
    """The commit path's valid-masked chunk re-ingest must agree with
    vanilla per-token decode on every KV layout: ring-SWA (aliased ring
    writes), quantized int8 KV, and the paged pool under both read
    paths."""
    cfg = _cfg()
    kw = {}
    if layout == "ring_swa":
        cfg = dataclasses.replace(cfg, sliding_window=8)
    elif layout == "int8_kv":
        cfg = dataclasses.replace(cfg, kv_cache_quant=True)
    else:
        kw = {"kv_layout": "paged", "page_size": 8, "num_pages": 8,
              "kv_read": "kernel" if layout == "paged_kernel" else "gather"}
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.RandomState(3)
    prompts = [_prompt(rng, 4), _prompt(rng, 7)]
    ref = {u: r.out for u, r in _run(_engine(cfg, params, **kw), prompts,
                                     max_new=6).items()}
    eng = _engine(cfg, params, spec_decode=SpecConfig(k=4, draft_head="copy"),
                  **kw)
    done = _run(eng, prompts, max_new=6)
    assert {u: r.out for u, r in done.items()} == ref
    assert eng.stats["spec_rounds"] > 0


# ---------------------------------------------------------------------------
# rollback property: the cache never sees a speculative write
# ---------------------------------------------------------------------------

@pytest.mark.property
def test_rollback_cache_property(setup):
    """Hypothesis property: after ANY workload (ragged prompts, budgets
    drawn adversarially) with rejections in it, the speculative engine's
    emitted streams equal a never-speculated engine's BIT-FOR-BIT and
    its cache matches position-for-position.  Verify-phase cache writes
    are discarded in-graph and commit re-ingests only accepted tokens,
    so not one rejected position may leak into KV state — a leak writes
    the WRONG token's KV (an O(1) delta for this model); the only
    tolerated difference is float accumulation order between the
    chunked commit path and vanilla's per-token decode writes (~1e-6,
    asserted < 1e-4)."""
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need the optional hypothesis package")
    from hypothesis import given, settings, strategies as st
    cfg, params = setup
    vanilla = _engine(cfg, params)
    spec = _engine(cfg, params, spec_decode=SpecConfig(k=4,
                                                       draft_head="tied"))
    seen_rollback = [0]

    @settings(deadline=None)
    @given(st.lists(st.tuples(st.integers(2, 10), st.integers(1, 8)),
                    min_size=1, max_size=2),
           st.integers(0, 2 ** 31 - 1))
    def prop(shapes, seed):
        rng = np.random.RandomState(seed)
        prompts = [_prompt(rng, n) for n, _ in shapes]
        max_new = [m for _, m in shapes]
        ref = _run(vanilla, prompts, max_new=max_new)
        got = _run(spec, prompts, max_new=max_new)
        assert {u: r.out for u, r in got.items()} == \
               {u: r.out for u, r in ref.items()}
        for a, b in zip(jax.tree.leaves(vanilla.cache),
                        jax.tree.leaves(spec.cache)):
            a, b = np.asarray(a), np.asarray(b)
            if np.issubdtype(a.dtype, np.integer):
                assert np.array_equal(a, b)      # positions/pages: exact
            else:
                delta = np.max(np.abs(a - b)) if a.size else 0.0
                assert delta < 1e-4, (
                    f"cache leaf diverged by {delta} — a rejected draft "
                    "leaked into committed KV state")
        seen_rollback[0] += sum(r.rollbacks for r in got.values())

    prop()
    assert seen_rollback[0] > 0, (
        "no drawn workload ever rejected a draft — the rollback path was "
        "never exercised and the property is vacuous")


def test_eviction_during_speculation_resumes_identical(setup):
    """A slot evicted between speculative windows re-prefills prompt +
    emitted tokens and resumes bit-identically; its folded
    accepted/rejected/rollback counters survive the preemption."""
    cfg, params = setup
    spec_kw = dict(kv_layout="paged", page_size=8, num_pages=6,
                   preemption=True,
                   spec_decode=SpecConfig(k=2, draft_head="tied"))
    rng = np.random.RandomState(4)
    shorts = [Request(uid=i, prompt=_prompt(rng, 4), max_new_tokens=8)
              for i in range(2)]
    premium = Request(uid=9, prompt=_prompt(rng, 20), max_new_tokens=4,
                      priority=1)
    # reference: vanilla solo runs (greedy + no codec: prompt-determined)
    ref = {}
    for r in shorts + [premium]:
        v = _engine(cfg, params, kv_layout="paged", page_size=8, num_pages=6)
        v.submit(Request(uid=r.uid, prompt=list(r.prompt),
                         max_new_tokens=r.max_new_tokens))
        ref[r.uid] = list(v.run())[0].out
        v.finished.clear()
    eng = _engine(cfg, params, **spec_kw)
    for r in shorts:
        eng.submit(r)
    eng.tick()                       # both shorts into mid-decode
    assert eng.active == 2 and eng.stats["evictions"] == 0
    eng.submit(premium)
    done = {r.uid: r for r in eng.run()}
    assert eng.stats["evictions"] >= 1
    assert {u: r.out for u, r in done.items()} == ref
    for r in done.values():
        assert r.accepted >= 0 and r.rejected >= 0 and r.rollbacks >= 0
    folded = sum(r.accepted for r in done.values())
    assert folded == eng.stats["spec_accepted"], (
        "per-request accepted counters lost across eviction")


# ---------------------------------------------------------------------------
# program table: zero post-warmup recompiles across (R, draft-R, k)
# ---------------------------------------------------------------------------

def test_zero_recompiles_across_r_and_k_switches(setup):
    """One pre-built program per (engine R bucket, draft bucket, k>1);
    bouncing the R pin and the k pin across every combination reuses the
    warm programs — each jit entry holds exactly one compiled trace."""
    cfg, params = setup
    eng = _engine(cfg, params, num_slots=4,
                  codec="adaptive:c3sl:R=4,min_R=2|int8",
                  spec_decode=SpecConfig(k=2, ladder=(1, 2, 4),
                                         draft="c3sl:R=2|int8",
                                         draft_head="tied"))
    assert set(eng._spec_programs) == {(R, None, k)
                                      for R in (2, 4) for k in (2, 4)}
    progs = dict(eng._spec_programs)
    rng = np.random.RandomState(5)
    for R, k in ((2, 2), (4, 4), (2, 4), (4, 2), (2, 2)):
        eng.codec.pin(R)
        eng._k_ctl.pin(k)
        for u in range(2):
            eng.submit(Request(uid=100 * R + 10 * k + u,
                               prompt=_prompt(rng, 4), max_new_tokens=4))
        eng.run()
        eng.finished.clear()
    assert all(eng._spec_programs[key] is progs[key] for key in progs), \
        "spec program table was rebuilt mid-flight"
    for key, prog in eng._spec_programs.items():
        if hasattr(prog, "_cache_size"):
            assert prog._cache_size() <= 1, (
                f"spec program {key} retraced: {prog._cache_size()} entries")
    assert set(eng.k_served) == {2, 4}


# ---------------------------------------------------------------------------
# front-door loopback: bit-identity + TOKENS streaming + draft handshake
# ---------------------------------------------------------------------------

def test_frontdoor_loopback_spec_bit_identity(setup):
    from repro.frontdoor.client import FrontDoorClient, FrontDoorError
    from repro.frontdoor.server import FrontDoorServer
    cfg, params = setup
    rng = np.random.RandomState(6)
    prompts = [_prompt(rng, 3 + 2 * i) for i in range(3)]
    ref = {u: r.out for u, r in _run(_engine(cfg, params), prompts,
                                     max_new=6).items()}

    async def loop():
        eng = _engine(cfg, params,
                      spec_decode=SpecConfig(k=4, draft_head="tied"))
        server = FrontDoorServer(eng)
        host, port = await server.start()
        bursts = []
        client = await FrontDoorClient.open(
            host, port, tenant="spec-t",
            on_tokens=lambda rid, toks: bursts.append((rid, toks)))
        # HELLO_OK advertises the pinned speculative contract
        assert client.server_info["spec_k"] == 4
        assert client.server_info["draft_head"] == "tied"
        assert client.server_info["draft"] == "none"   # raw f32 feedback
        outs = []
        try:
            for p in prompts:            # sequential: lockstep-free anyway
                outs.append(await client.generate(p, max_new=6))
        finally:
            await client.close()
            await server.stop()
        assert server.tick_error is None
        return outs, bursts

    outs, bursts = asyncio.run(loop())
    assert [o["tokens"] for o in outs] == [ref[u] for u in sorted(ref)]
    for o in outs:
        # TOKENS frames previewed a prefix of the final result, and on a
        # healthy loopback connection the whole output streamed
        assert o["streamed"] == o["tokens"]
        assert o["ttlt_s"] is not None and o["ttlt_s"] >= 0
        assert o["accepted"] + o["rejected"] > 0
    assert bursts and all(toks for _, toks in bursts)

    async def mismatched_draft():
        eng = _engine(cfg, params,
                      spec_decode=SpecConfig(k=2, draft="c3sl:R=2|int8"))
        server = FrontDoorServer(eng)
        host, port = await server.start()
        try:
            await FrontDoorClient.open(host, port, tenant="bad",
                                       draft="none", reconnect=False)
        finally:
            await server.stop()

    with pytest.raises(FrontDoorError, match="draft-channel mismatch"):
        asyncio.run(mismatched_draft())


# ---------------------------------------------------------------------------
# wire accounting
# ---------------------------------------------------------------------------

def test_wire_accounting_verify_rounds_ship_zero_fwd(setup):
    """Speculative decode windows ship NOTHING on the forward channel
    (server-side bottom-stack replay): forward bytes shrink to the
    prefill chunks, the draft channel's total reconciles exactly against
    the served round schedule, and wire_per_token stays consistent with
    the raw counters."""
    cfg, params = setup
    rng = np.random.RandomState(8)
    prompts = [_prompt(rng, 6), _prompt(rng, 6)]

    base = _engine(cfg, params, codec="c3sl:R=2|int8")
    base_done = _run(base, prompts, max_new=8)
    spec = _engine(cfg, params, codec="c3sl:R=2|int8",
                   spec_decode=SpecConfig(k=4, draft="c3sl:R=2|int8",
                                          draft_head="tied"))
    spec_done = _run(spec, prompts, max_new=8)
    assert {u: r.out for u, r in spec_done.items()} == \
           {u: r.out for u, r in base_done.items()}

    assert base.stats["wire_bytes_draft"] == 0
    assert spec.stats["wire_bytes_fwd"] < base.stats["wire_bytes_fwd"], \
        "verify rounds still shipped forward payloads"
    assert spec.stats["wire_bytes_draft"] == sum(
        rounds * spec._draft_round_wire_bytes(k)
        for k, rounds in spec.k_served.items())
    wpt = spec.wire_per_token()
    assert wpt["wire_bytes_fwd"] == spec.stats["payload_wire_bytes"]
    assert wpt["generated_tokens"] == sum(len(r.out)
                                          for r in spec_done.values())
    assert wpt["wire_bytes_per_token"] == pytest.approx(
        (wpt["wire_bytes_fwd"] + wpt["wire_bytes_draft"])
        / wpt["generated_tokens"])


def test_token_wire_bytes():
    assert token_wire_bytes(256) == 1
    assert token_wire_bytes(257) == 2
    assert token_wire_bytes(1 << 16) == 2
    assert token_wire_bytes((1 << 16) + 1) == 4


# ---------------------------------------------------------------------------
# accept_lengths: the group-lockstep acceptance rule
# ---------------------------------------------------------------------------

def _accept(fed, targets, live, **kw):
    kw.setdefault("group", 1)
    kw.setdefault("eos_id", None)
    B = len(fed)
    kw.setdefault("rem_new", jnp.full((B,), 99, jnp.int32))
    kw.setdefault("rem_pos", jnp.full((B,), 99, jnp.int32))
    return np.asarray(accept_lengths(jnp.asarray(fed, jnp.int32),
                                     jnp.asarray(targets, jnp.int32),
                                     jnp.asarray(live), **kw))


def test_accept_lengths_prefix_rule():
    fed = [[5, 7, 8, 9]]                  # last verified tok + 3 drafts
    assert _accept(fed, [[7, 8, 9, 1]], [True]).tolist() == [4]   # all match
    assert _accept(fed, [[7, 8, 2, 1]], [True]).tolist() == [3]
    assert _accept(fed, [[7, 1, 9, 1]], [True]).tolist() == [2]
    assert _accept(fed, [[1, 8, 9, 1]], [True]).tolist() == [1]   # floor 1


def test_accept_lengths_eos_and_budget_caps():
    fed = [[5, 7, 8, 9]]
    targets = [[7, 8, 9, 1]]              # would accept 4
    assert _accept(fed, [[7, 0, 9, 1]], [True], eos_id=0).tolist() == [2]
    assert _accept([[5, 0, 8, 9]], [[0, 8, 9, 1]], [True],
                   eos_id=0).tolist() == [1]        # EOS target at pos 0
    assert _accept(fed, targets, [True],
                   rem_new=jnp.asarray([2])).tolist() == [2]
    assert _accept(fed, targets, [True],
                   rem_pos=jnp.asarray([0])).tolist() == [1]   # floor stays 1


def test_accept_lengths_group_lockstep_and_dead_rows():
    fed = [[5, 7, 8, 9], [5, 7, 8, 9]]
    targets = [[7, 8, 9, 1], [7, 2, 9, 1]]          # rows accept 4 and 2
    assert _accept(fed, targets, [True, True]).tolist() == [4, 2]
    assert _accept(fed, targets, [True, True], group=2).tolist() == [2, 2]
    # a DEAD partner must never cap its group
    assert _accept(fed, targets, [True, False], group=2).tolist() == [4, 4]


# ---------------------------------------------------------------------------
# SpecConfig / AdaptiveK / engine validation
# ---------------------------------------------------------------------------

def test_spec_config_validation():
    with pytest.raises(ValueError, match="powers of two"):
        SpecConfig(k=3, ladder=(1, 3))
    with pytest.raises(ValueError, match="not in ladder"):
        SpecConfig(k=8, ladder=(1, 2, 4))
    with pytest.raises(ValueError, match="draft_head"):
        SpecConfig(draft_head="oracle")
    with pytest.raises(ValueError, match="ema"):
        SpecConfig(ema=1.0)
    assert SpecConfig(draft_head="copy").needs_feedback is False
    assert SpecConfig(draft_head="tied").needs_feedback is True


def test_engine_spec_validation(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="greedy"):
        BatchedEngine(params, cfg, num_slots=2, max_len=32, greedy=False,
                      spec_decode=SpecConfig())
    with pytest.raises(ValueError, match="chunked"):
        BatchedEngine(params, cfg, num_slots=2, max_len=32,
                      prefill_mode="decode", spec_decode=SpecConfig())
    swa = dataclasses.replace(cfg, sliding_window=4)
    swa_params = lm_lib.init_lm_params(jax.random.PRNGKey(0), swa)
    with pytest.raises(ValueError, match="sliding_window"):
        BatchedEngine(swa_params, swa, num_slots=2, max_len=32,
                      spec_decode=SpecConfig(k=8))
    # a link spec's draft: segment auto-enables speculation
    eng = _engine(cfg, params, codec="c3sl:R=2|int8 >> draft:c3sl:R=2|int8")
    assert eng.spec_cfg is not None and eng.draft_codec is not None


def test_adaptive_k_controller():
    cfg = SpecConfig(k=2, ladder=(1, 2, 4, 8), adaptive=True,
                     target_accept=0.5, ema=0.0, hysteresis=0.1)
    ctl = AdaptiveK(cfg)
    assert ctl.current_k == 2
    assert ctl.observe(0.9) == 4                     # above band: ramp up
    assert ctl.observe(0.9) == 8
    assert ctl.observe(0.9) == 8                     # ladder top: hold
    assert ctl.observe(0.5) == 8                     # inside deadband: hold
    assert ctl.observe(0.1) == 4                     # below band: ramp down
    assert ctl.observe(0.1) == 2
    assert ctl.observe(0.1) == 1                     # k=1 == speculation off
    assert ctl.observe(None) == 1                    # no signal: hold
    ctl.pin(8)
    assert ctl.observe(0.0) == 8                     # pinned: schedule fixed
    ctl.unpin()
    assert ctl.observe(0.0) == 4
    with pytest.raises(ValueError, match="not in ladder"):
        ctl.pin(16)
    # non-adaptive configs come up pinned at cfg.k
    fixed = AdaptiveK(SpecConfig(k=4))
    assert fixed.observe(1.0) == 4 and fixed.observe(0.0) == 4
