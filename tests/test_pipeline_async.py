"""Double-buffered pod-pipeline channel tests.

Pins the ``async_depth`` staleness semantics on a deterministic two-pod
simulated mesh: depth=1 IS the synchronous schedule; depth=2 consumes
microbatch t's payload at step t+2 (one-slot skew) — pairing is preserved,
so loss AND grads are bit-identical to the synchronous schedule while the
scan grows exactly depth-1 bubble steps (pinned through the compiled HLO's
trip-count-aware FLOP totals).  Runs in subprocesses (XLA device count
locks at first jax init)."""
import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 2) -> dict:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


COMMON = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp, numpy as np
    from repro import transport
    from repro.codecs import build
    from repro.launch import mesh as mesh_lib

    mesh = mesh_lib.make_host_mesh(data=1, model=1, pod=2)
    B, S, E, M = 16, 4, 6, 4
    rng = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    embed_p = jax.random.normal(k1, (7, E)) * 0.3
    blocks = jax.random.normal(k2, (2, 1, E, E)) * 0.2
    head_p = jax.random.normal(k3, (E,)) * 0.5

    def embed_fn(p, x):  return p[x]
    def stage_fn(bl, h): return jnp.tanh(h @ bl[0])
    def head_loss_fn(hp, h, y): return jnp.mean(((h @ hp) - y) ** 2)

    x = jax.random.randint(k4, (B, S), 0, 7)
    y = jax.random.normal(jax.random.PRNGKey(9), (B, S))
    D = S * E
    batch = {"x": x, "y": y}

    def run(depth, codec, params):
        lf = transport.make_pod_pipeline_loss_fn(
            embed_fn, stage_fn, head_loss_fn, codec, mesh,
            num_microbatches=M, async_depth=depth)
        with mesh_lib.set_mesh(mesh):
            return jax.jit(jax.value_and_grad(lf))(params, batch)

    def leaves_equal(a, b):
        return all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
""")


def test_depth1_and_depth2_bit_identical_c3sl():
    """The skew delays payload consumption but never mis-pairs microbatch
    payloads with labels, so loss and gradients are bit-identical across
    depths — the staleness-semantics pin, with the paper codec on the
    channel."""
    r = run_py(COMMON + textwrap.dedent("""
        codec = build("c3sl:R=2", D=D)
        params = {"embed": embed_p, "blocks": blocks, "head": head_p,
                  "codec": codec.init(jax.random.PRNGKey(7))}
        l1, g1 = run(1, codec, params)
        l2, g2 = run(2, codec, params)
        l3, g3 = run(3, codec, params)
        print(json.dumps({
            "l1": float(l1), "l2": float(l2), "l3": float(l3),
            "g12": bool(leaves_equal(g1, g2)),
            "g13": bool(leaves_equal(g1, g3)),
        }))
    """))
    assert r["l1"] == r["l2"] == r["l3"], r
    assert r["g12"] and r["g13"], r


def test_depth2_matches_per_microbatch_reference():
    """Deterministic two-pod regression: the skewed schedule's loss equals
    the hand-rolled per-microbatch reference (each microbatch through
    front -> codec round-trip -> back, paired with its OWN labels) — the
    warmup slots' zero payloads are masked out and contribute nothing."""
    r = run_py(COMMON + textwrap.dedent("""
        codec = build("c3sl:R=2", D=D)
        params = {"embed": embed_p, "blocks": blocks, "head": head_p,
                  "codec": codec.init(jax.random.PRNGKey(7))}
        l2, _ = run(2, codec, params)
        mb = B // M
        tot = 0.0
        for m in range(M):
            h = embed_fn(params["embed"], x[m*mb:(m+1)*mb])
            h = stage_fn(jax.tree.map(lambda a: a[0], params["blocks"]), h)
            Zf = h.reshape(mb, D)
            Zf = codec.decode(params["codec"], codec.encode(params["codec"], Zf))
            h = stage_fn(jax.tree.map(lambda a: a[1], params["blocks"]),
                         Zf.reshape(h.shape))
            tot = tot + head_loss_fn(params["head"], h, y[m*mb:(m+1)*mb])
        print(json.dumps({"pipe": float(l2), "ref": float(tot / M)}))
    """))
    assert abs(r["pipe"] - r["ref"]) < 1e-5 * max(1.0, abs(r["ref"])), r


def test_depth_adds_exactly_one_bubble_step_per_unit():
    """The scan runs M + depth steps — pinned through the compiled HLO's
    trip-count-aware collective stats: the channel ppermute fires once per
    scan step with a fixed payload, so total collective-permute bytes are
    exactly (M + depth) x payload_bytes for every depth."""
    r = run_py(COMMON + textwrap.dedent("""
        from repro.launch import hloparse

        codec = build("c3sl:R=2", D=D)
        params = {"embed": embed_p, "blocks": blocks, "head": head_p,
                  "codec": codec.init(jax.random.PRNGKey(7))}

        def permute_bytes(depth):
            lf = transport.make_pod_pipeline_loss_fn(
                embed_fn, stage_fn, head_loss_fn, codec, mesh,
                num_microbatches=M, async_depth=depth)
            with mesh_lib.set_mesh(mesh):
                compiled = jax.jit(lf).lower(params, batch).compile()
            a = hloparse.analyze(compiled.as_text())
            return a["coll_by_op"].get("collective-permute", 0.0)

        mb = B // M
        payload_bytes = codec.wire_bytes(mb)
        print(json.dumps({"p1": permute_bytes(1), "p3": permute_bytes(3),
                          "M": M, "payload": payload_bytes}))
    """))
    assert r["payload"] > 0
    assert r["p1"] == (r["M"] + 1) * r["payload"], r
    assert r["p3"] == (r["M"] + 3) * r["payload"], r


def test_asymmetric_link_on_the_pipeline_channel():
    """A ``bwd:`` codec on the pod channel: the forward loss is identical
    (the seam is identity), the backward ppermute's gradient payload is
    re-compressed, so grads differ from the mirrored run."""
    r = run_py(COMMON + textwrap.dedent("""
        codec = build("c3sl:R=2", D=D)
        params = {"embed": embed_p, "blocks": blocks, "head": head_p,
                  "codec": codec.init(jax.random.PRNGKey(7))}
        l1, g1 = run(2, codec, params)
        link = transport.build_link("c3sl:R=2 >> bwd:c3sl:R=2", D=D)
        lp = link.init(jax.random.PRNGKey(7))
        l2, g2 = run(2, link, dict(params, codec=lp))
        diff = float(sum(jnp.abs(a - b).sum() for a, b in
                         zip(jax.tree.leaves(g1["embed"]),
                             jax.tree.leaves(g2["embed"]))))
        print(json.dumps({"l1": float(l1), "l2": float(l2), "diff": diff}))
    """))
    assert r["l1"] == r["l2"], r
    assert r["diff"] > 0, r


def test_adaptive_link_rejected_by_pipeline():
    """The pipeline compiles ONE program; handing it an unresolved adaptive
    channel must fail loudly, not silently bake a bucket."""
    r = run_py(COMMON + textwrap.dedent("""
        link = transport.build_link(
            "adaptive:c3sl:R=4,min_R=2 >> bwd:c3sl:R=2", D=D)
        try:
            transport.make_pod_pipeline_loss_fn(
                embed_fn, stage_fn, head_loss_fn, link, mesh,
                num_microbatches=M)
            ok = False
        except ValueError as e:
            ok = "static" in str(e)
        # pin_link resolves it
        static = transport.pin_link(link)
        transport.make_pod_pipeline_loss_fn(
            embed_fn, stage_fn, head_loss_fn, static, mesh,
            num_microbatches=M)
        print(json.dumps({"ok": bool(ok), "pinned": static.spec()}))
    """))
    assert r["ok"], r
    assert r["pinned"] == "c3sl:R=2,D=24 >> bwd:c3sl:R=2,D=24", r
