"""Directional transport layer tests: link specs, mirrored bit-identity vs
the shared-codec path, the gradient-compression seam, per-direction wire
accounting, and the zero-recompile guarantee across (R_fwd, R_bwd) bucket
ladders."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs, transport
from repro.codecs import build
from repro.core import hrr
from repro.transport import SplitLink, build_link, grad_roundtrip


# --------------------------------------------------------------------------
# link spec grammar
# --------------------------------------------------------------------------

def test_is_link_spec_and_parse():
    assert transport.is_link_spec("c3sl:R=8 >> bwd:c3sl:R=4")
    assert not transport.is_link_spec("c3sl:R=8|int8")
    assert transport.parse_link_spec("c3sl:R=8|int8 >> bwd:c3sl:R=4") == \
        ("c3sl:R=8|int8", "c3sl:R=4", None)
    assert transport.parse_link_spec("c3sl:R=8") == ("c3sl:R=8", None, None)
    assert transport.parse_link_spec(
        "c3sl:R=16|int8 >> bwd:c3sl:R=8 >> draft:c3sl:R=32|int8") == \
        ("c3sl:R=16|int8", "c3sl:R=8", "c3sl:R=32|int8")
    # draft-only links need no bwd: stage, and tag order is free
    assert transport.parse_link_spec("c3sl:R=8 >> draft:c3sl:R=4") == \
        ("c3sl:R=8", None, "c3sl:R=4")
    assert transport.parse_link_spec(
        "c3sl:R=8 >> draft:c3sl:R=4 >> bwd:c3sl:R=2") == \
        ("c3sl:R=8", "c3sl:R=2", "c3sl:R=4")


def test_link_spec_errors():
    with pytest.raises(ValueError, match="bwd:"):
        transport.parse_link_spec("c3sl:R=8 >> c3sl:R=4")
    with pytest.raises(ValueError, match="duplicate"):
        transport.parse_link_spec("a >> bwd:b >> bwd:c")
    with pytest.raises(ValueError, match="more than two"):
        transport.parse_link_spec("a >> bwd:b >> draft:c >> draft:d")
    with pytest.raises(ValueError, match="duplicate"):
        transport.parse_link_spec("a >> draft:b >> draft:c")
    with pytest.raises(ValueError, match="empty backward"):
        transport.parse_link_spec("c3sl:R=8 >> bwd:")
    with pytest.raises(ValueError, match="empty draft"):
        transport.parse_link_spec("c3sl:R=8 >> draft:")
    with pytest.raises(ValueError, match="flat"):
        SplitLink(build("bnpp:R=4,C=8,H=4,W=4"), build("c3sl:R=2,D=64"))


def test_trainable_bwd_codec_rejected():
    """The gradient seam returns zero cotangents for the backward codec's
    params, so a trainable bwd codec would silently stay at init while
    corrupting every gradient — construction must fail loudly (fwd stays
    free to train; c3sl's fixed keys are fine on either side)."""
    with pytest.raises(ValueError, match="cannot train"):
        build_link("c3sl:R=4,D=64 >> bwd:dense:R=4,D=64")
    with pytest.raises(ValueError, match="cannot train"):
        build_link("c3sl:R=4,D=64 >> bwd:dense:R=4,D=64|int8")
    # trainable FORWARD codecs are fine (their params backprop normally)
    assert not build_link("dense:R=4,D=64 >> bwd:c3sl:R=2,D=64").mirrored


@pytest.mark.parametrize("spec", [
    "c3sl:R=8,D=64 >> bwd:c3sl:R=4,D=64",
    "c3sl:R=16,D=64|int8 >> bwd:c3sl:R=8,D=64",
    "c3sl:R=8,D=64|int8 >> bwd:c3sl:R=2,D=64|int8",
    "adaptive:c3sl:R=8,D=64,min_R=2 >> bwd:adaptive:c3sl:R=4,D=64,min_R=2",
    "adaptive:c3sl:R=8,D=256,min_R=2|topk:k=16 >> bwd:c3sl:R=2,D=256|int8",
])
def test_asymmetric_spec_roundtrips(spec):
    link = build_link(spec)
    assert link.spec() == spec
    assert build_link(link.spec()).spec() == spec
    assert not link.mirrored


def test_mirrored_spec_is_plain_codec_spec():
    link = build_link("c3sl:R=4,D=64|int8")
    assert link.mirrored
    assert link.spec() == "c3sl:R=4,D=64|int8"
    # mirrored params ARE the forward codec's params (pre-transport tree)
    p = link.init(jax.random.PRNGKey(0))
    ps = build("c3sl:R=4,D=64|int8").init(jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(p["keys"]), np.asarray(ps["keys"]))


# --------------------------------------------------------------------------
# mirrored link == shared-codec path, bit-identically
# --------------------------------------------------------------------------

def _split_mlp(D_in=8, D_cut=64, n_cls=4, B=16, seed=0):
    rng = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    net = {"front": {"w": jax.random.normal(k1, (D_in, D_cut)) * D_in ** -0.5},
           "back": {"w": jax.random.normal(k2, (D_cut, n_cls)) * D_cut ** -0.5}}
    batch = {"x": jax.random.normal(k3, (B, D_in)),
             "y": jax.random.normal(k4, (B, n_cls))}
    return net, batch


def _front(p, x):
    return jax.nn.relu(x @ p["w"])


def _back(p, z):
    return z @ p["w"]


def _mse(logits, y):
    return jnp.mean((logits - y) ** 2)


@pytest.mark.parametrize("spec", ["c3sl:R=4,D=64", "c3sl:R=4,D=64|int8"])
def test_mirrored_link_bit_identical_loss_and_grads(spec):
    """The PR-4 equivalence the refactor must preserve: a mirrored link
    (bwd == fwd, no ``bwd:`` stage) produces bit-identical loss AND grads
    to the shared-codec path, including through the int8 wire stage."""
    net, batch = _split_mlp()
    codec = build(spec)
    link = transport.as_link(codec)
    rng = jax.random.PRNGKey(7)

    def run(c):
        loss_fn = transport.make_split_loss_fn(_front, _back, c, _mse,
                                               with_metrics=True)
        params = {**net, "codec": c.init(rng)}
        (loss, m), g = jax.jit(jax.value_and_grad(
            loss_fn, has_aux=True))(params, batch)
        return loss, m["cut_snr"], g

    l_codec, snr_codec, g_codec = run(codec)
    l_link, snr_link, g_link = run(link)
    np.testing.assert_array_equal(np.asarray(l_codec), np.asarray(l_link))
    np.testing.assert_array_equal(np.asarray(snr_codec), np.asarray(snr_link))
    for a, b in zip(jax.tree.leaves(g_codec), jax.tree.leaves(g_link)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_engine_greedy_decode_bit_identical_with_link_spec():
    """Serving: a link spec's forward channel drives the engine, so greedy
    outputs are bit-identical to the plain codec spec (incl. |int8)."""
    from repro.configs.base import get_config, reduced
    from repro.models import lm as lm_lib
    from repro.serving.engine import BatchedEngine, Request
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=64,
                  d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=1,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)

    def run(spec):
        eng = BatchedEngine(params, cfg, num_slots=4, max_len=16, codec=spec,
                            chunk_size=4)
        for u in range(4):
            eng.submit(Request(uid=u, prompt=[1 + u, 2, 3], max_new_tokens=4))
        eng.run(max_steps=64)
        return eng

    plain = run("c3sl:R=4|int8")
    linked = run("c3sl:R=4|int8 >> bwd:c3sl:R=2|int8")
    assert linked.link_spec is not None
    assert [r.out for r in sorted(linked.finished, key=lambda r: r.uid)] == \
        [r.out for r in sorted(plain.finished, key=lambda r: r.uid)]
    # serving is forward-only: bwd accounted as zero, fwd == total
    assert linked.stats["wire_bytes_bwd"] == 0
    assert linked.stats["wire_bytes_fwd"] == \
        linked.stats["payload_wire_bytes"] == plain.stats["payload_wire_bytes"]


# --------------------------------------------------------------------------
# the gradient seam
# --------------------------------------------------------------------------

def test_grad_roundtrip_forward_is_identity_backward_compresses():
    bwd = build("c3sl:R=2,D=64")
    bp = bwd.init(jax.random.PRNGKey(3))
    payload = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    W = jax.random.normal(jax.random.PRNGKey(2), (8, 64))
    out = grad_roundtrip(bwd, payload, bp)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(payload))

    # d/d_payload of sum(seam(payload) * W) must be the bwd ROUND-TRIP of W
    g = jax.grad(lambda p: (grad_roundtrip(bwd, p, bp) * W).sum())(payload)
    expect = bwd.decode(bp, bwd.encode(bp, W))
    np.testing.assert_array_equal(np.asarray(g), np.asarray(expect))


def test_grad_probe_measures_gradient_retrieval_snr():
    bwd = build("c3sl:R=2,D=64")
    bp = bwd.init(jax.random.PRNGKey(3))
    payload = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    W = jax.random.normal(jax.random.PRNGKey(2), (8, 64))

    def f(p, probe):
        return (grad_roundtrip(bwd, p, bp, probe) * W).sum()

    _, snr = jax.grad(f, argnums=(0, 1))(payload, jnp.float32(0.0))
    expect = hrr.retrieval_snr(W, bwd.decode(bp, bwd.encode(bp, W)))
    np.testing.assert_allclose(float(snr), float(expect), rtol=1e-6)


def test_asymmetric_link_forward_identical_grads_differ():
    """The seam is identity in the forward pass — loss (and cut SNR) match
    the mirrored link bit-for-bit; only the backward pass changes."""
    net, batch = _split_mlp()
    rng = jax.random.PRNGKey(7)
    asym = build_link("c3sl:R=4,D=64 >> bwd:c3sl:R=2,D=64")
    mirr = transport.as_link(build("c3sl:R=4,D=64"))
    pa, pm = asym.init(rng), mirr.init(rng)

    def run(link, cp):
        loss_fn = transport.make_split_loss_fn(_front, _back, link, _mse)
        params = {**net, "codec": cp}
        probe = jnp.float32(0.0)
        loss, (g, gsnr) = jax.jit(jax.value_and_grad(
            loss_fn, argnums=(0, 2)))(params, batch, probe)
        return loss, g, gsnr

    l_a, g_a, snr_a = run(asym, pa)
    l_m, g_m, snr_m = run(mirr, pm)
    np.testing.assert_array_equal(np.asarray(l_a), np.asarray(l_m))
    # mirrored links have no seam: the probe's gradient is exactly zero;
    # the asymmetric link measures a real (finite, nonzero) gradient SNR
    assert float(snr_m) == 0.0
    assert np.isfinite(float(snr_a)) and float(snr_a) != 0.0
    diff = sum(float(jnp.abs(a - b).sum())
               for a, b in zip(jax.tree.leaves(g_a["front"]),
                               jax.tree.leaves(g_m["front"])))
    assert diff > 0, "bwd codec did not touch the gradient"
    # the back half's grads live AFTER the seam: untouched
    for a, b in zip(jax.tree.leaves(g_a["back"]), jax.tree.leaves(g_m["back"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_equal_bwd_spec_roundtrips_and_shares_keys():
    """An explicit ``bwd:`` equal to the fwd spec inits both channels from
    the same rng — bit-identical key tables (the 'bwd == fwd' pin for the
    asymmetric params tree)."""
    link = build_link("c3sl:R=4,D=64 >> bwd:c3sl:R=4,D=64")
    p = link.init(jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(p["fwd"]["keys"]),
                                  np.asarray(p["bwd"]["keys"]))
    assert build_link(link.spec()).spec() == link.spec()


# --------------------------------------------------------------------------
# per-direction accounting
# --------------------------------------------------------------------------

def test_wire_bytes_per_direction():
    B = 16
    # mirrored: bwd == fwd (the gradient has the fwd compressed shape)
    m = transport.as_link(build("c3sl:R=4,D=64|int8"))
    assert m.wire_bytes_fwd(B) == (B // 4) * 64 + 4 * (B // 4)
    assert m.wire_bytes_bwd(B) == m.wire_bytes_fwd(B)
    # asymmetric: the gradient payload's B/R_fwd rows re-grouped by R_bwd
    a = build_link("c3sl:R=4,D=64|int8 >> bwd:c3sl:R=2,D=64")
    assert a.wire_bytes_fwd(B) == (B // 4) * 64 + 4 * (B // 4)
    assert a.wire_bytes_bwd(B) == (B // 4 // 2) * 64 * 4        # f32 wire
    assert a.total_wire_bytes(B) == a.wire_bytes_fwd(B) + a.wire_bytes_bwd(B)
    assert transport.split_comm_bytes(a, B) == a.total_wire_bytes(B)
    assert transport.split_comm_bytes(a, B, directions=1) == \
        a.wire_bytes_fwd(B)


def test_adaptive_link_accounting_follows_both_buckets():
    link = build_link(
        "adaptive:c3sl:R=8,min_R=2|int8 >> bwd:adaptive:c3sl:R=4,min_R=2",
        D=64)
    B = 32
    for rf in (2, 4, 8):
        for rb in (2, 4):
            link.fwd.codec.pin(rf)
            link.bwd.codec.pin(rb)
            assert link.wire_bytes_fwd(B) == (B // rf) * 64 + 4 * (B // rf)
            assert link.wire_bytes_bwd(B) == (B // rf // rb) * 64 * 4
            assert transport.link_program_key(link) == (rf, rb)


def test_link_clamp_trims_both_ladders():
    link = build_link(
        "adaptive:c3sl:R=8,min_R=2 >> bwd:adaptive:c3sl:R=8,min_R=2", D=64)
    c = codecs.clamp_R(link, 16)     # dispatches through SplitLink.with_max_R
    assert c.fwd.codec.ladder == (2, 4, 8)
    # fwd can ramp to 8 -> gradient payload can shrink to 16/8 = 2 rows, so
    # the bwd ladder must divide 2
    assert c.bwd.codec.ladder == (2,)
    assert build_link(c.spec()).spec() == c.spec()


# --------------------------------------------------------------------------
# zero recompiles across per-direction bucket ladders
# --------------------------------------------------------------------------

def test_zero_recompiles_across_directional_R_switches():
    """PR-4's trace-counter contract extended to the per-direction table:
    one compiled branch per (R_fwd, R_bwd) pair, switched host-side — a
    schedule bouncing both ladders independently must trace each pair
    EXACTLY once."""
    net, batch = _split_mlp(B=32)
    link = build_link(
        "adaptive:c3sl:R=8,min_R=2 >> bwd:adaptive:c3sl:R=4,min_R=2", D=64)
    link_params = link.init(jax.random.PRNGKey(7))
    traces = [0]

    def make_step(static_link, static_params):
        loss_fn = transport.make_split_loss_fn(_front, _back, static_link,
                                               _mse, with_metrics=True)

        @jax.jit
        def step(net, batch, probe):
            traces[0] += 1            # runs only while tracing
            params = {**net, "codec": static_params}
            (loss, m), (g, gsnr) = jax.value_and_grad(
                loss_fn, argnums=(0, 2), has_aux=True)(params, batch, probe)
            net2 = jax.tree.map(lambda a, b: a - 0.01 * b, net,
                                {"front": g["front"], "back": g["back"]})
            return net2, loss, m["cut_snr"], gsnr

        return step

    table = transport.build_link_program_table(link, link_params, make_step)
    assert sorted(table) == [(rf, rb) for rf in (2, 4, 8) for rb in (2, 4)]
    probe = jnp.float32(0.0)
    for key in table:
        net, *_ = table[key](net, batch, probe)
    assert traces[0] == 6
    schedule = [(2, 2), (8, 4), (2, 4), (4, 2), (8, 2), (4, 4), (2, 2),
                (8, 4), (8, 4), (2, 4)]
    for rf, rb in schedule:
        link.fwd.codec.pin(rf)
        link.bwd.codec.pin(rb)
        key = transport.link_program_key(link)
        assert key == (rf, rb)
        net, loss, snr, gsnr = table[key](net, batch, probe)
        assert np.isfinite(float(loss))
    assert traces[0] == 6, "a per-direction R switch triggered a retrace"


def test_bare_codec_table_matches_pr4_semantics():
    """Bare codecs (and their scalar program keys) flow through the link
    table helpers unchanged — the PR-4 call sites keep working."""
    codec = build("adaptive:c3sl:R=4,min_R=2", D=64)
    p = codec.init(jax.random.PRNGKey(0))
    table = transport.build_link_program_table(codec, p,
                                               lambda c, cp: c.spec())
    assert sorted(table) == [2, 4]
    assert transport.link_program_key(codec) == codecs.program_key(codec)
    static = build("c3sl:R=4,D=64")
    table = transport.build_link_program_table(static, {}, lambda c, cp: 1)
    assert list(table) == [None]
