"""Property-based tests (hypothesis) for the v2 frame codec and the
mask-aware HRR unbind.

Frame integrity contract: a frame either decodes to EXACTLY what was
encoded, or raises loudly — truncation at EVERY byte boundary and any
single-bit flip anywhere in the body must surface as FrameCorruption
(wire damage, NACKable) or ProtocolError (malformed content), never as a
silently mis-decoded frame.  Mask-aware unbind contract: retrieval SNR
is exact at zero erasures and monotonically non-increasing (within
per-sample noise) as the erased fraction grows.

Example budget comes from the session profile in conftest.py
(``HYPOTHESIS_PROFILE=ci`` in the dedicated CI job).
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

pytestmark = pytest.mark.property

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis package")
from hypothesis import given, strategies as st  # noqa: E402

from repro.codecs import build                  # noqa: E402
from repro.core import hrr                      # noqa: E402
from repro.faults import FaultPlan              # noqa: E402
from repro.frontdoor import (CTRL_SEQ, FrameCorruption, MsgType,  # noqa: E402
                             ProtocolError, decode_frame, encode_frame,
                             pack_array, unpack_array)

_WIRE_DTYPES = ("int32", "int8", "uint8", "float32", "float16")

headers = st.dictionaries(
    st.text(st.characters(min_codepoint=32, max_codepoint=126), max_size=8),
    st.one_of(st.integers(-2**31, 2**31 - 1), st.text(max_size=12),
              st.booleans(), st.none()),
    max_size=4)


@given(mtype=st.sampled_from(list(MsgType)), header=headers,
       payload=st.binary(max_size=64),
       seq=st.one_of(st.integers(0, 2**32 - 2), st.just(CTRL_SEQ)))
def test_frame_roundtrip_exact(mtype, header, payload, seq):
    frame = encode_frame(mtype, header, payload, seq=seq)
    m2, h2, p2, s2 = decode_frame(frame[4:])
    assert (m2, h2, p2, s2) == (mtype, header, payload, seq)


@given(header=headers, payload=st.binary(max_size=32))
def test_truncation_at_every_boundary_fails_loudly(header, payload):
    body = encode_frame(MsgType.SUBMIT, header, payload, seq=3)[4:]
    for cut in range(len(body)):
        with pytest.raises(ProtocolError):
            decode_frame(body[:cut])


@given(header=headers, payload=st.binary(max_size=32), data=st.data())
def test_any_single_bitflip_is_frame_corruption(header, payload, data):
    body = bytearray(encode_frame(MsgType.RESULT, header, payload, seq=1)[4:])
    i = data.draw(st.integers(0, len(body) - 1))
    body[i] ^= 1 << data.draw(st.integers(0, 7))
    # CRC32 catches every single-bit error; a flip inside the crc field
    # itself mismatches the recomputed value the same way
    with pytest.raises(FrameCorruption):
        decode_frame(bytes(body))


@st.composite
def wire_arrays(draw):
    dtype = np.dtype(draw(st.sampled_from(_WIRE_DTYPES)))
    shape = draw(st.lists(st.integers(0, 5), min_size=1, max_size=3))
    n = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    return np.frombuffer(draw(st.binary(min_size=n, max_size=n)),
                         dtype=dtype).reshape(shape)


@given(arr=wire_arrays(), rid=st.integers(0, 2**31 - 1))
def test_array_payload_roundtrip_bit_exact(arr, rid):
    hdr, payload = pack_array(arr)
    frame = encode_frame(MsgType.SUBMIT, {"rid": rid, **hdr}, payload, seq=0)
    _, h2, p2, _ = decode_frame(frame[4:])
    out = unpack_array(h2, p2)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    assert out.tobytes() == arr.tobytes()       # NaN-safe bit equality
    assert json.loads(json.dumps(h2)) == h2     # header stays JSON-clean


@given(seed=st.integers(0, 2**31 - 1))
def test_masked_unbind_snr_monotone_in_erasure(seed):
    D, B, R = 256, 8, 4
    codec = build(f"c3sl:R={R}", D=D)
    params = codec.init(jax.random.PRNGKey(1))
    rng = np.random.RandomState(seed)
    Z = jnp.asarray(rng.randn(B, D).astype(np.float32))
    payload = codec.encode(params, Z)
    plan = FaultPlan(seed=0, packets=16)
    order = rng.permutation(16)
    snrs = []
    for n_erased in (0, 4, 8, 12):
        keep_p = np.ones((payload.shape[0], 16), dtype=bool)
        keep_p[:, order[:n_erased]] = False
        keep = jnp.asarray(plan.expand_packets(payload.shape, keep_p))
        snrs.append(float(hrr.retrieval_snr(
            Z, codec.decode_masked(params, payload, keep))))
    base = float(hrr.retrieval_snr(Z, codec.decode(params, payload)))
    assert snrs[0] == pytest.approx(base, abs=1e-5)   # exact at zero loss
    for lo, hi in zip(snrs[1:], snrs):
        assert lo <= hi + 0.75, snrs
