"""Dry-run machinery integration test on a small simulated mesh.

Runs in a subprocess (device count locks at first jax init).  Exercises:
reduced-arch lower+compile with shardings, hloparse roofline extraction,
and the pipeline dry-run path with the codec.
"""
import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 16) -> dict:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=480)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_dryrun_reduced_arch_small_mesh():
    code = textwrap.dedent("""
        import json, dataclasses
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import get_config, reduced
        from repro.launch import dryrun as dr, hloparse, mesh as mesh_lib
        from repro.models import lm as lm_lib
        from repro.sharding import rules as sh

        mesh = mesh_lib.make_host_mesh(data=4, model=4)
        cfg = reduced(get_config("deepseek-7b"))
        params = lm_lib.abstract_params(cfg, jnp.bfloat16)
        param_sh = sh.param_shardings(params, mesh, mode="train")
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        batch_sh = sh.batch_shardings(batch, mesh)
        opt, train_step = dr.build_train_step(cfg, num_microbatches=2)
        opt_state = jax.eval_shape(opt.init, params)
        opt_sh = sh.opt_state_shardings(opt_state, mesh)
        with mesh_lib.set_mesh(mesh):
            lowered = jax.jit(train_step,
                              in_shardings=(param_sh, opt_sh, batch_sh),
                              out_shardings=(param_sh, opt_sh,
                                             NamedSharding(mesh, P()))
                              ).lower(params, opt_state, batch)
            compiled = lowered.compile()
        stats = hloparse.analyze(compiled.as_text())
        mem = compiled.memory_analysis()
        print(json.dumps({
            "flops": stats["dot_flops"],
            "coll": stats["coll_bytes"],
            "peak": int(mem.argument_size_in_bytes + mem.temp_size_in_bytes),
        }))
    """)
    r = run_py(code)
    assert r["flops"] > 1e8       # ~6*N*T/devices with remat (~2.6e8 analytic)
    assert r["coll"] > 0          # TP/FSDP collectives present
    assert 0 < r["peak"] < 32 * 2 ** 30


def test_pipeline_dryrun_compression_ratio_small_mesh():
    code = textwrap.dedent("""
        import json
        import repro.launch.dryrun as dr
        import repro.launch.mesh as mesh_lib
        # shrink the production mesh to the simulated host devices
        mesh_lib.make_production_mesh = \
            lambda multi_pod=False: mesh_lib.make_host_mesh(data=2, model=2, pod=2)
        dr.SHAPES = dict(dr.SHAPES,
                         train_4k=dict(seq_len=64, global_batch=8, kind="train"))
        import dataclasses
        from repro.configs.base import get_config, reduced, register
        small = reduced(get_config("deepseek-7b"))
        import repro.configs.base as base
        base._REGISTRY["tiny"] = lambda: dataclasses.replace(small, name="tiny")
        ident = dr.pipeline_dryrun("tiny", codec_kind="none", num_microbatches=2,
                                   save=False)
        c3 = dr.pipeline_dryrun("tiny", codec_kind="c3sl", R=2,
                                num_microbatches=2, save=False)
        print(json.dumps({"ident": ident["interpod_permute_bytes"],
                          "c3": c3["interpod_permute_bytes"]}))
    """)
    r = run_py(code, devices=8)
    # pair distance on the (2,2,2) mesh is 4, not 256 — just check both ran
    # and produced collective stats
    assert r["ident"] >= 0 and r["c3"] >= 0


def test_collective_parser_pod_distance():
    from repro.launch.dryrun import _pod_permute_bytes
    ln = ("%cp = f32[1,1024]{1,0} collective-permute(%x), channel_id=3, "
          "source_target_pairs={{0,256},{1,257}}")
    assert _pod_permute_bytes(ln) == 1024 * 4
    ln2 = ("%cp = f32[1,1024]{1,0} collective-permute(%x), channel_id=3, "
           "source_target_pairs={{0,1},{1,2}}")
    assert _pod_permute_bytes(ln2) == 0
