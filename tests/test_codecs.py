"""Codec round-trip, accounting, and split-step integration tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec as codec_lib
from repro.core import split as split_lib
from repro.core.bottlenet import BottleNetPPCodec


def test_identity_codec_roundtrip():
    c = codec_lib.IdentityCodec(D=64)
    Z = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    p = c.init(jax.random.PRNGKey(1))
    np.testing.assert_array_equal(np.asarray(c.decode(p, c.encode(p, Z))), np.asarray(Z))
    assert c.wire_bytes(8) == 8 * 64 * 4


@pytest.mark.parametrize("R", [2, 4, 8])
@pytest.mark.parametrize("backend", ["fft", "pallas"])
def test_c3sl_codec_shapes_and_bytes(R, backend):
    B, D = 16, 256
    c = codec_lib.C3SLCodec(R=R, D=D, backend=backend)
    p = c.init(jax.random.PRNGKey(0))
    Z = jax.random.normal(jax.random.PRNGKey(1), (B, D))
    S = c.encode(p, Z)
    assert S.shape == (B // R, D)
    Zhat = c.decode(p, S)
    assert Zhat.shape == (B, D)
    assert c.wire_bytes(B) == (B // R) * D * 4
    assert c.param_count() == R * D
    assert c.flops(B) == 2 * B * D * D


def test_c3sl_backends_agree():
    B, D, R = 8, 256, 4
    Z = jax.random.normal(jax.random.PRNGKey(1), (B, D))
    outs = {}
    for backend in ("fft", "direct", "pallas"):
        c = codec_lib.C3SLCodec(R=R, D=D, backend=backend)
        p = c.init(jax.random.PRNGKey(0))
        outs[backend] = np.asarray(c.decode(p, c.encode(p, Z)))
    np.testing.assert_allclose(outs["fft"], outs["direct"], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(outs["fft"], outs["pallas"], rtol=1e-3, atol=1e-3)


def test_c3sl_int8_wire():
    c = codec_lib.C3SLCodec(R=4, D=256, quant_bits=8)
    p = c.init(jax.random.PRNGKey(0))
    Z = jax.random.normal(jax.random.PRNGKey(1), (8, 256))
    Zhat = c.decode(p, c.encode(p, Z))
    assert Zhat.shape == Z.shape
    assert c.wire_bytes(8) == 2 * 256 * 1 + 4 * 2  # int8 payload + f32 scales
    # STE gradient flows
    g = jax.grad(lambda z: (c.decode(p, c.encode(p, z)) ** 2).sum())(Z)
    assert np.isfinite(np.asarray(g)).all() and np.abs(np.asarray(g)).sum() > 0


def test_dense_bottleneck_codec():
    c = codec_lib.DenseBottleneckCodec(R=4, D=128)
    p = c.init(jax.random.PRNGKey(0))
    Z = jax.random.normal(jax.random.PRNGKey(1), (8, 128))
    S = c.encode(p, Z)
    assert S.shape == (8, 32)
    assert c.decode(p, S).shape == (8, 128)
    assert c.param_count() == (128 + 1) * 32 + (32 + 1) * 128


@pytest.mark.parametrize("R", [2, 4, 8, 16])
def test_bottlenetpp_codec_roundtrip_and_formulas(R):
    B, C, H, W = 4, 64, 8, 8
    c = BottleNetPPCodec(R=R, C=C, H=H, W=W)
    p = c.init(jax.random.PRNGKey(0))
    Z = jax.random.normal(jax.random.PRNGKey(1), (B, C, H, W))
    S = c.encode(p, Z)
    assert S.shape == (B, 4 * C // R, H // 2, W // 2)
    Zhat = c.decode(p, S)
    assert Zhat.shape == Z.shape
    # Table 2 formulas
    k = 2
    want_params = (C * k * k + 1) * (4 * C // R) + ((4 * C // R) * k * k + 1) * C
    assert c.param_count() == want_params


def test_split_loss_trains_through_codec():
    """End-to-end: tiny front/back MLP + C3-SL codec; loss decreases."""
    D_in, D_cut, n_cls = 16, 64, 4
    rng = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    params = {
        "front": {"w": jax.random.normal(k1, (D_in, D_cut)) * D_in ** -0.5},
        "back": {"w": jax.random.normal(k2, (D_cut, n_cls)) * D_cut ** -0.5},
        "codec": codec_lib.C3SLCodec(R=4, D=D_cut).init(k3),
    }
    codec = codec_lib.C3SLCodec(R=4, D=D_cut)

    def front(p, x):
        return jax.nn.relu(x @ p["w"])

    def back(p, z):
        return z @ p["w"]

    def ce(logits, y):
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y])

    loss_fn = split_lib.make_split_loss_fn(front, back, codec, ce)

    x = jax.random.normal(k4, (32, D_in))
    y = jax.random.randint(jax.random.PRNGKey(5), (32,), 0, n_cls)
    batch = {"x": x, "y": y}

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(loss_fn)(p, batch)
        p = jax.tree.map(lambda a, b: a - 0.1 * b, p, g)
        return p, l

    losses = []
    for _ in range(40):
        params, l = step(params)
        losses.append(float(l))
    assert losses[-1] < losses[0] * 0.8, losses[::10]


def test_cached_key_spectrum_matches_and_survives_optimizer():
    """fft-backend params carry keys_fft = rfft(keys); encode/decode with it
    are bit-identical to recomputing, and the frozen complex leaf rides
    through the optimizer stack (grads, Adam, apply_updates) untouched."""
    import warnings
    from repro.optim import adam, apply_updates, clip_by_global_norm
    B, D, R = 8, 64, 4
    c = codec_lib.C3SLCodec(R=R, D=D)
    p = c.init(jax.random.PRNGKey(0))
    assert "keys_fft" in p and jnp.iscomplexobj(p["keys_fft"])
    Z = jax.random.normal(jax.random.PRNGKey(1), (B, D))
    no_cache = {"keys": p["keys"]}
    assert bool(jnp.all(c.encode(p, Z) == c.encode(no_cache, Z)))
    assert bool(jnp.all(c.decode(p, c.encode(p, Z))
                        == c.decode(no_cache, c.encode(no_cache, Z))))

    params = {"w": jnp.ones((D,)), "codec": p}

    def loss(q):
        return (c.decode(q["codec"], c.encode(q["codec"], Z * q["w"])) ** 2).mean()

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        grads = jax.grad(loss)(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        opt = adam(1e-2)
        st = opt.init(params)
        upd, st = opt.update(grads, st, params)
        new = apply_updates(params, upd)
    assert bool(jnp.all(new["codec"]["keys_fft"] == p["keys_fft"]))
    assert bool(jnp.all(new["codec"]["keys"] == p["keys"]))  # stop_gradient
    assert not bool(jnp.all(new["w"] == params["w"]))        # net still trains


def test_codec_gradient_is_compressed_shape():
    """The backward channel tensor (dS) has the compressed shape — paper's
    bidirectional saving."""
    B, D, R = 8, 64, 4
    c = codec_lib.C3SLCodec(R=R, D=D)
    p = c.init(jax.random.PRNGKey(0))
    Z = jax.random.normal(jax.random.PRNGKey(1), (B, D))

    S, vjp = jax.vjp(lambda s: c.decode(p, s), c.encode(p, Z))
    (dS,) = vjp(jnp.ones((B, D)))
    assert dS.shape == (B // R, D)  # gradient crosses the wire compressed


def test_adaptive_pinned_train_step_bit_identical_to_static():
    """AdaptiveC3SL pinned to a constant schedule must be BIT-identical to
    the static c3sl:R=k codec through a full jitted train step (loss AND
    grads), including the |int8 chain — the wrapper only ever delegates to
    pre-built bucket codecs whose params init from the same rng."""
    from repro import codecs as codecs_lib
    D_in, D_cut, n_cls, B = 16, 64, 4, 32
    rng = jax.random.PRNGKey(0)
    k1, k2, k4 = jax.random.split(rng, 3)
    net = {
        "front": {"w": jax.random.normal(k1, (D_in, D_cut)) * D_in ** -0.5},
        "back": {"w": jax.random.normal(k2, (D_cut, n_cls)) * D_cut ** -0.5},
    }
    x = jax.random.normal(k4, (B, D_in))
    y = jax.random.randint(jax.random.PRNGKey(5), (B,), 0, n_cls)
    batch = {"x": x, "y": y}

    def front(p, x):
        return jax.nn.relu(x @ p["w"])

    def back(p, z):
        return z @ p["w"]

    def ce(logits, y):
        return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(y.shape[0]), y])

    for adaptive_spec, static_spec in [
        ("adaptive:c3sl:R=8,D=64,min_R=2", "c3sl:R=4,D=64"),
        ("adaptive:c3sl:R=8,D=64,min_R=2|int8", "c3sl:R=4,D=64|int8"),
    ]:
        a = codecs_lib.build(adaptive_spec).pin(4)
        s = codecs_lib.build(static_spec)
        pa = {**net, "codec": a.init(jax.random.PRNGKey(7))}
        ps = {**net, "codec": s.init(jax.random.PRNGKey(7))}
        step_a = jax.jit(jax.value_and_grad(
            split_lib.make_split_loss_fn(front, back, a, ce), has_aux=False))
        step_s = jax.jit(jax.value_and_grad(
            split_lib.make_split_loss_fn(front, back, s, ce), has_aux=False))
        la, ga = step_a(pa, batch)
        ls, gs = step_s(ps, batch)
        assert float(la) == float(ls), (adaptive_spec, float(la), float(ls))
        for part in ("front", "back"):
            for k in ga[part]:
                np.testing.assert_array_equal(np.asarray(ga[part][k]),
                                              np.asarray(gs[part][k]))


def test_split_metrics_surface_cut_snr():
    """with_metrics=True yields the cut-layer retrieval SNR alongside the
    loss — the Adaptive-R controller's signal — and matches the standalone
    apply_codec(with_snr=True) computation."""
    from repro.core import hrr
    codec = codec_lib.C3SLCodec(R=4, D=64)
    p = codec.init(jax.random.PRNGKey(0))
    Z = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    Zhat, snr = split_lib.apply_codec(codec, p, Z, with_snr=True)
    np.testing.assert_array_equal(np.asarray(Zhat),
                                  np.asarray(split_lib.apply_codec(codec, p, Z)))
    assert float(snr) == float(hrr.retrieval_snr(Z, Zhat))

    loss_fn = split_lib.make_split_loss_fn(
        lambda p, x: x, lambda p, z: z.sum(-1, keepdims=True), codec,
        lambda logits, y: jnp.mean(logits), with_metrics=True)
    params = {"front": {}, "back": {}, "codec": p}
    loss, metrics = loss_fn(params, {"x": Z, "y": None})
    assert np.isfinite(float(loss))
    assert np.isfinite(float(metrics["cut_snr"]))
