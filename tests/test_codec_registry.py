"""repro.codecs registry tests: spec round-trip, error paths, Chain
accounting, wire stages, and protocol-level dispatch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs
from repro.codecs import Chain, build


# --------------------------------------------------------------------------
# spec strings
# --------------------------------------------------------------------------

@pytest.mark.parametrize("spec", [
    "identity:D=64",
    "c3sl:R=4,D=256",
    "c3sl:R=8,D=256,backend=direct",
    "c3sl:R=4,D=256,unitary=true",
    "c3sl:R=4,D=256,backend=pallas,key_seed=3",
    "dense:R=4,D=128",
    "bnpp:R=4,C=64,H=8,W=8",
    "c3sl:R=4,D=256|int8",
    "c3sl:R=4,D=512|topk:ratio=0.1",
    "c3sl:R=2,D=128|topk:k=16|int8",
    "identity:D=32|noop",
])
def test_spec_string_roundtrip(spec):
    assert build(spec).spec() == spec


def test_build_defaults_fill_runtime_dims():
    c = build("c3sl:R=8,backend=fft|int8", D=4096)
    assert c.R == 8 and c.D == 4096
    # explicit spec args win over defaults
    c = build("c3sl:R=8,D=64", D=4096, R=2)
    assert c.R == 8 and c.D == 64
    # defaults a stage doesn't declare are ignored
    build("identity", D=64, R=4, unitary=False)


def test_every_registered_transform_buildable_from_spec():
    for name in codecs.available()["transform"]:
        c = build(name, D=64, R=2, C=16, H=4, W=4)
        assert c.spec().startswith(c.spec_name)
        assert c.feature_layout in ("flat", "nchw")


def test_unknown_name_and_bad_args_raise():
    with pytest.raises(ValueError, match="unknown transform"):
        build("nope:R=4")
    with pytest.raises(ValueError, match="bogus"):
        build("c3sl:R=4,D=64,bogus=1")
    with pytest.raises(ValueError, match="missing required"):
        build("c3sl:R=4")
    with pytest.raises(ValueError, match="unknown wire stage"):
        build("c3sl:R=4,D=64|whatever")
    with pytest.raises(ValueError, match="unknown transform"):
        build("int8")  # wire stage can't lead a spec
    with pytest.raises(ValueError, match="malformed"):
        build("c3sl:R4,D=64")
    with pytest.raises(ValueError):
        build("dense:R=3,D=64")  # D % R != 0 -> dataclass validation
    with pytest.raises(ValueError):
        build("c3sl:R=4,D=64,backend=cuda")


def test_codecspec_is_serializable_both_ways():
    spec = codecs.CodecSpec.parse("c3sl:R=4,unitary=true,backend=direct")
    assert spec.name == "c3sl"
    assert spec.args == {"R": 4, "unitary": True, "backend": "direct"}
    assert codecs.CodecSpec.parse(str(spec)) == spec


# --------------------------------------------------------------------------
# Chain accounting
# --------------------------------------------------------------------------

def test_chain_int8_matches_old_inlined_quant_numbers():
    B, R, D = 8, 4, 256
    c = build(f"c3sl:R={R},D={D}|int8")
    assert isinstance(c, Chain)
    # the numbers the inlined quant_bits=8 codec used to report
    assert c.wire_bytes(B) == (B // R) * D * 1 + 4 * (B // R)
    assert c.flops(B) == 2 * B * D * D
    assert c.param_count() == R * D
    assert c.payload_shape(B) == (B // R, D)
    # and the legacy shim constructor agrees exactly
    from repro.core.codec import C3SLCodec as legacy
    l = legacy(R=R, D=D, quant_bits=8)
    assert (l.wire_bytes(B), l.flops(B), l.param_count()) == \
        (c.wire_bytes(B), c.flops(B), c.param_count())


def test_chain_roundtrip_shapes_and_ste_gradient():
    c = build("c3sl:R=4,D=256|int8")
    p = c.init(jax.random.PRNGKey(0))
    Z = jax.random.normal(jax.random.PRNGKey(1), (8, 256))
    S = c.encode(p, Z)
    assert S.shape == (2, 256)
    assert c.decode(p, S).shape == Z.shape
    g = jax.grad(lambda z: (c.decode(p, c.encode(p, z)) ** 2).sum())(Z)
    assert np.isfinite(np.asarray(g)).all()
    assert np.abs(np.asarray(g)).sum() > 0


def test_chain_delegates_protocol_surface():
    c = build("c3sl:R=2,D=64|int8")
    assert c.R == 2 and c.D == 64
    assert c.feature_layout == "flat"
    # noop wire keeps the f32 byte accounting of the bare transform
    bare = build("c3sl:R=2,D=64")
    assert build("c3sl:R=2,D=64|noop").wire_bytes(8) == bare.wire_bytes(8)


def test_topk_wire_mask_encoded_accounting():
    c = build("c3sl:R=2,D=512|topk:k=32")
    G = 8 // 2
    # per payload row: D-bit mask + k f32 values
    assert c.wire_bytes(8) == G * (512 // 8 + 4 * 32)
    p = c.init(jax.random.PRNGKey(0))
    Z = jax.random.normal(jax.random.PRNGKey(1), (8, 512))
    S = c.encode(p, Z)
    nz = (np.asarray(S) != 0).sum(axis=-1)
    assert nz.max() <= 32  # exact-k even under magnitude ties
    g = jax.grad(lambda z: (c.encode(p, z) ** 2).sum())(Z)
    assert np.abs(np.asarray(g)).sum() > 0  # straight-through


def test_topk_ratio_and_validation():
    t = codecs.TopKSparsify(ratio=0.25)
    assert t.wire_bytes((4, 64)) == 4 * (8 + 4 * 16)
    with pytest.raises(ValueError):
        codecs.TopKSparsify(ratio=0.0)
    with pytest.raises(ValueError):
        codecs.TopKSparsify(k=-1)


def test_topk_exact_k_under_ties():
    # tied magnitudes must not inflate the payload past k values/row
    x = jnp.array([[3.0, 3.0, 3.0, 1.0]])
    out = np.asarray(codecs.TopKSparsify(k=2).apply(x))
    assert (out != 0).sum() == 2


def test_apply_quant_bits_helper():
    assert codecs.apply_quant_bits("c3sl:R=4", None) == "c3sl:R=4"
    assert codecs.apply_quant_bits("c3sl:R=4", 8) == "c3sl:R=4|int8"
    # idempotent when the spec already names the stage
    assert codecs.apply_quant_bits("c3sl:R=4|int8", 8) == "c3sl:R=4|int8"
    with pytest.raises(ValueError, match="only int8"):
        codecs.apply_quant_bits("c3sl:R=4", 4)


# --------------------------------------------------------------------------
# protocol dispatch + helpers
# --------------------------------------------------------------------------

def test_apply_codec_dispatches_on_feature_layout_not_isinstance():
    from repro.core.split import apply_codec
    rng = jax.random.PRNGKey(0)
    conv = build("bnpp:R=4,C=16,H=4,W=4")
    assert conv.feature_layout == "nchw"
    Z = jax.random.normal(rng, (4, 16, 4, 4))
    assert apply_codec(conv, conv.init(rng), Z).shape == Z.shape
    flat = build("c3sl:R=2,D=64")
    Zf = jax.random.normal(rng, (4, 2, 32))  # flattened per-sample to (4, 64)
    assert apply_codec(flat, flat.init(rng), Zf).shape == Zf.shape


def test_clamp_R_rebuilds_through_chain():
    c = codecs.clamp_R(build("c3sl:R=8,D=64|int8"), 2)
    assert c.R == 2 and c.spec() == "c3sl:R=2,D=64|int8"
    # no-ops: already small enough, or no R field
    assert codecs.clamp_R(build("c3sl:R=2,D=64"), 4).R == 2
    assert codecs.clamp_R(build("identity:D=64"), 1).spec() == "identity:D=64"


def test_sequence_group_encode_validates_divisibility():
    c = build("c3sl:R=4,D=32")
    p = c.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="not divisible by R=4"):
        codecs.sequence_group_encode(c, p, jnp.zeros((1, 63, 32)))
    payload = codecs.sequence_group_encode(
        c, p, jax.random.normal(jax.random.PRNGKey(1), (1, 64, 32)))
    assert payload.shape == (1, 16, 32)   # sequence-grouped 3-D layout
    # groups that would straddle the leading axis fall back to the flat form
    flat = codecs.sequence_group_encode(
        c, p, jax.random.normal(jax.random.PRNGKey(1), (2, 6, 32)))
    assert flat.shape == (3, 32)


def test_engine_accepts_spec_strings():
    from repro.configs.base import get_config, reduced
    from repro.models import lm as lm_lib
    from repro.serving.engine import BatchedEngine, Request
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=64,
                  d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=1,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    eng = BatchedEngine(params, cfg, num_slots=2, max_len=16,
                        codec="c3sl:R=2|int8")
    assert eng.codec.spec() == "c3sl:R=2,D=64|int8"
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=2))
    done = eng.run(max_steps=32)
    assert len(done) == 1 and len(done[0].out) >= 1
    # "none" means codec off, matching the launch CLIs
    assert BatchedEngine(params, cfg, num_slots=2, max_len=16,
                         codec="none").codec is None


@pytest.mark.parametrize("spec,max_R", [
    ("c3sl:R=8,D=64", 2),
    ("c3sl:R=8,D=64|int8", 2),
    ("c3sl:R=8,D=64,backend=direct,unitary=true|topk:k=8|int8", 4),
    ("dense:R=8,D=64", 4),
    ("identity:D=64|noop", 1),
    ("adaptive:c3sl:R=16,D=64,min_R=2", 4),
    ("adaptive:c3sl:R=16,D=64,min_R=2,target_snr=-6.0|int8", 8),
])
def test_clamp_R_result_spec_reparses_through_build(spec, max_R):
    """clamp_R on ANY spec-built codec — bare, Chain, adaptive — must return
    a codec whose .spec() round-trips through build() to an equal spec (the
    rebuilt string was previously never re-parse-tested)."""
    clamped = codecs.clamp_R(build(spec), max_R)
    s = clamped.spec()
    rebuilt = build(s)
    assert rebuilt.spec() == s
    assert getattr(rebuilt, "R", 1) == getattr(clamped, "R", 1)
    # and a clamp that changes nothing keeps the original spec verbatim
    assert codecs.clamp_R(build(spec), 1024).spec() == build(spec).spec()


def test_engine_accepts_adaptive_spec_strings():
    from repro.configs.base import get_config, reduced
    from repro.models import lm as lm_lib
    from repro.serving.engine import BatchedEngine, Request
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=64,
                  d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=1,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)
    # num_slots=2 clamps the ladder through the adaptive wrapper
    eng = BatchedEngine(params, cfg, num_slots=2, max_len=16,
                        codec="adaptive:c3sl:R=4,min_R=2|int8")
    assert isinstance(eng.codec, codecs.AdaptiveC3SL)
    assert eng.codec.spec() == "adaptive:c3sl:R=2,D=64,min_R=2|int8"
    assert eng.codec.ladder == (2,)
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=2))
    done = eng.run(max_steps=32)
    assert len(done) == 1 and len(done[0].out) >= 1
    assert eng.stats["payload_wire_bytes"] > 0
