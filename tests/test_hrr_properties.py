"""Property-based tests (hypothesis) for the HRR codec invariants.

Invariants under test (paper Sec. 3, Eq. 4):
  * Encode is linear in Z (superposition principle).
  * Self-retrieval: unbinding a single bound feature recovers it with high SNR.
  * Cross-talk: retrieval error grows with R but stays bounded — relative
    error scales ~ sqrt(R / D) for unit-norm random keys.
  * Random keys are quasi-orthogonal in high dimension.
  * VJP symmetry: the adjoint of encode is decode with the same keys.
  * Retrieval SNR is non-increasing in R (in expectation) across backends,
    and unitary-key self-retrieval stays exact under superposition — the
    invariants that make SNR a valid Adaptive-R control signal
    (repro.codecs.adaptive).

Example budgets come from the settings profiles in conftest.py: small and
randomized under tier-1 (``dev``), large and derandomized in the dedicated
CI property job (``HYPOTHESIS_PROFILE=ci``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis package")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import hrr

pytestmark = pytest.mark.property

SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


@given(seed=SEEDS, r=st.sampled_from([1, 2, 4, 8]))
def test_encode_is_linear(seed, r):
    rng = jax.random.PRNGKey(seed)
    kz, kw, kk = jax.random.split(rng, 3)
    D = 256
    Z1 = jax.random.normal(kz, (2, r, D))
    Z2 = jax.random.normal(kw, (2, r, D))
    K = hrr.generate_keys(kk, r, D)
    a, b = 0.7, -1.3
    lhs = hrr.bind_superpose(a * Z1 + b * Z2, K)
    rhs = a * hrr.bind_superpose(Z1, K) + b * hrr.bind_superpose(Z2, K)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-4)


@given(seed=SEEDS)
def test_self_retrieval_single_binding(seed):
    """R=1 Gaussian keys: Zhat_f = |F(K)_f|^2 Z_f with |F(K)|^2 ~ Exp(1).

    The raw L2 noise is therefore ~1.0 relative and the cosine ~1/sqrt(2);
    the decoded feature still points at the signal (positive spectrum).
    """
    rng = jax.random.PRNGKey(seed)
    kz, kk = jax.random.split(rng)
    D = 2048
    Z = jax.random.normal(kz, (1, 1, D))
    K = hrr.generate_keys(kk, 1, D)
    Zhat = hrr.unbind(hrr.bind_superpose(Z, K), K)
    cos = float(jnp.vdot(Z, Zhat) / (jnp.linalg.norm(Z) * jnp.linalg.norm(Zhat)))
    assert 0.5 < cos <= 1.0  # theory: E ~ 1/sqrt(2) ~ 0.707
    rel = float(jnp.linalg.norm(Zhat - Z) / jnp.linalg.norm(Z))
    assert rel < 2.0  # self-noise ~ 1.0 relative


@given(seed=SEEDS)
def test_unitary_keys_exact_self_retrieval(seed):
    """Beyond-paper unitary keys: binding is an exact rotation at R=1."""
    rng = jax.random.PRNGKey(seed)
    kz, kk = jax.random.split(rng)
    D = 2048
    Z = jax.random.normal(kz, (1, 1, D))
    K = hrr.generate_keys(kk, 1, D, unitary=True)
    Zhat = hrr.unbind(hrr.bind_superpose(Z, K), K)
    np.testing.assert_allclose(np.asarray(Zhat), np.asarray(Z), rtol=1e-3, atol=1e-3)


@given(seed=SEEDS)
def test_crosstalk_matches_sqrtR_noise_model(seed):
    """Raw retrieval error ~ sqrt(R) for Gaussian keys (self 1 + cross R-1)."""
    rng = jax.random.PRNGKey(seed)
    D = 2048
    errs = {}
    for R in (2, 8):
        kz, kk = jax.random.split(jax.random.fold_in(rng, R))
        Z = jax.random.normal(kz, (1, R, D))
        K = hrr.generate_keys(kk, R, D)
        Zhat = hrr.unbind(hrr.bind_superpose(Z, K), K)
        errs[R] = float(jnp.linalg.norm(Zhat - Z) / jnp.linalg.norm(Z))
    assert errs[2] < errs[8]
    assert 0.6 * np.sqrt(2) < errs[2] < 1.6 * np.sqrt(2)
    assert 0.6 * np.sqrt(8) < errs[8] < 1.6 * np.sqrt(8)


@given(seed=SEEDS)
def test_unitary_keys_strictly_beat_gaussian_keys(seed):
    rng = jax.random.PRNGKey(seed)
    D = 2048
    R = 4
    kz, kk = jax.random.split(rng)
    Z = jax.random.normal(kz, (2, R, D))
    Kg = hrr.generate_keys(kk, R, D, unitary=False)
    Ku = hrr.generate_keys(kk, R, D, unitary=True)
    err = lambda K: float(jnp.linalg.norm(hrr.unbind(hrr.bind_superpose(Z, K), K) - Z)
                          / jnp.linalg.norm(Z))
    assert err(Ku) < err(Kg)


@given(seed=SEEDS)
def test_keys_quasi_orthogonal(seed):
    K = hrr.generate_keys(jax.random.PRNGKey(seed), 16, 4096)
    G = np.asarray(K @ K.T)
    off = G - np.eye(16)
    np.testing.assert_allclose(np.diag(G), 1.0, rtol=1e-5)
    assert np.abs(off).max() < 0.12  # |cos| ~ 1/sqrt(D) = 0.016, 6-sigma headroom


@given(seed=SEEDS, r=st.sampled_from([2, 4]))
def test_encode_adjoint_is_decode(seed, r):
    """<S', encode(Z)> == <decode(S'), Z> for all S', Z (linear adjoint pair)."""
    rng = jax.random.PRNGKey(seed)
    kz, ks, kk = jax.random.split(rng, 3)
    D = 512
    Z = jax.random.normal(kz, (3, r, D))
    Sp = jax.random.normal(ks, (3, D))
    K = hrr.generate_keys(kk, r, D)
    lhs = float(jnp.vdot(Sp, hrr.bind_superpose(Z, K)))
    rhs = float(jnp.vdot(hrr.unbind(Sp, K), Z))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3)


def test_relative_error_scales_like_sqrt_R_over_D():
    """Eq. 4 noise model: cross-talk power ~ (R-1)/D per dim -> rel err ~ sqrt(R/D)."""
    rng = jax.random.PRNGKey(0)
    D = 4096
    rels = []
    for R in (2, 4, 8, 16):
        kz, kk = jax.random.split(jax.random.fold_in(rng, R))
        Z = jax.random.normal(kz, (4, R, D))
        K = hrr.generate_keys(kk, R, D)
        Zhat = hrr.unbind(hrr.bind_superpose(Z, K), K)
        rel = float(jnp.linalg.norm(Zhat - Z) / jnp.linalg.norm(Z))
        rels.append(rel)
        pred = np.sqrt(R / D) * np.sqrt(D / 1.0) / np.sqrt(D)  # ~ sqrt(R/D) * sqrt(D)? keep loose
    # check rel err roughly doubles per 4x R (sqrt scaling), within 2x slack
    ratio = rels[2] / rels[0]
    assert 1.2 < ratio < 4.0, rels


# ---------------------------------------------------------------------------
# Adaptive-R control-signal invariants (repro.codecs.adaptive)
# ---------------------------------------------------------------------------

@given(seed=SEEDS, backend=st.sampled_from(["fft", "direct"]))
def test_retrieval_snr_non_increasing_in_R(seed, backend):
    """The controller's core assumption: more superposed features can only
    cost fidelity — retrieval SNR is non-increasing in R (in expectation;
    averaged over 4 groups x R features of seeded keys) for BOTH execution
    backends.  Theory (Eq. 4): noise power ~ self(1) + cross-talk(R-1), so
    each R doubling costs ~3 dB — far above the sampling jitter of the
    averaged estimate, hence the tight tolerance."""
    D = 256 if backend == "direct" else 1024   # direct materializes (D, D)
    rng = jax.random.PRNGKey(seed)
    snrs = []
    for R in (1, 2, 4, 8):
        kz, kk = jax.random.split(jax.random.fold_in(rng, R))
        Z = jax.random.normal(kz, (4, R, D))
        K = hrr.generate_keys(kk, R, D)
        Zhat = hrr.unbind(hrr.bind_superpose(Z, K, backend=backend), K,
                          backend=backend)
        snrs.append(float(hrr.retrieval_snr(Z, Zhat)))
    for lo, hi in zip(snrs[1:], snrs[:-1]):
        assert lo <= hi + 0.5, (backend, snrs)


@given(seed=SEEDS, r=st.sampled_from([2, 4, 8]))
def test_unitary_self_term_exact_under_superposition(seed, r):
    """Unitary keys: each feature's SELF term survives superposition exactly
    — decompose the retrieval by linearity into per-binding contributions
    U_j = unbind(bind(Z_j with K_j alone)), and (a) U_j's own row recovers
    Z_j to fp tolerance even though the codec serves it superposed with
    R-1 others, (b) the contributions sum back to the full retrieval.  So
    the retrieval error is PURE cross-talk: observed SNR moves only with R
    and feature statistics, never with a per-key self-noise floor — which
    is what makes it a meaningful rate-control signal."""
    D = 512
    rng = jax.random.PRNGKey(seed)
    kz, kk = jax.random.split(rng)
    Z = jax.random.normal(kz, (2, r, D))
    K = hrr.generate_keys(kk, r, D, unitary=True)
    Zhat = hrr.unbind(hrr.bind_superpose(Z, K), K)          # (2, r, D)
    contribs = []
    for j in range(r):
        S_j = hrr.bind_superpose(Z[:, j:j + 1], K[j:j + 1])  # only binding j
        U_j = hrr.unbind(S_j, K)                             # (2, r, D)
        contribs.append(np.asarray(U_j))
        # (a) the self term is exact: feature j comes back from its own
        # binding untouched (this is what breaks for Gaussian keys, whose
        # |F(K)|^2 spectral jitter adds ~1.0 relative self-noise)
        np.testing.assert_allclose(np.asarray(U_j[:, j]), np.asarray(Z[:, j]),
                                   rtol=1e-3, atol=1e-3)
    # (b) linearity: the per-binding contributions sum to the retrieval,
    # so error == sum of the j != i cross-talk terms and nothing else
    np.testing.assert_allclose(np.asarray(Zhat), sum(contribs),
                               rtol=1e-3, atol=1e-3)
