"""Wire-stage byte accounting vs the runtime representation, for payloads of
every rank — in particular the 3-D sequence-grouped layout
(C, B/R, D) that chunked prefill ships through ``sequence_group_encode``.

The audit these tests pin: a wire stage's "row" is everything but the
trailing axis (scales and top-k masks are per trailing-axis row at runtime),
so ``wire_bytes`` must count ``prod(shape[:-1])`` rows — for a prefill chunk
that is C * B/R scales/masks, not the decode step's B/R.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs
from repro.codecs import Int8STEQuant, NoOpWire, TopKSparsify, build


def test_int8_3d_accounting_matches_runtime():
    shape = (5, 4, 64)                        # (chunk, groups, D)
    stage = Int8STEQuant()
    # 1 byte/value + one f32 scale per TRAILING-AXIS ROW: 5*4 rows, not 4
    assert stage.wire_bytes(shape) == math.prod(shape) + 4 * (5 * 4)
    # rank-invariant: the 3-D layout is a reshape of the flat 2-D payload
    assert stage.wire_bytes(shape) == stage.wire_bytes((20, 64))
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    q3 = np.asarray(stage.apply(x))
    q2 = np.asarray(stage.apply(x.reshape(20, 64)))
    np.testing.assert_array_equal(q3.reshape(20, 64), q2)
    # runtime really quantizes per trailing-axis row: every row hits the
    # absmax grid point exactly (scale = absmax/127 -> |q| max == absmax)
    np.testing.assert_allclose(np.abs(q3).max(-1), np.abs(np.asarray(x)).max(-1),
                               rtol=1e-6)


def test_topk_3d_accounting_matches_runtime():
    shape = (3, 4, 64)
    stage = TopKSparsify(k=8)
    # per trailing-axis row: a D-bit mask + k f32 survivors, 3*4 rows
    assert stage.wire_bytes(shape) == (3 * 4) * (64 // 8 + 4 * 8)
    assert stage.wire_bytes(shape) == stage.wire_bytes((12, 64))
    x = jax.random.normal(jax.random.PRNGKey(1), shape)
    y = np.asarray(stage.apply(x))
    nz = (y != 0).sum(-1)
    assert nz.shape == (3, 4) and (nz == 8).all()   # exact-k per 3-D row
    np.testing.assert_array_equal(
        y.reshape(12, 64), np.asarray(stage.apply(x.reshape(12, 64))))


def test_noop_3d_accounting():
    assert NoOpWire().wire_bytes((5, 4, 64)) == 5 * 4 * 64 * 4


def test_sequence_grouped_chain_payload_and_accounting():
    """End to end: sequence_group_encode ships the 3-D layout through a
    Chain; bytes follow the true row count and the math is bit-identical
    to the flat path."""
    C, B, D, R = 6, 8, 32, 4
    codec = build(f"c3sl:R={R},D={D}|int8")
    p = codec.init(jax.random.PRNGKey(0))
    Z = jax.random.normal(jax.random.PRNGKey(1), (C, B, D))
    payload = codecs.sequence_group_encode(codec, p, Z)
    assert payload.shape == (C, B // R, D)
    flat = codec.encode(p, Z.reshape(C * B, D))
    np.testing.assert_array_equal(
        np.asarray(payload).reshape(C * B // R, D), np.asarray(flat))
    # per-chunk accounting: shape-based == per-position x decode-step bytes
    chunk_bytes = codecs.payload_wire_bytes(codec, payload.shape)
    assert chunk_bytes == C * codec.wire_bytes(B)
    assert chunk_bytes == codec.wire_bytes(C * B)
    # and decodes back to (C, B, D) identically to the flat round-trip
    Zhat = codecs.sequence_group_decode(codec, p, payload, C, B)
    np.testing.assert_array_equal(
        np.asarray(Zhat), np.asarray(codec.decode(p, flat)).reshape(C, B, D))


def test_payload_wire_bytes_bare_transform_is_f32():
    codec = build("c3sl:R=4,D=32")
    assert codecs.payload_wire_bytes(codec, (6, 2, 32)) == 6 * 2 * 32 * 4
    # with a trailing topk stage the LAST stage owns the wire
    chained = build("c3sl:R=4,D=32|topk:k=4")
    assert codecs.payload_wire_bytes(chained, (6, 2, 32)) \
        == (6 * 2) * (32 // 8 + 4 * 4)


def test_per_step_bytes_follow_R_schedule_batch_and_sequence_grouped():
    """Under an Adaptive-R schedule, per-step payload_wire_bytes must track
    the bucket serving each step EXACTLY — int8 scale bytes included — for
    both the decode path's batch-wise (B/R, D) payload and chunked
    prefill's sequence-grouped (C, B/R, D) layout."""
    B, C, D = 16, 5, 64
    codec = codecs.build("adaptive:c3sl:R=8,min_R=2|int8", D=D)
    p = codec.init(jax.random.PRNGKey(0))
    for R in (2, 4, 8, 4, 2):                 # a schedule that walks around
        codec.pin(R)
        # batch-wise decode step: shape == runtime payload, bytes == 1/value
        # + one f32 scale per row
        payload = codec.encode(p, jax.random.normal(jax.random.PRNGKey(R),
                                                    (B, D)))
        assert payload.shape == (B // R, D) == codec.payload_shape(B)
        step_bytes = codecs.payload_wire_bytes(codec, payload.shape)
        assert step_bytes == (B // R) * D + 4 * (B // R)
        assert step_bytes == codec.wire_bytes(B)
        # sequence-grouped prefill chunk: rows multiply by C
        shape3 = codecs.chunk_payload_shape(codec, B, C)
        assert shape3 == (C, B // R, D)
        chunk_bytes = codecs.payload_wire_bytes(codec, shape3)
        assert chunk_bytes == C * step_bytes
        # and the helper mirrors the runtime layout bit-for-bit
        Z3 = jax.random.normal(jax.random.PRNGKey(R + 100), (C, B, D))
        assert codecs.sequence_group_encode(codec.current, p[f"R{R}"],
                                            Z3).shape == shape3


def test_engine_wire_byte_stats_match_dispatch_counts():
    """The engine's stats["payload_wire_bytes"] is exactly
    decode_steps * step_bytes + prefill_chunks * chunk_bytes for a static
    codec, and follows the served R schedule under an adaptive one."""
    from repro.configs.base import get_config, reduced
    from repro.models import lm as lm_lib
    from repro.serving.engine import BatchedEngine, Request
    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=64,
                  d_ff=128, vocab_size=64, num_heads=2, num_kv_heads=1,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)

    def run(spec, pin=None):
        eng = BatchedEngine(params, cfg, num_slots=4, max_len=16, codec=spec,
                            chunk_size=4)
        if pin is not None:
            eng.codec.pin(pin)
        for u in range(4):
            eng.submit(Request(uid=u, prompt=[1 + u, 2, 3, 4, 5],
                               max_new_tokens=3))
        eng.run(max_steps=64)
        return eng

    eng = run("c3sl:R=4|int8")
    step_b = codecs.payload_wire_bytes(eng.codec,
                                       eng.codec.payload_shape(4))
    chunk_b = codecs.payload_wire_bytes(
        eng.codec, codecs.chunk_payload_shape(eng.codec, 4, eng.chunk_size))
    assert eng.stats["payload_wire_bytes"] == (
        eng.stats["decode_steps"] * step_b
        + eng.stats["prefill_chunks"] * chunk_b)

    eng = run("adaptive:c3sl:R=4,min_R=2|int8", pin=2)
    bucket = eng.codec.buckets[2]
    step_b = codecs.payload_wire_bytes(bucket, bucket.payload_shape(4))
    chunk_b = codecs.payload_wire_bytes(
        bucket, codecs.chunk_payload_shape(bucket, 4, eng.chunk_size))
    # r_served counts one entry per executed decode step + prefill chunk
    assert sum(eng.r_served.values()) == (eng.stats["decode_steps"]
                                          + eng.stats["prefill_chunks"])
    assert eng.stats["payload_wire_bytes"] == (
        eng.stats["decode_steps"] * step_b
        + eng.stats["prefill_chunks"] * chunk_b)
    assert set(eng.r_served) == {2}
