"""Wire-stage byte accounting vs the runtime representation, for payloads of
every rank — in particular the 3-D sequence-grouped layout
(C, B/R, D) that chunked prefill ships through ``sequence_group_encode``.

The audit these tests pin: a wire stage's "row" is everything but the
trailing axis (scales and top-k masks are per trailing-axis row at runtime),
so ``wire_bytes`` must count ``prod(shape[:-1])`` rows — for a prefill chunk
that is C * B/R scales/masks, not the decode step's B/R.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro import codecs
from repro.codecs import Int8STEQuant, NoOpWire, TopKSparsify, build


def test_int8_3d_accounting_matches_runtime():
    shape = (5, 4, 64)                        # (chunk, groups, D)
    stage = Int8STEQuant()
    # 1 byte/value + one f32 scale per TRAILING-AXIS ROW: 5*4 rows, not 4
    assert stage.wire_bytes(shape) == math.prod(shape) + 4 * (5 * 4)
    # rank-invariant: the 3-D layout is a reshape of the flat 2-D payload
    assert stage.wire_bytes(shape) == stage.wire_bytes((20, 64))
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    q3 = np.asarray(stage.apply(x))
    q2 = np.asarray(stage.apply(x.reshape(20, 64)))
    np.testing.assert_array_equal(q3.reshape(20, 64), q2)
    # runtime really quantizes per trailing-axis row: every row hits the
    # absmax grid point exactly (scale = absmax/127 -> |q| max == absmax)
    np.testing.assert_allclose(np.abs(q3).max(-1), np.abs(np.asarray(x)).max(-1),
                               rtol=1e-6)


def test_topk_3d_accounting_matches_runtime():
    shape = (3, 4, 64)
    stage = TopKSparsify(k=8)
    # per trailing-axis row: a D-bit mask + k f32 survivors, 3*4 rows
    assert stage.wire_bytes(shape) == (3 * 4) * (64 // 8 + 4 * 8)
    assert stage.wire_bytes(shape) == stage.wire_bytes((12, 64))
    x = jax.random.normal(jax.random.PRNGKey(1), shape)
    y = np.asarray(stage.apply(x))
    nz = (y != 0).sum(-1)
    assert nz.shape == (3, 4) and (nz == 8).all()   # exact-k per 3-D row
    np.testing.assert_array_equal(
        y.reshape(12, 64), np.asarray(stage.apply(x.reshape(12, 64))))


def test_noop_3d_accounting():
    assert NoOpWire().wire_bytes((5, 4, 64)) == 5 * 4 * 64 * 4


def test_sequence_grouped_chain_payload_and_accounting():
    """End to end: sequence_group_encode ships the 3-D layout through a
    Chain; bytes follow the true row count and the math is bit-identical
    to the flat path."""
    C, B, D, R = 6, 8, 32, 4
    codec = build(f"c3sl:R={R},D={D}|int8")
    p = codec.init(jax.random.PRNGKey(0))
    Z = jax.random.normal(jax.random.PRNGKey(1), (C, B, D))
    payload = codecs.sequence_group_encode(codec, p, Z)
    assert payload.shape == (C, B // R, D)
    flat = codec.encode(p, Z.reshape(C * B, D))
    np.testing.assert_array_equal(
        np.asarray(payload).reshape(C * B // R, D), np.asarray(flat))
    # per-chunk accounting: shape-based == per-position x decode-step bytes
    chunk_bytes = codecs.payload_wire_bytes(codec, payload.shape)
    assert chunk_bytes == C * codec.wire_bytes(B)
    assert chunk_bytes == codec.wire_bytes(C * B)
    # and decodes back to (C, B, D) identically to the flat round-trip
    Zhat = codecs.sequence_group_decode(codec, p, payload, C, B)
    np.testing.assert_array_equal(
        np.asarray(Zhat), np.asarray(codec.decode(p, flat)).reshape(C, B, D))


def test_payload_wire_bytes_bare_transform_is_f32():
    codec = build("c3sl:R=4,D=32")
    assert codecs.payload_wire_bytes(codec, (6, 2, 32)) == 6 * 2 * 32 * 4
    # with a trailing topk stage the LAST stage owns the wire
    chained = build("c3sl:R=4,D=32|topk:k=4")
    assert codecs.payload_wire_bytes(chained, (6, 2, 32)) \
        == (6 * 2) * (32 // 8 + 4 * 4)
