"""repro.analysis: lint rules R1-R5 (fixture positives + negatives),
suppression, baseline diffing, self-application, and — behind the
``sanitize`` marker — the runtime sanitizer tier (engine invariant
checks, checkify wiring, front-door tick-error surfacing).

The static half is pure-stdlib (ast) and fast; it runs under tier-1.
The sanitize-marked half compiles real engine programs and is excluded
from tier-1 timing (see conftest.py: set REPRO_SANITIZE=1 to run it, as
the CI analysis-gate job does).
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (BASELINE_NAME, diff_against_baseline,
                            lint_paths, lint_source, load_baseline,
                            write_baseline)

REPO_ROOT = Path(__file__).resolve().parent.parent


def findings(source: str, rule: str):
    report = lint_source(textwrap.dedent(source))
    assert not report.errors, report.errors
    return [f for f in report.findings if f.rule == rule]


# ---------------------------------------------------------------------------
# R1: recompile hazards
# ---------------------------------------------------------------------------

def test_r1_flags_jit_built_in_loop():
    hits = findings("""
        import jax
        def train(steps, f):
            for i in range(steps):
                step = jax.jit(f)
                step(i)
    """, "R1")
    assert len(hits) == 1 and "loop" in hits[0].message


def test_r1_flags_immediately_invoked_jit_lambda():
    hits = findings("""
        import jax
        def f(x):
            return jax.jit(lambda y: y * 2)(x)
    """, "R1")
    assert len(hits) == 1 and "lambda" in hits[0].message


def test_r1_clean_on_hoisted_jit_and_program_table():
    assert findings("""
        import jax
        from repro import codecs

        def make(codec, params):
            def body(x):
                return x
            return jax.jit(body)

        def build(codec, params):
            table = codecs.build_program_table(codec, params, make)
            step = jax.jit(lambda_free_fn)
            for key in (2, 4):
                table[key](key)          # dispatch in a loop is FINE
            return table, step
    """, "R1") == []


def test_r1_clean_jit_in_loop_outside_function_scope_boundary():
    # the loop is OUTSIDE the def: the wrapper is built once per loop
    # iteration of the OUTER scope, not once per call of the inner fn
    assert findings("""
        import jax
        def build(f):
            return jax.jit(f)
        for name in ("a", "b"):
            pass
    """, "R1") == []


# ---------------------------------------------------------------------------
# R2: use-after-donate
# ---------------------------------------------------------------------------

def test_r2_flags_read_after_donating_call():
    hits = findings("""
        import jax
        step = jax.jit(body, donate_argnums=(0,))
        def loop(cache, x):
            out = step(cache, x)
            return cache["k"]          # donated buffer read
    """, "R2")
    assert len(hits) == 1 and "donated" in hits[0].message


def test_r2_clean_when_rebound_from_result():
    assert findings("""
        import jax
        step = jax.jit(body, donate_argnums=(0,))
        def loop(cache, x):
            cache = step(cache, x)     # same-line rebind (engine idiom)
            return cache["k"]
    """, "R2") == []


def test_r2_clean_without_donation():
    assert findings("""
        import jax
        step = jax.jit(body)
        def loop(cache, x):
            out = step(cache, x)
            return cache["k"]
    """, "R2") == []


def test_r2_donate_argnames_variant():
    hits = findings("""
        import jax
        step = jax.jit(body, donate_argnames=("cache",))
        def loop(cache, x):
            out = step(x, cache=cache)
            return cache
    """, "R2")
    assert len(hits) == 1


# ---------------------------------------------------------------------------
# R3: hidden host syncs
# ---------------------------------------------------------------------------

def test_r3_flags_item_inside_jitted_function():
    hits = findings("""
        import jax
        @jax.jit
        def step(x):
            return x.item()
    """, "R3")
    assert len(hits) == 1 and ".item()" in hits[0].message


def test_r3_flags_float_in_program_dispatch_loop():
    hits = findings("""
        import jax
        from repro import transport
        step_fns = transport.build_link_program_table(c, p, make)
        def train(steps, params, batch):
            losses = []
            for step in range(steps):
                params, loss = step_fns[key](params, batch)
                losses.append(float(loss))
            return losses
    """, "R3")
    assert len(hits) == 1 and "serializes dispatch" in hits[0].message


def test_r3_flags_truthiness_on_traced_argument():
    hits = findings("""
        import jax
        @jax.jit
        def step(x):
            if x:
                return x
            return -x
    """, "R3")
    assert len(hits) == 1 and "truthiness" in hits[0].message


def test_r3_clean_float_outside_dispatch_loop():
    assert findings("""
        import jax
        step = jax.jit(body)
        def train(steps, params, batch):
            losses = []
            for i in range(steps):
                params, loss = step(params, batch)
                losses.append(loss)
            return [float(l) for l in losses]   # one deferred sync
    """, "R3") == []


def test_r3_clean_float_in_plain_loop():
    # no compiled program dispatched in the loop: host-side math is fine
    assert findings("""
        def accumulate(items):
            total = 0.0
            for x in items:
                total += float(x)
            return total
    """, "R3") == []


# ---------------------------------------------------------------------------
# R4: codec accounting completeness
# ---------------------------------------------------------------------------

def test_r4_flags_transform_missing_accounting():
    hits = findings("""
        from repro.codecs.base import register
        @register("broken")
        class Broken:
            def encode(self, params, x):
                return x
            def decode(self, params, y):
                return y
    """, "R4")
    assert len(hits) == 1
    for m in ("payload_shape", "wire_bytes", "flops"):
        assert m in hits[0].message


def test_r4_flags_wire_stage_missing_apply():
    hits = findings("""
        from repro.codecs.base import register
        @register("w", kind="wire")
        class W:
            def wire_bytes(self, shape):
                return 0
            def flops(self, shape):
                return 0
    """, "R4")
    assert len(hits) == 1 and "apply" in hits[0].message


def test_r4_clean_full_surface():
    assert findings("""
        from repro.codecs.base import register
        @register("ok")
        class Ok:
            def encode(self, params, x): return x
            def decode(self, params, y): return y
            def payload_shape(self, B): return (B,)
            def wire_bytes(self, B): return 4 * B
            def flops(self, B): return 0
    """, "R4") == []


def test_r4_ignores_unregistered_classes():
    assert findings("""
        class Helper:
            def encode(self, x): return x
    """, "R4") == []


# ---------------------------------------------------------------------------
# R5: asyncio race / hygiene
# ---------------------------------------------------------------------------

def test_r5a_flags_blocking_sleep_in_async_def():
    hits = findings("""
        import asyncio, time
        async def handler():
            time.sleep(1.0)
    """, "R5")
    assert len(hits) == 1 and "blocking" in hits[0].message


def test_r5a_clean_asyncio_sleep():
    assert findings("""
        import asyncio
        async def handler():
            await asyncio.sleep(1.0)
    """, "R5") == []


def test_r5b_flags_dropped_create_task():
    hits = findings("""
        import asyncio
        def spawn(coro):
            asyncio.create_task(coro)
    """, "R5")
    assert len(hits) == 1 and "weak ref" in hits[0].message


def test_r5b_clean_retained_task():
    assert findings("""
        import asyncio
        def spawn(self, coro):
            task = asyncio.create_task(coro)
            self._tasks.add(task)
            return task
    """, "R5") == []


def test_r5c_flags_swallowed_cancellation():
    hits = findings("""
        import asyncio
        async def worker(task):
            try:
                await task
            except asyncio.CancelledError:
                pass
    """, "R5")
    assert len(hits) == 1 and "cancellation" in hits[0].message


def test_r5c_clean_reraised_cancellation():
    assert findings("""
        import asyncio
        async def worker(task):
            try:
                await task
            except asyncio.CancelledError:
                cleanup()
                raise
    """, "R5") == []


def test_r5d_flags_mutation_while_iterating_across_await():
    hits = findings("""
        import asyncio
        async def sweep(self):
            for sid, sess in self.sessions.items():
                await sess.flush()
                self.sessions.pop(sid)
    """, "R5")
    assert len(hits) == 1 and "snapshot" in hits[0].message


def test_r5d_clean_snapshot_iteration():
    assert findings("""
        import asyncio
        async def sweep(self):
            for sid, sess in list(self.sessions.items()):
                await sess.flush()
                self.sessions.pop(sid)
    """, "R5") == []


# ---------------------------------------------------------------------------
# suppression + baseline
# ---------------------------------------------------------------------------

def test_inline_suppression_moves_finding_to_suppressed():
    src = textwrap.dedent("""
        import asyncio, time
        async def handler():
            time.sleep(1.0)  # lint-ok: R5 measured: sub-ms on this path
    """)
    report = lint_source(src)
    assert report.findings == []
    assert len(report.suppressed) == 1
    assert report.suppressed[0].reason == "measured: sub-ms on this path"


def test_suppression_is_rule_specific():
    src = textwrap.dedent("""
        import asyncio, time
        async def handler():
            time.sleep(1.0)  # lint-ok: R3 wrong rule id
    """)
    report = lint_source(src)
    assert len(report.findings) == 1     # R5 still fires


def test_suppression_multiple_rules_one_comment():
    src = textwrap.dedent("""
        import asyncio, time
        async def handler():
            time.sleep(1.0)  # lint-ok: R3, R5 both quiet
    """)
    assert lint_source(src).findings == []


def test_baseline_roundtrip_and_diffing(tmp_path):
    src = textwrap.dedent("""
        import asyncio
        def spawn(coro):
            asyncio.create_task(coro)
    """)
    report = lint_source(src, path="pkg/mod.py")
    assert len(report.findings) == 1
    bl = tmp_path / BASELINE_NAME
    write_baseline(report, bl)

    # identical findings: nothing new, nothing fixed
    new, fixed = diff_against_baseline(report, load_baseline(bl))
    assert new == [] and not fixed

    # the finding moved lines (edits above it): fingerprint still matches
    moved = lint_source("\n\n\n" + src, path="pkg/mod.py")
    new, fixed = diff_against_baseline(moved, load_baseline(bl))
    assert new == [] and not fixed

    # a NEW violation of the same rule elsewhere is new
    grown = lint_source(src + textwrap.dedent("""
        def spawn2(coro):
            asyncio.ensure_future(coro)
    """), path="pkg/mod.py")
    new, _ = diff_against_baseline(grown, load_baseline(bl))
    assert len(new) == 1 and "ensure_future" in new[0].code

    # the violation got fixed: the baseline reports it as stale
    clean = lint_source("x = 1\n", path="pkg/mod.py")
    new, fixed = diff_against_baseline(clean, load_baseline(bl))
    assert new == [] and sum(fixed.values()) == 1


def test_syntax_error_is_reported_not_crashed():
    report = lint_source("def broken(:\n")
    assert report.errors and report.findings == []


# ---------------------------------------------------------------------------
# self-application: the shipped tree is clean vs the committed baseline
# ---------------------------------------------------------------------------

def test_src_tree_is_clean_against_committed_baseline():
    report = lint_paths([REPO_ROOT / "src"], root=REPO_ROOT)
    assert not report.errors, report.errors
    baseline = load_baseline(REPO_ROOT / BASELINE_NAME)
    new, _ = diff_against_baseline(report, baseline)
    assert new == [], "new lint findings vs baseline:\n" + "\n".join(
        str(f) for f in new)


def test_committed_baseline_has_no_grandfathered_findings():
    # the shipped baseline is EMPTY by policy: fix or suppress, never
    # grandfather (suppressions are recorded separately, with rationale)
    assert sum(load_baseline(REPO_ROOT / BASELINE_NAME).values()) == 0


def test_cli_check_gate_passes_on_src():
    from repro.analysis.__main__ import main
    assert main(["--check", str(REPO_ROOT / "src")]) == 0


# ---------------------------------------------------------------------------
# runtime sanitizers (sanitize-marked: excluded from tier-1 timing)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_engine_factory():
    import jax
    from repro.configs.base import get_config, reduced
    from repro.models import lm as lm_lib
    from repro.serving.engine import BatchedEngine

    cfg = reduced(get_config("deepseek-7b"), num_layers=2, d_model=128,
                  d_ff=256, vocab_size=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    params = lm_lib.init_lm_params(jax.random.PRNGKey(0), cfg)

    def build(**kw):
        kw.setdefault("num_slots", 4)
        kw.setdefault("max_len", 64)
        kw.setdefault("codec", "c3sl:R=2|int8")
        kw.setdefault("kv_layout", "paged")
        kw.setdefault("page_size", 8)
        kw.setdefault("num_pages", 32)
        kw.setdefault("sync_every", 2)
        kw.setdefault("preemption", True)
        return BatchedEngine(params, cfg, greedy=True, seed=0, **kw)

    return build


@pytest.mark.sanitize
def test_engine_sanitizer_clean_run_exercises_all_checks(
        tiny_engine_factory):
    from repro.analysis.sanitize import EngineSanitizer
    from repro.serving.engine import Request
    eng = tiny_engine_factory()
    san = EngineSanitizer(eng)
    eng.attach_sanitizer(san)
    # staggered lengths on 3 of 4 slots: ticks see a dead/live mix, so
    # the cut probe actually runs (not just the cheap host checks)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=[1 + i, 2, 3, 4],
                           max_new_tokens=4 + 4 * i))
    done = eng.run()
    assert len(done) == 3
    assert san.counts["pool"] > 0
    assert san.counts["slot_state"] > 0
    assert san.counts["cut_zeroing"] > 0, (
        "live-slot-zeroing invariant never exercised", san.counts)


@pytest.mark.sanitize
def test_engine_sanitizer_trips_on_dirty_empty_slot(tiny_engine_factory):
    from repro.analysis.sanitize import EngineSanitizer, SanitizerError
    from repro.serving.engine import Request
    eng = tiny_engine_factory()
    san = EngineSanitizer(eng)
    eng.submit(Request(uid=0, prompt=[1, 2, 3], max_new_tokens=2))
    eng.run()
    # emulate a broken retire: device state says an empty slot is active
    eng.state["active"] = eng.state["active"].at[1].set(True)
    with pytest.raises(SanitizerError, match="not inert"):
        san.check_slot_state(eng)


@pytest.mark.sanitize
def test_engine_sanitizer_trips_on_pool_leak(tiny_engine_factory):
    from repro.analysis.sanitize import EngineSanitizer, SanitizerError
    eng = tiny_engine_factory()
    san = EngineSanitizer(eng)

    class LeakyAllocator:
        free_pages = 1               # pages vanished: free+in_use < total

    eng.allocator = LeakyAllocator()
    with pytest.raises(SanitizerError, match="accounting"):
        san.check_pool(eng)


@pytest.mark.sanitize
def test_cut_zeroing_check_detects_unmasked_encode(tiny_engine_factory):
    """The negative control for the PR 7 invariant: a probe built WITHOUT
    the live mask (the pre-fix code path) must report nonzero dead-row
    contribution on a half-occupied batch — proving the check would have
    caught the original bug, and still guards the fixed path."""
    import jax
    import jax.numpy as jnp
    from repro.analysis.sanitize import EngineSanitizer, SanitizerError
    from repro.models import lm as lm_lib
    from repro.serving.engine import Request

    eng = tiny_engine_factory()
    san = EngineSanitizer(eng)
    eng.attach_sanitizer(san)
    eng.submit(Request(uid=0, prompt=[1, 2, 3, 4], max_new_tokens=8))
    eng.submit(Request(uid=1, prompt=[5, 6, 7, 8], max_new_tokens=8))
    eng.tick(); eng.tick()           # rows mid-decode, 2 of 4 slots live
    live = eng.state["active"] & ~eng.state["done"]
    assert 0 < int(jnp.sum(live)) < eng.num_slots

    cfg, paged = eng.cfg, eng.paged

    def unmasked_probe(params, cache, state):
        liv = state["active"] & ~state["done"]
        # live=None reproduces the pre-PR7 encode: no zeroing of dead rows
        _, _, cut = lm_lib.decode_step(
            params, cache, state["last_tok"][:, None], state["pos"], cfg,
            codec=eng.codec, codec_params=eng.codec_params, paged=paged,
            live=None, return_cut=True)
        dead = (~liv).astype(cut.dtype)[:, None]
        return jnp.sum(jnp.abs(cut) * dead), liv.sum()

    san._probes = {None: jax.jit(unmasked_probe)}
    with pytest.raises(SanitizerError, match="live-slot zeroing"):
        san.check_cut_zeroing(eng)
    # and the REAL path passes the same check on the same state
    fixed = EngineSanitizer(eng)
    fixed.check_cut_zeroing(eng)
    assert fixed.counts["cut_zeroing"] == 1


@pytest.mark.sanitize
def test_checkify_jit_catches_nonfinite():
    import jax.numpy as jnp
    from jax.experimental import checkify
    from repro.analysis.sanitize import checkify_jit

    def bad(x):
        return jnp.log(x)            # log(-1) -> nan under float_checks

    fn = checkify_jit(bad)
    assert float(fn(jnp.float32(1.0))) == 0.0
    with pytest.raises(checkify.JaxRuntimeError):
        fn(jnp.float32(-1.0))


@pytest.mark.sanitize
def test_train_sanitizer_trips_on_nan():
    from repro.analysis.sanitize import SanitizerError, TrainSanitizer
    ts = TrainSanitizer()
    ts.check_step(0, loss=1.25, gnorm=0.5)
    assert ts.steps_checked == 1
    with pytest.raises(SanitizerError, match="loss"):
        ts.check_step(1, loss=float("nan"), gnorm=0.5)


@pytest.mark.sanitize
def test_frontdoor_surfaces_tick_loop_crash(tiny_engine_factory):
    """PR 7-class latent bug, now fixed: an engine exception inside the
    auto-tick loop used to kill the task silently and hang every tenant.
    It must now cancel the connections (clients fail fast) and surface
    the original exception through server.stop()."""
    import asyncio
    from repro.analysis.sanitize import SanitizerError
    from repro.frontdoor import (AdmissionController, FrontDoorClient,
                                 FrontDoorServer, TenantPolicy)

    eng = tiny_engine_factory()

    class TrippingSanitizer:
        def on_tick(self, engine):
            raise SanitizerError("injected invariant trip")

    eng.attach_sanitizer(TrippingSanitizer())
    server = FrontDoorServer(
        eng, admission=AdmissionController(
            max_queue_depth=8, default_policy=TenantPolicy(max_inflight=2)))

    async def go():
        host, port = await server.start()
        client = await FrontDoorClient.open(host, port, tenant="t",
                                            codec="c3sl:R=2|int8")
        try:
            with pytest.raises(Exception):
                # the submit admits work -> the next tick trips -> the
                # conn task is cancelled -> the pending call fails fast
                # instead of hanging forever
                await asyncio.wait_for(
                    client.generate([1, 2, 3], max_new=4), timeout=30)
        finally:
            try:
                await client.close()
            except Exception:
                pass
        assert isinstance(server.tick_error, SanitizerError)
        with pytest.raises(SanitizerError, match="injected"):
            await server.stop()

    asyncio.run(go())
