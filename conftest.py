"""Test-session config: hypothesis settings profiles + sanitize gating.

The property suite (tests/test_hrr_properties.py, marked ``property``)
reads its example budget from a profile instead of per-test ``@settings``,
so the same tests run two ways:

* ``dev`` (default) — small budget, randomized: keeps tier-1
  (``pytest -x -q``) fast on laptops and in the main CI job.
* ``ci`` — derandomized with a much higher example budget: the dedicated
  property-test CI job runs ``HYPOTHESIS_PROFILE=ci`` and uploads junit
  XML (see .github/workflows/ci.yml).

hypothesis is an optional dependency everywhere (the property modules
importorskip it), so this registration must be too.

Tests marked ``sanitize`` (runtime sanitizer coverage: per-tick engine
invariant probes compile EXTRA jit programs per R bucket) are excluded
from tier-1 timing by default and run in the CI ``analysis-gate`` job
with ``REPRO_SANITIZE=1``.
"""
import os

import pytest


def pytest_collection_modifyitems(config, items):
    if os.environ.get("REPRO_SANITIZE") == "1":
        return
    skip = pytest.mark.skip(
        reason="sanitizer-heavy test: set REPRO_SANITIZE=1 to run "
               "(the CI analysis-gate job does)")
    for item in items:
        if "sanitize" in item.keywords:
            item.add_marker(skip)


try:
    from hypothesis import settings
except ImportError:          # property tests importorskip; nothing to set up
    pass
else:
    settings.register_profile("dev", max_examples=15, deadline=None)
    settings.register_profile("ci", max_examples=150, deadline=None,
                              derandomize=True, print_blob=True)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
