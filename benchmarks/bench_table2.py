"""Paper Table 2 reproduction: codec parameter/FLOP formulas, exact.

C3-SL:        params = R*D              flops = 2*B*D^2
BottleNet++:  params = (Ck^2+1)(4C/R) + ((4C/R)k^2+1)C
              flops  = B(2Ck^2+1)(4C/R)H'W' + B((8C/R)k^2+1)CHW
"""
from __future__ import annotations

from repro.configs.paper import PAPER_RS, RESNET50_CIFAR100, VGG16_CIFAR10
from repro.codecs import BottleNetPPCodec, C3SLCodec


def rows():
    out = []
    for cfg in (VGG16_CIFAR10, RESNET50_CIFAR100):
        C, H, W = cfg.cut_shape
        B = cfg.batch_size
        for R in PAPER_RS:
            c3 = C3SLCodec(R=R, D=cfg.D)
            bn = BottleNetPPCodec(R=R, C=C, H=H, W=W)
            out.append({
                "config": cfg.name, "R": R,
                "c3sl_params": c3.param_count(),
                "c3sl_flops": c3.flops(B),
                "bnpp_params": bn.param_count(),
                "bnpp_flops": bn.flops(B),
                "mem_ratio": bn.param_count() / c3.param_count(),
                "flop_ratio": bn.flops(B) / c3.flops(B),
            })
    return out


def main():
    print("# Table 2: codec params/FLOPs (exact formulas)")
    print("config,R,c3sl_params,c3sl_flops,bnpp_params,bnpp_flops,"
          "mem_ratio,flop_ratio")
    for r in rows():
        print(f"{r['config']},{r['R']},{r['c3sl_params']},{r['c3sl_flops']},"
              f"{r['bnpp_params']},{r['bnpp_flops']},{r['mem_ratio']:.0f},"
              f"{r['flop_ratio']:.2f}")


if __name__ == "__main__":
    main()
